//! HTTP serving load harness: drives the `server` front end over
//! loopback and reports latency percentiles, throughput, and the
//! backpressure refusal rate.
//!
//!     cargo bench --bench loadgen
//!
//! Four generator modes, run back to back:
//!
//! - **closed loop** (one-shot): C client threads, each issuing requests
//!   strictly back-to-back (a new request only after the previous
//!   response), one TCP connection per request. Offered load adapts to
//!   service rate, so this measures the server's sustainable latency
//!   distribution (`server_p50_latency_ms`, `server_p99_latency_ms`)
//!   and token throughput (`server_tokens_per_s`) without queue blowup.
//! - **closed loop** (keep-alive): the same workload down one reused
//!   connection per client thread. The requests/s ratio against the
//!   one-shot loop is `server_keepalive_speedup` — what connection
//!   reuse is actually worth on this stack (connect + teardown per
//!   request vs. amortized).
//! - **open loop**: requests arrive on a fixed schedule regardless of
//!   completions (the arrival process does not slow down when the
//!   server does — how real traffic behaves). The rate is set to 2x the
//!   just-measured closed-loop capacity, so the bounded pending queue
//!   must refuse work; `server_429_rate` is the measured refusal
//!   fraction. A closed-loop generator structurally cannot measure
//!   this, which is why both modes exist.
//! - **misbehaving clients**: a pack of slow-loris connections (full
//!   headers, then a body that never finishes) against a short-timeout
//!   server while honest keep-alive clients run alongside. Every
//!   misbehaving connection must be put down with a typed `408`/`503`
//!   (`server_shed_rate_misbehaving`, ideally 1.0) and every honest
//!   request must still complete.
//!
//! Coordinated omission: closed-loop latency percentiles are honest
//! only below saturation — a closed generator slows down with the
//! server, silently omitting the arrivals that would have queued. The
//! open-loop phase exists precisely because its arrival schedule never
//! coordinates with server state; refusal rate under overload comes
//! from there, never from the closed loop.
//!
//! Results merge into `BENCH_perf.json` under `derived`, preserving
//! everything the perf bench wrote.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use apt::json::{self, Json};
use apt::model::{Transformer, TransformerConfig};
use apt::serve::EngineConfig;
use apt::server::{client, Server, ServerConfig, ServerHandle};
use apt::util::Rng;

const OUT_PATH: &str = "BENCH_perf.json";
const MAX_NEW_TOKENS: usize = 16;
const CLOSED_CLIENTS: usize = 8;
const CLOSED_PER_CLIENT: usize = 25;
const OPEN_SECONDS: f64 = 2.0;
const OPEN_MAX_ARRIVALS: usize = 400;
const LORIS_CLIENTS: usize = 16;

fn start_server() -> ServerHandle {
    let model = Transformer::init(
        TransformerConfig {
            vocab: 61,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 48,
            max_seq: 128,
        },
        &mut Rng::new(13),
    );
    let cfg = ServerConfig {
        engine: EngineConfig::default(),
        // small enough that honest overload actually trips 429s in the
        // open-loop phase; the closed loop (<= CLOSED_CLIENTS pending)
        // never touches it
        max_pending: 16,
        ..Default::default()
    };
    Server::start(model, "127.0.0.1:0", cfg).expect("bind loopback")
}

fn gen_body(salt: usize) -> String {
    let toks: Vec<String> = (0..12).map(|i| ((i * 7 + salt * 13 + 1) % 61).to_string()).collect();
    format!(
        r#"{{"prompt": [{}], "max_new_tokens": {MAX_NEW_TOKENS}}}"#,
        toks.join(",")
    )
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    assert!(!sorted_ms.is_empty());
    let idx = (p * (sorted_ms.len() - 1) as f64).round() as usize;
    sorted_ms[idx]
}

/// Closed loop: returns (sorted latencies in ms, tokens/s, requests/s).
fn closed_loop(addr: std::net::SocketAddr) -> (Vec<f64>, f64, f64) {
    let wall = Instant::now();
    let workers: Vec<_> = (0..CLOSED_CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut lat = Vec::with_capacity(CLOSED_PER_CLIENT);
                let mut toks = 0usize;
                for i in 0..CLOSED_PER_CLIENT {
                    let body = gen_body(c * CLOSED_PER_CLIENT + i);
                    let t0 = Instant::now();
                    let r = client::request(addr, "POST", "/v1/generate", Some(&body))
                        .expect("loopback request");
                    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
                    lat.push(t0.elapsed().as_secs_f64() * 1e3);
                    toks += r
                        .json()
                        .ok()
                        .and_then(|v| v.get("tokens").and_then(Json::as_arr).map(<[Json]>::len))
                        .unwrap_or(0);
                }
                (lat, toks)
            })
        })
        .collect();
    let mut lat = Vec::new();
    let mut toks = 0usize;
    for w in workers {
        let (l, t) = w.join().expect("closed-loop client");
        lat.extend(l);
        toks += t;
    }
    let secs = wall.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    (lat, toks as f64 / secs, (CLOSED_CLIENTS * CLOSED_PER_CLIENT) as f64 / secs)
}

/// Closed loop again, but each client thread holds ONE keep-alive
/// connection for all its requests. Returns requests/s; the ratio
/// against the one-shot loop is the measured value of reuse.
fn closed_loop_keepalive(addr: std::net::SocketAddr) -> f64 {
    let wall = Instant::now();
    let workers: Vec<_> = (0..CLOSED_CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut kc = client::Client::new(addr);
                for i in 0..CLOSED_PER_CLIENT {
                    let body = gen_body(c * CLOSED_PER_CLIENT + i);
                    let r = kc
                        .request("POST", "/v1/generate", Some(&body))
                        .expect("keep-alive request");
                    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
                }
                kc.connects_made()
            })
        })
        .collect();
    let connects: usize = workers.into_iter().map(|w| w.join().expect("keep-alive client")).sum();
    let secs = wall.elapsed().as_secs_f64();
    println!(
        "  {} requests over {connects} TCP connection(s)",
        CLOSED_CLIENTS * CLOSED_PER_CLIENT
    );
    (CLOSED_CLIENTS * CLOSED_PER_CLIENT) as f64 / secs
}

/// Open loop at `rate_hz`: returns (arrivals, 429 count, 503 count,
/// other-failure count). Each arrival is its own thread so a slow
/// response never delays the next arrival — that independence is the
/// point. Both refusal shapes are expected under overload: 429 from the
/// bounded pending queue, 503 from accept-time connection shedding once
/// arrivals outrun the bounded worker pool's backlog.
fn open_loop(addr: std::net::SocketAddr, rate_hz: f64) -> (usize, usize, usize, usize) {
    let total = ((rate_hz * OPEN_SECONDS) as usize).clamp(50, OPEN_MAX_ARRIVALS);
    let refused = Arc::new(AtomicUsize::new(0));
    let shed = Arc::new(AtomicUsize::new(0));
    let failed = Arc::new(AtomicUsize::new(0));
    let start = Instant::now();
    let mut workers = Vec::with_capacity(total);
    for i in 0..total {
        let target = Duration::from_secs_f64(i as f64 / rate_hz);
        let elapsed = start.elapsed();
        if target > elapsed {
            std::thread::sleep(target - elapsed);
        }
        let refused = refused.clone();
        let shed = shed.clone();
        let failed = failed.clone();
        workers.push(std::thread::spawn(move || {
            let body = gen_body(i);
            match client::request(addr, "POST", "/v1/generate", Some(&body)) {
                Ok(r) if r.status == 200 => {}
                Ok(r) if r.status == 429 => {
                    refused.fetch_add(1, Ordering::Relaxed);
                }
                Ok(r) if r.status == 503 => {
                    shed.fetch_add(1, Ordering::Relaxed);
                }
                _ => {
                    failed.fetch_add(1, Ordering::Relaxed);
                }
            }
        }));
    }
    for w in workers {
        let _ = w.join();
    }
    (
        total,
        refused.load(Ordering::Relaxed),
        shed.load(Ordering::Relaxed),
        failed.load(Ordering::Relaxed),
    )
}

/// Misbehaving-client mode: `LORIS_CLIENTS` slow-loris connections
/// (complete headers, a body that never arrives) against a server with
/// short timeouts and a small pool, while honest keep-alive clients run
/// alongside. Returns the fraction of misbehaving connections the
/// server put down with a typed `408` or `503` — anything else (a hang,
/// an untyped close) drags the rate below 1.0, which is the regression
/// this mode exists to catch.
fn misbehaving_clients() -> f64 {
    let model = Transformer::init(
        TransformerConfig {
            vocab: 61,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            d_ff: 48,
            max_seq: 128,
        },
        &mut Rng::new(17),
    );
    let cfg = ServerConfig {
        read_timeout_ms: 150,
        header_deadline_ms: 400,
        idle_timeout_ms: 500,
        pool_workers: 4,
        conn_backlog: 4,
        ..Default::default()
    };
    let h = Server::start(model, "127.0.0.1:0", cfg).expect("bind loopback");
    let addr = h.addr();

    let shed = Arc::new(AtomicUsize::new(0));
    let loris: Vec<_> = (0..LORIS_CLIENTS)
        .map(|_| {
            let shed = shed.clone();
            std::thread::spawn(move || {
                let raw = "POST /v1/generate HTTP/1.1\r\nContent-Length: 64\r\n\r\n{\"prompt\"";
                if matches!(client::raw_roundtrip_status(addr, raw), Ok(408 | 503)) {
                    shed.fetch_add(1, Ordering::Relaxed);
                }
            })
        })
        .collect();

    // honest traffic alongside the abuse: every request must complete,
    // retrying politely when shed at accept time (503) or refused (429)
    let honest: Vec<_> = (0..2)
        .map(|c| {
            std::thread::spawn(move || {
                let mut kc = client::Client::new(addr);
                for i in 0..8 {
                    let body = gen_body(1000 + c * 8 + i);
                    let mut attempts = 0;
                    loop {
                        match kc.request("POST", "/v1/generate", Some(&body)) {
                            Ok(r) if r.status == 200 => break,
                            Ok(r) if r.status == 503 || r.status == 429 => {
                                attempts += 1;
                                assert!(attempts < 50, "honest request starved out");
                                std::thread::sleep(Duration::from_millis(50));
                            }
                            Ok(r) => panic!("honest request got {}", r.status),
                            Err(e) => panic!("honest request failed: {e}"),
                        }
                    }
                }
            })
        })
        .collect();

    for w in loris {
        let _ = w.join();
    }
    for w in honest {
        w.join().expect("honest client");
    }
    let m = client::request(addr, "GET", "/metrics", None).expect("metrics");
    let text = String::from_utf8_lossy(&m.body).into_owned();
    for k in [
        "apt_http_responses_408_total",
        "apt_http_responses_503_shed_total",
        "apt_engine_kv_pages_live",
    ] {
        println!("  {k} {}", client::metric(&text, k).unwrap_or(0));
    }
    h.shutdown();
    shed.load(Ordering::Relaxed) as f64 / LORIS_CLIENTS as f64
}

/// Merge the six server keys into BENCH_perf.json's `derived` object,
/// preserving whatever the perf bench wrote there.
fn merge_results(p50: f64, p99: f64, tok_s: f64, rate_429: f64, ka_speedup: f64, shed_rate: f64) {
    let mut root = std::fs::read_to_string(OUT_PATH)
        .ok()
        .and_then(|t| json::parse(&t).ok())
        .unwrap_or_else(Json::obj);
    if !matches!(root, Json::Obj(_)) {
        root = Json::obj();
    }
    let mut derived = match root.get("derived") {
        Some(d @ Json::Obj(_)) => d.clone(),
        _ => Json::obj(),
    };
    derived
        .set("server_p50_latency_ms", Json::Num(p50))
        .set("server_p99_latency_ms", Json::Num(p99))
        .set("server_tokens_per_s", Json::Num(tok_s))
        .set("server_429_rate", Json::Num(rate_429))
        .set("server_keepalive_speedup", Json::Num(ka_speedup))
        .set("server_shed_rate_misbehaving", Json::Num(shed_rate));
    root.set("derived", derived);
    std::fs::write(OUT_PATH, format!("{}\n", root.to_string_pretty())).expect("write BENCH_perf");
}

fn main() {
    // `cargo bench` passes --bench; any other arg is a no-op filter for
    // interface parity with the perf bench
    let h = start_server();
    let addr = h.addr();

    println!(
        "== closed loop: {CLOSED_CLIENTS} clients x {CLOSED_PER_CLIENT} requests, {MAX_NEW_TOKENS} tokens each =="
    );
    let (lat, tok_s, req_s) = closed_loop(addr);
    let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
    println!("  p50 {p50:8.3} ms   p99 {p99:8.3} ms");
    println!("  {tok_s:8.0} tokens/s   {req_s:8.1} requests/s");

    println!(
        "== closed loop, keep-alive: {CLOSED_CLIENTS} clients x {CLOSED_PER_CLIENT} requests, one connection each =="
    );
    let ka_req_s = closed_loop_keepalive(addr);
    let ka_speedup = ka_req_s / req_s;
    println!("  {ka_req_s:8.1} requests/s ({ka_speedup:.2}x one-shot)");

    // overload: offer 2x the measured sustainable rate so refusals are a
    // property of the bounded queue, not of an arbitrary magic number
    let rate = (req_s * 2.0).max(25.0);
    println!("== open loop: {rate:.0} arrivals/s for {OPEN_SECONDS}s (2x closed-loop capacity) ==");
    let (total, refused, shed, failed) = open_loop(addr, rate);
    assert_eq!(failed, 0, "only 200/429/503 are acceptable under overload");
    let rate_429 = refused as f64 / total as f64;
    println!("  {total} arrivals, {refused} refused 429, {shed} shed 503 (429 rate {rate_429:.3})");

    let m = client::request(addr, "GET", "/metrics", None).expect("metrics");
    let text = String::from_utf8_lossy(&m.body).into_owned();
    for k in
        ["apt_engine_completions_total", "apt_http_responses_429_total", "apt_engine_kv_pages_live"]
    {
        println!("  {k} {}", client::metric(&text, k).unwrap_or(0));
    }
    h.shutdown();

    println!("== misbehaving clients: {LORIS_CLIENTS} slow-loris conns vs a short-timeout server ==");
    let shed_rate = misbehaving_clients();
    println!("  shed rate {shed_rate:.3} (typed 408/503 per misbehaving connection)");

    merge_results(p50, p99, tok_s, rate_429, ka_speedup, shed_rate);
    println!(
        "\nwrote server_{{p50,p99}}_latency_ms / server_tokens_per_s / server_429_rate / \
         server_keepalive_speedup / server_shed_rate_misbehaving to {OUT_PATH}"
    );
}
