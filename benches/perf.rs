//! Perf microbenchmarks for the hot paths (criterion is unavailable
//! offline; this is a hand-rolled warmup+repeat harness with median/p90).
//! Used by the EXPERIMENTS.md §Perf iteration log.
//!
//!     cargo bench --bench perf [filter]

use apt::linalg::inv_spd;
use apt::prune::{
    compensate_m, compensate_sequential, select_24_m, select_unstructured_s, sparsegpt_prune,
    HessianAccumulator, Mask, Sparsity,
};
use apt::linalg::cholesky_upper;
use apt::tensor::{Mat, MatF64};
use apt::util::{Quantiles, Rng, Timer};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    for _ in 0..2 {
        f();
    }
    let mut q = Quantiles::new();
    for _ in 0..iters {
        let t = Timer::start();
        f();
        q.push(t.elapsed_ms());
    }
    println!(
        "{name:<44} median {:>9.3} ms   p90 {:>9.3} ms   n={}",
        q.median(),
        q.quantile(0.9),
        q.len()
    );
}

fn setup(n: usize, m: usize, seed: u64) -> (Mat, MatF64, MatF64) {
    let mut rng = Rng::new(seed);
    let w = Mat::randn(n, m, 1.0, &mut rng);
    let x = Mat::randn(2 * m, m, 1.0, &mut rng);
    let mut acc = HessianAccumulator::new(m);
    acc.add_chunk(&x);
    let hd = acc.damped(0.01);
    let hinv = inv_spd(&hd).unwrap();
    (w, hd, hinv)
}

fn main() {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_default();
    let run = |name: &str| filter.is_empty() || name.contains(&filter);

    println!("== L3 hot paths (native) ==");

    if run("gemm") {
        let mut rng = Rng::new(1);
        let a = Mat::randn(512, 512, 1.0, &mut rng);
        let b = Mat::randn(512, 512, 1.0, &mut rng);
        bench("gemm 512x512x512", 10, || {
            std::hint::black_box(a.matmul(&b));
        });
        bench("gemm_tb 512x512x512", 10, || {
            std::hint::black_box(a.matmul_tb(&b));
        });
    }

    if run("hessian") {
        let mut rng = Rng::new(2);
        let x = Mat::randn(512, 256, 1.0, &mut rng);
        bench("hessian accumulate 2XtX (512x256)", 10, || {
            let mut acc = HessianAccumulator::new(256);
            acc.add_chunk(&x);
            std::hint::black_box(acc);
        });
        bench("hessian accumulate (convert-in-loop)", 10, || {
            let mut acc = HessianAccumulator::new(256);
            acc.add_chunk_convert_in_loop(&x);
            std::hint::black_box(acc);
        });
    }

    if run("finalize") {
        let (_w, _hd, _hinv) = setup(8, 256, 3);
        let mut rng = Rng::new(3);
        let x = Mat::randn(512, 256, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(256);
        acc.add_chunk(&x);
        bench("hessian finalize (chol+inv, m=256)", 8, || {
            std::hint::black_box(acc.finalize(0.01));
        });
    }

    if run("compensate") {
        let (w0, _hd, hinv) = setup(256, 256, 4);
        let mask = select_unstructured_s(&w0, &hinv.diag(), 0, 256, 0.5);
        bench("compensate_m n=256 m=256 k=128", 6, || {
            let mut w = w0.clone();
            std::hint::black_box(compensate_m(&mut w, &mask, &hinv));
        });
        let (w0l, _hd, hinvl) = setup(256, 512, 5);
        let maskl = select_unstructured_s(&w0l, &hinvl.diag(), 0, 512, 0.5);
        bench("compensate_m n=256 m=512 k=256", 4, || {
            let mut w = w0l.clone();
            std::hint::black_box(compensate_m(&mut w, &maskl, &hinvl));
        });
    }

    if run("sequential") {
        let (w0, _hd, hinv) = setup(256, 256, 6);
        let u = cholesky_upper(&hinv).unwrap();
        let mask = select_unstructured_s(&w0, &hinv.diag(), 0, 256, 0.5);
        bench("sparsegpt sweep n=256 m=256", 6, || {
            let mut w = w0.clone();
            compensate_sequential(&mut w, &mask, &u);
            std::hint::black_box(w);
        });
        let (w0b, _hd, hinvb) = setup(256, 256, 7);
        bench("sparsegpt full (mask+sweep) S=64", 6, || {
            let mut w = w0b.clone();
            std::hint::black_box(sparsegpt_prune(
                &mut w,
                &hinvb,
                Sparsity::Unstructured { rate: 0.5 },
                Some(64),
                false,
            ));
        });
    }

    if run("mask24") {
        let (w, _hd, hinv) = setup(512, 512, 8);
        bench("select_24_m (Eq12 6-combo) 512x512", 10, || {
            std::hint::black_box(select_24_m(&w, &hinv, 0, 512));
        });
    }

    if run("sparse") {
        let mut rng = Rng::new(9);
        let mut w = Mat::randn(256, 512, 1.0, &mut rng);
        apt::prune::magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.8 });
        let csr = apt::sparse::Csr::from_dense(&w);
        let x = Mat::randn(64, 512, 1.0, &mut rng);
        bench("dense matmul_tb 64x512 @ (256,512)", 20, || {
            std::hint::black_box(x.matmul_tb(&w));
        });
        bench("csr matmul_tb @80% sparsity", 20, || {
            std::hint::black_box(csr.matmul_tb(&x));
        });
    }

    if run("hlo") {
        if let Ok(rt) = apt::runtime::Runtime::load(std::path::Path::new("artifacts")) {
            if let Some(entry) = rt.find("prune_24_mm", 256, 256) {
                let entry = entry.clone();
                let (w, _hd, hinv) = setup(256, 256, 10);
                let hinv32 = hinv.to_f32();
                // include one warm compile, then measure steady-state exec
                let _ = rt.exec_prune(&entry, &w, &hinv32);
                bench("hlo prune_24_mm 256x256 (PJRT exec)", 6, || {
                    std::hint::black_box(rt.exec_prune(&entry, &w, &hinv32).unwrap());
                });
            }
            if let Some(entry) = rt.find_m("hessian_update", 256) {
                let entry = entry.clone();
                let mut rng = Rng::new(11);
                let x = Mat::randn(entry.t, 256, 1.0, &mut rng);
                let h = Mat::zeros(256, 256);
                let _ = rt.exec(&entry, &[&x, &h], &[], &[256]);
                bench("hlo hessian_update 128x256 (PJRT exec)", 10, || {
                    std::hint::black_box(rt.exec(&entry, &[&x, &h], &[], &[256]).unwrap());
                });
            }
        } else {
            println!("(artifacts missing; hlo benches skipped)");
        }
    }
}
