//! Perf microbenchmarks for the hot paths (criterion is unavailable
//! offline; this is a hand-rolled warmup+repeat harness with median/p90).
//! Used by the PERF.md iteration log.
//!
//!     cargo bench --bench perf [filter]        # or scripts/bench.sh
//!
//! Every run writes `BENCH_perf.json` at the repo root (median/p90 per
//! kernel + derived speedups + one end-to-end pipeline report) and prints
//! a delta table against the previous JSON if one exists. A filtered run
//! only re-measures matching kernels and keeps the previous numbers for
//! the rest.

use std::collections::BTreeMap;

use apt::coordinator::{prune_model, PipelineConfig};
use apt::data::{CorpusGen, Profile};
use apt::json::{self, Json};
use apt::linalg::{cholesky_blocked, cholesky_unblocked, cholesky_upper, inv_spd};
use apt::model::{
    train, DecodeSession, DecodeState, LanguageModel, Mamba, MambaConfig, TrainConfig,
    Transformer, TransformerConfig,
};
use apt::prune::{
    column_blocks, compensate_m, compensate_sequential, select_24_m, select_unstructured_s,
    sparsegpt_prune, HessianAccumulator, IncrementalMrp, Mask, Method, PruneConfig, Sparsity,
};
use apt::tensor::{Mat, MatF64};
use apt::util::{num_threads, Quantiles, Rng, Timer};

const OUT_PATH: &str = "BENCH_perf.json";

#[derive(Clone, Copy)]
struct Stats {
    median: f64,
    p90: f64,
    iters: usize,
}

struct Recorder {
    kernels: BTreeMap<String, Stats>,
    derived: BTreeMap<String, f64>,
    pipeline: Option<Json>,
    /// Kernels actually measured in this run (vs carried over from the
    /// previous JSON on a filtered run) — the delta table's row set.
    measured: Vec<String>,
}

impl Recorder {
    fn new() -> Recorder {
        Recorder {
            kernels: BTreeMap::new(),
            derived: BTreeMap::new(),
            pipeline: None,
            measured: Vec::new(),
        }
    }

    /// Warmup twice, run `iters` times, record + print median/p90.
    /// Returns the median (ms) so callers can derive speedups.
    fn bench<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) -> f64 {
        for _ in 0..2 {
            f();
        }
        let mut q = Quantiles::new();
        for _ in 0..iters {
            let t = Timer::start();
            f();
            q.push(t.elapsed_ms());
        }
        let (median, p90) = (q.median(), q.quantile(0.9));
        println!("{name:<52} median {median:>9.3} ms   p90 {p90:>9.3} ms   n={}", q.len());
        self.kernels.insert(name.to_string(), Stats { median, p90, iters: q.len() });
        self.measured.push(name.to_string());
        median
    }

    fn to_json(&self) -> Json {
        let mut kernels = Json::obj();
        for (name, s) in &self.kernels {
            let mut e = Json::obj();
            e.set("median_ms", Json::Num(s.median))
                .set("p90_ms", Json::Num(s.p90))
                .set("iters", Json::Num(s.iters as f64));
            kernels.set(name, e);
        }
        let mut derived = Json::obj();
        for (name, v) in &self.derived {
            derived.set(name, Json::Num(*v));
        }
        let mut root = Json::obj();
        root.set("schema", Json::Str("bench-perf-v1".into()))
            .set("threads", Json::Num(num_threads() as f64))
            .set("kernels", kernels)
            .set("derived", derived);
        if let Some(p) = &self.pipeline {
            root.set("pipeline", p.clone());
        }
        root
    }
}

/// Fold kernels from a previous run into the recorder (filtered runs keep
/// unmeasured kernels' last numbers) and return the previous medians for
/// the delta table.
fn load_previous(rec: &mut Recorder) -> BTreeMap<String, f64> {
    let mut prev_medians = BTreeMap::new();
    let Ok(text) = std::fs::read_to_string(OUT_PATH) else {
        return prev_medians;
    };
    let Ok(root) = json::parse(&text) else {
        eprintln!("(previous {OUT_PATH} unparseable; ignoring)");
        return prev_medians;
    };
    if let Some(Json::Obj(kernels)) = root.get("kernels") {
        for (name, entry) in kernels {
            let median = entry.get("median_ms").and_then(Json::as_f64);
            let p90 = entry.get("p90_ms").and_then(Json::as_f64);
            let iters = entry.get("iters").and_then(Json::as_f64).unwrap_or(0.0);
            if let (Some(median), Some(p90)) = (median, p90) {
                prev_medians.insert(name.clone(), median);
                rec.kernels
                    .insert(name.clone(), Stats { median, p90, iters: iters as usize });
            }
        }
    }
    if let Some(Json::Obj(derived)) = root.get("derived") {
        for (name, v) in derived {
            if let Some(v) = v.as_f64() {
                rec.derived.insert(name.clone(), v);
            }
        }
    }
    if let Some(p) = root.get("pipeline") {
        rec.pipeline = Some(p.clone());
    }
    prev_medians
}

fn print_delta(prev: &BTreeMap<String, f64>, rec: &Recorder) {
    if prev.is_empty() {
        return;
    }
    println!("\n== delta vs previous {OUT_PATH} ==");
    for name in &rec.measured {
        let (Some(&old), Some(new)) = (prev.get(name), rec.kernels.get(name)) else {
            continue;
        };
        if old <= 0.0 {
            continue;
        }
        let pct = (new.median / old - 1.0) * 100.0;
        println!("{name:<52} {old:>9.3} -> {:>9.3} ms  ({pct:>+6.1}%)", new.median);
    }
}

/// Init a transformer and, when `sp` is set, magnitude-prune + pack
/// every block linear into the matching sparse layout — the model
/// builder shared by the decode-session and serving-engine benches.
fn prune_pack_transformer(cfg: TransformerConfig, seed: u64, sp: Option<Sparsity>) -> Transformer {
    use apt::model::BLOCK_LINEARS;
    use apt::sparse::WeightStore;
    let mut m = Transformer::init(cfg, &mut Rng::new(seed));
    if let Some(sp) = sp {
        for b in 0..cfg.n_layers {
            for name in BLOCK_LINEARS {
                apt::prune::magnitude_prune(m.weight_mut(b, name).dense_mut(), sp);
                let w = m.weight(b, name).to_dense();
                *m.weight_mut(b, name) = WeightStore::pack(&w, sp);
            }
        }
    }
    m
}

fn setup(n: usize, m: usize, seed: u64) -> (Mat, MatF64, MatF64) {
    let mut rng = Rng::new(seed);
    let w = Mat::randn(n, m, 1.0, &mut rng);
    let x = Mat::randn(2 * m, m, 1.0, &mut rng);
    let mut acc = HessianAccumulator::new(m);
    acc.add_chunk(&x);
    let hd = acc.damped(0.01);
    let hinv = inv_spd(&hd).unwrap();
    (w, hd, hinv)
}

/// Blockwise SM/MM compensation: reference (re-factor cumulative set per
/// block) vs incremental (growing per-row factors). Masks are recorded
/// once from the real selection flow so both solvers replay the identical
/// schedule; equivalence is asserted before timing.
fn bench_mrp_blockwise(rec: &mut Recorder) {
    let n = 512;
    let s = 16;
    for (label, two_four) in [("SM 0.5", false), ("MM 2:4", true)] {
        let (w0, _hd, hinv) = setup(n, n, if two_four { 13 } else { 12 });
        let diag = hinv.diag();
        // Record the per-block masks (+ cumulative snapshots for the
        // reference path) from one incremental pass over the real flow.
        let mut blocks: Vec<Mask> = Vec::new();
        let mut cums: Vec<Mask> = Vec::new();
        let w_inc = {
            let mut w = w0.clone();
            let mut inc = IncrementalMrp::new(&hinv, n);
            let mut cum = Mask::new(n, n);
            for (c0, c1) in column_blocks(n, Some(s)) {
                let bm = if two_four {
                    select_24_m(&w, &hinv, c0, c1).0
                } else {
                    select_unstructured_s(&w, &diag, c0, c1, 0.5)
                };
                cum.or_with(&bm);
                inc.compensate_block(&mut w, &bm);
                blocks.push(bm);
                cums.push(cum.clone());
            }
            w
        };
        // One reference replay to assert the solvers agree on this shape.
        {
            let mut w = w0.clone();
            for cum in &cums {
                compensate_m(&mut w, cum, &hinv);
            }
            let d = w.max_abs_diff(&w_inc);
            assert!(d < 1e-5, "solver divergence {d} on {label}");
            println!("mrp {label}: incremental vs reference max |dw| = {d:.2e}");
        }
        let name_ref = format!("mrp blockwise {label} S={s} {n}x{n} (reference)");
        let name_inc = format!("mrp blockwise {label} S={s} {n}x{n} (incremental)");
        let med_ref = rec.bench(&name_ref, 3, || {
            let mut w = w0.clone();
            for cum in &cums {
                std::hint::black_box(compensate_m(&mut w, cum, &hinv));
            }
        });
        let med_inc = rec.bench(&name_inc, 5, || {
            let mut w = w0.clone();
            let mut inc = IncrementalMrp::new(&hinv, n);
            for bm in &blocks {
                std::hint::black_box(inc.compensate_block(&mut w, bm));
            }
        });
        let speedup = med_ref / med_inc.max(1e-9);
        let key = if two_four { "mrp_mm_24_speedup" } else { "mrp_sm_unstructured_speedup" };
        rec.derived.insert(key.to_string(), speedup);
        println!("  -> {label} incremental speedup: {speedup:.2}x (median)");
    }
}

/// Sparse-vs-dense `matmul_tb` across formats and batch shapes; records
/// the realized kernel speedups and compression ratios under `derived`.
fn bench_sparse_kernels(rec: &mut Recorder) {
    use apt::sparse::{Csr, Csr16, Packed24};
    let mut rng = Rng::new(9);

    // unstructured 80% -> CSR
    let mut w = Mat::randn(256, 512, 1.0, &mut rng);
    apt::prune::magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.8 });
    let csr = Csr::from_dense(&w);
    let csr16 = Csr16::from_dense(&w);
    let x = Mat::randn(64, 512, 1.0, &mut rng);
    let d = rec.bench("dense matmul_tb 64x512 @ (256,512)", 20, || {
        std::hint::black_box(x.matmul_tb(&w));
    });
    let c = rec.bench("csr matmul_tb @80% sparsity", 20, || {
        std::hint::black_box(csr.matmul_tb(&x));
    });
    rec.derived.insert("csr_matmul_speedup_80".into(), d / c.max(1e-9));
    rec.derived
        .insert("csr_compression_80".into(), csr.dense_bytes() as f64 / csr.bytes() as f64);
    // u16-index CSR: same kernel body, half the index bytes per nnz
    let c16 = rec.bench("csr16 matmul_tb @80% sparsity", 20, || {
        std::hint::black_box(csr16.matmul_tb(&x));
    });
    rec.derived.insert("csr16_matmul_speedup_80".into(), d / c16.max(1e-9));
    rec.derived.insert(
        "csr16_compression_80".into(),
        csr16.dense_bytes() as f64 / csr16.bytes() as f64,
    );

    // 2:4 -> packed layout, executed without densifying
    let mut w24 = Mat::randn(256, 512, 1.0, &mut rng);
    apt::prune::magnitude_prune(&mut w24, Sparsity::two_four());
    let packed = Packed24::from_dense(&w24).unwrap();
    let d24 = rec.bench("dense matmul_tb 64x512 @ 2:4", 20, || {
        std::hint::black_box(x.matmul_tb(&w24));
    });
    let p24 = rec.bench("packed24 matmul_tb 64x512", 20, || {
        std::hint::black_box(packed.matmul_tb(&x));
    });
    rec.derived.insert("packed24_matmul_speedup".into(), d24 / p24.max(1e-9));
    rec.derived.insert(
        "packed24_compression".into(),
        packed.dense_bytes() as f64 / packed.bytes() as f64,
    );

    // single-token decode shape (t = 1): the serving hot path
    let x1 = Mat::randn(1, 512, 1.0, &mut rng);
    let d1 = rec.bench("dense matmul_tb 1x512 @ (256,512)", 50, || {
        std::hint::black_box(x1.matmul_tb(&w));
    });
    let c1 = rec.bench("csr matmul_tb 1x512 @80%", 50, || {
        std::hint::black_box(csr.matmul_tb(&x1));
    });
    let c16_1 = rec.bench("csr16 matmul_tb 1x512 @80%", 50, || {
        std::hint::black_box(csr16.matmul_tb(&x1));
    });
    let p1 = rec.bench("packed24 matmul_tb 1x512", 50, || {
        std::hint::black_box(packed.matmul_tb(&x1));
    });
    rec.derived.insert("csr_decode_speedup_80".into(), d1 / c1.max(1e-9));
    rec.derived.insert("csr16_decode_speedup_80".into(), d1 / c16_1.max(1e-9));
    rec.derived.insert("packed24_decode_speedup".into(), d1 / p1.max(1e-9));
}

/// End-to-end pruned-model decode: the same magnitude-2:4 / 80%-CSR
/// transformer run dense vs from its packed `WeightStore` layouts, plus
/// the whole-checkpoint compression ratio.
fn bench_pruned_decode(rec: &mut Recorder) {
    use apt::model::BLOCK_LINEARS;
    use apt::sparse::WeightStore;

    let cfg = TransformerConfig {
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 256,
        max_seq: 64,
    };
    let mut model = Transformer::init(cfg, &mut Rng::new(31));
    for b in 0..cfg.n_layers {
        for name in BLOCK_LINEARS {
            apt::prune::magnitude_prune(
                model.weight_mut(b, name).dense_mut(),
                Sparsity::two_four(),
            );
        }
    }
    let pack_as = |model: &Transformer, sp: Sparsity| -> Transformer {
        let mut out = Transformer { cfg: model.cfg, params: model.params.clone() };
        for b in 0..cfg.n_layers {
            for name in BLOCK_LINEARS {
                let w = out.weight(b, name).to_dense();
                *out.weight_mut(b, name) = WeightStore::pack(&w, sp);
            }
        }
        out
    };
    let packed = pack_as(&model, Sparsity::two_four());
    let toks: Vec<u32> = (0..48).map(|i| (i * 7 % 512) as u32).collect();
    let d = rec.bench("decode 48tok d128 L4 (dense 2:4 weights)", 10, || {
        std::hint::black_box(model.predict_last_full(&toks));
    });
    let p = rec.bench("decode 48tok d128 L4 (packed24 stores)", 10, || {
        std::hint::black_box(packed.predict_last_full(&toks));
    });
    rec.derived.insert("decode_packed24_speedup".into(), d / p.max(1e-9));
    rec.derived.insert(
        "model_compression_24".into(),
        packed.params.dense_bytes() as f64 / packed.params.bytes() as f64,
    );

    // 80% unstructured variant of the same geometry -> CSR stores
    let mut m80 = Transformer::init(cfg, &mut Rng::new(32));
    for b in 0..cfg.n_layers {
        for name in BLOCK_LINEARS {
            apt::prune::magnitude_prune(
                m80.weight_mut(b, name).dense_mut(),
                Sparsity::Unstructured { rate: 0.8 },
            );
        }
    }
    let csr80 = pack_as(&m80, Sparsity::Unstructured { rate: 0.8 });
    let d80 = rec.bench("decode 48tok d128 L4 (dense 80% weights)", 10, || {
        std::hint::black_box(m80.predict_last_full(&toks));
    });
    let c80 = rec.bench("decode 48tok d128 L4 (csr stores)", 10, || {
        std::hint::black_box(csr80.predict_last_full(&toks));
    });
    rec.derived.insert("decode_csr_speedup_80".into(), d80 / c80.max(1e-9));
    rec.derived.insert(
        "model_compression_csr_80".into(),
        csr80.params.dense_bytes() as f64 / csr80.params.bytes() as f64,
    );
}

/// Incremental decode sessions vs the quadratic no-cache path: prefill a
/// 256-token context, then 64 single-token steps. The baseline re-runs
/// the full (growing) context through every block per step
/// (`predict_last_full`, already using the `logits_last` fast path); the
/// session path pays O(T·L) per step from its K/V caches (O(1) for
/// mamba's recurrent state). Records `decode_session_speedup_{dense,
/// packed24,csr,mamba}` under `derived` — expected ≫1 at this length.
fn bench_decode_session(rec: &mut Recorder) {
    let cfg = TransformerConfig {
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 256,
        max_seq: 512,
    };
    let prefill: Vec<u32> = (0..256).map(|i| (i * 7 % 512) as u32).collect();
    let steps: Vec<u32> = (0..64).map(|i| (i * 13 % 512) as u32).collect();

    let variants: [(&str, Transformer); 3] = [
        ("dense", prune_pack_transformer(cfg, 61, None)),
        ("packed24", prune_pack_transformer(cfg, 62, Some(Sparsity::two_four()))),
        ("csr", prune_pack_transformer(cfg, 63, Some(Sparsity::Unstructured { rate: 0.8 }))),
    ];
    let run_pair = |rec: &mut Recorder, label: &str, model: &dyn LanguageModel| {
        let f = rec.bench(
            &format!("decode_session full-fwd prefill256+64steps ({label})"),
            2,
            || {
                let mut ctx = prefill.clone();
                for &t in &steps {
                    std::hint::black_box(model.predict_last_full(&ctx));
                    ctx.push(t);
                }
            },
        );
        let s = rec.bench(
            &format!("decode_session incremental prefill256+64steps ({label})"),
            5,
            || {
                let mut sess = DecodeSession::new(model);
                sess.prefill(&prefill);
                for &t in &steps {
                    std::hint::black_box(sess.step(t));
                }
            },
        );
        rec.derived
            .insert(format!("decode_session_speedup_{label}"), f / s.max(1e-9));
        println!("  -> decode_session {label}: {:.2}x", f / s.max(1e-9));
    };
    for (label, model) in &variants {
        run_pair(rec, label, model);
    }

    // mamba: the recurrent-state path (O(1) per step in context length)
    let mcfg = MambaConfig { vocab: 512, d_model: 128, d_inner: 256, n_layers: 4, max_seq: 512 };
    let mamba = Mamba::init(mcfg, &mut Rng::new(64));
    run_pair(rec, "mamba", &mamba);
}

/// Batched serving engine vs B=1: B concurrent greedy streams (64-token
/// prompts, 32 new tokens each) through one `Engine`, for B ∈ {1, 4,
/// 16}. Each engine step runs ALL streams through a single (B, d)
/// matmul per linear, so weight reads amortize across the batch — the
/// regime where sparse-layout serving pays off. Prompts are pre-admitted
/// (`Engine::admit`) OUTSIDE the timed region, so the recorded numbers
/// isolate the decode loop the batching redesign targets. Records
/// `engine_throughput_tokens_per_s_{b1,b4,b16}` (decoded tokens per
/// second) and `engine_batch_speedup_{b4,b16}` (per-token decode
/// throughput vs B=1) under `derived`, for dense and packed24 2:4
/// weight stores.
fn bench_serve(rec: &mut Recorder) {
    use apt::serve::{Engine, EngineConfig, Request};

    let cfg = TransformerConfig {
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 256,
        max_seq: 512,
    };
    let (prefill_len, new_toks, iters) = (64usize, 32usize, 5usize);
    let prompt = |i: usize| -> Vec<u32> {
        (0..prefill_len).map(|j| ((j * 7 + i * 13) % 512) as u32).collect()
    };
    for (label, model) in [
        ("dense", prune_pack_transformer(cfg, 71, None)),
        ("packed24", prune_pack_transformer(cfg, 72, Some(Sparsity::two_four()))),
    ] {
        let make_engine = |bsz: usize| {
            let mut eng = Engine::new(&model, EngineConfig { max_batch: bsz, ..Default::default() });
            for i in 0..bsz {
                eng.submit(Request::greedy(prompt(i), new_toks));
            }
            eng.admit(); // prefill OUTSIDE the timed region
            eng
        };
        let mut thr = BTreeMap::new();
        for &bsz in &[1usize, 4, 16] {
            // pre-admitted engines for the expected calls; rebuild on
            // demand if the harness's warmup count ever changes
            let mut prepped: Vec<Engine> = (0..iters + 2).map(|_| make_engine(bsz)).collect();
            let med = rec.bench(
                &format!("engine decode b{bsz} {new_toks}new ({label})"),
                iters,
                || {
                    let mut eng = prepped.pop().unwrap_or_else(|| make_engine(bsz));
                    eng.run();
                    std::hint::black_box(eng.take_finished());
                },
            );
            let tps = (bsz * new_toks) as f64 / (med / 1000.0).max(1e-9);
            thr.insert(bsz, tps);
            // dense gets the canonical keys; other layouts are suffixed
            let suffix = if label == "dense" { String::new() } else { format!("_{label}") };
            rec.derived
                .insert(format!("engine_throughput_tokens_per_s_b{bsz}{suffix}"), tps);
        }
        for &bsz in &[4usize, 16] {
            let speedup = thr[&bsz] / thr[&1].max(1e-9);
            let suffix = if label == "dense" { String::new() } else { format!("_{label}") };
            rec.derived.insert(format!("engine_batch_speedup_b{bsz}{suffix}"), speedup);
            println!("  -> engine {label} b{bsz}: {speedup:.2}x per-token throughput vs b1");
        }
    }
}

/// Resilience-path costs. `engine_cancel_reclaim_ns`: cancelling a
/// mid-flight stream, which drops its decode state and returns its K/V
/// pages through the freelist. `engine_preempt_recompute_overhead`:
/// wall-clock ratio of finishing an over-budget workload under a tight
/// `max_kv_pages` (recompute preemption + re-admission) vs the same
/// workload unconstrained — the price of fitting in half the memory.
fn bench_resilience(rec: &mut Recorder) {
    use apt::serve::{Engine, EngineConfig, Request, RequestId};

    let cfg = TransformerConfig {
        vocab: 256,
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        d_ff: 96,
        max_seq: 256,
    };
    let model = Transformer::init(cfg, &mut Rng::new(81));
    let prompt =
        |i: usize| -> Vec<u32> { (0..48).map(|j| ((j * 7 + i * 13) % 256) as u32).collect() };

    // Engines are prepared (submitted + admitted + a few decode steps,
    // so every stream holds pages) OUTSIDE the timed region; each
    // iteration cancels one engine's 4 live streams.
    let iters = 10usize;
    let make = || {
        let mut eng = Engine::new(&model, EngineConfig { max_batch: 4, ..Default::default() });
        let ids: Vec<RequestId> =
            (0..4).map(|i| eng.submit(Request::greedy(prompt(i), 24))).collect();
        eng.admit();
        for _ in 0..4 {
            eng.step();
        }
        (eng, ids)
    };
    let mut prepped: Vec<_> = (0..iters + 2).map(|_| make()).collect();
    let med = rec.bench("engine cancel 4 mid-flight streams", iters, || {
        let (mut eng, ids) = prepped.pop().unwrap_or_else(make);
        for id in ids {
            std::hint::black_box(eng.cancel(id));
        }
        assert_eq!(eng.kv_pages_live(), 0, "cancel must reclaim every page");
    });
    let ns = med * 1e6 / 4.0;
    rec.derived.insert("engine_cancel_reclaim_ns".into(), ns);
    println!("  -> cancel + page reclaim: {ns:.0} ns per stream");

    // Same 6-request workload with room for everyone vs a 16-page
    // budget: admission fits four 48-token prompts (4 pages each), so
    // the decode-growth enforcer must preempt when streams cross the
    // 64-row page boundary — recompute preemption on the hot path.
    let run_with = |budget: Option<usize>| {
        let mut eng = Engine::new(
            &model,
            EngineConfig { max_batch: 4, max_kv_pages: budget, ..Default::default() },
        );
        for i in 0..6 {
            eng.submit(Request::greedy(prompt(i), 24));
        }
        eng.run();
        eng
    };
    // the ratio is only meaningful if the tight run actually preempts
    let preemptions = run_with(Some(16)).stats().preemptions;
    assert!(preemptions > 0, "16-page budget failed to trigger preemption");
    let free = rec.bench("engine 6 reqs unbounded pages", 8, || {
        std::hint::black_box(run_with(None).take_finished());
    });
    let tight = rec.bench("engine 6 reqs 16-page budget", 8, || {
        std::hint::black_box(run_with(Some(16)).take_finished());
    });
    let ratio = tight / free.max(1e-9);
    rec.derived.insert("engine_preempt_recompute_overhead".into(), ratio);
    println!(
        "  -> over-budget workload: {ratio:.2}x wall clock vs unbounded \
         ({preemptions} preemptions)"
    );
}

/// Sliding-window K/V eviction at long T: the old contiguous-shift
/// layout (append + drop the leading row = O(W·d) memmove per step) vs
/// the paged layout (append + cursor advance, whole pages recycled =
/// O(1) per step, no row copying). Records
/// `decode_eviction_ns_per_step_{shift,paged}` under `derived`.
fn bench_paged_eviction(rec: &mut Recorder) {
    use apt::tensor::PagedKv;
    let (w, d, steps) = (512usize, 128usize, 4096usize);
    let row = vec![1.0f32; d];
    let med_shift = rec.bench("kv eviction shift W=512 d=128 4096 steps", 10, || {
        let mut m = Mat::zeros(0, d);
        for _ in 0..w {
            m.append_row(&row);
        }
        for _ in 0..steps {
            m.append_row(&row);
            m.drop_leading_rows(1);
        }
        std::hint::black_box(&m);
    });
    let med_paged = rec.bench("kv eviction paged W=512 d=128 4096 steps", 10, || {
        let mut p = PagedKv::new(d);
        for _ in 0..w {
            p.append_row(&row);
        }
        for _ in 0..steps {
            p.append_row(&row);
            p.evict_to(w);
        }
        std::hint::black_box(&p);
    });
    rec.derived.insert("decode_eviction_ns_per_step_shift".into(), med_shift * 1e6 / steps as f64);
    rec.derived.insert("decode_eviction_ns_per_step_paged".into(), med_paged * 1e6 / steps as f64);
    println!(
        "  -> eviction per step: shift {:.0} ns vs paged {:.0} ns",
        med_shift * 1e6 / steps as f64,
        med_paged * 1e6 / steps as f64
    );
}

/// Bursty admission: 8 queued 64-token prompts prefilled one-by-one
/// (the pre-packing admission path) vs as ONE padded Full-arm batch
/// (`prefill_batch`, what `Engine::admit` now runs). Records
/// `engine_prefill_packed_speedup` under `derived`.
fn bench_prefill_packed(rec: &mut Recorder) {
    let cfg = TransformerConfig {
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 256,
        max_seq: 512,
    };
    let model = prune_pack_transformer(cfg, 81, None);
    let (bsz, plen) = (8usize, 64usize);
    let prompts: Vec<Vec<u32>> = (0..bsz)
        .map(|i| (0..plen).map(|j| ((j * 7 + i * 13) % 512) as u32).collect())
        .collect();
    let refs: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let per = rec.bench("prefill admission 8x64tok (per-request)", 10, || {
        for p in &prompts {
            let mut st = model.decode_state();
            std::hint::black_box(model.prefill_append(&mut st, 0, p));
        }
    });
    let packed = rec.bench("prefill admission 8x64tok (packed batch)", 10, || {
        let mut sts: Vec<DecodeState> = (0..bsz).map(|_| model.decode_state()).collect();
        std::hint::black_box(model.prefill_batch(&mut sts, &refs));
    });
    let speedup = per / packed.max(1e-9);
    rec.derived.insert("engine_prefill_packed_speedup".into(), speedup);
    println!("  -> packed cross-request prefill: {speedup:.2}x vs per-request");
}

/// Threaded vs serial per-stream attention in the batched decode step at
/// large B·T (16 streams, 512 cached positions each, window-pinned so
/// every step sees the same T). The serial baseline is forced via
/// `APT_BATCH_ATTN_THRESHOLD`; the threaded run forces the pool on.
/// Records `batch_attn_thread_speedup` under `derived`.
fn bench_batch_attn(rec: &mut Recorder) {
    let cfg = TransformerConfig {
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 256,
        max_seq: 1024,
    };
    let model = prune_pack_transformer(cfg, 91, None);
    let (bsz, t) = (16usize, 512usize);
    let prompts: Vec<Vec<u32>> = (0..bsz)
        .map(|i| (0..t).map(|j| ((j * 7 + i * 13) % 512) as u32).collect())
        .collect();
    let refs: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let mut states: Vec<DecodeState> = (0..bsz).map(|_| model.decode_state()).collect();
    model.prefill_batch(&mut states, &refs);
    let mut poss: Vec<usize> = vec![t; bsz];
    let run_steps = |states: &mut Vec<DecodeState>, poss: &mut Vec<usize>, n: usize| {
        for _ in 0..n {
            let toks: Vec<u32> = (0..bsz).map(|i| ((poss[i] * 7 + i) % 512) as u32).collect();
            let h = model.decode_step_batch(states, poss, &toks);
            std::hint::black_box(&h);
            for (i, st) in states.iter_mut().enumerate() {
                st.enforce_window(t); // O(1) paged eviction pins T
                poss[i] += 1;
            }
        }
    };
    std::env::set_var("APT_BATCH_ATTN_THRESHOLD", usize::MAX.to_string());
    let serial = rec.bench("batch decode b16 T512 8 steps (serial attn)", 8, || {
        run_steps(&mut states, &mut poss, 8);
    });
    std::env::set_var("APT_BATCH_ATTN_THRESHOLD", "1");
    let threaded = rec.bench("batch decode b16 T512 8 steps (threaded attn)", 8, || {
        run_steps(&mut states, &mut poss, 8);
    });
    std::env::remove_var("APT_BATCH_ATTN_THRESHOLD");
    let speedup = serial / threaded.max(1e-9);
    rec.derived.insert("batch_attn_thread_speedup".into(), speedup);
    println!("  -> threaded batch attention: {speedup:.2}x vs serial at B·T = {}", bsz * t);
}

/// Self-speculative serving vs the plain dense engine: the dense target
/// serves the same greedy workload directly and in draft-propose /
/// target-verify rounds against a magnitude-2:4 pruned copy of its OWN
/// weights (the pair `coordinator::prune_draft_model` produces), for
/// k ∈ {2, 4, 8}. The lossless gate (`spec_serve_report` asserts
/// bit-identical outputs) runs once untimed; the timed runs pre-admit
/// prompts like `bench_serve` (draft prefill stays inside the timed
/// region — the speculative path really pays it). Records
/// `spec_decode_tokens_per_s_{k2,k4,k8}`, `spec_acceptance_rate` (at
/// k=4), and `spec_decode_speedup_vs_dense` (best k) under `derived`.
fn bench_speculative(rec: &mut Recorder) {
    use apt::model::BLOCK_LINEARS;
    use apt::serve::speculative::spec_serve_report;
    use apt::serve::{Engine, EngineConfig, Request};
    use apt::sparse::WeightStore;

    let cfg = TransformerConfig {
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 256,
        max_seq: 512,
    };
    let target = prune_pack_transformer(cfg, 101, None);
    let mut draft = Transformer { cfg: target.cfg, params: target.params.clone() };
    let sp = Sparsity::two_four();
    for b in 0..cfg.n_layers {
        for name in BLOCK_LINEARS {
            apt::prune::magnitude_prune(draft.weight_mut(b, name).dense_mut(), sp);
            let w = draft.weight(b, name).to_dense();
            *draft.weight_mut(b, name) = WeightStore::pack(&w, sp);
        }
    }
    let (bsz, plen, new_toks, iters) = (4usize, 64usize, 32usize, 5usize);
    let prompts: Vec<Vec<u32>> = (0..bsz)
        .map(|i| (0..plen).map(|j| ((j * 7 + i * 13) % 512) as u32).collect())
        .collect();
    let ecfg = EngineConfig { max_batch: bsz, ..Default::default() };

    let probe = spec_serve_report(&target, &draft, &prompts, new_toks, 4, ecfg);
    rec.derived.insert("spec_acceptance_rate".into(), probe.acceptance_rate);
    println!(
        "  -> spec acceptance rate {:.3} ({:.2} tokens/round at k=4)",
        probe.acceptance_rate, probe.tokens_per_round
    );

    let make_dense = || {
        let mut eng = Engine::new(&target, ecfg);
        for p in &prompts {
            eng.submit(Request::greedy(p.clone(), new_toks));
        }
        eng.admit(); // target prefill OUTSIDE the timed region
        eng
    };
    let mut prepped: Vec<Engine> = (0..iters + 2).map(|_| make_dense()).collect();
    let dense_med = rec.bench(&format!("spec_decode dense b{bsz} {new_toks}new"), iters, || {
        let mut eng = prepped.pop().unwrap_or_else(|| make_dense());
        eng.run();
        std::hint::black_box(eng.take_finished());
    });
    let dense_tps = (bsz * new_toks) as f64 / (dense_med / 1000.0).max(1e-9);
    rec.derived.insert("spec_decode_tokens_per_s_dense".into(), dense_tps);

    let mut best_tps = 0.0f64;
    for k in [2usize, 4, 8] {
        let make_spec = || {
            let mut eng = Engine::speculative(&target, &draft, k, ecfg);
            for p in &prompts {
                eng.submit(Request::greedy(p.clone(), new_toks));
            }
            eng.admit();
            eng
        };
        let mut prepped: Vec<Engine> = (0..iters + 2).map(|_| make_spec()).collect();
        let med = rec.bench(
            &format!("spec_decode speculative k{k} b{bsz} {new_toks}new"),
            iters,
            || {
                let mut eng = prepped.pop().unwrap_or_else(|| make_spec());
                eng.run();
                std::hint::black_box(eng.take_finished());
            },
        );
        let tps = (bsz * new_toks) as f64 / (med / 1000.0).max(1e-9);
        best_tps = best_tps.max(tps);
        rec.derived.insert(format!("spec_decode_tokens_per_s_k{k}"), tps);
    }
    let speedup = best_tps / dense_tps.max(1e-9);
    rec.derived.insert("spec_decode_speedup_vs_dense".into(), speedup);
    println!("  -> speculative best-k throughput vs dense engine: {speedup:.2}x");
}

/// Structured pruning vs element-sparse serving at matched 50% budget:
/// a structured-pruned transformer (half the heads, half the FFN
/// channels — every block linear a physically smaller dense matmul)
/// against a magnitude-50% csr16 model of the same geometry, through
/// the same prefill+decode workload. Records
/// `structured_decode_tokens_per_s`, `structured_vs_csr_speedup` and
/// the pipeline's achieved `structured_flops_ratio` under `derived`.
fn bench_structured(rec: &mut Recorder) {
    use apt::coordinator::structured_prune_transformer;
    use apt::prune::StructuredConfig;

    let cfg = TransformerConfig {
        vocab: 512,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ff: 256,
        max_seq: 512,
    };
    let mut structured = prune_pack_transformer(cfg, 111, None);
    let mut rng = Rng::new(112);
    let calib: Vec<Vec<u32>> =
        (0..8).map(|_| (0..32).map(|_| rng.below(512) as u32).collect()).collect();
    let rep = structured_prune_transformer(&mut structured, &calib, &StructuredConfig::new(0.5))
        .unwrap();
    rec.derived.insert("structured_flops_ratio".into(), rep.flops_ratio());
    println!("  -> structured pipeline FLOPs ratio: {:.3}", rep.flops_ratio());

    // element-sparse baseline at the same 50% weight budget
    let csr = prune_pack_transformer(cfg, 111, Some(Sparsity::Unstructured { rate: 0.5 }));

    let prefill: Vec<u32> = (0..128).map(|i| (i * 7 % 512) as u32).collect();
    let steps = 64usize;
    let run_decode = |rec: &mut Recorder, label: &str, model: &dyn LanguageModel| -> f64 {
        let med = rec.bench(
            &format!("decode_session prefill128+{steps}steps ({label})"),
            5,
            || {
                let mut sess = DecodeSession::new(model);
                sess.prefill(&prefill);
                for i in 0..steps {
                    std::hint::black_box(sess.step((i * 13 % 512) as u32));
                }
            },
        );
        steps as f64 / (med / 1000.0).max(1e-9)
    };
    let tps_structured = run_decode(rec, "structured 0.5", &structured);
    let tps_csr = run_decode(rec, "csr16 0.5", &csr);
    rec.derived.insert("structured_decode_tokens_per_s".into(), tps_structured);
    rec.derived.insert("structured_vs_csr_speedup".into(), tps_structured / tps_csr.max(1e-9));
    println!(
        "  -> structured decode: {tps_structured:.0} tok/s ({:.2}x vs csr16)",
        tps_structured / tps_csr.max(1e-9)
    );
}

/// End-to-end coordinator run (calibrate -> prune -> propagate) on a
/// small trained transformer, so every future PR has a pipeline-level
/// trajectory, not just kernel medians.
fn bench_pipeline(rec: &mut Recorder) {
    let gen = CorpusGen::new(60, 2, 17);
    let data = gen.generate(Profile::C4Like, 30_000, 1);
    let vocab = gen.tokenizer.vocab_size();
    let mut model = Transformer::init(
        TransformerConfig { vocab, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 96, max_seq: 64 },
        &mut Rng::new(3),
    );
    train(
        &mut model,
        &data,
        &TrainConfig { steps: 60, batch: 8, seq_len: 32, log_every: 1000, ..Default::default() },
    );
    let calib = data.sample_calibration(16, 32, &mut Rng::new(9));
    let cfg = PipelineConfig::new(
        PruneConfig::new(Method::SM, Sparsity::Unstructured { rate: 0.5 }).with_block(Some(16)),
    );
    rec.bench("pipeline SM 0.5 S=16 transformer d64 L2", 3, || {
        let mut m = Transformer { cfg: model.cfg, params: model.params.clone() };
        std::hint::black_box(prune_model(&mut m, &calib, &cfg, None).unwrap());
    });
    // Keep one full stage-timing report for the JSON trajectory.
    let mut m = Transformer { cfg: model.cfg, params: model.params.clone() };
    let report = prune_model(&mut m, &calib, &cfg, None).unwrap();
    rec.pipeline = Some(report.to_json());
}

fn main() {
    let filter = std::env::args().skip(1).find(|a| !a.starts_with('-')).unwrap_or_default();
    let run = |name: &str| filter.is_empty() || name.contains(&filter);

    let mut rec = Recorder::new();
    let prev = load_previous(&mut rec);

    println!("== L3 hot paths (native) ==");

    if run("gemm") {
        let mut rng = Rng::new(1);
        let a = Mat::randn(512, 512, 1.0, &mut rng);
        let b = Mat::randn(512, 512, 1.0, &mut rng);
        rec.bench("gemm 512x512x512", 10, || {
            std::hint::black_box(a.matmul(&b));
        });
        rec.bench("gemm_tb 512x512x512", 10, || {
            std::hint::black_box(a.matmul_tb(&b));
        });

        // K-dimension cache tiling: a K-heavy shape where the untiled
        // inner loop streams `b` out of cache once per output row-chunk.
        // Both runs produce bitwise-identical output (the per-element
        // accumulation order is unchanged); only locality differs.
        let ak = Mat::randn(128, 4096, 1.0, &mut rng);
        let bk = Mat::randn(4096, 256, 1.0, &mut rng);
        let mut out = Mat::zeros(128, 256);
        let untiled = rec.bench("gemm_into 128x4096x256 (untiled)", 10, || {
            out.data.fill(0.0); // matmul_into accumulates
            apt::tensor::matmul_into_tiled(&ak, &bk, &mut out, usize::MAX);
            std::hint::black_box(&out);
        });
        let tiled = rec.bench("gemm_into 128x4096x256 (k-tiled 128)", 10, || {
            out.data.fill(0.0);
            apt::tensor::matmul_into_tiled(&ak, &bk, &mut out, 128);
            std::hint::black_box(&out);
        });
        let speedup = untiled / tiled.max(1e-9);
        rec.derived.insert("gemm_k_tiling_speedup".into(), speedup);
        println!("  -> gemm K-tiling: {speedup:.2}x vs untiled at K=4096");
    }

    if run("hessian") {
        let mut rng = Rng::new(2);
        let x = Mat::randn(512, 256, 1.0, &mut rng);
        rec.bench("hessian accumulate 2XtX (512x256)", 10, || {
            let mut acc = HessianAccumulator::new(256);
            acc.add_chunk(&x);
            std::hint::black_box(acc);
        });
        rec.bench("hessian accumulate (convert-in-loop)", 10, || {
            let mut acc = HessianAccumulator::new(256);
            acc.add_chunk_convert_in_loop(&x);
            std::hint::black_box(acc);
        });
    }

    if run("finalize") {
        let mut rng = Rng::new(3);
        let x = Mat::randn(512, 256, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(256);
        acc.add_chunk(&x);
        rec.bench("hessian finalize (chol+inv, m=256)", 8, || {
            std::hint::black_box(acc.finalize(0.01));
        });
    }

    if run("cholesky") {
        let mut rng = Rng::new(14);
        let x = Mat::randn(768, 384, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(384);
        acc.add_chunk(&x);
        let hd = acc.damped(0.01);
        rec.bench("cholesky unblocked m=384", 8, || {
            std::hint::black_box(cholesky_unblocked(&hd).unwrap());
        });
        rec.bench("cholesky blocked-parallel m=384", 8, || {
            std::hint::black_box(cholesky_blocked(&hd, 64).unwrap());
        });
    }

    if run("compensate") {
        let (w0, _hd, hinv) = setup(256, 256, 4);
        let mask = select_unstructured_s(&w0, &hinv.diag(), 0, 256, 0.5);
        rec.bench("compensate_m n=256 m=256 k=128", 6, || {
            let mut w = w0.clone();
            std::hint::black_box(compensate_m(&mut w, &mask, &hinv));
        });
        let (w0l, _hd, hinvl) = setup(256, 512, 5);
        let maskl = select_unstructured_s(&w0l, &hinvl.diag(), 0, 512, 0.5);
        rec.bench("compensate_m n=256 m=512 k=256", 4, || {
            let mut w = w0l.clone();
            std::hint::black_box(compensate_m(&mut w, &maskl, &hinvl));
        });
    }

    if run("mrp") {
        bench_mrp_blockwise(&mut rec);
    }

    if run("select") {
        let (w, _hd, hinv) = setup(512, 512, 15);
        let diag = hinv.diag();
        rec.bench("select_unstructured_s 512x512 (flat)", 20, || {
            std::hint::black_box(select_unstructured_s(&w, &diag, 0, 512, 0.5));
        });
        rec.bench("select_unstructured_s 512x512 (tuple ref)", 20, || {
            std::hint::black_box(apt::prune::mrp::select_unstructured_s_reference(
                &w, &diag, 0, 512, 0.5,
            ));
        });
    }

    if run("sequential") {
        let (w0, _hd, hinv) = setup(256, 256, 6);
        let u = cholesky_upper(&hinv).unwrap();
        let mask = select_unstructured_s(&w0, &hinv.diag(), 0, 256, 0.5);
        rec.bench("sparsegpt sweep n=256 m=256", 6, || {
            let mut w = w0.clone();
            compensate_sequential(&mut w, &mask, &u);
            std::hint::black_box(w);
        });
        let (w0b, _hd, hinvb) = setup(256, 256, 7);
        rec.bench("sparsegpt full (mask+sweep) S=64", 6, || {
            let mut w = w0b.clone();
            std::hint::black_box(sparsegpt_prune(
                &mut w,
                &hinvb,
                Sparsity::Unstructured { rate: 0.5 },
                Some(64),
                false,
            ));
        });
    }

    if run("mask24") {
        let (w, _hd, hinv) = setup(512, 512, 8);
        rec.bench("select_24_m (Eq12 6-combo) 512x512", 10, || {
            std::hint::black_box(select_24_m(&w, &hinv, 0, 512));
        });
    }

    if run("sparse") {
        bench_sparse_kernels(&mut rec);
    }

    if run("decode") {
        bench_pruned_decode(&mut rec);
        bench_decode_session(&mut rec);
    }

    if run("paged") {
        bench_paged_eviction(&mut rec);
    }

    if run("serve") {
        bench_serve(&mut rec);
        bench_prefill_packed(&mut rec);
        bench_batch_attn(&mut rec);
    }

    if run("resilience") {
        bench_resilience(&mut rec);
    }

    if run("speculative") {
        bench_speculative(&mut rec);
    }

    if run("structured") {
        bench_structured(&mut rec);
    }

    if run("pipeline") {
        bench_pipeline(&mut rec);
    }

    if run("hlo") {
        if let Ok(rt) = apt::runtime::Runtime::load(std::path::Path::new("artifacts")) {
            if let Some(entry) = rt.find("prune_24_mm", 256, 256) {
                let entry = entry.clone();
                let (w, _hd, hinv) = setup(256, 256, 10);
                let hinv32 = hinv.to_f32();
                // include one warm compile, then measure steady-state exec
                let _ = rt.exec_prune(&entry, &w, &hinv32);
                rec.bench("hlo prune_24_mm 256x256 (PJRT exec)", 6, || {
                    std::hint::black_box(rt.exec_prune(&entry, &w, &hinv32).unwrap());
                });
            }
            if let Some(entry) = rt.find_m("hessian_update", 256) {
                let entry = entry.clone();
                let mut rng = Rng::new(11);
                let x = Mat::randn(entry.t, 256, 1.0, &mut rng);
                let h = Mat::zeros(256, 256);
                let _ = rt.exec(&entry, &[&x, &h], &[], &[256]);
                rec.bench("hlo hessian_update 128x256 (PJRT exec)", 10, || {
                    std::hint::black_box(rt.exec(&entry, &[&x, &h], &[], &[256]).unwrap());
                });
            }
        } else {
            println!("(artifacts missing or pjrt feature off; hlo benches skipped)");
        }
    }

    print_delta(&prev, &rec);

    let body = rec.to_json().to_string_pretty();
    match std::fs::write(OUT_PATH, body + "\n") {
        Ok(()) => println!("\nwrote {OUT_PATH} ({} kernels)", rec.kernels.len()),
        Err(e) => eprintln!("failed to write {OUT_PATH}: {e}"),
    }
}
