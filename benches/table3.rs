//! Regenerates the paper's table3 on the scaled substitute workload.
//! `cargo bench --bench table3` (set APT_FAST=1 for a smoke run).
fn main() -> anyhow::Result<()> {
    let zoo = apt::harness::Zoo::new(42);
    let out = apt::harness::run_table("table3", &zoo, None)?;
    println!("{out}");
    Ok(())
}
