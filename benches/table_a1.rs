//! Regenerates the paper's table_a1 on the scaled substitute workload.
//! `cargo bench --bench table_a1` (set APT_FAST=1 for a smoke run).
fn main() -> anyhow::Result<()> {
    let zoo = apt::harness::Zoo::new(42);
    let out = apt::harness::run_table("table_a1", &zoo, None)?;
    println!("{out}");
    Ok(())
}
