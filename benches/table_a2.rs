//! Regenerates the paper's table_a2 on the scaled substitute workload.
//! `cargo bench --bench table_a2` (set APT_FAST=1 for a smoke run).
fn main() -> anyhow::Result<()> {
    let zoo = apt::harness::Zoo::new(42);
    let out = apt::harness::run_table("table_a2", &zoo, None)?;
    println!("{out}");
    Ok(())
}
