//! Integration tests across the whole stack: pipeline end-to-end, engine
//! parity (native vs AOT/PJRT), checkpoint round-trips, sparse packing of
//! pipeline output, and failure injection.

use apt::coordinator::{prune_model, PipelineConfig};
use apt::data::{CorpusGen, Profile};
use apt::eval::perplexity;
use apt::model::{train, LanguageModel, TrainConfig, Transformer, TransformerConfig};
use apt::prune::{magnitude_prune, Method, PruneConfig, Sparsity};
use apt::runtime::{Backend, Runtime};
use apt::sparse::{Packed24, WeightStore};
use apt::util::Rng;

fn trained_model(gen: &CorpusGen, d: usize, layers: usize, steps: usize) -> Transformer {
    let vocab = gen.tokenizer.vocab_size();
    let mut model = Transformer::init(
        TransformerConfig {
            vocab,
            d_model: d,
            n_layers: layers,
            n_heads: 2,
            d_ff: 2 * d,
            max_seq: 64,
        },
        &mut Rng::new(7),
    );
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    train(
        &mut model,
        &data,
        &TrainConfig { steps, batch: 4, seq_len: 32, log_every: steps, ..Default::default() },
    );
    model
}

#[test]
fn full_stack_prune_then_eval_then_pack() {
    let gen = CorpusGen::new(60, 2, 31);
    let model = trained_model(&gen, 32, 2, 60);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(8, 32, &mut Rng::new(2));

    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(Method::SM, Sparsity::two_four()));
    let report = prune_model(&mut pruned, &calib, &cfg, None).unwrap();
    assert_eq!(report.linears.len(), 14);
    assert!((report.overall_sparsity() - 0.5).abs() < 0.01);

    // the coordinator left every pruned linear in the hardware 2:4 layout
    for b in 0..2 {
        for name in ["wq", "wk", "wv", "wo", "w1", "w2", "w3"] {
            let w = pruned.weight(b, name);
            assert_eq!(w.format(), "packed24", "block {b} {name}");
            // the layout is consistent: re-pack of the densified weights
            // reproduces the stored layout bit-for-bit
            let repacked = Packed24::from_dense(&w.to_dense())
                .unwrap_or_else(|e| panic!("block {b} {name}: {e}"));
            assert_eq!(&WeightStore::Packed24(repacked), w);
            assert!(w.bytes() < w.dense_bytes());
        }
    }
    assert!((report.compression_ratio() - 16.0 / 9.0).abs() < 1e-9);

    // eval runs straight from the packed layout and returns finite ppl
    let eval_data = gen.generate(Profile::Wt2Like, 2_048, 3);
    let ppl = perplexity(&pruned, &eval_data, 64);
    assert!(ppl.is_finite() && ppl > 1.0);
}

#[test]
fn engine_parity_native_vs_hlo() {
    // When artifacts exist, the HLO engine must produce a valid 2:4 model
    // with quality close to native (same math, f32 vs f64 accumulation).
    if cfg!(not(feature = "pjrt")) {
        eprintln!("pjrt feature off; skipping parity test");
        return;
    }
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing; skipping parity test");
        return;
    }
    let rt = Runtime::load(dir).unwrap();
    let gen = CorpusGen::new(60, 2, 32);
    // d=128 so the (128,128)/(256,128)/(128,256) artifacts cover all linears
    let model = trained_model(&gen, 128, 1, 30);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(8, 32, &mut Rng::new(4));
    let eval_data = gen.generate(Profile::Wt2Like, 2_048, 5);

    let run = |backend: Backend| -> (f64, f64) {
        let mut m = Transformer { cfg: model.cfg, params: model.params.clone() };
        let cfg = PipelineConfig::new(PruneConfig::new(Method::SM, Sparsity::two_four()))
            .with_engine(backend);
        let rep = prune_model(&mut m, &calib, &cfg, Some(&rt)).unwrap();
        (perplexity(&m, &eval_data, 64), rep.hlo_fraction())
    };
    let (ppl_native, frac_native) = run(Backend::Native);
    let (ppl_hlo, frac_hlo) = run(Backend::Hlo);
    assert_eq!(frac_native, 0.0);
    assert!(frac_hlo > 0.9, "hlo engine should cover the layers: {frac_hlo}");
    let rel = (ppl_hlo - ppl_native).abs() / ppl_native;
    assert!(rel < 0.05, "native {ppl_native} vs hlo {ppl_hlo}");
}

#[test]
fn pruned_checkpoint_roundtrip() {
    let gen = CorpusGen::new(60, 2, 33);
    let model = trained_model(&gen, 32, 2, 20);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(4, 32, &mut Rng::new(6));
    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SS,
        Sparsity::Unstructured { rate: 0.7 },
    ));
    prune_model(&mut pruned, &calib, &cfg, None).unwrap();

    let dir = std::env::temp_dir().join("apt_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pruned.ats");
    pruned.save(&path).unwrap();
    let loaded = Transformer::load(pruned.cfg, &path).unwrap();
    // layouts, sparsity and behaviour survive the round-trip exactly:
    // the pipeline packed the linears into u16-index CSR and the ATS2
    // checkpoint preserves that layout (and its compression) on disk
    for name in loaded.params.names() {
        assert_eq!(loaded.params.get(name).unwrap(), pruned.params.get(name).unwrap());
    }
    assert_eq!(loaded.weight(0, "w1").format(), "csr16");
    assert_eq!(loaded.params.bytes(), pruned.params.bytes());
    assert!(loaded.params.bytes() < loaded.params.dense_bytes());
    let toks: Vec<u32> = (0..32).map(|i| (i % 50) as u32).collect();
    assert_eq!(
        pruned.forward_loss(&toks, (1, 32)),
        loaded.forward_loss(&toks, (1, 32))
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn csr_fast_path_matches_dense_forward() {
    let gen = CorpusGen::new(60, 2, 34);
    let model = trained_model(&gen, 32, 1, 20);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(4, 32, &mut Rng::new(8));
    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SM,
        Sparsity::Unstructured { rate: 0.8 },
    ));
    prune_model(&mut pruned, &calib, &cfg, None).unwrap();

    // the pipeline already left w1 in u16-index CSR (cols < 65536); its
    // matmul matches a dense run
    let w = pruned.weight(0, "w1");
    assert_eq!(w.format(), "csr16");
    let dense_w = w.to_dense();
    let x = apt::tensor::Mat::randn(8, w.cols(), 1.0, &mut Rng::new(9));
    let dense = x.matmul_tb(&dense_w);
    let sparse = w.matmul_tb(&x);
    assert!(dense.max_abs_diff(&sparse) < 1e-4);
    assert!(w.sparsity() > 0.75);

    // and the whole-model forward agrees with an all-dense copy
    let dense_model = Transformer { cfg: pruned.cfg, params: pruned.params.densified() };
    let toks: Vec<u32> = (0..32).map(|i| (i % 50) as u32).collect();
    let a = pruned.next_token_logprobs(&toks, (1, 32));
    let b = dense_model.next_token_logprobs(&toks, (1, 32));
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }
}

/// Tentpole acceptance: both model families run forward/eval from `Csr`
/// and `Packed24` stores with activations within 1e-5 of dense and masks
/// preserved bit-for-bit, across both sparsity patterns.
#[test]
fn weightstore_forward_equivalence_both_families_both_patterns() {
    use apt::model::{Mamba, MambaConfig, BLOCK_LINEARS, MAMBA_LINEARS};

    let tcfg = TransformerConfig {
        vocab: 47,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 32,
    };
    let mcfg = MambaConfig { vocab: 47, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 32 };
    let toks: Vec<u32> = (0..24).map(|i| (i * 5 % 47) as u32).collect();

    for sparsity in [Sparsity::Unstructured { rate: 0.6 }, Sparsity::two_four()] {
        // --- transformer
        let mut t = Transformer::init(tcfg, &mut Rng::new(41));
        for b in 0..tcfg.n_layers {
            for name in BLOCK_LINEARS {
                magnitude_prune(t.weight_mut(b, name).dense_mut(), sparsity);
            }
        }
        let mut packed = Transformer { cfg: t.cfg, params: t.params.clone() };
        for b in 0..tcfg.n_layers {
            for name in BLOCK_LINEARS {
                let w = packed.weight(b, name).to_dense();
                let store = WeightStore::pack(&w, sparsity);
                assert_eq!(store.to_dense(), w, "mask must survive bit-for-bit");
                *packed.weight_mut(b, name) = store;
            }
        }
        let a = t.next_token_logprobs(&toks, (1, 24));
        let b = packed.next_token_logprobs(&toks, (1, 24));
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "transformer {sparsity:?}: {x} vs {y}");
        }

        // --- mamba
        let mut m = Mamba::init(mcfg, &mut Rng::new(42));
        for b in 0..mcfg.n_layers {
            for name in MAMBA_LINEARS {
                magnitude_prune(m.weight_mut(b, name).dense_mut(), sparsity);
            }
        }
        let mut mpacked = Mamba { cfg: m.cfg, params: m.params.clone() };
        for b in 0..mcfg.n_layers {
            for name in MAMBA_LINEARS {
                let w = mpacked.weight(b, name).to_dense();
                let store = WeightStore::pack(&w, sparsity);
                assert_eq!(store.to_dense(), w, "mask must survive bit-for-bit");
                *mpacked.weight_mut(b, name) = store;
            }
        }
        let a = m.forward_loss(&toks, (1, 24));
        let b = mpacked.forward_loss(&toks, (1, 24));
        assert!((a - b).abs() < 1e-5, "mamba {sparsity:?}: {a} vs {b}");
    }
}

/// 2 families × 3 weight layouts: the model grid the serving-equivalence
/// tests sweep. Layout "dense" leaves init weights alone; "csr16"/
/// "packed24" prune + pack every block linear and assert the store
/// actually left the dense format (pack auto-selects the u16-index CSR
/// at these widths).
fn layout_variants() -> Vec<(String, Box<dyn LanguageModel>)> {
    use apt::model::{Mamba, MambaConfig, BLOCK_LINEARS, MAMBA_LINEARS};

    let tcfg = TransformerConfig {
        vocab: 47,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 256,
    };
    let mcfg = MambaConfig { vocab: 47, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 256 };
    let mut models: Vec<(String, Box<dyn LanguageModel>)> = Vec::new();
    for (layout, sparsity) in [
        ("dense", None),
        ("csr16", Some(Sparsity::Unstructured { rate: 0.6 })),
        ("packed24", Some(Sparsity::two_four())),
    ] {
        let mut t = Transformer::init(tcfg, &mut Rng::new(51));
        let mut m = Mamba::init(mcfg, &mut Rng::new(52));
        if let Some(sp) = sparsity {
            for b in 0..tcfg.n_layers {
                for name in BLOCK_LINEARS {
                    magnitude_prune(t.weight_mut(b, name).dense_mut(), sp);
                    let w = t.weight(b, name).to_dense();
                    *t.weight_mut(b, name) = WeightStore::pack(&w, sp);
                    assert_eq!(t.weight(b, name).format(), layout, "{name}");
                }
                for name in MAMBA_LINEARS {
                    magnitude_prune(m.weight_mut(b, name).dense_mut(), sp);
                    let w = m.weight(b, name).to_dense();
                    *m.weight_mut(b, name) = WeightStore::pack(&w, sp);
                    assert_eq!(m.weight(b, name).format(), layout, "{name}");
                }
            }
        }
        models.push((format!("microllama/{layout}"), Box::new(t)));
        models.push((format!("micromamba/{layout}"), Box::new(m)));
    }
    models
}

/// Tentpole acceptance: the incremental decode session reproduces the
/// full quadratic forward to <1e-5 at the logits, for both families ×
/// all three weight layouts (Dense, Csr, Packed24) × prefill lengths
/// {1, 7, 64}, including a prefill split mid-sequence and token-by-token
/// stepping.
#[test]
fn incremental_decode_matches_full_forward() {
    use apt::model::DecodeSession;

    for (label, model) in &layout_variants() {
        for (case, prefill_len) in [(0u64, 1usize), (1, 7), (2, 64)] {
            let mut rng = Rng::new(90 + case);
            let toks: Vec<u32> = (0..prefill_len).map(|_| rng.below(47) as u32).collect();

            // reference: full quadratic forward, logits at last position
            let mut x = model.embed_tokens(&toks);
            for b in 0..model.n_blocks() {
                x = model.forward_block(b, &x, (1, toks.len()));
            }
            let want = model.logits_last(&x);

            let check = |got: &[f32], how: &str| {
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(&want) {
                    assert!(
                        (g - w).abs() < 1e-5,
                        "{label} len={prefill_len} {how}: {g} vs {w}"
                    );
                }
            };

            // one-shot prefill
            let mut s = DecodeSession::new(model.as_ref());
            check(s.prefill(&toks), "one-shot prefill");
            assert_eq!(s.len(), prefill_len);

            if prefill_len > 1 {
                // prefill split mid-sequence
                let mid = prefill_len / 2;
                let mut s2 = DecodeSession::new(model.as_ref());
                s2.prefill(&toks[..mid]);
                check(s2.prefill(&toks[mid..]), "split prefill");

                // token-by-token stepping
                let mut s3 = DecodeSession::new(model.as_ref());
                s3.prefill(&toks[..1]);
                for &t in &toks[1..] {
                    s3.step(t);
                }
                check(s3.last_logits(), "token-by-token");
            }
        }

        // session continuation scoring matches the full-forward oracle
        let ctx: Vec<u32> = (0..12).map(|i| (i * 7 % 47) as u32).collect();
        let cont = [3u32, 19, 8];
        let a = model.continuation_logprob(&ctx, &cont);
        let b = model.continuation_logprob_full(&ctx, &cont);
        assert!((a - b).abs() < 1e-5, "{label}: {a} vs {b}");
    }
}

/// Serving-engine acceptance: a batched engine over B ∈ {2, 4, 7}
/// mixed-length greedy streams reproduces B independent `DecodeSession`s
/// — identical token streams and final logits within 1e-5 — for both
/// families × all three weight layouts.
#[test]
fn engine_batch_matches_independent_sessions() {
    use apt::model::DecodeSession;
    use apt::serve::{Engine, EngineConfig, Request};

    for (label, model) in &layout_variants() {
        for &bsz in &[2usize, 4, 7] {
            // mixed prompt lengths and generation budgets per stream
            let prompts: Vec<Vec<u32>> = (0..bsz)
                .map(|i| (0..2 + (i * 5) % 11 + i).map(|j| ((j * 3 + i * 7) % 47) as u32).collect())
                .collect();
            let gens: Vec<usize> = (0..bsz).map(|i| 3 + i % 4).collect();

            let mut eng =
                Engine::new(model.as_ref(), EngineConfig { max_batch: bsz, ..Default::default() });
            for i in 0..bsz {
                eng.submit(Request::greedy(prompts[i].clone(), gens[i]));
            }
            eng.run();
            let mut done = eng.take_finished();
            assert_eq!(done.len(), bsz, "{label} B={bsz}");
            done.sort_by_key(|c| c.id);

            for i in 0..bsz {
                let mut s = DecodeSession::new(model.as_ref());
                s.prefill(&prompts[i]);
                let toks = s.generate(gens[i]);
                assert_eq!(done[i].tokens, toks, "{label} B={bsz} stream {i}");
                for (a, b) in done[i].last_logits.iter().zip(s.last_logits()) {
                    assert!(
                        (a - b).abs() < 1e-5,
                        "{label} B={bsz} stream {i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

/// Paged-K/V acceptance: at context lengths that cross multiple 64-row
/// page boundaries, the incremental session still reproduces the
/// full-forward oracle to <1e-5 (exact in practice) — both families ×
/// Dense/Csr16/Packed24, one-shot and split prefill.
#[test]
fn paged_kv_matches_full_forward_across_page_boundaries() {
    use apt::model::DecodeSession;

    // 150 tokens: crosses the 64-row page boundary at 64 and 128, ends
    // mid-page; the split at 100 lands inside the second page.
    let t_len = 150usize;
    for (label, model) in &layout_variants() {
        let mut rng = Rng::new(130);
        let toks: Vec<u32> = (0..t_len).map(|_| rng.below(47) as u32).collect();

        let mut x = model.embed_tokens(&toks);
        for b in 0..model.n_blocks() {
            x = model.forward_block(b, &x, (1, toks.len()));
        }
        let want = model.logits_last(&x);

        let check = |got: &[f32], how: &str| {
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "{label} {how}: {g} vs {w}");
            }
        };
        let mut s = DecodeSession::new(model.as_ref());
        check(s.prefill(&toks), "one-shot prefill");
        // split prefill: the continuation chunk enters through the
        // incremental arm against a partially-filled page
        let mut s2 = DecodeSession::new(model.as_ref());
        s2.prefill(&toks[..100]);
        check(s2.prefill(&toks[100..]), "split prefill");
    }
}

/// Page-eviction boundary cases through the serving surfaces: windows
/// equal to the page size (64), smaller than a page, and not a multiple
/// of the page size, under sustained eviction (prompt + generation ≫
/// window). The engine's batch arm and the windowed single-stream
/// session must agree token-for-token, and the cache must stay bounded.
#[test]
fn paged_eviction_window_boundary_cases() {
    use apt::model::DecodeSession;
    use apt::serve::{Engine, EngineConfig, Request};

    for (label, model) in &layout_variants() {
        // 64 == page size; 50 and 100 straddle it without dividing it
        for &w in &[64usize, 50, 100] {
            let prompt: Vec<u32> = (0..120).map(|i| ((i * 5 + 3) % 47) as u32).collect();
            let gen = 40usize;
            let mut eng =
                Engine::new(model.as_ref(), EngineConfig { max_batch: 2, max_seq: Some(w), ..Default::default() });
            eng.submit(Request::greedy(prompt.clone(), gen));
            while eng.has_work() {
                eng.step();
            }
            let c = eng.take_finished().remove(0);
            assert_eq!(c.tokens.len(), gen, "{label} w={w}");

            let mut s = DecodeSession::with_window(model.as_ref(), w);
            s.prefill(&prompt);
            assert_eq!(s.generate(gen), c.tokens, "{label} w={w}");
            assert!(s.len() == prompt.len() + gen, "{label} w={w}");
        }
    }
}

/// Packed cross-request admission reproduces per-request prefills: a
/// burst of mixed-length prompts admitted in one step must generate
/// exactly what independent sessions generate (the padded Full-arm pass
/// is bit-identical per stream), including under a window and for the
/// prefill-only (zero-budget) completions whose logits come from the
/// batched (B, V) matmul.
#[test]
fn packed_prefill_admission_matches_independent_sessions() {
    use apt::model::DecodeSession;
    use apt::serve::{Engine, EngineConfig, Request};

    for (label, model) in &layout_variants() {
        for max_seq in [None, Some(32usize)] {
            let prompts: Vec<Vec<u32>> = (0..5)
                .map(|i| (0..3 + i * 9).map(|j| ((j * 3 + i * 7) % 47) as u32).collect())
                .collect();
            // i = 3 ⇒ 30 tokens ≤ window; i = 4 ⇒ 39 tokens > window,
            // forcing the per-request windowed fallback inside a packed
            // admission burst
            let mut eng = Engine::new(model.as_ref(), EngineConfig { max_batch: 8, max_seq, ..Default::default() });
            for p in &prompts {
                eng.submit(Request::greedy(p.clone(), 4));
            }
            eng.submit(Request::greedy(prompts[1].clone(), 0)); // prefill-only
            eng.run();
            let mut done = eng.take_finished();
            done.sort_by_key(|c| c.id);
            assert_eq!(done.len(), 6, "{label}");

            for (i, p) in prompts.iter().enumerate() {
                let mut s = match max_seq {
                    Some(w) => DecodeSession::with_window(model.as_ref(), w),
                    None => DecodeSession::new(model.as_ref()),
                };
                s.prefill(p);
                if i == 1 {
                    // the zero-budget completion carries the prompt logits
                    for (a, b) in done[5].last_logits.iter().zip(s.last_logits()) {
                        assert!((a - b).abs() < 1e-5, "{label} prefill-only: {a} vs {b}");
                    }
                }
                assert_eq!(done[i].tokens, s.generate(4), "{label} stream {i}");
            }
        }
    }
}

/// Seeded sampling through the engine is reproducible (same seed → same
/// tokens) and seed-sensitive (different seeds diverge), independent of
/// what else shares the batch.
#[test]
fn engine_seeded_sampling_deterministic_across_batches() {
    use apt::serve::{Engine, EngineConfig, Request, SamplingParams};

    let gen = CorpusGen::new(60, 2, 38);
    let model = trained_model(&gen, 32, 2, 20);
    let prompt: Vec<u32> = (0..8).map(|i| (i * 3 % 50) as u32).collect();

    let run = |seed: u64, with_mates: bool| -> Vec<u32> {
        let mut eng = Engine::new(&model, EngineConfig::default());
        let id = eng.submit(Request {
            prompt: prompt.clone(),
            max_new_tokens: 10,
            sampling: SamplingParams::temperature(1.3, seed),
        });
        if with_mates {
            eng.submit(Request::greedy((0..5).map(|i| (i % 50) as u32).collect(), 10));
            eng.submit(Request {
                prompt: (0..3).map(|i| ((i * 9) % 50) as u32).collect(),
                max_new_tokens: 10,
                sampling: SamplingParams::top_k(5, 0.9, seed ^ 0xff),
            });
        }
        eng.run();
        let done = eng.take_finished();
        done.into_iter().find(|c| c.id == id).expect("completed").tokens
    };

    assert_eq!(run(3, false), run(3, false), "same seed must reproduce");
    assert_eq!(run(3, false), run(3, true), "batch mates must not perturb the stream");
    assert_ne!(run(3, false), run(4, false), "different seeds should diverge");
}

/// Zero-shot regression: the session-routed suite reproduces the
/// full-forward path's accuracy on every metric.
#[test]
fn zeroshot_suite_matches_full_forward_path() {
    use apt::data::{TaskGen, TaskKind};
    use apt::eval::{choice_accuracy, lambada_accuracy};
    use apt::model::log_softmax_at;

    let gen = CorpusGen::new(70, 2, 37);
    let model = trained_model(&gen, 32, 2, 80);

    let tg = TaskGen::new(&gen);
    let tasks = tg.choice_suite(TaskKind::HellaSwagLike, 40, 1);
    let acc_session = choice_accuracy(&model, &tasks);
    // reference: same selection rule, quadratic full-forward scoring
    let mut correct = 0usize;
    for t in &tasks {
        let mut best = 0usize;
        let mut best_lp = f64::NEG_INFINITY;
        for (i, cand) in t.candidates.iter().enumerate() {
            let lp = model.continuation_logprob_full(&t.context, cand)
                / cand.len().max(1) as f64;
            if lp > best_lp {
                best_lp = lp;
                best = i;
            }
        }
        if best == t.answer {
            correct += 1;
        }
    }
    let acc_full = correct as f64 / tasks.len() as f64;
    assert!(
        (acc_session - acc_full).abs() < 1e-12,
        "choice: session {acc_session} vs full {acc_full}"
    );

    let lt = tg.lambada_suite(30, 2);
    let acc_session = lambada_accuracy(&model, &lt);
    let mut correct = 0usize;
    for t in &lt {
        if model.predict_last_full(&t.context) == t.answer {
            correct += 1;
        }
    }
    let acc_full = correct as f64 / lt.len() as f64;
    assert!(
        (acc_session - acc_full).abs() < 1e-12,
        "lambada: session {acc_session} vs full {acc_full}"
    );

    // and the per-position session logprobs agree with the perplexity
    // path's full-forward numbers on one window
    let toks: Vec<u32> = (0..24).map(|i| (i * 11 % 50) as u32).collect();
    let full_lp = model.next_token_logprobs(&toks, (1, toks.len()));
    let mut s = apt::model::DecodeSession::new(&model);
    s.prefill(&toks[..1]);
    for (i, &tok) in toks[1..].iter().enumerate() {
        let lp = log_softmax_at(s.last_logits(), tok as usize);
        assert!((lp - full_lp[i]).abs() < 1e-5, "pos {i}: {lp} vs {}", full_lp[i]);
        s.step(tok);
    }
}

#[test]
fn failure_injection_bad_calibration() {
    // Degenerate calibration (constant tokens -> rank-1 activations) must
    // not crash: dampening escalation handles the singular Hessian.
    let gen = CorpusGen::new(60, 2, 35);
    let model = trained_model(&gen, 32, 1, 10);
    let calib: Vec<Vec<u32>> = (0..4).map(|_| vec![5u32; 32]).collect();
    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SM,
        Sparsity::Unstructured { rate: 0.5 },
    ));
    let report = prune_model(&mut pruned, &calib, &cfg, None).unwrap();
    assert!((report.overall_sparsity() - 0.5).abs() < 0.03);
    for l in &report.linears {
        assert!(l.pred_loss.is_finite());
    }
}

/// Dense target + same-weight pruned drafts for the speculative grid:
/// per family, the dense model and one draft per sparse layout {csr,
/// csr16, packed24}, all pruned from the SAME initial weights (csr is
/// forced to u32 indices — `WeightStore::pack` would auto-select csr16
/// at these widths).
#[allow(clippy::type_complexity)]
fn spec_model_grid(
) -> Vec<(String, Box<dyn LanguageModel>, Vec<(String, Box<dyn LanguageModel>)>)> {
    use apt::model::{Mamba, MambaConfig, BLOCK_LINEARS, MAMBA_LINEARS};
    use apt::sparse::Csr;

    let tcfg = TransformerConfig {
        vocab: 47,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 256,
    };
    let mcfg = MambaConfig { vocab: 47, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 256 };
    let layouts = [
        ("csr", Sparsity::Unstructured { rate: 0.6 }),
        ("csr16", Sparsity::Unstructured { rate: 0.6 }),
        ("packed24", Sparsity::two_four()),
    ];
    let mut out: Vec<(String, Box<dyn LanguageModel>, Vec<(String, Box<dyn LanguageModel>)>)> =
        Vec::new();

    let dense_t = Transformer::init(tcfg, &mut Rng::new(51));
    let mut t_drafts: Vec<(String, Box<dyn LanguageModel>)> = Vec::new();
    for (layout, sp) in layouts {
        let mut d = Transformer { cfg: dense_t.cfg, params: dense_t.params.clone() };
        for b in 0..tcfg.n_layers {
            for name in BLOCK_LINEARS {
                magnitude_prune(d.weight_mut(b, name).dense_mut(), sp);
                let w = d.weight(b, name).to_dense();
                *d.weight_mut(b, name) = if layout == "csr" {
                    WeightStore::Csr(Csr::from_dense(&w))
                } else {
                    WeightStore::pack(&w, sp)
                };
                assert_eq!(d.weight(b, name).format(), layout, "{name}");
            }
        }
        t_drafts.push((layout.to_string(), Box::new(d)));
    }
    out.push(("microllama".to_string(), Box::new(dense_t), t_drafts));

    let dense_m = Mamba::init(mcfg, &mut Rng::new(52));
    let mut m_drafts: Vec<(String, Box<dyn LanguageModel>)> = Vec::new();
    for (layout, sp) in layouts {
        let mut d = Mamba { cfg: dense_m.cfg, params: dense_m.params.clone() };
        for b in 0..mcfg.n_layers {
            for name in MAMBA_LINEARS {
                magnitude_prune(d.weight_mut(b, name).dense_mut(), sp);
                let w = d.weight(b, name).to_dense();
                *d.weight_mut(b, name) = if layout == "csr" {
                    WeightStore::Csr(Csr::from_dense(&w))
                } else {
                    WeightStore::pack(&w, sp)
                };
                assert_eq!(d.weight(b, name).format(), layout, "{name}");
            }
        }
        m_drafts.push((layout.to_string(), Box::new(d)));
    }
    out.push(("micromamba".to_string(), Box::new(dense_m), m_drafts));
    out
}

/// ISSUE 6 lossless gate: speculative output is bit-identical
/// token-for-token to plain greedy dense decoding for both model
/// families × every draft layout {Csr, Csr16, Packed24} × every
/// k ∈ {1, 2, 4, 8}. The drafts are pruned from the same weights as the
/// target, so proposals agree often but not always — both the accept
/// and the rollback paths run.
#[test]
fn speculative_generate_matches_plain_greedy() {
    use apt::model::DecodeSession;
    use apt::serve::speculative::SpecSession;

    for (family, target, drafts) in &spec_model_grid() {
        let prompt: Vec<u32> = (0..9).map(|i| ((i * 11 + 5) % 47) as u32).collect();
        let mut plain = DecodeSession::new(target.as_ref());
        plain.prefill(&prompt);
        let want = plain.generate(24);
        for (layout, draft) in drafts {
            for k in [1usize, 2, 4, 8] {
                let mut s = SpecSession::new(target.as_ref(), draft.as_ref(), k);
                s.prefill(&prompt);
                let got = s.generate(24);
                assert_eq!(got, want, "{family} draft={layout} k={k}");
                let st = *s.stats();
                assert_eq!(st.emitted, 24, "{family} draft={layout} k={k}");
                // a round emits at most k + 1 tokens, so at least
                // ceil(24 / (k + 1)) rounds ran
                assert!(
                    st.rounds >= 24usize.div_ceil(k + 1),
                    "{family} draft={layout} k={k}: {} rounds",
                    st.rounds
                );
                assert!(st.accepted <= st.proposed, "{family} draft={layout} k={k}");
            }
        }
    }
}

/// The lossless gate holds under a sliding `max_seq` window too: a
/// windowed transformer target verifies token-by-token (batched appends
/// would attend evicted rows), a windowed mamba target still batches —
/// both must reproduce the plain windowed session exactly, including
/// with real eviction (prompt + generation ≫ window).
#[test]
fn speculative_windowed_target_matches_plain_windowed() {
    use apt::model::DecodeSession;
    use apt::serve::speculative::SpecSession;

    for (family, target, drafts) in &spec_model_grid() {
        for w in [10usize, 64] {
            let prompt: Vec<u32> = (0..20).map(|i| ((i * 7 + 3) % 47) as u32).collect();
            let mut plain = DecodeSession::with_window(target.as_ref(), w);
            plain.prefill(&prompt);
            let want = plain.generate(20);
            for (layout, draft) in drafts {
                let mut s =
                    SpecSession::with_window(target.as_ref(), draft.as_ref(), 4, w);
                s.prefill(&prompt);
                assert_eq!(s.generate(20), want, "{family} draft={layout} w={w}");
            }
        }
    }
}

/// End-to-end "prune → keep both → serve speculatively": the coordinator
/// prunes a copy of the trained dense model into a draft
/// (`prune_draft_model`), the speculative engine serves a greedy batch
/// against the dense engine baseline (`spec_serve_report` asserts the
/// outputs bit-identical), and the eval-side agreement predictor is
/// consistent with a trained-draft setup.
#[test]
fn engine_speculative_end_to_end_prune_then_serve() {
    use apt::coordinator::prune_draft_model;
    use apt::eval::greedy_agreement;
    use apt::serve::speculative::spec_serve_report;
    use apt::serve::EngineConfig;

    let gen = CorpusGen::new(60, 2, 34);
    let target = trained_model(&gen, 32, 2, 30);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(4, 32, &mut Rng::new(8));
    let mut draft = Transformer { cfg: target.cfg, params: target.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SS,
        Sparsity::Unstructured { rate: 0.5 },
    ));
    let report = prune_draft_model(&target, &mut draft, &calib, &cfg, None).unwrap();
    assert!((report.overall_sparsity() - 0.5).abs() < 0.03);

    let v = gen.tokenizer.vocab_size() as u32;
    let prompts: Vec<Vec<u32>> = (0..4)
        .map(|i| (0..6 + i).map(|j| ((j * 5 + i * 3) as u32) % v).collect())
        .collect();
    let r = spec_serve_report(
        &target,
        &draft,
        &prompts,
        12,
        4,
        EngineConfig { max_batch: 3, ..Default::default() },
    );
    assert_eq!(r.total_tokens, 48);
    assert!(r.rounds > 0);
    assert!((0.0..=1.0).contains(&r.acceptance_rate));
    assert!(r.tokens_per_round >= 1.0);

    // offline acceptance predictor runs on the same pair
    let ws: Vec<&[u32]> = calib.iter().map(|c| c.as_slice()).collect();
    let agree = greedy_agreement(&target, &draft, &ws);
    assert!((0.0..=1.0).contains(&agree), "agreement {agree}");
}

#[test]
fn mismatched_runtime_shapes_fall_back_to_native() {
    if cfg!(not(feature = "pjrt")) {
        return;
    }
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::load(dir).unwrap();
    let gen = CorpusGen::new(60, 2, 36);
    // d=40: no artifact covers these shapes -> native fallback everywhere
    let model = trained_model(&gen, 40, 1, 10);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(4, 32, &mut Rng::new(10));
    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(Method::SM, Sparsity::two_four()))
        .with_engine(Backend::Hlo);
    let report = prune_model(&mut pruned, &calib, &cfg, Some(&rt)).unwrap();
    assert_eq!(report.hlo_fraction(), 0.0);
    assert!((report.overall_sparsity() - 0.5).abs() < 0.02);
}

// ---------------------------------------------------------------------------
// structured pruning: reduced-shape stores end to end
// ---------------------------------------------------------------------------

fn rand_calib(n: usize, len: usize, vocab: usize, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..len).map(|_| rng.below(vocab) as u32).collect()).collect()
}

/// Tentpole oracle gate: the physically reduced model reproduces the
/// masked full-shape oracle to <1e-5 at the logits, for both families.
/// The masked run makes byte-identical keep decisions on the same
/// calibration set and leaves exact zeros in the dropped consumer
/// columns, so the only difference is the dense-matmul shape — dropped
/// columns contribute exact-zero terms the reduced matmul simply skips.
#[test]
fn structured_reduced_matches_masked_oracle_both_families() {
    use apt::coordinator::{structured_prune_mamba, structured_prune_transformer};
    use apt::model::{Mamba, MambaConfig};
    use apt::prune::StructuredConfig;

    let probe: Vec<u32> = (0..20).map(|i| ((i * 13 + 2) % 47) as u32).collect();
    let cfg = StructuredConfig::new(0.5);
    let mcfg_masked = StructuredConfig { masked: true, ..cfg };

    // --- transformer
    let tcfg = TransformerConfig {
        vocab: 47,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 64,
    };
    let base = Transformer::init(tcfg, &mut Rng::new(61));
    let calib = rand_calib(6, 24, 47, 62);
    let mut reduced = Transformer { cfg: base.cfg, params: base.params.clone() };
    let rep = structured_prune_transformer(&mut reduced, &calib, &cfg).unwrap();
    assert!((rep.flops_ratio() - 0.5).abs() < 1e-9, "{}", rep.flops_ratio());
    let mut masked = Transformer { cfg: base.cfg, params: base.params.clone() };
    let mrep = structured_prune_transformer(&mut masked, &calib, &mcfg_masked).unwrap();
    assert_eq!(mrep.flops_ratio(), 1.0, "masked run keeps full shapes");
    assert_eq!(reduced.weight(0, "wq").shape(), (8, 16), "half the heads");
    assert_eq!(masked.weight(0, "wq").shape(), (16, 16));
    let a = reduced.next_token_logprobs(&probe, (1, probe.len()));
    let b = masked.next_token_logprobs(&probe, (1, probe.len()));
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-5, "transformer: {x} vs {y}");
    }

    // --- mamba
    let mcfg = MambaConfig { vocab: 47, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 64 };
    let base = Mamba::init(mcfg, &mut Rng::new(63));
    let calib = rand_calib(6, 24, 47, 64);
    let mut reduced = Mamba { cfg: base.cfg, params: base.params.clone() };
    let rep = structured_prune_mamba(&mut reduced, &calib, &cfg).unwrap();
    assert!(rep.flops_ratio() < 0.65, "{}", rep.flops_ratio());
    let mut masked = Mamba { cfg: base.cfg, params: base.params.clone() };
    structured_prune_mamba(&mut masked, &calib, &mcfg_masked).unwrap();
    assert_eq!(reduced.weight(0, "out_proj").shape(), (12, 10), "half the channels");
    assert_eq!(masked.weight(0, "out_proj").shape(), (12, 20));
    let a = reduced.next_token_logprobs(&probe, (1, probe.len()));
    let b = masked.next_token_logprobs(&probe, (1, probe.len()));
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-5, "mamba: {x} vs {y}");
    }
}

/// Structured-pruned copies of both families at keep 0.5 for the
/// serving / speculative / checkpoint gates below. Every consumer and
/// producer linear must actually land in the reduced-dense store.
fn structured_variants() -> Vec<(String, Box<dyn LanguageModel>)> {
    use apt::coordinator::{structured_prune_mamba, structured_prune_transformer};
    use apt::model::{Mamba, MambaConfig};
    use apt::prune::StructuredConfig;

    let cfg = StructuredConfig::new(0.5);
    let tcfg = TransformerConfig {
        vocab: 47,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 256,
    };
    let mut t = Transformer::init(tcfg, &mut Rng::new(71));
    structured_prune_transformer(&mut t, &rand_calib(6, 24, 47, 72), &cfg).unwrap();
    for b in 0..tcfg.n_layers {
        for name in ["wq", "wk", "wv", "wo", "w1", "w2", "w3"] {
            assert_eq!(t.weight(b, name).format(), "dense_reduced", "block {b} {name}");
        }
    }

    let mcfg = MambaConfig { vocab: 47, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 256 };
    let mut m = Mamba::init(mcfg, &mut Rng::new(73));
    structured_prune_mamba(&mut m, &rand_calib(6, 24, 47, 74), &cfg).unwrap();
    for b in 0..mcfg.n_layers {
        for name in ["in_proj", "dt_proj", "out_proj"] {
            assert_eq!(m.weight(b, name).format(), "dense_reduced", "block {b} {name}");
        }
    }

    vec![
        ("microllama/structured".to_string(), Box::new(t)),
        ("micromamba/structured".to_string(), Box::new(m)),
    ]
}

/// Serving gate: structured-pruned models run the whole decode surface
/// unchanged — incremental sessions reproduce the full quadratic
/// forward to <1e-5 (split prefill and token-by-token stepping
/// included), and a batched engine reproduces independent sessions
/// token-for-token.
#[test]
fn structured_model_decode_and_engine_match_full_forward() {
    use apt::model::DecodeSession;
    use apt::serve::{Engine, EngineConfig, Request};

    for (label, model) in &structured_variants() {
        let mut rng = Rng::new(75);
        let toks: Vec<u32> = (0..24).map(|_| rng.below(47) as u32).collect();
        let mut x = model.embed_tokens(&toks);
        for b in 0..model.n_blocks() {
            x = model.forward_block(b, &x, (1, toks.len()));
        }
        let want = model.logits_last(&x);
        let check = |got: &[f32], how: &str| {
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "{label} {how}: {g} vs {w}");
            }
        };
        let mut s = DecodeSession::new(model.as_ref());
        check(s.prefill(&toks), "one-shot prefill");
        let mut s2 = DecodeSession::new(model.as_ref());
        s2.prefill(&toks[..11]);
        check(s2.prefill(&toks[11..]), "split prefill");
        let mut s3 = DecodeSession::new(model.as_ref());
        s3.prefill(&toks[..1]);
        for &t in &toks[1..] {
            s3.step(t);
        }
        check(s3.last_logits(), "token-by-token");

        // batched engine vs independent sessions
        let bsz = 3usize;
        let prompts: Vec<Vec<u32>> = (0..bsz)
            .map(|i| (0..3 + i * 4).map(|j| ((j * 3 + i * 7) % 47) as u32).collect())
            .collect();
        let mut eng =
            Engine::new(model.as_ref(), EngineConfig { max_batch: bsz, ..Default::default() });
        for p in &prompts {
            eng.submit(Request::greedy(p.clone(), 5));
        }
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), bsz, "{label}");
        for (i, p) in prompts.iter().enumerate() {
            let mut s = DecodeSession::new(model.as_ref());
            s.prefill(p);
            assert_eq!(done[i].tokens, s.generate(5), "{label} stream {i}");
        }
    }
}

/// Speculative gate: a structured-pruned draft proposes for its own
/// dense source weights and the output stays bit-identical to plain
/// greedy decoding, per family; the serve-level report runs the same
/// pair through batched engines.
#[test]
fn speculative_structured_draft_matches_plain_greedy() {
    use apt::coordinator::{structured_prune_mamba, structured_prune_transformer};
    use apt::model::{DecodeSession, Mamba, MambaConfig};
    use apt::prune::StructuredConfig;
    use apt::serve::speculative::{spec_serve_report, SpecSession};
    use apt::serve::EngineConfig;

    let cfg = StructuredConfig::new(0.5);
    let tcfg = TransformerConfig {
        vocab: 47,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 256,
    };
    let target_t = Transformer::init(tcfg, &mut Rng::new(81));
    let mut draft_t = Transformer { cfg: target_t.cfg, params: target_t.params.clone() };
    structured_prune_transformer(&mut draft_t, &rand_calib(6, 24, 47, 82), &cfg).unwrap();

    let mcfg = MambaConfig { vocab: 47, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 256 };
    let target_m = Mamba::init(mcfg, &mut Rng::new(83));
    let mut draft_m = Mamba { cfg: target_m.cfg, params: target_m.params.clone() };
    structured_prune_mamba(&mut draft_m, &rand_calib(6, 24, 47, 84), &cfg).unwrap();

    let pairs: Vec<(&str, &dyn LanguageModel, &dyn LanguageModel)> = vec![
        ("microllama", &target_t, &draft_t),
        ("micromamba", &target_m, &draft_m),
    ];
    for (family, target, draft) in pairs {
        let prompt: Vec<u32> = (0..9).map(|i| ((i * 11 + 5) % 47) as u32).collect();
        let mut plain = DecodeSession::new(target);
        plain.prefill(&prompt);
        let want = plain.generate(24);
        for k in [2usize, 4] {
            let mut s = SpecSession::new(target, draft, k);
            s.prefill(&prompt);
            assert_eq!(s.generate(24), want, "{family} k={k}");
            assert_eq!(s.stats().emitted, 24, "{family} k={k}");
        }
        let prompts: Vec<Vec<u32>> = (0..3)
            .map(|i| (0..5 + i).map(|j| ((j * 5 + i * 3) % 47) as u32).collect())
            .collect();
        let r = spec_serve_report(
            target,
            draft,
            &prompts,
            8,
            4,
            EngineConfig { max_batch: 3, ..Default::default() },
        );
        assert_eq!(r.total_tokens, 24, "{family}");
        assert!((0.0..=1.0).contains(&r.acceptance_rate), "{family}");
    }
}

/// Checkpoint gate: reduced-shape stores survive the ATS2 round-trip
/// for both families — layouts, kept-index maps and behaviour exactly.
#[test]
fn structured_checkpoint_roundtrip_both_families() {
    use apt::coordinator::{structured_prune_mamba, structured_prune_transformer};
    use apt::model::{Mamba, MambaConfig};
    use apt::prune::StructuredConfig;

    let dir = std::env::temp_dir().join("apt_integration_structured");
    std::fs::create_dir_all(&dir).unwrap();
    let toks: Vec<u32> = (0..20).map(|i| (i * 7 % 47) as u32).collect();
    let cfg = StructuredConfig::new(0.5);

    // --- transformer
    let tcfg = TransformerConfig {
        vocab: 47,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 24,
        max_seq: 64,
    };
    let mut t = Transformer::init(tcfg, &mut Rng::new(71));
    structured_prune_transformer(&mut t, &rand_calib(6, 24, 47, 72), &cfg).unwrap();
    let path = dir.join("structured_t.ats");
    t.save(&path).unwrap();
    let loaded = Transformer::load(t.cfg, &path).unwrap();
    for name in loaded.params.names() {
        assert_eq!(loaded.params.get(name).unwrap(), t.params.get(name).unwrap());
    }
    assert_eq!(loaded.weight(0, "wo").format(), "dense_reduced");
    assert_eq!(loaded.weight(0, "wo").shape(), (16, 8), "physical shape");
    assert_eq!(loaded.weight(0, "wo").n_params(), 16 * 16, "logical geometry");
    assert_eq!(
        t.forward_loss(&toks, (1, toks.len())),
        loaded.forward_loss(&toks, (1, toks.len())),
        "transformer behaviour must survive exactly"
    );
    assert!(loaded.params.bytes() < loaded.params.dense_bytes());
    std::fs::remove_file(&path).ok();

    // --- mamba
    let mcfg = MambaConfig { vocab: 47, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 64 };
    let mut m = Mamba::init(mcfg, &mut Rng::new(73));
    structured_prune_mamba(&mut m, &rand_calib(6, 24, 47, 74), &cfg).unwrap();
    let path = dir.join("structured_m.ats");
    m.save(&path).unwrap();
    let loaded = Mamba::load(m.cfg, &path).unwrap();
    for name in loaded.params.names() {
        assert_eq!(loaded.params.get(name).unwrap(), m.params.get(name).unwrap());
    }
    assert_eq!(loaded.weight(0, "dt_proj").format(), "dense_reduced");
    assert_eq!(loaded.weight(0, "dt_proj").shape(), (10, 10), "physical shape");
    assert_eq!(loaded.weight(0, "dt_proj").n_params(), 20 * 20, "logical geometry");
    assert_eq!(
        m.forward_loss(&toks, (1, toks.len())),
        loaded.forward_loss(&toks, (1, toks.len())),
        "mamba behaviour must survive exactly"
    );
    assert!(loaded.params.bytes() < loaded.params.dense_bytes());
    std::fs::remove_file(&path).ok();
}

/// Eval gate: the full "train → structured prune → eval" path — the
/// report carries per-block kept counts and the achieved FLOPs ratio,
/// and perplexity runs straight off the reduced layouts.
#[test]
fn structured_prune_then_eval_end_to_end() {
    use apt::coordinator::structured_prune_transformer;
    use apt::prune::StructuredConfig;

    let gen = CorpusGen::new(60, 2, 39);
    let model = trained_model(&gen, 32, 2, 40);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(6, 32, &mut Rng::new(12));

    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let rep = structured_prune_transformer(&mut pruned, &calib, &StructuredConfig::new(0.5))
        .unwrap();
    assert_eq!(rep.blocks.len(), 2);
    for b in &rep.blocks {
        assert_eq!(b.kept_heads, Some((1, 2)));
        assert_eq!(b.kept_ffn, Some((32, 64)));
        assert_eq!(b.kept_channels, None);
    }
    assert!((rep.flops_ratio() - 0.5).abs() < 1e-9);
    assert!(rep.to_json().to_string().contains("kept_heads"));

    let eval_data = gen.generate(Profile::Wt2Like, 2_048, 3);
    let ppl = perplexity(&pruned, &eval_data, 64);
    let ppl_dense = perplexity(&model, &eval_data, 64);
    assert!(ppl.is_finite() && ppl > 1.0, "structured ppl {ppl}");
    // half the heads and channels hurt, but reconstruction keeps the
    // model in the same regime as its dense source
    assert!(ppl < ppl_dense * 30.0, "structured {ppl} vs dense {ppl_dense}");
}

/// Resilience acceptance: a scripted fault plan (one NaN quarantine, one
/// forced preemption) against a 4-stream batch must leave every UNTOUCHED
/// stream bit-identical to a fault-free run — across both families × all
/// weight layouts (Dense, Csr16, Packed24, DenseReduced). The preempted
/// stream must still finish with its exact fault-free output (recompute
/// preemption is lossless), and the poisoned stream must retire early
/// with a typed error and a verified prefix. This is the blast-radius
/// invariant `serve::faults` documents.
#[test]
fn resilience_fault_grid_spares_untouched_streams() {
    use apt::serve::faults::FaultPlan;
    use apt::serve::{
        Completion, Engine, EngineConfig, EngineStats, ErrorKind, FinishReason, Request,
    };

    let mut models = layout_variants();
    models.extend(structured_variants());
    for (label, model) in &models {
        let run = |plan: FaultPlan| -> (Vec<Completion>, EngineStats) {
            let mut eng = Engine::new(model.as_ref(), EngineConfig::default());
            for i in 0..4usize {
                let p: Vec<u32> =
                    (0..4 + i * 2).map(|j| ((j * 3 + i * 7) % 47) as u32).collect();
                eng.submit(Request::greedy(p, 8));
            }
            eng.set_fault_plan(plan);
            eng.run();
            let mut done = eng.take_finished();
            done.sort_by_key(|c| c.id);
            (done, eng.stats())
        };
        let (base, base_st) = run(FaultPlan::new());
        assert_eq!(base_st.quarantined, 0, "{label}");
        assert_eq!(base_st.preemptions, 0, "{label}");
        let plan =
            FaultPlan::new().nan_logits(base[1].id, 2).force_preempt(base[2].id, 2);
        let touched = plan.touched();
        let (done, st) = run(plan);
        assert_eq!(st.quarantined, 1, "{label}");
        assert_eq!(st.preemptions, 1, "{label}");
        assert_eq!(done.len(), 4, "{label}");
        // blast radius: streams the plan never touched are bit-identical
        for (c, b) in done.iter().zip(&base) {
            if touched.contains(&c.id) {
                continue;
            }
            assert_eq!(c.tokens, b.tokens, "{label}: untouched {:?} diverged", c.id);
            assert_eq!(c.last_logits, b.last_logits, "{label}: untouched {:?}", c.id);
            assert_eq!(c.finish, FinishReason::Length, "{label}");
        }
        // the preempted stream was evicted and recomputed — losslessly
        assert_eq!(done[2].tokens, base[2].tokens, "{label}: preemption must be invisible");
        assert_eq!(done[2].finish, FinishReason::Length, "{label}");
        // the poisoned stream retires early, typed, with a verified prefix
        assert_eq!(
            done[1].finish,
            FinishReason::Error(ErrorKind::NonFiniteLogits),
            "{label}"
        );
        let n = done[1].tokens.len();
        assert!((2..8).contains(&n), "{label}: quarantine point {n}");
        assert_eq!(done[1].tokens[..], base[1].tokens[..n], "{label}: poisoned prefix");
        assert!(
            done[1].last_logits.iter().any(|v| !v.is_finite()),
            "{label}: poisoned evidence must ride out in the completion"
        );
    }
}

/// Budget acceptance across layouts: a 4-page budget (one stream's worth
/// for these 2-layer transformers) serializes a 3-stream workload that
/// would otherwise hold 12 pages at once — every request still completes
/// with its exact solo output and the live-page bound holds after every
/// step. Mamba models hold no K/V pages, so the same config leaves them
/// fully batched (the budget is a no-op, not a throttle).
#[test]
fn resilience_page_budget_completes_over_budget_workload() {
    use apt::model::DecodeSession;
    use apt::serve::{Engine, EngineConfig, FinishReason, Request};

    let mut models = layout_variants();
    models.extend(structured_variants());
    for (label, model) in &models {
        let mut eng = Engine::new(
            model.as_ref(),
            EngineConfig { max_batch: 4, max_kv_pages: Some(4), ..Default::default() },
        );
        let prompts: Vec<Vec<u32>> = (0..3)
            .map(|i| (0..5 + i).map(|j| ((j * 5 + i * 11) % 47) as u32).collect())
            .collect();
        for p in &prompts {
            eng.submit(Request::greedy(p.clone(), 6));
        }
        let is_tf = label.starts_with("microllama");
        while eng.has_work() {
            eng.step();
            assert!(eng.kv_pages_live() <= 4, "{label}: budget exceeded");
            if is_tf {
                assert!(eng.active() <= 1, "{label}: 4 pages must serialize streams");
            }
        }
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3, "{label}");
        for (i, c) in done.iter().enumerate() {
            assert_eq!(c.finish, FinishReason::Length, "{label}");
            let mut s = DecodeSession::new(model.as_ref());
            s.prefill(&prompts[i]);
            assert_eq!(c.tokens, s.generate(6), "{label} stream {i}");
        }
        assert_eq!(eng.stats().preemptions, 0, "{label}: admission gating suffices");
        let peak = eng.stats().kv_pages_peak;
        if is_tf {
            assert_eq!(peak, 4, "{label}");
        } else {
            assert_eq!(peak, 0, "{label}: mamba holds no pages");
        }
    }
}

// ---------------------------------------------------------------- HTTP front end

/// Poll `/metrics` until `pred` holds over the exposition text, up to
/// ~10s; panics with the last exposition on timeout so a failed wait
/// shows the actual ledger.
fn await_metrics(
    addr: std::net::SocketAddr,
    what: &str,
    pred: impl Fn(&str) -> bool,
) -> String {
    use apt::server::client;
    let mut last = String::new();
    for _ in 0..500 {
        if let Ok(m) = client::request(addr, "GET", "/metrics", None) {
            last = String::from_utf8_lossy(&m.body).into_owned();
            if pred(&last) {
                return last;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    panic!("timed out waiting for {what}; last /metrics:\n{last}");
}

#[test]
fn http_streamed_tokens_match_library_engine() {
    use apt::serve::{Engine, EngineConfig, Request, SamplingParams};
    use apt::server::{client, Server, ServerConfig};

    // a trained model so the distribution is peaked (greedy and seeded
    // sampling both have something real to disagree about)
    let gen = CorpusGen::new(60, 2, 31);
    let model = trained_model(&gen, 32, 2, 60);
    let vocab = gen.tokenizer.vocab_size();
    let prompt: Vec<u32> = (0..6).map(|i| ((i * 7 + 1) % vocab) as u32).collect();
    let sampled = SamplingParams { temperature: 0.7, top_k: Some(5), seed: 11 };

    // library path first (the server takes the model by value)
    let mut eng = Engine::new(&model, EngineConfig::default());
    eng.submit(Request::greedy(prompt.clone(), 8));
    eng.submit(Request { prompt: prompt.clone(), max_new_tokens: 8, sampling: sampled });
    eng.run();
    let mut done = eng.take_finished();
    done.sort_by_key(|c| c.id);
    let (expect_greedy, expect_sampled) = (done[0].tokens.clone(), done[1].tokens.clone());

    let h = Server::start(model, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let plist: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    let pjson = format!("[{}]", plist.join(","));

    // plain greedy over HTTP == greedy through the library Engine
    let body = format!(r#"{{"prompt": {pjson}, "max_new_tokens": 8}}"#);
    let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    let v = r.json().unwrap();
    assert_eq!(v.get("finish").unwrap().as_str(), Some("length"));
    let got: Vec<u32> = v
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as u32)
        .collect();
    assert_eq!(got, expect_greedy, "HTTP plain response != library engine");

    // streamed seeded sampling over HTTP == the library run, byte for
    // byte (seed and top_k thread through the JSON body intact)
    let body = format!(
        r#"{{"prompt": {pjson}, "max_new_tokens": 8, "temperature": 0.7, "top_k": 5, "seed": 11, "stream": true}}"#
    );
    let (status, chunks) = client::stream_request(h.addr(), "/v1/generate", &body).unwrap();
    assert_eq!(status, 200);
    let (toks, terminal) = client::split_stream(&chunks);
    assert_eq!(toks, expect_sampled, "HTTP stream != library engine");
    let terminal = terminal.expect("terminal chunk");
    assert_eq!(terminal.get("finish").unwrap().as_str(), Some("length"));
    assert_eq!(terminal.get("tokens_generated").unwrap().as_usize(), Some(8));

    // the metrics ledger agrees and the engine drained to zero pages
    let text = await_metrics(h.addr(), "2 completions", |t| {
        client::metric(t, "apt_engine_completions_total") == Some(2)
    });
    let get = |k: &str| client::metric(&text, k).unwrap_or_else(|| panic!("missing {k}"));
    assert_eq!(get("apt_engine_completions_length_total"), 2);
    assert_eq!(get("apt_engine_tokens_generated_total"), 16);
    assert_eq!(get("apt_engine_kv_pages_live"), 0);
    assert_eq!(get("apt_engine_streams_active"), 0);
    h.shutdown();
}

#[test]
fn http_stream_disconnect_cancels_and_frees_pages() {
    use apt::serve::EngineConfig;
    use apt::server::{client, Server, ServerConfig};

    let model = Transformer::init(
        TransformerConfig {
            vocab: 31,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
        },
        &mut Rng::new(9),
    );
    // windowed K/V so a huge token ask decodes indefinitely instead of
    // outgrowing max_seq — the cancel must be what stops it
    let cfg = ServerConfig {
        engine: EngineConfig { max_seq: Some(32), ..Default::default() },
        ..Default::default()
    };
    let h = Server::start(model, "127.0.0.1:0", cfg).unwrap();

    let body = r#"{"prompt": [1, 2, 3, 4], "max_new_tokens": 20000, "stream": true}"#;
    let mut st = client::open_stream(h.addr(), "/v1/generate", body).unwrap();
    assert_eq!(st.status, 200);
    for _ in 0..3 {
        assert!(st.next_chunk().unwrap().is_some(), "stream produced tokens");
    }
    drop(st); // client walks away mid-stream

    // the failed chunk write must cancel the engine request: exactly one
    // cancelled completion, and its K/V pages reclaim (live count drains
    // to zero long before 20k tokens could have decoded)
    let text = await_metrics(h.addr(), "disconnect cancel + page reclaim", |t| {
        client::metric(t, "apt_engine_completions_cancelled_total") == Some(1)
            && client::metric(t, "apt_engine_kv_pages_live") == Some(0)
    });
    let get = |k: &str| client::metric(&text, k).unwrap_or_else(|| panic!("missing {k}"));
    assert_eq!(get("apt_engine_completions_total"), 1);
    assert_eq!(get("apt_http_stream_disconnects_total"), 1);
    assert_eq!(get("apt_engine_streams_active"), 0);
    h.shutdown();
}

#[test]
fn http_backpressure_429_without_engine_state_leak() {
    use apt::server::{client, Server, ServerConfig};

    let model = Transformer::init(
        TransformerConfig {
            vocab: 31,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
        },
        &mut Rng::new(9),
    );
    let cfg = ServerConfig { max_pending: 2, ..Default::default() };
    let h = Server::start(model, "127.0.0.1:0", cfg).unwrap();
    let addr = h.addr();

    // freeze the engine (commands still answered, nothing steps) so the
    // queue fills deterministically instead of by winning a race
    h.pause_engine();
    let body = r#"{"prompt": [5, 6, 7], "max_new_tokens": 3}"#;
    let waiters: Vec<_> = (0..2)
        .map(|_| {
            std::thread::spawn(move || {
                client::request(addr, "POST", "/v1/generate", Some(body)).unwrap()
            })
        })
        .collect();
    await_metrics(addr, "queue depth 2", |t| {
        client::metric(t, "apt_engine_queue_depth") == Some(2)
    });

    // the bounded queue refuses the third request before the engine
    // sees it
    let r = client::request(addr, "POST", "/v1/generate", Some(body)).unwrap();
    assert_eq!(r.status, 429);
    assert_eq!(r.header("retry-after"), Some("1"));
    assert!(String::from_utf8_lossy(&r.body).contains("queue"), "429 body names the cause");

    h.resume_engine();
    for w in waiters {
        let r = w.join().unwrap();
        assert_eq!(r.status, 200, "queued requests complete after resume");
        assert_eq!(r.json().unwrap().get("finish").unwrap().as_str(), Some("length"));
    }
    // the refused request left nothing behind: exactly the two admitted
    // completions, empty queue, zero live pages
    let text = await_metrics(addr, "drain after resume", |t| {
        client::metric(t, "apt_engine_completions_total") == Some(2)
            && client::metric(t, "apt_engine_kv_pages_live") == Some(0)
    });
    let get = |k: &str| client::metric(&text, k).unwrap_or_else(|| panic!("missing {k}"));
    assert_eq!(get("apt_engine_queue_depth"), 0);
    assert_eq!(get("apt_http_responses_429_total"), 1);
    h.shutdown();
}

/// The wire-fault blast-radius gate: with a scripted slow-loris, a
/// mid-body stall and a mid-stream disconnect running against the
/// server, well-behaved requests must come back BYTE-IDENTICAL to an
/// unfaulted run, the engine must drain to zero K/V pages, every pool
/// worker must join on shutdown, and every hostile connection must land
/// in a typed `/metrics` counter. The faults are injected at the wire
/// layer's normal read/write points (`server::netfaults`), so this is
/// the production code path end to end.
#[test]
fn http_wire_fault_blast_radius_spares_clean_streams() {
    use apt::server::netfaults::{ConnScript, NetFaultPlan};
    use apt::server::{client, Server, ServerConfig};

    let make_model = || {
        Transformer::init(
            TransformerConfig {
                vocab: 31,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 24,
                max_seq: 64,
            },
            &mut Rng::new(9),
        )
    };
    let hostile_stream_body =
        r#"{"prompt": [1, 2, 3, 4], "max_new_tokens": 8, "stream": true}"#;
    let plain_body = r#"{"prompt": [5, 6, 7], "max_new_tokens": 6}"#;
    let clean_stream_body = r#"{"prompt": [8, 9, 10], "max_new_tokens": 6, "stream": true}"#;

    // ---- unfaulted baseline: same submit order as the faulted run, so
    // request ids (which appear in response bodies) line up and the
    // comparison below really is byte-for-byte
    let (baseline_plain, baseline_chunks) = {
        let h = Server::start(make_model(), "127.0.0.1:0", ServerConfig::default()).unwrap();
        let (st, _) = client::stream_request(h.addr(), "/v1/generate", hostile_stream_body).unwrap();
        assert_eq!(st, 200);
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(plain_body)).unwrap();
        assert_eq!(r.status, 200);
        let (st, chunks) =
            client::stream_request(h.addr(), "/v1/generate", clean_stream_body).unwrap();
        assert_eq!(st, 200);
        h.shutdown();
        (r.body, chunks)
    };

    // ---- faulted run: conn 0 is a slow loris (trickled reads stalling
    // mid-header), conn 1 stalls mid-body, conn 2 disconnects mid-stream
    let raw_request = |body: &str| {
        format!("POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}", body.len(), body)
    };
    let stall_wire = raw_request(plain_body);
    let head_len = stall_wire.len() - plain_body.len();
    let plan = NetFaultPlan::new()
        .on_conn(0, ConnScript::clean().trickle(1).stall_after(20))
        .on_conn(1, ConnScript::clean().stall_after(head_len + plain_body.len() / 2))
        .on_conn(2, ConnScript::clean().drop_after(150));
    let h = Server::start_with_netfaults(make_model(), "127.0.0.1:0", ServerConfig::default(), plan)
        .unwrap();
    let addr = h.addr();

    // conn 0: the full request is sent, but the scripted wire trickles
    // it byte-at-a-time and stalls at byte 20 — typed 408, worker freed
    let status = client::raw_roundtrip_status(addr, &raw_request(plain_body)).unwrap();
    assert_eq!(status, 408, "slow loris maps to a typed 408");
    // conn 1: headers arrive whole, the body stalls halfway through its
    // declared Content-Length — the same typed 408
    let status = client::raw_roundtrip_status(addr, &stall_wire).unwrap();
    assert_eq!(status, 408, "mid-body stall maps to a typed 408");
    // conn 2: the stream starts, then the wire drops every write past
    // byte 150 — the server must take its normal disconnect path
    {
        let mut st = client::open_stream(addr, "/v1/generate", hostile_stream_body).unwrap();
        assert_eq!(st.status, 200, "headers fit under the drop point");
        while let Ok(Some(_)) = st.next_chunk() {}
    }

    // ---- well-behaved requests, byte-identical to the baseline
    let r = client::request(addr, "POST", "/v1/generate", Some(plain_body)).unwrap();
    assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
    assert_eq!(r.body, baseline_plain, "plain response altered by concurrent wire faults");
    let (st, chunks) = client::stream_request(addr, "/v1/generate", clean_stream_body).unwrap();
    assert_eq!(st, 200);
    assert_eq!(chunks, baseline_chunks, "streamed response altered by concurrent wire faults");

    // ---- ledger: every hostile connection in a typed counter, engine
    // drained to zero pages, nothing still active
    let text = await_metrics(addr, "fault ledger + drain", |t| {
        client::metric(t, "apt_engine_completions_cancelled_total") == Some(1)
            && client::metric(t, "apt_engine_kv_pages_live") == Some(0)
    });
    let get = |k: &str| client::metric(&text, k).unwrap_or_else(|| panic!("missing {k}"));
    assert_eq!(get("apt_http_responses_408_total"), 2, "both stalls typed as 408");
    assert_eq!(get("apt_net_stalls_total"), 2, "both scripted stalls fired");
    assert_eq!(get("apt_net_disconnects_total"), 1, "scripted disconnect fired");
    assert_eq!(get("apt_net_short_io_conns_total"), 1, "the trickled conn is accounted");
    assert_eq!(get("apt_http_stream_disconnects_total"), 1);
    assert_eq!(get("apt_engine_completions_cancelled_total"), 1, "disconnect cancelled its stream");
    assert_eq!(get("apt_engine_streams_active"), 0);
    assert_eq!(get("apt_engine_queue_depth"), 0);

    // ---- full thread reclamation: every pool worker joins
    let report = h.shutdown();
    assert_eq!(report.pool_workers_joined, ServerConfig::default().pool_workers);
}

/// Keep-alive across the integration surface: many requests on one
/// reused connection produce the same responses as one-shot
/// connections, and the server's reuse/accept ledger proves only one
/// connection was ever opened by the reusing client.
#[test]
fn http_keepalive_reuse_matches_one_shot_responses() {
    use apt::server::{client, Server, ServerConfig};

    let model = Transformer::init(
        TransformerConfig {
            vocab: 31,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
        },
        &mut Rng::new(9),
    );
    let h = Server::start(model, "127.0.0.1:0", ServerConfig::default()).unwrap();
    let addr = h.addr();

    let bodies: Vec<String> = (0..4)
        .map(|i| format!(r#"{{"prompt": [{}, {}], "max_new_tokens": 4}}"#, i + 1, i + 2))
        .collect();
    // one-shot responses first (each opens its own connection)...
    let one_shot: Vec<Vec<u32>> = bodies
        .iter()
        .map(|b| {
            let r = client::request(addr, "POST", "/v1/generate", Some(b)).unwrap();
            assert_eq!(r.status, 200);
            r.json()
                .unwrap()
                .get("tokens")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|t| t.as_f64().unwrap() as u32)
                .collect()
        })
        .collect();
    // ...then the same requests down ONE kept-alive connection
    let before = client::metric(
        &String::from_utf8_lossy(
            &client::request(addr, "GET", "/metrics", None).unwrap().body,
        ),
        "apt_http_conns_accepted_total",
    )
    .unwrap();
    let mut c = client::Client::new(addr);
    for (b, expect) in bodies.iter().zip(&one_shot) {
        let r = c.request("POST", "/v1/generate", Some(b)).unwrap();
        assert_eq!(r.status, 200);
        let got: Vec<u32> = r
            .json()
            .unwrap()
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_f64().unwrap() as u32)
            .collect();
        assert_eq!(&got, expect, "keep-alive changed a response");
    }
    assert_eq!(c.connects_made(), 1, "four requests rode one connection");
    drop(c);
    let text = await_metrics(addr, "keepalive ledger", |t| {
        client::metric(t, "apt_http_keepalive_reuses_total") == Some(3)
    });
    let after = client::metric(&text, "apt_http_conns_accepted_total").unwrap();
    // the reusing client accounts for exactly one accepted connection
    // (metrics polls add their own, all after `before` was read — so the
    // delta is 1 reusing conn + the polls, never 4)
    assert!(after >= before + 1, "reusing client was accepted");
    h.shutdown();
}
