//! Integration tests across the whole stack: pipeline end-to-end, engine
//! parity (native vs AOT/PJRT), checkpoint round-trips, sparse packing of
//! pipeline output, and failure injection.

use apt::coordinator::{prune_model, PipelineConfig};
use apt::data::{CorpusGen, Profile};
use apt::eval::perplexity;
use apt::model::{train, LanguageModel, TrainConfig, Transformer, TransformerConfig};
use apt::prune::{Method, PruneConfig, Sparsity};
use apt::runtime::{Engine, Runtime};
use apt::sparse::{Csr, Packed24};
use apt::util::Rng;

fn trained_model(gen: &CorpusGen, d: usize, layers: usize, steps: usize) -> Transformer {
    let vocab = gen.tokenizer.vocab_size();
    let mut model = Transformer::init(
        TransformerConfig {
            vocab,
            d_model: d,
            n_layers: layers,
            n_heads: 2,
            d_ff: 2 * d,
            max_seq: 64,
        },
        &mut Rng::new(7),
    );
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    train(
        &mut model,
        &data,
        &TrainConfig { steps, batch: 4, seq_len: 32, log_every: steps, ..Default::default() },
    );
    model
}

#[test]
fn full_stack_prune_then_eval_then_pack() {
    let gen = CorpusGen::new(60, 2, 31);
    let model = trained_model(&gen, 32, 2, 60);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(8, 32, &mut Rng::new(2));

    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(Method::SM, Sparsity::two_four()));
    let report = prune_model(&mut pruned, &calib, &cfg, None).unwrap();
    assert_eq!(report.linears.len(), 14);
    assert!((report.overall_sparsity() - 0.5).abs() < 0.01);

    // every pruned linear must pack into the hardware 2:4 format
    for b in 0..2 {
        for name in ["wq", "wk", "wv", "wo", "w1", "w2", "w3"] {
            let w = pruned.weight(b, name);
            let packed = Packed24::from_dense(w)
                .unwrap_or_else(|e| panic!("block {b} {name}: {e}"));
            assert_eq!(&packed.to_dense(), w);
        }
    }

    // eval still runs and returns finite ppl
    let eval_data = gen.generate(Profile::Wt2Like, 2_048, 3);
    let ppl = perplexity(&pruned, &eval_data, 64);
    assert!(ppl.is_finite() && ppl > 1.0);
}

#[test]
fn engine_parity_native_vs_hlo() {
    // When artifacts exist, the HLO engine must produce a valid 2:4 model
    // with quality close to native (same math, f32 vs f64 accumulation).
    if cfg!(not(feature = "pjrt")) {
        eprintln!("pjrt feature off; skipping parity test");
        return;
    }
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing; skipping parity test");
        return;
    }
    let rt = Runtime::load(dir).unwrap();
    let gen = CorpusGen::new(60, 2, 32);
    // d=128 so the (128,128)/(256,128)/(128,256) artifacts cover all linears
    let model = trained_model(&gen, 128, 1, 30);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(8, 32, &mut Rng::new(4));
    let eval_data = gen.generate(Profile::Wt2Like, 2_048, 5);

    let run = |engine: Engine| -> (f64, f64) {
        let mut m = Transformer { cfg: model.cfg, params: model.params.clone() };
        let cfg = PipelineConfig::new(PruneConfig::new(Method::SM, Sparsity::two_four()))
            .with_engine(engine);
        let rep = prune_model(&mut m, &calib, &cfg, Some(&rt)).unwrap();
        (perplexity(&m, &eval_data, 64), rep.hlo_fraction())
    };
    let (ppl_native, frac_native) = run(Engine::Native);
    let (ppl_hlo, frac_hlo) = run(Engine::Hlo);
    assert_eq!(frac_native, 0.0);
    assert!(frac_hlo > 0.9, "hlo engine should cover the layers: {frac_hlo}");
    let rel = (ppl_hlo - ppl_native).abs() / ppl_native;
    assert!(rel < 0.05, "native {ppl_native} vs hlo {ppl_hlo}");
}

#[test]
fn pruned_checkpoint_roundtrip() {
    let gen = CorpusGen::new(60, 2, 33);
    let model = trained_model(&gen, 32, 2, 20);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(4, 32, &mut Rng::new(6));
    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SS,
        Sparsity::Unstructured { rate: 0.7 },
    ));
    prune_model(&mut pruned, &calib, &cfg, None).unwrap();

    let dir = std::env::temp_dir().join("apt_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("pruned.ats");
    pruned.save(&path).unwrap();
    let loaded = Transformer::load(pruned.cfg, &path).unwrap();
    // sparsity and behaviour survive the round-trip exactly
    for name in loaded.params.names() {
        assert_eq!(loaded.params.get(name).unwrap(), pruned.params.get(name).unwrap());
    }
    let toks: Vec<u32> = (0..32).map(|i| (i % 50) as u32).collect();
    assert_eq!(
        pruned.forward_loss(&toks, (1, 32)),
        loaded.forward_loss(&toks, (1, 32))
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn csr_fast_path_matches_dense_forward() {
    let gen = CorpusGen::new(60, 2, 34);
    let model = trained_model(&gen, 32, 1, 20);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(4, 32, &mut Rng::new(8));
    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SM,
        Sparsity::Unstructured { rate: 0.8 },
    ));
    prune_model(&mut pruned, &calib, &cfg, None).unwrap();

    let w = pruned.weight(0, "w1");
    let csr = Csr::from_dense(w);
    let x = apt::tensor::Mat::randn(8, w.cols, 1.0, &mut Rng::new(9));
    let dense = x.matmul_tb(w);
    let sparse = csr.matmul_tb(&x);
    assert!(dense.max_abs_diff(&sparse) < 1e-4);
    assert!(csr.sparsity() > 0.75);
}

#[test]
fn failure_injection_bad_calibration() {
    // Degenerate calibration (constant tokens -> rank-1 activations) must
    // not crash: dampening escalation handles the singular Hessian.
    let gen = CorpusGen::new(60, 2, 35);
    let model = trained_model(&gen, 32, 1, 10);
    let calib: Vec<Vec<u32>> = (0..4).map(|_| vec![5u32; 32]).collect();
    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(
        Method::SM,
        Sparsity::Unstructured { rate: 0.5 },
    ));
    let report = prune_model(&mut pruned, &calib, &cfg, None).unwrap();
    assert!((report.overall_sparsity() - 0.5).abs() < 0.03);
    for l in &report.linears {
        assert!(l.pred_loss.is_finite());
    }
}

#[test]
fn mismatched_runtime_shapes_fall_back_to_native() {
    if cfg!(not(feature = "pjrt")) {
        return;
    }
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        return;
    }
    let rt = Runtime::load(dir).unwrap();
    let gen = CorpusGen::new(60, 2, 36);
    // d=40: no artifact covers these shapes -> native fallback everywhere
    let model = trained_model(&gen, 40, 1, 10);
    let data = gen.generate(Profile::C4Like, 20_000, 1);
    let calib = data.sample_calibration(4, 32, &mut Rng::new(10));
    let mut pruned = Transformer { cfg: model.cfg, params: model.params.clone() };
    let cfg = PipelineConfig::new(PruneConfig::new(Method::SM, Sparsity::two_four()))
        .with_engine(Engine::Hlo);
    let report = prune_model(&mut pruned, &calib, &cfg, Some(&rt)).unwrap();
    assert_eq!(report.hlo_fraction(), 0.0);
    assert!((report.overall_sparsity() - 0.5).abs() < 0.02);
}
