//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The sealed build environment has no registry access, so the real
//! crates.io `anyhow` cannot be fetched; this shim implements exactly the
//! subset this repository uses: [`Result`], [`Error`], the `anyhow!`,
//! `bail!` and `ensure!` macros, and the [`Context`] extension trait on
//! `Result<T, E: std::error::Error>` and `Option<T>`.
//!
//! Mirrors the real crate's key design point: `Error` deliberately does
//! NOT implement `std::error::Error`, which is what lets the blanket
//! `From<E: std::error::Error>` conversion coexist with the reflexive
//! `From<Error>` impl used by `?`.

use std::error::Error as StdError;
use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Boxed dynamic error with a stack of human-readable context strings
/// (outermost context last, like anyhow's context chain).
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
    context: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { inner: Box::new(Message(msg.to_string())), context: Vec::new() }
    }

    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { inner: Box::new(e), context: Vec::new() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Like anyhow: display the outermost context if any, else the root.
        match self.context.last() {
            Some(c) => write!(f, "{c}"),
            None => write!(f, "{}", self.inner),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")?;
        // Remaining contexts (inner to outer were pushed in order; show
        // the chain below the headline, innermost last) plus the root.
        let mut causes: Vec<String> =
            self.context.iter().rev().skip(1).map(String::clone).collect();
        if !self.context.is_empty() {
            causes.push(self.inner.to_string());
        }
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

/// Plain-string error payload for `anyhow!` / `bail!`.
struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Message {}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn context_on_result_and_option() {
        let e = io_err().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("gone"), "{dbg}");

        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert!(inner().is_err());

        fn fails(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(fails(5).unwrap(), 5);
        assert!(fails(-1).is_err());
        assert_eq!(fails(11).unwrap_err().to_string(), "too big: 11");
        let e = anyhow!("plain {}", "message");
        assert_eq!(e.to_string(), "plain message");
    }
}
