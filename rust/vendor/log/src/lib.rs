//! Minimal offline stand-in for the `log` crate facade.
//!
//! Implements the subset this repository uses: the [`Log`] trait,
//! [`Record`]/[`Metadata`], [`set_logger`]/[`set_max_level`], and the
//! five level macros. Semantics match the real facade for that subset:
//! records above the max level are dropped, and nothing is emitted until
//! a logger is installed.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

#[derive(Clone, Copy, Debug)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("logger already installed")
    }
}

static LOGGER: OnceLock<&'static (dyn Log + 'static)> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Trace as usize);

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> usize {
    MAX_LEVEL.load(Ordering::Relaxed)
}

#[doc(hidden)]
pub fn __private_log(level: Level, args: fmt::Arguments) {
    if (level as usize) > max_level() {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Error, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Warn, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Info, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Debug, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__private_log($crate::Level::Trace, format_args!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static SEEN: AtomicUsize = AtomicUsize::new(0);

    struct Counter;
    impl Log for Counter {
        fn enabled(&self, _: &Metadata) -> bool {
            true
        }
        fn log(&self, record: &Record) {
            // Exercise the accessors the downstream logger uses.
            let _ = format!("[{}] {}", record.level(), record.args());
            SEEN.fetch_add(1, Ordering::Relaxed);
        }
        fn flush(&self) {}
    }

    static C: Counter = Counter;

    #[test]
    fn filtering_and_dispatch() {
        set_logger(&C).ok();
        set_max_level(LevelFilter::Info);
        crate::info!("hello {}", 1);
        crate::debug!("dropped");
        crate::warn!("kept");
        assert_eq!(SEEN.load(Ordering::Relaxed), 2);
        // second install attempt fails but is harmless
        assert!(set_logger(&C).is_err());
    }
}
