//! Minimal JSON (serde is unavailable offline): value model, recursive-
//! descent parser, writer. Used for artifact manifests, configs, results.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `a.b.c` style path lookup.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        let mut o = Json::obj();
        for (k, v) in pairs {
            o.set(k, v);
        }
        o
    }

    // ---------------------------------------------------------------- write

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// -------------------------------------------------------------------- parse

pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing characters at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            m.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = parse(t).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{t}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2].path("b").unwrap().as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_pretty() {
        let mut o = Json::obj();
        o.set("name", Json::Str("prune_sm".into()))
            .set("n", Json::Num(128.0))
            .set("tags", Json::Arr(vec![Json::Str("a".into()), Json::Num(1.5)]));
        let pretty = o.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), o);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn escapes() {
        let v = Json::Str("tab\t\"q\" \\ \u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"caf\u{e9} \\u00e9\"").unwrap();
        assert_eq!(v.as_str(), Some("café é"));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let text = r#"{"format":"hlo-text-v1","entries":[{"name":"prune_sm","file":"f.hlo.txt","n":128,"m":128,"inputs":[{"shape":[128,128],"dtype":"float32"}]}]}"#;
        let v = parse(text).unwrap();
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("n").unwrap().as_usize(), Some(128));
        assert_eq!(
            e.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }
}
