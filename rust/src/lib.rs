//! APT-Repro: reproduction of "Pruning Foundation Models for High Accuracy
//! without Retraining" (Zhao et al., EMNLP 2024 Findings) as a three-layer
//! Rust + JAX + Pallas system. See DESIGN.md for the architecture and
//! EXPERIMENTS.md for measured results.
//!
//! Layer map:
//! - L3 (this crate): coordinator pipeline, pruning solvers, models, eval,
//!   benches, CLI.
//! - L2/L1 (python/compile): JAX prune graphs + Pallas kernels, AOT-lowered
//!   to `artifacts/*.hlo.txt`, executed here via [`runtime`] (PJRT).

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod harness;
pub mod io;
pub mod json;
pub mod linalg;
pub mod model;
pub mod prune;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod sparse;
pub mod tensor;
pub mod util;
