//! L3 coordinator: the layer-wise pruning pipeline (the paper's system
//! contribution — single-device memory-bounded post-training compression).
//!
//! Per transformer/Mamba block, exactly SparseGPT's sequential scheme:
//!   1. *Calibrate*: stream every calibration batch through the block
//!      (weights still dense), accumulating one Hessian per linear layer.
//!      Batches fan out over a worker pool; each worker owns private
//!      accumulators which are merged (bounded memory: one block's
//!      Hessians + one batch of activations per worker).
//!   2. *Prune*: each linear of the block is an independent job — the
//!      worker pool solves them concurrently (native solver or AOT HLO via
//!      the PJRT runtime, per `Backend`).
//!   3. *Pack*: each pruned linear is swapped, in place, into the
//!      [`WeightStore`] layout matching its sparsity pattern (CSR for
//!      unstructured — u16 indices when cols fit, u32 otherwise — and
//!      packed 2:4 for semi-structured; kept dense below the byte
//!      break-even), so every later stage — propagation below,
//!      perplexity/zero-shot eval, serving — executes the sparse
//!      kernels and the realized compression is reported per linear in
//!      [`PipelineReport`].
//!   4. *Propagate*: re-run the batches through the now-pruned block to
//!      produce the next block's inputs. A bounded channel applies
//!      backpressure so at most `queue_cap` activation batches are ever
//!      in flight.
//!
//! Python never runs here; the HLO engine executes artifacts prepared by
//! `make artifacts`.

use std::collections::BTreeMap;
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;

use anyhow::Result;

use crate::json::Json;
use crate::model::{LanguageModel, Mamba, Transformer};
use crate::prune::{
    column_groups, compensate_columns, dropped_columns, group_scores, kept_columns, prune_layer,
    select_kept_groups, HessianAccumulator, LayerPruneResult, Mask, PruneConfig, Sparsity,
    StructuredConfig,
};
use crate::runtime::{Backend, Runtime};
use crate::sparse::{ReducedDense, WeightStore};
use crate::tensor::Mat;
use crate::util::{num_threads, profile, Timer};

/// Pipeline configuration on top of the per-layer `PruneConfig`.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub prune: PruneConfig,
    /// Sequences per activation batch flowing through the pipeline.
    pub batch: usize,
    /// Bounded-channel capacity between propagate and consume stages.
    pub queue_cap: usize,
    pub engine: Backend,
}

impl PipelineConfig {
    pub fn new(prune: PruneConfig) -> Self {
        PipelineConfig { prune, batch: 8, queue_cap: 4, engine: Backend::Native }
    }

    pub fn with_engine(mut self, e: Backend) -> Self {
        self.engine = e;
        self
    }
}

/// Per-linear outcome + which engine actually solved it + the packed
/// layout it was left in.
#[derive(Clone, Debug)]
pub struct LinearReport {
    pub block: usize,
    pub name: String,
    pub shape: (usize, usize),
    pub sparsity: f64,
    pub pred_loss: f64,
    pub elapsed_ms: f64,
    pub engine: &'static str,
    /// Layout the linear was packed into ("csr16" / "csr" / "packed24",
    /// or "dense" when packing would not have shrunk it).
    pub format: &'static str,
    /// Actual bytes of the packed layout.
    pub bytes: usize,
    /// Bytes the same weights would occupy densely.
    pub dense_bytes: usize,
}

#[derive(Debug, Default)]
pub struct PipelineReport {
    pub linears: Vec<LinearReport>,
    pub total_ms: f64,
    pub calib_ms: f64,
    pub prune_ms: f64,
    pub propagate_ms: f64,
    pub n_calib_tokens: usize,
}

impl PipelineReport {
    pub fn overall_sparsity(&self) -> f64 {
        let total: usize = self.linears.iter().map(|l| l.shape.0 * l.shape.1).sum();
        let pruned: f64 = self
            .linears
            .iter()
            .map(|l| l.sparsity * (l.shape.0 * l.shape.1) as f64)
            .sum();
        pruned / total.max(1) as f64
    }

    pub fn hlo_fraction(&self) -> f64 {
        let hlo = self.linears.iter().filter(|l| l.engine == "hlo").count();
        hlo as f64 / self.linears.len().max(1) as f64
    }

    /// Total bytes of the packed pruned linears.
    pub fn packed_bytes(&self) -> usize {
        self.linears.iter().map(|l| l.bytes).sum()
    }

    /// Bytes the same linears would occupy densely.
    pub fn dense_bytes(&self) -> usize {
        self.linears.iter().map(|l| l.dense_bytes).sum()
    }

    /// dense / packed across all pruned linears (>1 = compression win).
    pub fn compression_ratio(&self) -> f64 {
        self.dense_bytes() as f64 / self.packed_bytes().max(1) as f64
    }

    /// Machine-readable form (BENCH_perf.json's `pipeline` section and any
    /// external tooling): stage timings plus one record per linear.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("total_ms", Json::Num(self.total_ms))
            .set("calib_ms", Json::Num(self.calib_ms))
            .set("prune_ms", Json::Num(self.prune_ms))
            .set("propagate_ms", Json::Num(self.propagate_ms))
            .set("n_calib_tokens", Json::Num(self.n_calib_tokens as f64))
            .set("overall_sparsity", Json::Num(self.overall_sparsity()))
            .set("hlo_fraction", Json::Num(self.hlo_fraction()))
            .set("packed_bytes", Json::Num(self.packed_bytes() as f64))
            .set("dense_bytes", Json::Num(self.dense_bytes() as f64))
            .set("compression_ratio", Json::Num(self.compression_ratio()));
        let linears: Vec<Json> = self
            .linears
            .iter()
            .map(|l| {
                let mut e = Json::obj();
                e.set("block", Json::Num(l.block as f64))
                    .set("name", Json::Str(l.name.clone()))
                    .set("rows", Json::Num(l.shape.0 as f64))
                    .set("cols", Json::Num(l.shape.1 as f64))
                    .set("sparsity", Json::Num(l.sparsity))
                    // NaN marks "no Eq. 12 prediction" (non-MRP methods);
                    // it has no JSON literal, so map it to null.
                    .set(
                        "pred_loss",
                        if l.pred_loss.is_finite() { Json::Num(l.pred_loss) } else { Json::Null },
                    )
                    .set("elapsed_ms", Json::Num(l.elapsed_ms))
                    .set("engine", Json::Str(l.engine.to_string()))
                    .set("format", Json::Str(l.format.to_string()))
                    .set("bytes", Json::Num(l.bytes as f64))
                    .set("dense_bytes", Json::Num(l.dense_bytes as f64));
                e
            })
            .collect();
        o.set("linears", Json::Arr(linears));
        o
    }
}

/// Prune a model in place against calibration sequences.
pub fn prune_model(
    model: &mut dyn LanguageModel,
    calib: &[Vec<u32>],
    cfg: &PipelineConfig,
    runtime: Option<&Runtime>,
) -> Result<PipelineReport> {
    let total_timer = Timer::start();
    let mut acts = embed_calib(model, calib, cfg.batch);

    let mut report = PipelineReport {
        n_calib_tokens: calib.len() * calib[0].len(),
        ..Default::default()
    };

    for b in 0..model.n_blocks() {
        // ---- stage 1: calibrate (parallel batch fan-out, merged accums)
        let calib_timer = Timer::start();
        let accs = profile("pipeline.calibrate", || calibrate_block(model, b, &acts));
        report.calib_ms += calib_timer.elapsed_ms();

        // ---- stage 2: prune every linear of this block concurrently
        let prune_timer = Timer::start();
        let linear_names: Vec<&'static str> = model.linear_names().to_vec();
        let jobs: Vec<(usize, &'static str, Mat, &HessianAccumulator)> = linear_names
            .iter()
            .map(|&name| {
                let w = model.block_weight(b, name).to_dense();
                let acc = accs.get(name).expect("hessian for linear");
                (b, name, w, acc)
            })
            .collect();
        let results: Vec<(&'static str, Mat, LayerPruneResult, &'static str)> =
            profile("pipeline.prune", || run_prune_jobs(jobs, cfg, runtime));
        for (name, w_new, res, engine) in results {
            // Pack into the layout matching the sparsity pattern; the
            // propagate stage below (and every later eval) runs the
            // sparse kernels directly from this layout.
            let store = WeightStore::pack(&w_new, cfg.prune.sparsity);
            report.linears.push(LinearReport {
                block: b,
                name: name.to_string(),
                shape: w_new.shape(),
                sparsity: w_new.sparsity(),
                pred_loss: res.pred_loss,
                elapsed_ms: res.elapsed_ms,
                engine,
                format: store.format(),
                bytes: store.bytes(),
                dense_bytes: store.dense_bytes(),
            });
            *model.block_weight_mut(b, name) = store;
            let _ = res.mask;
        }
        report.prune_ms += prune_timer.elapsed_ms();

        // ---- stage 3: propagate through the pruned block (bounded queue)
        let prop_timer = Timer::start();
        acts = profile("pipeline.propagate", || propagate_block(model, b, acts, cfg.queue_cap));
        report.propagate_ms += prop_timer.elapsed_ms();

        log::info!(
            "block {b}: calib {:.0}ms prune {:.0}ms propagate {:.0}ms",
            report.calib_ms, report.prune_ms, report.propagate_ms
        );
    }

    report.total_ms = total_timer.elapsed_ms();
    Ok(report)
}

/// Prune `draft` (a fresh copy of `dense`) in place to produce a
/// speculative-decoding draft — the "prune → keep both → serve
/// speculatively" wiring: the caller keeps `dense` as the lossless
/// verification target and hands both to
/// [`Engine::speculative`](crate::serve::Engine::speculative) or a
/// [`SpecSession`](crate::serve::speculative::SpecSession). Checks the
/// pair actually speaks the same token space (same arch/vocab) before
/// pruning; the returned report is the usual [`PipelineReport`].
pub fn prune_draft_model(
    dense: &dyn LanguageModel,
    draft: &mut dyn LanguageModel,
    calib: &[Vec<u32>],
    cfg: &PipelineConfig,
    runtime: Option<&Runtime>,
) -> Result<PipelineReport> {
    assert_eq!(dense.arch(), draft.arch(), "draft must copy the target architecture");
    assert_eq!(dense.vocab(), draft.vocab(), "draft and target must share a vocabulary");
    assert_eq!(dense.n_params(), draft.n_params(), "draft must start as a copy of the target");
    prune_model(draft, calib, cfg, runtime)
}

/// Batch + embed calibration sequences: the shared prologue of the
/// unstructured and structured pipelines.
fn embed_calib(
    model: &dyn LanguageModel,
    calib: &[Vec<u32>],
    batch: usize,
) -> Vec<(Mat, (usize, usize))> {
    assert!(!calib.is_empty());
    let seq_len = calib[0].len();
    assert!(calib.iter().all(|c| c.len() == seq_len), "uniform calib seq_len");
    calib
        .chunks(batch.max(1))
        .map(|seqs| {
            let toks = seqs.concat();
            let bsz = toks.len() / seq_len;
            (model.embed_tokens(&toks), (bsz, seq_len))
        })
        .collect()
}

// ---------------------------------------------------------------------------
// structured pruning: heads / FFN channels / mamba inner channels
// ---------------------------------------------------------------------------

/// One linear's outcome under structured pruning: the logical (full)
/// shape it had, the physical shape it executes at afterwards, and the
/// Eq. 12 predicted loss where the linear was the scored consumer
/// (NaN for producer slices — those are lossless once the consumer
/// columns are exact zeros).
#[derive(Clone, Debug)]
pub struct StructuredLinearReport {
    pub block: usize,
    pub name: String,
    pub full_shape: (usize, usize),
    pub reduced_shape: (usize, usize),
    pub pred_loss: f64,
    pub format: &'static str,
}

/// Per-block structural outcome: (kept, total) unit counts for each
/// family that applies to the architecture.
#[derive(Clone, Copy, Debug)]
pub struct StructuredBlockReport {
    pub block: usize,
    pub kept_heads: Option<(usize, usize)>,
    pub kept_ffn: Option<(usize, usize)>,
    pub kept_channels: Option<(usize, usize)>,
}

#[derive(Debug, Default)]
pub struct StructuredReport {
    pub linears: Vec<StructuredLinearReport>,
    pub blocks: Vec<StructuredBlockReport>,
    pub total_ms: f64,
    pub masked: bool,
}

impl StructuredReport {
    /// Per-token multiply-add FLOPs (2·rows·cols summed over the block
    /// linears) at the logical shapes. The depthwise conv is excluded on
    /// both sides — it shrinks proportionally and is O(k·e), not O(e²).
    pub fn flops_before(&self) -> usize {
        self.linears.iter().map(|l| 2 * l.full_shape.0 * l.full_shape.1).sum()
    }

    /// Per-token multiply-add FLOPs at the physical shapes actually
    /// executed after pruning.
    pub fn flops_after(&self) -> usize {
        self.linears.iter().map(|l| 2 * l.reduced_shape.0 * l.reduced_shape.1).sum()
    }

    /// Achieved compute fraction (< 1 = fewer FLOPs). A `masked: true`
    /// run reports 1.0 — the oracle zeroes but never shrinks.
    pub fn flops_ratio(&self) -> f64 {
        self.flops_after() as f64 / self.flops_before().max(1) as f64
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("total_ms", Json::Num(self.total_ms))
            .set("masked", Json::Bool(self.masked))
            .set("flops_before", Json::Num(self.flops_before() as f64))
            .set("flops_after", Json::Num(self.flops_after() as f64))
            .set("flops_ratio", Json::Num(self.flops_ratio()));
        let pair = |p: Option<(usize, usize)>| match p {
            Some((kept, total)) => {
                let mut e = Json::obj();
                e.set("kept", Json::Num(kept as f64)).set("total", Json::Num(total as f64));
                e
            }
            None => Json::Null,
        };
        let blocks: Vec<Json> = self
            .blocks
            .iter()
            .map(|bl| {
                let mut e = Json::obj();
                e.set("block", Json::Num(bl.block as f64))
                    .set("heads", pair(bl.kept_heads))
                    .set("ffn", pair(bl.kept_ffn))
                    .set("channels", pair(bl.kept_channels));
                e
            })
            .collect();
        o.set("blocks", Json::Arr(blocks));
        let linears: Vec<Json> = self
            .linears
            .iter()
            .map(|l| {
                let mut e = Json::obj();
                e.set("block", Json::Num(l.block as f64))
                    .set("name", Json::Str(l.name.clone()))
                    .set("full_rows", Json::Num(l.full_shape.0 as f64))
                    .set("full_cols", Json::Num(l.full_shape.1 as f64))
                    .set("rows", Json::Num(l.reduced_shape.0 as f64))
                    .set("cols", Json::Num(l.reduced_shape.1 as f64))
                    .set(
                        "pred_loss",
                        if l.pred_loss.is_finite() { Json::Num(l.pred_loss) } else { Json::Null },
                    )
                    .set("format", Json::Str(l.format.to_string()));
                e
            })
            .collect();
        o.set("linears", Json::Arr(linears));
        o
    }
}

/// `Some(kept)` only when the keep-set actually drops something — a
/// full keep-set stays a plain dense store with no index map.
fn maybe(kept: &[u32], full: usize) -> Option<&[u32]> {
    if kept.len() == full {
        None
    } else {
        Some(kept)
    }
}

/// Swap linear `name` of block `b` for its structured outcome and record
/// it. `w` carries any Eq. 13 compensation already applied at the full
/// logical shape; in masked (oracle) mode it is stored back as-is, dense
/// and full-size, otherwise it is sliced down to the kept rows/columns.
fn install_structured(
    model: &mut dyn LanguageModel,
    b: usize,
    name: &str,
    w: Mat,
    kept_rows: Option<&[u32]>,
    kept_cols: Option<&[u32]>,
    masked: bool,
    pred_loss: f64,
    report: &mut StructuredReport,
) -> Result<()> {
    let full_shape = w.shape();
    let store = if masked || (kept_rows.is_none() && kept_cols.is_none()) {
        WeightStore::Dense(w)
    } else {
        WeightStore::DenseReduced(ReducedDense::from_dense(&w, kept_rows, kept_cols)?)
    };
    report.linears.push(StructuredLinearReport {
        block: b,
        name: name.to_string(),
        full_shape,
        reduced_shape: store.shape(),
        pred_loss,
        format: store.format(),
    });
    *model.block_weight_mut(b, name) = store;
    Ok(())
}

/// Structured pruning for the transformer family: per block, score the
/// attention heads on `wo`'s Hessian (head_dim-wide column groups) and
/// the FFN channels on `w2`'s (single columns), keep the
/// highest-scoring units under the budget, Eq. 13-compensate the
/// consumer, then physically slice consumer columns and producer rows
/// (`wq`/`wk`/`wv` per head, `w1`/`w3` per channel) into
/// [`ReducedDense`] stores. With `cfg.masked` the model is left at full
/// shape with exact zeros in the dropped consumer columns — the oracle
/// the reduced model is gated against.
pub fn structured_prune_transformer(
    model: &mut Transformer,
    calib: &[Vec<u32>],
    cfg: &StructuredConfig,
) -> Result<StructuredReport> {
    let timer = Timer::start();
    let dh = model.cfg.head_dim();
    let mut acts = embed_calib(model, calib, cfg.batch);
    let mut report = StructuredReport { masked: cfg.masked, ..Default::default() };
    for b in 0..model.n_blocks() {
        let accs = profile("structured.calibrate", || calibrate_block(model, b, &acts));

        // ---- attention heads: consumer wo, producers wq/wk/wv
        let hinv = accs.get("wo").expect("wo hessian").finalize(cfg.gamma).1;
        let mut wo = model.block_weight(b, "wo").to_dense();
        let head_groups = column_groups(wo.cols, dh);
        let head_scores = group_scores(&wo, &hinv, &head_groups);
        let kept_head_groups = select_kept_groups(&head_scores, cfg.keep_heads);
        let kept_head_cols = kept_columns(&kept_head_groups, dh);
        let dropped = dropped_columns(&kept_head_cols, wo.cols);
        let loss = compensate_columns(&mut wo, &hinv, &dropped);
        let n_heads = head_groups.len();
        let kc = maybe(&kept_head_cols, n_heads * dh);
        install_structured(model, b, "wo", wo, None, kc, cfg.masked, loss, &mut report)?;
        for name in ["wq", "wk", "wv"] {
            let w = model.block_weight(b, name).to_dense();
            install_structured(model, b, name, w, kc, None, cfg.masked, f64::NAN, &mut report)?;
        }

        // ---- FFN channels: consumer w2, producers w1/w3
        let hinv = accs.get("w2").expect("w2 hessian").finalize(cfg.gamma).1;
        let mut w2 = model.block_weight(b, "w2").to_dense();
        let d_ff = w2.cols;
        let ffn_scores = group_scores(&w2, &hinv, &column_groups(d_ff, 1));
        let kept_ffn = kept_columns(&select_kept_groups(&ffn_scores, cfg.keep_ffn), 1);
        let loss = compensate_columns(&mut w2, &hinv, &dropped_columns(&kept_ffn, d_ff));
        let kc = maybe(&kept_ffn, d_ff);
        install_structured(model, b, "w2", w2, None, kc, cfg.masked, loss, &mut report)?;
        for name in ["w1", "w3"] {
            let w = model.block_weight(b, name).to_dense();
            install_structured(model, b, name, w, kc, None, cfg.masked, f64::NAN, &mut report)?;
        }

        report.blocks.push(StructuredBlockReport {
            block: b,
            kept_heads: Some((kept_head_groups.len(), n_heads)),
            kept_ffn: Some((kept_ffn.len(), d_ff)),
            kept_channels: None,
        });
        acts = profile("structured.propagate", || propagate_block(model, b, acts, cfg.queue_cap));
    }
    report.total_ms = timer.elapsed_ms();
    Ok(report)
}

/// Structured pruning for the mamba family: one inner channel feeds TWO
/// consumers — `out_proj` (as an input column) and `dt_proj` (the
/// per-channel dt mixing takes every channel as input) — so a channel's
/// removal loss is the SUM of its Eq. 12 group losses on both Hessians,
/// and both consumers are Eq. 13-compensated. The producer slices are
/// `in_proj` rows {c} ∪ {e + c} (x and z halves), `dt_proj` rows, and
/// the depthwise conv columns (physically shrunk in place — depthwise
/// is per-channel, so this is exact).
pub fn structured_prune_mamba(
    model: &mut Mamba,
    calib: &[Vec<u32>],
    cfg: &StructuredConfig,
) -> Result<StructuredReport> {
    let timer = Timer::start();
    let mut acts = embed_calib(model, calib, cfg.batch);
    let mut report = StructuredReport { masked: cfg.masked, ..Default::default() };
    for b in 0..model.n_blocks() {
        let accs = profile("structured.calibrate", || calibrate_block(model, b, &acts));
        let hinv_out = accs.get("out_proj").expect("out_proj hessian").finalize(cfg.gamma).1;
        let hinv_dt = accs.get("dt_proj").expect("dt_proj hessian").finalize(cfg.gamma).1;
        let mut out_proj = model.block_weight(b, "out_proj").to_dense();
        let mut dt_proj = model.block_weight(b, "dt_proj").to_dense();
        let e = out_proj.cols;

        let groups = column_groups(e, 1);
        let mut scores = group_scores(&out_proj, &hinv_out, &groups);
        for (s, extra) in scores.iter_mut().zip(group_scores(&dt_proj, &hinv_dt, &groups)) {
            *s += extra;
        }
        let kept = kept_columns(&select_kept_groups(&scores, cfg.keep_channels), 1);
        let dropped = dropped_columns(&kept, e);
        let loss_out = compensate_columns(&mut out_proj, &hinv_out, &dropped);
        let loss_dt = compensate_columns(&mut dt_proj, &hinv_dt, &dropped);

        let kc = maybe(&kept, e);
        install_structured(model, b, "out_proj", out_proj, None, kc, cfg.masked, loss_out, &mut report)?;
        install_structured(model, b, "dt_proj", dt_proj, kc, kc, cfg.masked, loss_dt, &mut report)?;
        // in_proj emits x then z, e rows each: keep rows {c} ∪ {e + c}
        let kept_xz: Vec<u32> =
            kept.iter().copied().chain(kept.iter().map(|&c| c + e as u32)).collect();
        let in_proj = model.block_weight(b, "in_proj").to_dense();
        install_structured(
            model, b, "in_proj", in_proj, maybe(&kept_xz, 2 * e), None, cfg.masked, f64::NAN,
            &mut report,
        )?;
        // depthwise conv: slice (CONV_K, e) weights + (1, e) bias to the
        // kept channels; stays a plain dense param (shapes are derived at
        // runtime from out_proj, so no index map is needed here)
        if !cfg.masked && kc.is_some() {
            for cname in ["conv_w", "conv_b"] {
                let key = format!("blocks.{b}.{cname}");
                let sliced = {
                    let cw = model.params.dense(&key)?;
                    let mut s = Mat::zeros(cw.rows, kept.len());
                    for r in 0..cw.rows {
                        let src = cw.row(r);
                        let dst = s.row_mut(r);
                        for (pc, &lc) in kept.iter().enumerate() {
                            dst[pc] = src[lc as usize];
                        }
                    }
                    s
                };
                model.params.insert(&key, sliced);
            }
        }

        report.blocks.push(StructuredBlockReport {
            block: b,
            kept_heads: None,
            kept_ffn: None,
            kept_channels: Some((kept.len(), e)),
        });
        acts = profile("structured.propagate", || propagate_block(model, b, acts, cfg.queue_cap));
    }
    report.total_ms = timer.elapsed_ms();
    Ok(report)
}

/// Stage 1: one Hessian accumulator per linear name, batches in parallel.
/// Per-chunk accumulators are merged in chunk order (not completion
/// order) so the f64 Hessians are bit-reproducible run to run — the
/// structured path's masked-oracle gate compares two pipeline runs over
/// the same calibration and needs them to make identical decisions.
fn calibrate_block(
    model: &dyn LanguageModel,
    b: usize,
    acts: &[(Mat, (usize, usize))],
) -> BTreeMap<&'static str, HessianAccumulator> {
    let names = model.linear_names();
    let nt = num_threads().min(acts.len().max(1));
    let chunk = acts.len().div_ceil(nt);
    let parts: Mutex<Vec<(usize, BTreeMap<&'static str, HessianAccumulator>)>> =
        Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for (ci, batch_chunk) in acts.chunks(chunk).enumerate() {
            let parts = &parts;
            s.spawn(move || {
                let mut local: BTreeMap<&'static str, HessianAccumulator> = BTreeMap::new();
                for (x, bt) in batch_chunk {
                    let _ = model.forward_block_collect(b, x, *bt, &mut |name, input| {
                        let canonical = names
                            .iter()
                            .find(|&&n| n == name)
                            .expect("linear name registered");
                        local
                            .entry(canonical)
                            .or_insert_with(|| HessianAccumulator::new(input.cols))
                            .add_chunk(input);
                    });
                }
                parts.lock().unwrap().push((ci, local));
            });
        }
    });
    let mut parts = parts.into_inner().unwrap();
    parts.sort_by_key(|(ci, _)| *ci);
    let mut merged: BTreeMap<&'static str, HessianAccumulator> = BTreeMap::new();
    for (_, local) in parts {
        for (name, acc) in local {
            match merged.get_mut(name) {
                Some(dst) => dst.merge(&acc),
                None => {
                    merged.insert(name, acc);
                }
            }
        }
    }
    merged
}

/// Stage 2: independent per-linear prune jobs. Native jobs fan out to the
/// worker pool; HLO jobs run on the coordinator thread (the xla crate's
/// PJRT handles are not Send — PJRT itself multithreads internally).
fn run_prune_jobs(
    jobs: Vec<(usize, &'static str, Mat, &HessianAccumulator)>,
    cfg: &PipelineConfig,
    runtime: Option<&Runtime>,
) -> Vec<(&'static str, Mat, LayerPruneResult, &'static str)> {
    let mut native_jobs = Vec::new();
    let mut hlo_jobs = Vec::new();
    for job in jobs {
        let use_hlo = cfg.engine == Backend::Hlo
            && runtime.map(|rt| artifact_for(rt, &cfg.prune, &job.2).is_some()).unwrap_or(false);
        if use_hlo {
            hlo_jobs.push(job);
        } else {
            native_jobs.push(job);
        }
    }

    let out = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for (block, name, mut w, acc) in native_jobs {
            let out = &out;
            s.spawn(move || {
                let res = prune_layer(&mut w, acc, &cfg.prune)
                    .unwrap_or_else(|e| panic!("prune block {block} {name}: {e}"));
                out.lock().unwrap().push((name, w, res, "native"));
            });
        }
        // HLO jobs on this thread, overlapping with the native workers.
        for (block, name, mut w, acc) in hlo_jobs {
            let rt = runtime.expect("hlo job implies runtime");
            let entry = artifact_for(rt, &cfg.prune, &w).expect("checked above");
            let res = prune_one_hlo(&mut w, acc, cfg, rt, &entry)
                .unwrap_or_else(|e| panic!("hlo prune block {block} {name}: {e}"));
            out.lock().unwrap().push((name, w, res, "hlo"));
        }
    });
    let mut v = out.into_inner().unwrap();
    v.sort_by_key(|(name, ..)| *name);
    v
}

/// Execute one linear on the PJRT engine.
fn prune_one_hlo(
    w: &mut Mat,
    acc: &HessianAccumulator,
    cfg: &PipelineConfig,
    rt: &Runtime,
    entry: &crate::runtime::ArtifactEntry,
) -> Result<LayerPruneResult> {
    let timer = Timer::start();
    let (_hd, hinv) = acc.finalize(cfg.prune.gamma);
    let hinv32 = hinv.to_f32();
    let (w_new, pred_loss) = rt.exec_prune(entry, w, &hinv32)?;
    let mut mask = Mask::new(w.rows, w.cols);
    for r in 0..w.rows {
        for c in 0..w.cols {
            if w_new[(r, c)] == 0.0 && w[(r, c)] != 0.0 {
                mask.set(r, c, true);
            }
        }
    }
    *w = w_new;
    Ok(LayerPruneResult { mask, pred_loss, elapsed_ms: timer.elapsed_ms() })
}

/// Map (method, sparsity) to the artifact graph name; HLO graphs implement
/// the S=all variant, so block_size must be None to hit this path.
fn artifact_for<'rt>(
    rt: &'rt Runtime,
    prune: &PruneConfig,
    w: &Mat,
) -> Option<crate::runtime::ArtifactEntry> {
    use crate::prune::Method;
    if prune.block_size.is_some() {
        return None;
    }
    let name = match (prune.method, prune.sparsity) {
        (Method::SM, Sparsity::Unstructured { rate }) if (rate - 0.5).abs() < 1e-9 => "prune_sm",
        (Method::SM, Sparsity::SemiStructured { n: 2, m: 4 }) => "prune_24_sm",
        (Method::MM, Sparsity::SemiStructured { n: 2, m: 4 }) => "prune_24_mm",
        (Method::MS, Sparsity::SemiStructured { n: 2, m: 4 }) => "prune_24_ms",
        _ => return None,
    };
    rt.find(name, w.rows, w.cols).cloned()
}

/// Stage 3: pipelined propagation. A producer thread pushes batch indexes
/// through a bounded channel (capacity = queue_cap) to model the paper's
/// memory bound; consumers run the pruned block forward.
fn propagate_block(
    model: &dyn LanguageModel,
    b: usize,
    acts: Vec<(Mat, (usize, usize))>,
    queue_cap: usize,
) -> Vec<(Mat, (usize, usize))> {
    let n = acts.len();
    let out: Mutex<Vec<Option<(Mat, (usize, usize))>>> = Mutex::new((0..n).map(|_| None).collect());
    let (tx, rx) = sync_channel::<(usize, Mat, (usize, usize))>(queue_cap.max(1));
    let rx = Mutex::new(rx);
    let workers = num_threads().min(n.max(1));
    std::thread::scope(|s| {
        // producer: feeds batches, blocks when the queue is full
        s.spawn(move || {
            for (i, (x, bt)) in acts.into_iter().enumerate() {
                if tx.send((i, x, bt)).is_err() {
                    break;
                }
            }
        });
        for _ in 0..workers {
            let rx = &rx;
            let out = &out;
            s.spawn(move || loop {
                let msg = { rx.lock().unwrap().recv() };
                match msg {
                    Ok((i, x, bt)) => {
                        let y = model.forward_block(b, &x, bt);
                        out.lock().unwrap()[i] = Some((y, bt));
                    }
                    Err(_) => break,
                }
            });
        }
    });
    out.into_inner().unwrap().into_iter().map(|o| o.expect("batch propagated")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusGen, Profile};
    use crate::model::{train, Mamba, MambaConfig, TrainConfig, Transformer, TransformerConfig};
    use crate::prune::Method;
    use crate::util::Rng;

    fn setup_transformer() -> (CorpusGen, crate::data::Dataset, Transformer) {
        let gen = CorpusGen::new(60, 2, 17);
        let data = gen.generate(Profile::C4Like, 30_000, 1);
        let vocab = gen.tokenizer.vocab_size();
        let mut model = Transformer::init(
            TransformerConfig { vocab, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 48, max_seq: 64 },
            &mut Rng::new(3),
        );
        train(
            &mut model,
            &data,
            &TrainConfig { steps: 150, batch: 8, seq_len: 32, log_every: 50, ..Default::default() },
        );
        (gen, data, model)
    }

    #[test]
    fn pipeline_prunes_every_linear_to_target() {
        let (_gen, data, mut model) = setup_transformer();
        let calib = data.sample_calibration(16, 32, &mut Rng::new(9));
        let cfg = PipelineConfig::new(PruneConfig::new(
            Method::SM,
            Sparsity::Unstructured { rate: 0.5 },
        ));
        let report = prune_model(&mut model, &calib, &cfg, None).unwrap();
        assert_eq!(report.linears.len(), 2 * 7);
        assert!((report.overall_sparsity() - 0.5).abs() < 0.03, "{}", report.overall_sparsity());
        for l in &report.linears {
            assert!((l.sparsity - 0.5).abs() < 0.05, "{l:?}");
        }
        // machine-readable form round-trips through the JSON writer/parser
        let j = report.to_json();
        let parsed = crate::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.get("linears").and_then(crate::json::Json::as_arr).unwrap().len(),
            2 * 7
        );
        assert!(parsed.get("total_ms").and_then(crate::json::Json::as_f64).unwrap() > 0.0);
    }

    #[test]
    fn pipeline_perplexity_ordering_ss_vs_magnitude() {
        // End-to-end: SM pruning must hurt perplexity less than magnitude.
        let (gen, data, model) = setup_transformer();
        let eval_data = gen.generate(Profile::Wt2Like, 4_096, 2);
        let calib = data.sample_calibration(24, 32, &mut Rng::new(10));
        let base_ppl = crate::eval::perplexity(&model, &eval_data, 64);

        // 60% sparsity separates the methods decisively at this tiny scale.
        let run = |method: Method| -> f64 {
            let mut m = Transformer { cfg: model.cfg, params: model.params.clone() };
            let cfg = PipelineConfig::new(PruneConfig::new(
                method,
                Sparsity::Unstructured { rate: 0.6 },
            ));
            prune_model(&mut m, &calib, &cfg, None).unwrap();
            crate::eval::perplexity(&m, &eval_data, 64)
        };
        let mag = run(Method::Magnitude);
        let sm = run(Method::SM);
        assert!(sm >= base_ppl * 0.9, "pruning shouldn't improve much: {sm} vs {base_ppl}");
        assert!(sm < mag, "SM {sm} must beat magnitude {mag}");
    }

    #[test]
    fn pipeline_works_for_mamba() {
        let gen = CorpusGen::new(60, 2, 19);
        let data = gen.generate(Profile::C4Like, 20_000, 1);
        let vocab = gen.tokenizer.vocab_size();
        let mut model = Mamba::init(
            MambaConfig { vocab, d_model: 24, d_inner: 40, n_layers: 2, max_seq: 64 },
            &mut Rng::new(4),
        );
        train(
            &mut model,
            &data,
            &TrainConfig { steps: 50, batch: 4, seq_len: 32, log_every: 25, ..Default::default() },
        );
        let calib = data.sample_calibration(8, 32, &mut Rng::new(11));
        let cfg = PipelineConfig::new(PruneConfig::new(Method::SM, Sparsity::two_four()));
        let report = prune_model(&mut model, &calib, &cfg, None).unwrap();
        assert_eq!(report.linears.len(), 2 * 3);
        assert!((report.overall_sparsity() - 0.5).abs() < 0.01);
    }

    #[test]
    fn pipeline_packs_linears_and_reports_compression() {
        // 2:4 → every linear ends up in the packed24 layout (9/16 of the
        // dense bytes) and the compression shows up in the JSON report.
        let (_gen, data, mut model) = setup_transformer();
        let calib = data.sample_calibration(8, 32, &mut Rng::new(21));
        let cfg = PipelineConfig::new(PruneConfig::new(Method::SM, Sparsity::two_four()));
        let report = prune_model(&mut model, &calib, &cfg, None).unwrap();
        for l in &report.linears {
            assert_eq!(l.format, "packed24", "{l:?}");
            assert_eq!(l.bytes * 16, l.dense_bytes * 9, "{l:?}");
            let stored = model.weight(l.block, &l.name);
            assert_eq!(stored.format(), "packed24");
            assert_eq!(stored.bytes(), l.bytes);
        }
        assert!((report.compression_ratio() - 16.0 / 9.0).abs() < 1e-9);
        let parsed = crate::json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert!(
            parsed.get("compression_ratio").and_then(crate::json::Json::as_f64).unwrap() > 1.7
        );
        assert_eq!(
            parsed.get("linears").and_then(crate::json::Json::as_arr).unwrap()[0]
                .get("format")
                .and_then(crate::json::Json::as_str)
                .unwrap(),
            "packed24"
        );
        // the packed model still evaluates
        let toks: Vec<u32> = (0..32).map(|i| (i % 50) as u32).collect();
        assert!(model.forward_loss(&toks, (1, 32)).is_finite());

        // unstructured → u16-index CSR (every linear here has cols ≪ 65536)
        let (_gen2, data2, mut model2) = setup_transformer();
        let calib2 = data2.sample_calibration(8, 32, &mut Rng::new(22));
        let cfg2 = PipelineConfig::new(PruneConfig::new(
            Method::SM,
            Sparsity::Unstructured { rate: 0.7 },
        ));
        let report2 = prune_model(&mut model2, &calib2, &cfg2, None).unwrap();
        for l in &report2.linears {
            assert_eq!(l.format, "csr16", "{l:?}");
            assert!(l.bytes < l.dense_bytes, "{l:?}");
        }
        assert!(report2.compression_ratio() > 1.2);
    }

    #[test]
    fn backpressure_queue_small_capacity_still_correct() {
        let (_gen, data, mut model) = setup_transformer();
        let calib = data.sample_calibration(12, 32, &mut Rng::new(12));
        let mut cfg = PipelineConfig::new(PruneConfig::new(
            Method::SS,
            Sparsity::Unstructured { rate: 0.5 },
        ));
        cfg.queue_cap = 1; // maximum backpressure
        cfg.batch = 2;
        let report = prune_model(&mut model, &calib, &cfg, None).unwrap();
        assert!((report.overall_sparsity() - 0.5).abs() < 0.03);
    }

    #[test]
    fn structured_pipeline_halves_transformer_flops() {
        // keep 0.5 on (h=2, d_ff=48): 1 head and 24 channels survive, so
        // every block linear loses exactly half its physical size.
        let (_gen, data, mut model) = setup_transformer();
        let calib = data.sample_calibration(16, 32, &mut Rng::new(31));
        let report =
            structured_prune_transformer(&mut model, &calib, &StructuredConfig::new(0.5)).unwrap();

        assert_eq!(report.linears.len(), 2 * 7);
        assert!((report.flops_ratio() - 0.5).abs() < 1e-12, "{}", report.flops_ratio());
        for bl in &report.blocks {
            assert_eq!(bl.kept_heads, Some((1, 2)));
            assert_eq!(bl.kept_ffn, Some((24, 48)));
            assert_eq!(bl.kept_channels, None);
        }
        for b in 0..2 {
            assert_eq!(model.weight(b, "wq").shape(), (16, 32));
            assert_eq!(model.weight(b, "wo").shape(), (32, 16));
            assert_eq!(model.weight(b, "w1").shape(), (24, 32));
            assert_eq!(model.weight(b, "w2").shape(), (32, 24));
            for name in ["wq", "wk", "wv", "wo", "w1", "w2", "w3"] {
                let ws = model.weight(b, name);
                assert_eq!(ws.format(), "dense_reduced", "{b} {name}");
                // logical accounting stays at the full geometry
                let full = match name {
                    "wq" | "wk" | "wv" | "wo" => 32 * 32,
                    _ => 48 * 32,
                };
                assert_eq!(ws.n_params(), full, "{b} {name}");
            }
        }
        // consumers carry an Eq. 12 loss; producers are lossless (NaN)
        for l in &report.linears {
            if l.name == "wo" || l.name == "w2" {
                assert!(l.pred_loss.is_finite() && l.pred_loss >= 0.0, "{l:?}");
            } else {
                assert!(l.pred_loss.is_nan(), "{l:?}");
            }
        }
        // the reduced model still evaluates end to end
        let toks: Vec<u32> = (0..32).map(|i| (i % 50) as u32).collect();
        assert!(model.forward_loss(&toks, (1, 32)).is_finite());
        // machine-readable form round-trips
        let parsed = crate::json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert!(
            (parsed.get("flops_ratio").and_then(crate::json::Json::as_f64).unwrap() - 0.5).abs()
                < 1e-9
        );
        assert_eq!(
            parsed.get("blocks").and_then(crate::json::Json::as_arr).unwrap().len(),
            2
        );
    }

    #[test]
    fn structured_masked_oracle_agrees_with_reduced() {
        // Same calibration, one masked run and one reducing run: the
        // decisions must agree and the surviving consumer weights must
        // match (bitwise at block 0; later blocks see f32-reassociated
        // inputs, hence the tiny tolerance).
        let (_gen, data, model) = setup_transformer();
        let calib = data.sample_calibration(16, 32, &mut Rng::new(32));
        let mut reduced = Transformer { cfg: model.cfg, params: model.params.clone() };
        let mut masked = Transformer { cfg: model.cfg, params: model.params.clone() };
        let cfg = StructuredConfig::new(0.5);
        structured_prune_transformer(&mut reduced, &calib, &cfg).unwrap();
        let mcfg = StructuredConfig { masked: true, ..cfg };
        let mreport = structured_prune_transformer(&mut masked, &calib, &mcfg).unwrap();
        assert!(mreport.masked);
        assert!((mreport.flops_ratio() - 1.0).abs() < 1e-12, "oracle never shrinks");

        for b in 0..2 {
            // masked weights stay full-shape dense
            assert_eq!(masked.weight(b, "wo").shape(), (32, 32));
            assert_eq!(masked.weight(b, "wo").format(), "dense");
            let WeightStore::DenseReduced(rd) = reduced.weight(b, "wo") else {
                panic!("reduced wo must be dense_reduced");
            };
            let kept = rd.kept_cols.as_ref().expect("wo keeps a column map");
            let mwo = masked.weight(b, "wo").dense_view().into_owned();
            // dropped columns are exact zeros in the oracle
            for c in super::dropped_columns(kept, 32) {
                for r in 0..32 {
                    assert_eq!(mwo[(r, c)], 0.0, "block {b} col {c}");
                }
            }
            // surviving columns agree with the physically sliced store
            let mut max = 0.0f32;
            for r in 0..32 {
                for (pc, &lc) in kept.iter().enumerate() {
                    max = max.max((mwo[(r, lc as usize)] - rd.mat[(r, pc)]).abs());
                }
            }
            assert!(max < 1e-4, "block {b}: {max}");
            if b == 0 {
                assert_eq!(max, 0.0, "block 0 sees identical calibration inputs");
            }
        }
    }

    #[test]
    fn structured_pipeline_works_for_mamba() {
        let gen = CorpusGen::new(60, 2, 23);
        let data = gen.generate(Profile::C4Like, 20_000, 1);
        let vocab = gen.tokenizer.vocab_size();
        let mut model = Mamba::init(
            MambaConfig { vocab, d_model: 24, d_inner: 40, n_layers: 2, max_seq: 64 },
            &mut Rng::new(5),
        );
        train(
            &mut model,
            &data,
            &TrainConfig { steps: 50, batch: 4, seq_len: 32, log_every: 25, ..Default::default() },
        );
        let calib = data.sample_calibration(8, 32, &mut Rng::new(33));
        let report =
            structured_prune_mamba(&mut model, &calib, &StructuredConfig::new(0.5)).unwrap();

        assert_eq!(report.linears.len(), 2 * 3);
        for bl in &report.blocks {
            assert_eq!(bl.kept_channels, Some((20, 40)));
        }
        for b in 0..2 {
            assert_eq!(model.weight(b, "in_proj").shape(), (40, 24));
            assert_eq!(model.weight(b, "dt_proj").shape(), (20, 20));
            assert_eq!(model.weight(b, "out_proj").shape(), (24, 20));
            // depthwise conv physically shrunk alongside
            assert_eq!(model.params.dense(&format!("blocks.{b}.conv_w")).unwrap().cols, 20);
            assert_eq!(model.params.dense(&format!("blocks.{b}.conv_b")).unwrap().cols, 20);
        }
        // dt_proj is sliced on BOTH axes (it mixes channels)
        let WeightStore::DenseReduced(rd) = model.weight(0, "dt_proj") else {
            panic!("dt_proj must be dense_reduced");
        };
        assert_eq!(rd.kept_rows, rd.kept_cols);
        // in_proj keeps rows {c} ∪ {e + c}: x and z halves stay aligned
        let WeightStore::DenseReduced(ip) = model.weight(0, "in_proj") else {
            panic!("in_proj must be dense_reduced");
        };
        let kr = ip.kept_rows.as_ref().unwrap();
        assert_eq!(kr.len(), 40);
        for i in 0..20 {
            assert_eq!(kr[20 + i], kr[i] + 40);
        }
        assert!(report.flops_ratio() > 0.3 && report.flops_ratio() < 0.6);
        let toks: Vec<u32> = (0..32).map(|i| (i % 50) as u32).collect();
        assert!(model.forward_loss(&toks, (1, 32)).is_finite());

        // keep = 1.0 is the identity: plain dense stores, ratio 1.0
        let mut full = Mamba::init(
            MambaConfig { vocab, d_model: 24, d_inner: 40, n_layers: 2, max_seq: 64 },
            &mut Rng::new(5),
        );
        let r = structured_prune_mamba(&mut full, &calib, &StructuredConfig::new(1.0)).unwrap();
        assert!((r.flops_ratio() - 1.0).abs() < 1e-12);
        for l in &r.linears {
            assert_eq!(l.format, "dense", "{l:?}");
        }
    }
}
