//! Synthetic zero-shot tasks mirroring the paper's Table 3 suite.
//!
//! Candidate-selection tasks (HellaSwag-like 4-way, PIQA/WinoGrande-like
//! 2-way, ARC-like 4-way) are scored by length-normalized model likelihood
//! of each continuation; LAMBADA-like is last-token argmax prediction.
//! Chance floors match the paper's analysis: 25% / 50% / 25% / ~0%.

use super::corpus::CorpusGen;
use super::tokenizer::BOS;
use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct ChoiceTask {
    pub context: Vec<u32>,
    pub candidates: Vec<Vec<u32>>,
    pub answer: usize,
}

#[derive(Clone, Debug)]
pub struct LastWordTask {
    pub context: Vec<u32>,
    pub answer: u32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    HellaSwagLike, // 4-way topical continuation
    PiqaLike,      // 2-way verb plausibility
    ArcLike,       // 4-way noun association
    WinoLike,      // 2-way referent consistency
}

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::HellaSwagLike => "hellaswag-like",
            TaskKind::PiqaLike => "piqa-like",
            TaskKind::ArcLike => "arc-like",
            TaskKind::WinoLike => "winogrande-like",
        }
    }

    pub fn n_choices(&self) -> usize {
        match self {
            TaskKind::HellaSwagLike | TaskKind::ArcLike => 4,
            TaskKind::PiqaLike | TaskKind::WinoLike => 2,
        }
    }
}

pub struct TaskGen<'a> {
    gen: &'a CorpusGen,
}

impl<'a> TaskGen<'a> {
    pub fn new(gen: &'a CorpusGen) -> TaskGen<'a> {
        TaskGen { gen }
    }

    fn topical_sentence(&self, topic: usize, rng: &mut Rng) -> Vec<u32> {
        let lex = &self.gen.lexicon;
        let tk = &self.gen.tokenizer;
        let wt = |i: usize| tk.word_token(i);
        let mut s = vec![
            wt(lex.det(rng)),
            wt(lex.adj(topic, rng)),
            wt(lex.noun(topic, rng)),
            wt(lex.verb(topic, rng)),
            wt(lex.det(rng)),
            wt(lex.noun(topic, rng)),
        ];
        s.push(tk.punct_token("."));
        s
    }

    /// The correct candidate continues the context's topic; distractors
    /// come from other topics (model must have learned topic coherence).
    pub fn choice_task(&self, kind: TaskKind, rng: &mut Rng) -> ChoiceTask {
        let lex = &self.gen.lexicon;
        let n_choices = kind.n_choices();
        let topic = rng.below(lex.n_topics);

        let mut context = vec![BOS];
        let n_ctx = match kind {
            TaskKind::HellaSwagLike => 3,
            TaskKind::WinoLike => 2,
            _ => 2,
        };
        for _ in 0..n_ctx {
            context.extend(self.topical_sentence(topic, rng));
        }

        let mut candidates = Vec::with_capacity(n_choices);
        let answer = rng.below(n_choices);
        let mut distractor_topics: Vec<usize> =
            (0..lex.n_topics).filter(|&t| t != topic).collect();
        rng.shuffle(&mut distractor_topics);
        for c in 0..n_choices {
            let t = if c == answer {
                topic
            } else {
                distractor_topics[c % distractor_topics.len()]
            };
            candidates.push(self.topical_sentence(t, rng));
        }
        ChoiceTask { context, candidates, answer }
    }

    /// LAMBADA-like: context plants a recurring noun; answer is its token.
    pub fn lambada_task(&self, rng: &mut Rng) -> LastWordTask {
        let lex = &self.gen.lexicon;
        let tk = &self.gen.tokenizer;
        let topic = rng.below(lex.n_topics);
        let target = lex.noun(topic, rng);
        let wt = |i: usize| tk.word_token(i);
        let mut context = vec![BOS];
        for _ in 0..3 {
            context.push(wt(lex.det(rng)));
            context.push(wt(target));
            context.push(wt(lex.verb(topic, rng)));
            context.push(wt(lex.det(rng)));
            context.push(wt(lex.noun(topic, rng)));
            context.push(tk.punct_token("."));
        }
        context.push(wt(lex.det(rng)));
        context.push(wt(lex.noun(topic, rng)));
        context.push(wt(lex.verb(topic, rng)));
        context.push(wt(lex.det(rng)));
        LastWordTask { context, answer: tk.word_token(target) }
    }

    pub fn choice_suite(&self, kind: TaskKind, n: usize, seed: u64) -> Vec<ChoiceTask> {
        let mut rng = Rng::new(seed ^ 0x7a5c);
        (0..n).map(|_| self.choice_task(kind, &mut rng)).collect()
    }

    pub fn lambada_suite(&self, n: usize, seed: u64) -> Vec<LastWordTask> {
        let mut rng = Rng::new(seed ^ 0x1a3b);
        (0..n).map(|_| self.lambada_task(&mut rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusGen;

    fn setup() -> CorpusGen {
        CorpusGen::new(120, 4, 21)
    }

    #[test]
    fn choice_task_shapes() {
        let g = setup();
        let tg = TaskGen::new(&g);
        for kind in [TaskKind::HellaSwagLike, TaskKind::PiqaLike, TaskKind::ArcLike, TaskKind::WinoLike] {
            let suite = tg.choice_suite(kind, 20, 1);
            assert_eq!(suite.len(), 20);
            for t in &suite {
                assert_eq!(t.candidates.len(), kind.n_choices());
                assert!(t.answer < kind.n_choices());
                assert!(!t.context.is_empty());
                assert!(t.candidates.iter().all(|c| !c.is_empty()));
            }
        }
    }

    #[test]
    fn answers_roughly_uniform() {
        let g = setup();
        let tg = TaskGen::new(&g);
        let suite = tg.choice_suite(TaskKind::HellaSwagLike, 400, 2);
        let mut counts = [0usize; 4];
        for t in &suite {
            counts[t.answer] += 1;
        }
        for c in counts {
            assert!(c > 50, "{counts:?}");
        }
    }

    #[test]
    fn lambada_answer_recurs_in_context() {
        let g = setup();
        let tg = TaskGen::new(&g);
        for t in tg.lambada_suite(50, 3) {
            let occurrences = t.context.iter().filter(|&&x| x == t.answer).count();
            assert!(occurrences >= 3);
        }
    }

    #[test]
    fn deterministic_suites() {
        let g = setup();
        let tg = TaskGen::new(&g);
        let a = tg.choice_suite(TaskKind::PiqaLike, 10, 7);
        let b = tg.choice_suite(TaskKind::PiqaLike, 10, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.answer, y.answer);
        }
    }
}
