//! Synthetic data substrate: lexicon, tokenizer, corpora, zero-shot tasks.
//!
//! See DESIGN.md SS2 for why synthetic stand-ins preserve the behaviours
//! the paper's evaluation measures.

pub mod corpus;
pub mod lexicon;
pub mod tasks;
pub mod tokenizer;

pub use corpus::{CorpusGen, Dataset, Profile};
pub use lexicon::Lexicon;
pub use tasks::{ChoiceTask, LastWordTask, TaskGen, TaskKind};
pub use tokenizer::Tokenizer;
