//! Word-level tokenizer over a fixed lexicon-derived vocabulary.
//!
//! The vocab layout is: [specials][punctuation][lexicon words]. Encoding of
//! unknown words maps to `<unk>` (exercised by the distribution-shift
//! evals where a profile uses rare vocabulary).

use std::collections::HashMap;

use super::lexicon::Lexicon;

pub const BOS: u32 = 0;
pub const EOS: u32 = 1;
pub const UNK: u32 = 2;
pub const PAD: u32 = 3;
const SPECIALS: [&str; 4] = ["<bos>", "<eos>", "<unk>", "<pad>"];
const PUNCT: [&str; 3] = [".", ",", ";"];

#[derive(Clone, Debug)]
pub struct Tokenizer {
    vocab: Vec<String>,
    index: HashMap<String, u32>,
}

impl Tokenizer {
    pub fn from_lexicon(lex: &Lexicon) -> Tokenizer {
        let mut vocab: Vec<String> =
            SPECIALS.iter().chain(PUNCT.iter()).map(|s| s.to_string()).collect();
        vocab.extend(lex.words.iter().cloned());
        let index = vocab
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Tokenizer { vocab, index }
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Token id for a lexicon word id.
    pub fn word_token(&self, lexicon_word_id: usize) -> u32 {
        (SPECIALS.len() + PUNCT.len() + lexicon_word_id) as u32
    }

    pub fn punct_token(&self, p: &str) -> u32 {
        self.index[p]
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace()
            .map(|w| self.index.get(w).copied().unwrap_or(UNK))
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .map(|&i| self.vocab.get(i as usize).map(|s| s.as_str()).unwrap_or("<bad>"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn token_str(&self, id: u32) -> &str {
        &self.vocab[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok() -> Tokenizer {
        Tokenizer::from_lexicon(&Lexicon::generate(50, 2, 1))
    }

    #[test]
    fn specials_have_fixed_ids() {
        let t = tok();
        assert_eq!(t.token_str(BOS), "<bos>");
        assert_eq!(t.token_str(EOS), "<eos>");
        assert_eq!(t.token_str(UNK), "<unk>");
        assert_eq!(t.token_str(PAD), "<pad>");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = tok();
        let text = t.decode(&[7, 8, 9, 4]);
        let ids = t.encode(&text);
        assert_eq!(ids, vec![7, 8, 9, 4]);
    }

    #[test]
    fn unknown_maps_to_unk() {
        let t = tok();
        assert_eq!(t.encode("zzzzz-not-a-word"), vec![UNK]);
    }

    #[test]
    fn vocab_size_counts_everything() {
        let lex = Lexicon::generate(50, 2, 1);
        let t = Tokenizer::from_lexicon(&lex);
        assert_eq!(t.vocab_size(), 4 + 3 + lex.len());
    }

    #[test]
    fn word_token_maps_into_vocab() {
        let lex = Lexicon::generate(50, 2, 1);
        let t = Tokenizer::from_lexicon(&lex);
        let id = t.word_token(10);
        assert_eq!(t.token_str(id), lex.words[10]);
    }
}
