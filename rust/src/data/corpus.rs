//! Synthetic corpora standing in for C4 / WikiText-2 / PTB / LAMBADA.
//!
//! Each profile shares one lexicon + tokenizer (so one model serves all
//! evals) but differs in topic mixing, sentence geometry and noise — the
//! same *kind* of distribution shift the paper's calibrate-on-C4 /
//! evaluate-on-WT2+PTB setup measures. `LambadaLike` additionally plants a
//! recurring target noun whose final occurrence is predictable only from
//! long-range context (the paper's Sec. 5.3 sensitivity argument).

use super::lexicon::Lexicon;
use super::tokenizer::{Tokenizer, BOS, EOS};
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Profile {
    /// Broad topic mixture, long documents (calibration-style data).
    C4Like,
    /// Narrow encyclopedic: few topics per doc, longer sentences.
    Wt2Like,
    /// Short newswire-ish sentences, heavier punctuation.
    PtbLike,
    /// Discourse passages whose final word is context-determined.
    LambadaLike,
}

impl Profile {
    pub fn name(&self) -> &'static str {
        match self {
            Profile::C4Like => "synth-c4",
            Profile::Wt2Like => "synth-wt2",
            Profile::PtbLike => "synth-ptb",
            Profile::LambadaLike => "synth-lambada",
        }
    }

    pub fn from_name(s: &str) -> Option<Profile> {
        match s {
            "synth-c4" | "c4" => Some(Profile::C4Like),
            "synth-wt2" | "wt2" | "wikitext2" => Some(Profile::Wt2Like),
            "synth-ptb" | "ptb" => Some(Profile::PtbLike),
            "synth-lambada" | "lambada" => Some(Profile::LambadaLike),
            _ => None,
        }
    }
}

/// A tokenized corpus: flat stream plus document spans.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub tokens: Vec<u32>,
    pub doc_spans: Vec<(usize, usize)>,
    pub profile: Profile,
}

/// Shared generation context (one lexicon/tokenizer per experiment).
pub struct CorpusGen {
    pub lexicon: Lexicon,
    pub tokenizer: Tokenizer,
}

impl CorpusGen {
    pub fn new(content_words: usize, n_topics: usize, seed: u64) -> CorpusGen {
        let lexicon = Lexicon::generate(content_words, n_topics, seed);
        let tokenizer = Tokenizer::from_lexicon(&lexicon);
        CorpusGen { lexicon, tokenizer }
    }

    /// Default setup used across the repro: 480 content words, 8 topics
    /// (vocab 512 with specials+punct+function words).
    pub fn default_setup(seed: u64) -> CorpusGen {
        CorpusGen::new(480, 8, seed)
    }

    fn sentence(&self, topic: usize, rng: &mut Rng, long: bool, out: &mut Vec<u32>) {
        let lex = &self.lexicon;
        let tk = &self.tokenizer;
        let wt = |i: usize| tk.word_token(i);
        out.push(wt(lex.det(rng)));
        let n_adj = if long { rng.below(3) } else { rng.below(2) };
        for _ in 0..n_adj {
            out.push(wt(lex.adj(topic, rng)));
        }
        out.push(wt(lex.noun(topic, rng)));
        out.push(wt(lex.verb(topic, rng)));
        out.push(wt(lex.det(rng)));
        if long && rng.uniform() < 0.5 {
            out.push(wt(lex.adj(topic, rng)));
        }
        out.push(wt(lex.noun(topic, rng)));
        if long && rng.uniform() < 0.6 {
            out.push(wt(lex.prep(rng)));
            out.push(wt(lex.det(rng)));
            out.push(wt(lex.noun(topic, rng)));
        }
        if rng.uniform() < 0.25 {
            out.push(tk.punct_token(","));
            out.push(wt(lex.conj(rng)));
            out.push(wt(lex.noun(topic, rng)));
            out.push(wt(lex.verb(topic, rng)));
        }
        out.push(tk.punct_token("."));
    }

    /// One LAMBADA-style passage: a planted noun recurs, the passage's
    /// final content token is that noun again.
    fn lambada_passage(&self, rng: &mut Rng, out: &mut Vec<u32>) -> u32 {
        let lex = &self.lexicon;
        let tk = &self.tokenizer;
        let topic = rng.below(lex.n_topics);
        let target = lex.noun(topic, rng);
        let wt = |i: usize| tk.word_token(i);
        let n_sent = 3 + rng.below(3);
        for _ in 0..n_sent {
            // sentences referencing the target noun
            out.push(wt(lex.det(rng)));
            out.push(wt(target));
            out.push(wt(lex.verb(topic, rng)));
            out.push(wt(lex.det(rng)));
            out.push(wt(lex.noun(topic, rng)));
            out.push(tk.punct_token("."));
            if rng.uniform() < 0.5 {
                self.sentence(topic, rng, false, out);
            }
        }
        // closing sentence ending in the target
        out.push(wt(lex.det(rng)));
        out.push(wt(lex.noun(topic, rng)));
        out.push(wt(lex.verb(topic, rng)));
        out.push(wt(lex.det(rng)));
        out.push(wt(target));
        tk.word_token(target)
    }

    /// Generate roughly `n_tokens` tokens of the given profile.
    pub fn generate(&self, profile: Profile, n_tokens: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ 0xda7a);
        let mut tokens = Vec::with_capacity(n_tokens + 64);
        let mut doc_spans = Vec::new();
        while tokens.len() < n_tokens {
            let start = tokens.len();
            tokens.push(BOS);
            match profile {
                Profile::C4Like => {
                    let mut topic = rng.below(self.lexicon.n_topics);
                    let n_sent = 8 + rng.below(12);
                    for _ in 0..n_sent {
                        if rng.uniform() < 0.3 {
                            topic = rng.below(self.lexicon.n_topics);
                        }
                        let long = rng.uniform() < 0.5;
                        self.sentence(topic, &mut rng, long, &mut tokens);
                    }
                }
                Profile::Wt2Like => {
                    let topic = rng.below(self.lexicon.n_topics);
                    let n_sent = 12 + rng.below(10);
                    for _ in 0..n_sent {
                        // rare drift to an adjacent topic
                        let t = if rng.uniform() < 0.08 {
                            (topic + 1) % self.lexicon.n_topics
                        } else {
                            topic
                        };
                        self.sentence(t, &mut rng, true, &mut tokens);
                    }
                }
                Profile::PtbLike => {
                    let n_sent = 5 + rng.below(6);
                    for _ in 0..n_sent {
                        let topic = rng.below(self.lexicon.n_topics);
                        self.sentence(topic, &mut rng, false, &mut tokens);
                        if rng.uniform() < 0.3 {
                            tokens.push(self.tokenizer.punct_token(";"));
                        }
                    }
                }
                Profile::LambadaLike => {
                    self.lambada_passage(&mut rng, &mut tokens);
                }
            }
            tokens.push(EOS);
            doc_spans.push((start, tokens.len()));
        }
        tokens.truncate(n_tokens.max(doc_spans.last().map(|&(s, _)| s + 2).unwrap_or(0)));
        if let Some(last) = doc_spans.last_mut() {
            last.1 = last.1.min(tokens.len());
        }
        Dataset { tokens, doc_spans, profile }
    }
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Non-overlapping evaluation windows of `seq_len` (the standard
    /// strided perplexity protocol).
    pub fn eval_windows(&self, seq_len: usize) -> Vec<&[u32]> {
        self.tokens.chunks_exact(seq_len).collect()
    }

    /// Random calibration segments, `n` windows of `seq_len` tokens
    /// (the paper: 128 segments x 2048 tokens from the first shard).
    pub fn sample_calibration(&self, n: usize, seq_len: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
        assert!(self.tokens.len() > seq_len, "corpus smaller than seq_len");
        (0..n)
            .map(|_| {
                let s = rng.below(self.tokens.len() - seq_len);
                self.tokens[s..s + seq_len].to_vec()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> CorpusGen {
        CorpusGen::new(120, 4, 11)
    }

    #[test]
    fn generates_requested_length() {
        let g = gen();
        for p in [Profile::C4Like, Profile::Wt2Like, Profile::PtbLike, Profile::LambadaLike] {
            let d = g.generate(p, 5000, 1);
            assert!(d.len() >= 5000, "{:?} {}", p, d.len());
            assert!(!d.doc_spans.is_empty());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let g = gen();
        let a = g.generate(Profile::C4Like, 2000, 5);
        let b = g.generate(Profile::C4Like, 2000, 5);
        assert_eq!(a.tokens, b.tokens);
        let c = g.generate(Profile::C4Like, 2000, 6);
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let g = gen();
        let v = g.tokenizer.vocab_size() as u32;
        let d = g.generate(Profile::Wt2Like, 3000, 2);
        assert!(d.tokens.iter().all(|&t| t < v));
    }

    #[test]
    fn profiles_have_distinct_statistics() {
        let g = gen();
        let stat = |p: Profile| {
            let d = g.generate(p, 20_000, 3);
            let dots = d
                .tokens
                .iter()
                .filter(|&&t| t == g.tokenizer.punct_token("."))
                .count();
            dots as f64 / d.len() as f64
        };
        // PTB-like has a denser sentence boundary rate than WT2-like.
        assert!(stat(Profile::PtbLike) > stat(Profile::Wt2Like));
    }

    #[test]
    fn lambada_final_token_recur_in_context() {
        let g = gen();
        let d = g.generate(Profile::LambadaLike, 4000, 4);
        let mut checked = 0;
        for &(s, e) in &d.doc_spans {
            if e - s < 8 || d.tokens[e - 1] != EOS {
                continue;
            }
            let target = d.tokens[e - 2];
            let occurrences =
                d.tokens[s..e - 2].iter().filter(|&&t| t == target).count();
            assert!(occurrences >= 2, "target must recur in context");
            checked += 1;
        }
        assert!(checked > 3);
    }

    #[test]
    fn calibration_windows_shape() {
        let g = gen();
        let d = g.generate(Profile::C4Like, 10_000, 7);
        let mut rng = Rng::new(0);
        let cal = d.sample_calibration(16, 128, &mut rng);
        assert_eq!(cal.len(), 16);
        assert!(cal.iter().all(|w| w.len() == 128));
    }

    #[test]
    fn eval_windows_cover_stream() {
        let g = gen();
        let d = g.generate(Profile::PtbLike, 4096, 8);
        let w = d.eval_windows(256);
        assert_eq!(w.len(), d.len() / 256);
    }
}
