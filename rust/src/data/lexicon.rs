//! Deterministic pseudo-word lexicon + part-of-speech structure.
//!
//! Stands in for natural vocabularies (C4/WikiText/PTB are unavailable
//! offline). Words are syllable-composed, partitioned into parts of speech
//! and topic clusters, and drawn with Zipfian frequencies — enough
//! statistical structure for a small LM to learn non-trivial second-order
//! activation statistics, which is all the pruning math consumes.

use crate::util::Rng;

const ONSETS: [&str; 12] = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t"];
const NUCLEI: [&str; 6] = ["a", "e", "i", "o", "u", "ai"];
const CODAS: [&str; 6] = ["", "n", "r", "s", "l", "m"];

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pos {
    Noun,
    Verb,
    Adj,
    Det,
    Prep,
    Conj,
}

/// A generated vocabulary with POS classes and topic affinities.
#[derive(Clone, Debug)]
pub struct Lexicon {
    pub words: Vec<String>,
    pub pos: Vec<Pos>,
    /// topic id per word (function words get usize::MAX = all topics).
    pub topic: Vec<usize>,
    pub n_topics: usize,
    nouns: Vec<Vec<usize>>, // per-topic noun ids
    verbs: Vec<Vec<usize>>,
    adjs: Vec<Vec<usize>>,
    dets: Vec<usize>,
    preps: Vec<usize>,
    conjs: Vec<usize>,
}

fn make_word(rng: &mut Rng, syllables: usize) -> String {
    let mut w = String::new();
    for _ in 0..syllables {
        w.push_str(ONSETS[rng.below(ONSETS.len())]);
        w.push_str(NUCLEI[rng.below(NUCLEI.len())]);
        w.push_str(CODAS[rng.below(CODAS.len())]);
    }
    w
}

impl Lexicon {
    /// Build a lexicon of ~`content_words` content words over `n_topics`
    /// topic clusters plus a fixed function-word inventory.
    pub fn generate(content_words: usize, n_topics: usize, seed: u64) -> Lexicon {
        let mut rng = Rng::new(seed);
        let mut words = Vec::new();
        let mut pos = Vec::new();
        let mut topic = Vec::new();
        let mut seen = std::collections::HashSet::new();

        let push_unique = |rng: &mut Rng, p: Pos, t: usize, words: &mut Vec<String>,
                               pos: &mut Vec<Pos>, topic: &mut Vec<usize>,
                               seen: &mut std::collections::HashSet<String>| {
            loop {
                let syl = 1 + rng.below(3);
                let w = make_word(rng, syl);
                if seen.insert(w.clone()) {
                    words.push(w);
                    pos.push(p);
                    topic.push(t);
                    return words.len() - 1;
                }
            }
        };

        // Function words: shared across topics (usize::MAX).
        let mut dets = Vec::new();
        let mut preps = Vec::new();
        let mut conjs = Vec::new();
        for _ in 0..6 {
            dets.push(push_unique(&mut rng, Pos::Det, usize::MAX, &mut words, &mut pos, &mut topic, &mut seen));
        }
        for _ in 0..8 {
            preps.push(push_unique(&mut rng, Pos::Prep, usize::MAX, &mut words, &mut pos, &mut topic, &mut seen));
        }
        for _ in 0..4 {
            conjs.push(push_unique(&mut rng, Pos::Conj, usize::MAX, &mut words, &mut pos, &mut topic, &mut seen));
        }

        // Content words split 50% nouns / 30% verbs / 20% adjectives,
        // distributed round-robin over topics.
        let mut nouns = vec![Vec::new(); n_topics];
        let mut verbs = vec![Vec::new(); n_topics];
        let mut adjs = vec![Vec::new(); n_topics];
        let n_nouns = content_words / 2;
        let n_verbs = content_words * 3 / 10;
        let n_adjs = content_words - n_nouns - n_verbs;
        for i in 0..n_nouns {
            let t = i % n_topics;
            nouns[t].push(push_unique(&mut rng, Pos::Noun, t, &mut words, &mut pos, &mut topic, &mut seen));
        }
        for i in 0..n_verbs {
            let t = i % n_topics;
            verbs[t].push(push_unique(&mut rng, Pos::Verb, t, &mut words, &mut pos, &mut topic, &mut seen));
        }
        for i in 0..n_adjs {
            let t = i % n_topics;
            adjs[t].push(push_unique(&mut rng, Pos::Adj, t, &mut words, &mut pos, &mut topic, &mut seen));
        }

        Lexicon { words, pos, topic, n_topics, nouns, verbs, adjs, dets, preps, conjs }
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Zipfian draw from a word class (rank r weight ~ 1/(r+1)).
    fn zipf(ids: &[usize], rng: &mut Rng) -> usize {
        debug_assert!(!ids.is_empty());
        let n = ids.len();
        // Inverse-CDF for 1/(r+1) weights via cached harmonic approximation.
        let h = (n as f64 + 1.0).ln();
        let u = rng.uniform() * h;
        let r = (u.exp() - 1.0).floor() as usize;
        ids[r.min(n - 1)]
    }

    pub fn noun(&self, t: usize, rng: &mut Rng) -> usize {
        Self::zipf(&self.nouns[t % self.n_topics], rng)
    }

    pub fn verb(&self, t: usize, rng: &mut Rng) -> usize {
        Self::zipf(&self.verbs[t % self.n_topics], rng)
    }

    pub fn adj(&self, t: usize, rng: &mut Rng) -> usize {
        Self::zipf(&self.adjs[t % self.n_topics], rng)
    }

    pub fn det(&self, rng: &mut Rng) -> usize {
        Self::zipf(&self.dets, rng)
    }

    pub fn prep(&self, rng: &mut Rng) -> usize {
        Self::zipf(&self.preps, rng)
    }

    pub fn conj(&self, rng: &mut Rng) -> usize {
        Self::zipf(&self.conjs, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = Lexicon::generate(100, 4, 7);
        let b = Lexicon::generate(100, 4, 7);
        assert_eq!(a.words, b.words);
    }

    #[test]
    fn unique_words() {
        let lex = Lexicon::generate(300, 8, 1);
        let set: std::collections::HashSet<_> = lex.words.iter().collect();
        assert_eq!(set.len(), lex.words.len());
    }

    #[test]
    fn topic_partition_covers_all_topics() {
        let lex = Lexicon::generate(200, 5, 2);
        for t in 0..5 {
            assert!(!lex.nouns[t].is_empty());
            assert!(!lex.verbs[t].is_empty());
            assert!(!lex.adjs[t].is_empty());
        }
    }

    #[test]
    fn zipf_head_heavier_than_tail() {
        let lex = Lexicon::generate(200, 2, 3);
        let mut rng = Rng::new(9);
        let mut counts = std::collections::HashMap::new();
        for _ in 0..20_000 {
            *counts.entry(lex.noun(0, &mut rng)).or_insert(0usize) += 1;
        }
        let head = lex.nouns[0][0];
        let tail = *lex.nouns[0].last().unwrap();
        assert!(counts.get(&head).copied().unwrap_or(0) > counts.get(&tail).copied().unwrap_or(0) * 2);
    }

    #[test]
    fn pos_classes_disjoint() {
        let lex = Lexicon::generate(100, 2, 4);
        for (i, p) in lex.pos.iter().enumerate() {
            match p {
                Pos::Det => assert!(lex.dets.contains(&i)),
                Pos::Prep => assert!(lex.preps.contains(&i)),
                _ => {}
            }
        }
    }
}
