//! Zero-shot task evaluation (paper Table 3): candidate selection by
//! length-normalized continuation log-likelihood + LAMBADA-style last-word
//! argmax accuracy.
//!
//! Choice scoring routes through the serving engine's batched
//! primitives: a task's context is prefilled ONCE through the threaded
//! Full-attention arm, then ALL candidate continuations score as one
//! batch ([`crate::serve::score_continuations`]) — every decode step
//! runs the still-live candidates through a single (B, d) matmul per
//! linear, instead of per-candidate single-stream steps (let alone the
//! full O(T²·L) re-forward per candidate the seed paid). LAMBADA is a
//! single prediction per task, so it stays on the single-stream
//! `predict_last` session path (parallelized across tasks, like the
//! choice suite).

use crate::data::{ChoiceTask, LastWordTask};
use crate::model::LanguageModel;
use crate::serve::score_continuations;
use crate::util::num_threads;

/// Accuracy on a choice suite (fraction of tasks where the model ranks the
/// correct candidate first by per-token-normalized log-prob).
pub fn choice_accuracy(model: &dyn LanguageModel, tasks: &[ChoiceTask]) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    let nt = num_threads().min(tasks.len());
    let chunk = tasks.len().div_ceil(nt);
    let correct = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for ts in tasks.chunks(chunk) {
            let correct = &correct;
            s.spawn(move || {
                let mut local = 0usize;
                for t in ts {
                    // all candidates of the task score as one batch
                    let lps = score_continuations(model, &t.context, &t.candidates);
                    let mut best = 0usize;
                    let mut best_lp = f64::NEG_INFINITY;
                    for (i, cand) in t.candidates.iter().enumerate() {
                        let lp =
                            if cand.is_empty() { 0.0 } else { lps[i] / cand.len() as f64 };
                        if lp > best_lp {
                            best_lp = lp;
                            best = i;
                        }
                    }
                    if best == t.answer {
                        local += 1;
                    }
                }
                correct.fetch_add(local, std::sync::atomic::Ordering::Relaxed);
            });
        }
    });
    correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / tasks.len() as f64
}

/// LAMBADA-style accuracy: exact argmax prediction of the final token.
pub fn lambada_accuracy(model: &dyn LanguageModel, tasks: &[LastWordTask]) -> f64 {
    if tasks.is_empty() {
        return 0.0;
    }
    let nt = num_threads().min(tasks.len());
    let chunk = tasks.len().div_ceil(nt);
    let correct = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for ts in tasks.chunks(chunk) {
            let correct = &correct;
            s.spawn(move || {
                let mut local = 0usize;
                for t in ts {
                    if model.predict_last(&t.context) == t.answer {
                        local += 1;
                    }
                }
                correct.fetch_add(local, std::sync::atomic::Ordering::Relaxed);
            });
        }
    });
    correct.load(std::sync::atomic::Ordering::Relaxed) as f64 / tasks.len() as f64
}

/// The Table 3 row: perplexity-free accuracy block.
#[derive(Clone, Debug)]
pub struct ZeroShotReport {
    pub lambada: f64,
    pub hellaswag: f64,
    pub piqa: f64,
    pub arc: f64,
    pub winogrande: f64,
}

impl ZeroShotReport {
    pub fn average(&self) -> f64 {
        (self.lambada + self.hellaswag + self.piqa + self.arc + self.winogrande) / 5.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusGen, Profile, TaskGen, TaskKind};
    use crate::model::{train, TrainConfig, Transformer, TransformerConfig};
    use crate::util::Rng;

    #[test]
    fn trained_model_beats_chance_on_choice_tasks() {
        let gen = CorpusGen::new(80, 4, 11);
        let data = gen.generate(Profile::C4Like, 40_000, 1);
        let vocab = gen.tokenizer.vocab_size();
        let mut model = Transformer::init(
            TransformerConfig { vocab, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 48, max_seq: 64 },
            &mut Rng::new(5),
        );
        train(
            &mut model,
            &data,
            &TrainConfig { steps: 150, batch: 8, seq_len: 32, log_every: 50, ..Default::default() },
        );
        let tg = TaskGen::new(&gen);
        let tasks = tg.choice_suite(TaskKind::HellaSwagLike, 60, 1);
        let acc = choice_accuracy(&model, &tasks);
        assert!(acc > 0.30, "4-way accuracy {acc} should beat 25% chance");
        // LAMBADA-like: a small trained model may or may not copy; just
        // check range + determinism.
        let lt = tg.lambada_suite(40, 2);
        let lacc = lambada_accuracy(&model, &lt);
        assert!((0.0..=1.0).contains(&lacc));
        assert_eq!(lacc, lambada_accuracy(&model, &lt));
    }

    #[test]
    fn untrained_model_near_chance() {
        let gen = CorpusGen::new(80, 4, 12);
        let vocab = gen.tokenizer.vocab_size();
        let model = Transformer::init(
            TransformerConfig { vocab, d_model: 16, n_layers: 1, n_heads: 2, d_ff: 24, max_seq: 64 },
            &mut Rng::new(6),
        );
        let tg = TaskGen::new(&gen);
        let tasks = tg.choice_suite(TaskKind::PiqaLike, 100, 3);
        let acc = choice_accuracy(&model, &tasks);
        assert!((acc - 0.5).abs() < 0.2, "2-way accuracy {acc} should be near 50%");
    }

    #[test]
    fn report_average() {
        let r = ZeroShotReport { lambada: 0.2, hellaswag: 0.3, piqa: 0.6, arc: 0.4, winogrande: 0.5 };
        assert!((r.average() - 0.4).abs() < 1e-12);
    }
}
