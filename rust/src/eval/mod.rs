//! Evaluation: strided perplexity and the zero-shot task suite (Table 3's
//! metrics). Both parallelize over windows/tasks with scoped threads.

pub mod zeroshot;

pub use zeroshot::{choice_accuracy, lambada_accuracy, ZeroShotReport};

use crate::data::Dataset;
use crate::model::{log_softmax_at, DecodeSession, LanguageModel};
use crate::util::num_threads;

/// Strided perplexity: exp(mean NLL) over non-overlapping `seq_len`
/// windows — the protocol SparseGPT/Wanda report (raw-WikiText2 style).
pub fn perplexity(model: &dyn LanguageModel, data: &Dataset, seq_len: usize) -> f64 {
    let windows = data.eval_windows(seq_len);
    assert!(!windows.is_empty(), "dataset shorter than seq_len");
    perplexity_windows(model, &windows)
}

/// Perplexity over explicit windows (used by calibration-overlap ablation).
pub fn perplexity_windows(model: &dyn LanguageModel, windows: &[&[u32]]) -> f64 {
    let nt = num_threads().min(windows.len().max(1));
    let chunk = windows.len().div_ceil(nt);
    let totals = std::sync::Mutex::new((0.0f64, 0usize));
    std::thread::scope(|s| {
        for ws in windows.chunks(chunk) {
            let totals = &totals;
            s.spawn(move || {
                let mut nll = 0.0;
                let mut n = 0usize;
                for w in ws {
                    let lp = model.next_token_logprobs(w, (1, w.len()));
                    nll -= lp.iter().sum::<f64>();
                    n += lp.len();
                }
                let mut t = totals.lock().unwrap();
                t.0 += nll;
                t.1 += n;
            });
        }
    });
    let (nll, n) = totals.into_inner().unwrap();
    (nll / n.max(1) as f64).exp()
}

/// Streaming perplexity from ONE sliding-window [`DecodeSession`]:
/// for a transformer, every token is scored given the previous
/// `min(pos, window)` tokens, reusing the overlapping context across
/// positions instead of re-forwarding each window — O(N·W·L) total vs
/// O(N·W²·L) for per-window full forwards. The window only bounds
/// transformer K/V: a mamba session carries its O(1) recurrent state
/// through the WHOLE stream (O(N·L) total, unbounded conditioning), so
/// same-`window` numbers are not comparable across the two families.
///
/// This is a *variant*, not a replacement: the strided full-forward
/// [`perplexity`] stays the oracle the tables report. The streaming
/// number differs by design — every position past the first window sees
/// a full `window`-token context (no stride cliff), but the transformer
/// attends through an evicted-K/V approximation rather than an exact
/// re-forward. With `window >= data.len()` the two paths see identical
/// contexts and the session math is pinned to the full forward.
pub fn perplexity_streaming(model: &dyn LanguageModel, data: &Dataset, window: usize) -> f64 {
    assert!(window >= 1, "window must hold at least one position");
    let toks = &data.tokens;
    assert!(toks.len() >= 2, "dataset too short to score");
    let mut s = DecodeSession::with_window(model, window);
    s.prefill(&toks[..1]);
    let mut nll = 0.0f64;
    for (i, &t) in toks.iter().enumerate().skip(1) {
        nll -= log_softmax_at(s.last_logits(), t as usize);
        if i + 1 < toks.len() {
            s.step(t);
        }
    }
    (nll / (toks.len() - 1) as f64).exp()
}

/// Fraction of next-token positions where the draft's greedy argmax
/// equals the target's, over the given eval windows — a cheap offline
/// predictor of speculative-decoding acceptance rate (the verifier
/// accepts a proposal exactly when the two argmaxes agree on the true
/// prefix). Use it to choose a draft sparsity before paying for a
/// serving run: acceptance ≈ agreement, and speedup needs agreement to
/// clear `k·cost_draft/cost_target` (see PERF.md iteration 8).
/// Parallelizes over windows like [`perplexity_windows`].
pub fn greedy_agreement(
    target: &dyn LanguageModel,
    draft: &dyn LanguageModel,
    windows: &[&[u32]],
) -> f64 {
    assert_eq!(target.vocab(), draft.vocab(), "draft and target must share a vocabulary");
    assert!(!windows.is_empty(), "agreement needs at least one window");
    let nt = num_threads().min(windows.len());
    let chunk = windows.len().div_ceil(nt);
    let totals = std::sync::Mutex::new((0usize, 0usize));
    std::thread::scope(|s| {
        for ws in windows.chunks(chunk) {
            let totals = &totals;
            s.spawn(move || {
                let mut agree = 0usize;
                let mut n = 0usize;
                for w in ws {
                    let bt = (1, w.len());
                    let pt = target.next_token_argmaxes(w, bt);
                    let pd = draft.next_token_argmaxes(w, bt);
                    agree += pt.iter().zip(&pd).filter(|(a, b)| a == b).count();
                    n += pt.len();
                }
                let mut t = totals.lock().unwrap();
                t.0 += agree;
                t.1 += n;
            });
        }
    });
    let (agree, n) = totals.into_inner().unwrap();
    agree as f64 / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusGen, Profile};
    use crate::model::{train, TrainConfig, Transformer, TransformerConfig};
    use crate::util::Rng;

    fn trained_setup() -> (CorpusGen, Dataset, Dataset, Transformer) {
        let gen = CorpusGen::new(60, 2, 7);
        let train_data = gen.generate(Profile::C4Like, 30_000, 1);
        let eval_data = gen.generate(Profile::Wt2Like, 4_096, 2);
        let vocab = gen.tokenizer.vocab_size();
        let mut model = Transformer::init(
            TransformerConfig { vocab, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 48, max_seq: 64 },
            &mut Rng::new(3),
        );
        let cfg = TrainConfig { steps: 120, batch: 8, seq_len: 32, log_every: 40, ..Default::default() };
        train(&mut model, &train_data, &cfg);
        (gen, train_data, eval_data, model)
    }

    #[test]
    fn perplexity_finite_and_better_than_uniform() {
        let (gen, _tr, eval_data, model) = trained_setup();
        let ppl = perplexity(&model, &eval_data, 64);
        let uniform = gen.tokenizer.vocab_size() as f64;
        assert!(ppl.is_finite() && ppl > 1.0);
        assert!(ppl < uniform * 0.8, "trained ppl {ppl} should beat uniform {uniform}");
    }

    #[test]
    fn perplexity_deterministic() {
        let (_g, _tr, eval_data, model) = trained_setup();
        let a = perplexity(&model, &eval_data, 64);
        let b = perplexity(&model, &eval_data, 64);
        assert_eq!(a, b);
    }

    #[test]
    fn streaming_perplexity_matches_full_forward_when_window_covers_data() {
        use crate::model::{Mamba, MambaConfig};
        let toks: Vec<u32> = (0..24).map(|i| (i * 5 % 17) as u32).collect();
        let data = Dataset {
            tokens: toks.clone(),
            doc_spans: vec![(0, toks.len())],
            profile: Profile::Wt2Like,
        };
        let mut rng = Rng::new(21);
        let t = Transformer::init(
            TransformerConfig { vocab: 17, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, max_seq: 32 },
            &mut rng,
        );
        let m = Mamba::init(
            MambaConfig { vocab: 17, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 32 },
            &mut rng,
        );
        for model in [Box::new(t) as Box<dyn LanguageModel>, Box::new(m)] {
            // oracle: one full forward over the whole stream
            let lp = model.next_token_logprobs(&toks, (1, toks.len()));
            let oracle = (-lp.iter().sum::<f64>() / lp.len() as f64).exp();
            let streamed = perplexity_streaming(model.as_ref(), &data, toks.len());
            assert!(
                (streamed.ln() - oracle.ln()).abs() < 1e-5,
                "{}: {streamed} vs {oracle}",
                model.arch()
            );
        }
    }

    #[test]
    fn streaming_perplexity_bounded_window_is_finite_and_deterministic() {
        let toks: Vec<u32> = (0..40).map(|i| (i * 7 % 17) as u32).collect();
        let data = Dataset {
            tokens: toks,
            doc_spans: vec![(0, 40)],
            profile: Profile::Wt2Like,
        };
        let model = Transformer::init(
            TransformerConfig { vocab: 17, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, max_seq: 64 },
            &mut Rng::new(22),
        );
        let a = perplexity_streaming(&model, &data, 8);
        assert!(a.is_finite() && a > 1.0);
        assert_eq!(a, perplexity_streaming(&model, &data, 8));
    }

    #[test]
    fn greedy_agreement_is_one_for_self_and_drops_for_unrelated_draft() {
        let toks: Vec<u32> = (0..48).map(|i| (i * 5 % 17) as u32).collect();
        let windows: Vec<&[u32]> = toks.chunks(16).collect();
        let t = Transformer::init(
            TransformerConfig { vocab: 17, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, max_seq: 32 },
            &mut Rng::new(31),
        );
        assert_eq!(greedy_agreement(&t, &t, &windows), 1.0, "self-agreement");
        // an unrelated draft should agree less than perfectly (argmax
        // collisions are possible but not universal at vocab 17)
        let other = Transformer::init(
            TransformerConfig { vocab: 17, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, max_seq: 32 },
            &mut Rng::new(32),
        );
        let a = greedy_agreement(&t, &other, &windows);
        assert!((0.0..1.0).contains(&a), "agreement {a}");
        assert_eq!(a, greedy_agreement(&t, &other, &windows), "deterministic");
    }

    #[test]
    fn damaging_weights_increases_perplexity() {
        let (_g, _tr, eval_data, mut model) = trained_setup();
        let before = perplexity(&model, &eval_data, 64);
        // zero half of every attention projection crudely
        for b in 0..2 {
            for name in ["wq", "wk", "wv", "wo", "w1", "w2", "w3"] {
                let w = model.weight_mut(b, name).dense_mut();
                for i in 0..w.data.len() {
                    if i % 2 == 0 {
                        w.data[i] = 0.0;
                    }
                }
            }
        }
        let after = perplexity(&model, &eval_data, 64);
        assert!(after > before, "{after} vs {before}");
    }
}
