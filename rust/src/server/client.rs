//! Minimal loopback HTTP/1.1 client — just enough to drive this
//! server from the load harness (`benches/loadgen.rs`), the CI smokes
//! (`examples/http_serve.rs`, `examples/chaos_serve.rs`) and the test
//! suites. NOT a general HTTP client: `Content-Length` or chunked
//! response bodies, no redirects, no TLS — exactly the subset the
//! server speaks.
//!
//! Two shapes:
//! - the free functions ([`request`], [`open_stream`], …) are one-shot:
//!   one connection per call, `Connection: close`, with the read
//!   timeout caller-configurable via [`request_with_timeout`];
//! - [`Client`] holds a keep-alive connection and reuses it across
//!   requests, reconnecting transparently when the server (or the
//!   per-connection request cap) closes it — the client half of the
//!   server's keep-alive support, so tests and loadgen can measure
//!   connection reuse honestly.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::json::{self, Json};

/// Default read timeout: generous, so a wedged server fails a test
/// instead of hanging it. Every entry point has a `_with_timeout`
/// variant (or [`Client::with_timeout`]) for callers that need a short,
/// explicit bound — stall tests, open-loop load generation.
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(30);

/// A complete (non-streamed or fully-collected) response.
#[derive(Clone, Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// Parse the body as JSON (the generate endpoint's responses).
    pub fn json(&self) -> Result<Json, String> {
        json::parse(std::str::from_utf8(&self.body).map_err(|e| e.to_string())?)
    }
}

fn connect(addr: SocketAddr, timeout: Duration) -> io::Result<TcpStream> {
    let s = TcpStream::connect(addr)?;
    s.set_nodelay(true)?;
    s.set_read_timeout(Some(timeout))?;
    Ok(s)
}

fn write_request(
    s: &mut TcpStream,
    method: &str,
    path: &str,
    body: Option<&str>,
    close: bool,
) -> io::Result<()> {
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: loopback\r\n");
    if let Some(b) = body {
        head.push_str(&format!("Content-Type: application/json\r\nContent-Length: {}\r\n", b.len()));
    }
    if close {
        head.push_str("Connection: close\r\n");
    }
    head.push_str("\r\n");
    s.write_all(head.as_bytes())?;
    if let Some(b) = body {
        s.write_all(b.as_bytes())?;
    }
    s.flush()
}

/// Read `HTTP/1.1 <status> <reason>` plus headers off the reader.
fn read_head(r: &mut impl BufRead) -> io::Result<(u16, Vec<(String, String)>)> {
    let mut line = String::new();
    r.read_line(&mut line)?;
    let status = line.split(' ').nth(1).and_then(|s| s.parse::<u16>().ok()).ok_or_else(|| {
        io::Error::new(io::ErrorKind::InvalidData, format!("bad status line: {line:?}"))
    })?;
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        r.read_line(&mut line)?;
        let line = line.trim_end_matches(['\r', '\n']);
        if line.is_empty() {
            break;
        }
        if let Some((n, v)) = line.split_once(':') {
            headers.push((n.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    Ok((status, headers))
}

/// Read one chunk of a chunked body: `Some(data)` per frame, `None` at
/// the terminal zero-length chunk.
fn read_chunk(r: &mut impl BufRead) -> io::Result<Option<Vec<u8>>> {
    let mut size_line = String::new();
    r.read_line(&mut size_line)?;
    let size = usize::from_str_radix(size_line.trim(), 16).map_err(|_| {
        io::Error::new(io::ErrorKind::InvalidData, format!("bad chunk size: {size_line:?}"))
    })?;
    let mut data = vec![0u8; size + 2]; // chunk + trailing CRLF
    r.read_exact(&mut data)?;
    data.truncate(size);
    Ok(if size == 0 { None } else { Some(data) })
}

/// Read one complete response off the reader, consuming exactly its
/// bytes (so a keep-alive connection is positioned at the next
/// response afterwards). Chunked bodies are collected whole.
fn read_response(r: &mut BufReader<TcpStream>) -> io::Result<Response> {
    let (status, headers) = read_head(r)?;
    let resp = Response { status, headers, body: Vec::new() };
    let mut body = Vec::new();
    if resp.header("transfer-encoding").is_some_and(|v| v.eq_ignore_ascii_case("chunked")) {
        while let Some(chunk) = read_chunk(r)? {
            body.extend_from_slice(&chunk);
        }
    } else if let Some(n) = resp.header("content-length").and_then(|v| v.parse::<usize>().ok()) {
        body.resize(n, 0);
        r.read_exact(&mut body)?;
    } else {
        // no framing: the body runs to EOF (and the connection is dead)
        r.read_to_end(&mut body)?;
    }
    Ok(Response { body, ..resp })
}

/// One complete request/response round trip on a fresh `Connection:
/// close` connection, under [`DEFAULT_TIMEOUT`]. Chunked responses are
/// collected whole — use [`open_stream`] to consume chunks as they
/// arrive (or to abandon the stream mid-flight).
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> io::Result<Response> {
    request_with_timeout(addr, method, path, body, DEFAULT_TIMEOUT)
}

/// [`request`] with a caller-chosen read timeout — stall tests and
/// open-loop load generation need short, explicit bounds, not the
/// test-friendly 30 s default.
pub fn request_with_timeout(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> io::Result<Response> {
    let mut s = connect(addr, timeout)?;
    write_request(&mut s, method, path, body, true)?;
    read_response(&mut BufReader::new(s))
}

/// Write raw bytes (an intentionally malformed or deliberately partial
/// request) and return the response status. The socket stays open on
/// the write side — a partial request here looks to the server exactly
/// like a stalled client, which is what the 408 tests need.
pub fn raw_roundtrip_status(addr: SocketAddr, raw: &str) -> io::Result<u16> {
    let mut s = connect(addr, DEFAULT_TIMEOUT)?;
    s.write_all(raw.as_bytes())?;
    s.flush()?;
    let mut r = BufReader::new(s);
    Ok(read_head(&mut r)?.0)
}

/// A keep-alive client: holds one connection to `addr` and reuses it
/// across [`Client::request`] calls, reconnecting transparently when
/// the server closes it (idle timeout, per-connection request cap,
/// `Connection: close` response) or when a reused connection turns out
/// to be stale mid-roundtrip. [`Client::connects_made`] counts actual
/// TCP connects, so tests and loadgen can pin reuse honestly.
pub struct Client {
    addr: SocketAddr,
    timeout: Duration,
    conn: Option<BufReader<TcpStream>>,
    connects: usize,
}

impl Client {
    pub fn new(addr: SocketAddr) -> Client {
        Client::with_timeout(addr, DEFAULT_TIMEOUT)
    }

    pub fn with_timeout(addr: SocketAddr, timeout: Duration) -> Client {
        Client { addr, timeout, conn: None, connects: 0 }
    }

    /// TCP connections opened so far (1 after the first request if the
    /// server keeps the connection alive).
    pub fn connects_made(&self) -> usize {
        self.connects
    }

    /// One round trip, reusing the held connection when there is one.
    /// A reused connection that fails mid-roundtrip is presumed stale
    /// (the server closed it between requests — a race keep-alive
    /// clients must absorb) and retried ONCE on a fresh connection;
    /// errors on a fresh connection propagate.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> io::Result<Response> {
        let had_conn = self.conn.is_some();
        match self.roundtrip(method, path, body) {
            Ok(resp) => Ok(resp),
            Err(e) if had_conn => {
                // stale reuse: reconnect and retry the idempotent-by-
                // construction request once
                self.conn = None;
                let _ = e;
                self.roundtrip(method, path, body)
            }
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }

    fn roundtrip(&mut self, method: &str, path: &str, body: Option<&str>) -> io::Result<Response> {
        if self.conn.is_none() {
            let s = connect(self.addr, self.timeout)?;
            self.connects += 1;
            self.conn = Some(BufReader::new(s));
        }
        let r = self.conn.as_mut().expect("connection just ensured");
        let result = write_request(r.get_mut(), method, path, body, false)
            .and_then(|()| read_response(r));
        match result {
            Ok(resp) => {
                // the server said close (cap reached, shutdown): honor
                // it so the next request reconnects instead of failing
                let closing = resp.header("connection").is_some_and(|v| v.contains("close"))
                    || resp.header("content-length").is_none()
                        && resp.header("transfer-encoding").is_none();
                if closing {
                    self.conn = None;
                }
                Ok(resp)
            }
            Err(e) => {
                self.conn = None;
                Err(e)
            }
        }
    }
}

/// An open streaming response. Chunks arrive via [`Stream::next_chunk`];
/// dropping the value mid-stream closes the socket — exactly the
/// client-disconnect path the server must survive (and cancel on).
pub struct Stream {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    r: BufReader<TcpStream>,
}

impl Stream {
    /// Next chunk, or `None` at the terminal chunk.
    pub fn next_chunk(&mut self) -> io::Result<Option<Vec<u8>>> {
        read_chunk(&mut self.r)
    }
}

/// POST `body` to `path` and hand back the response as an open stream.
pub fn open_stream(addr: SocketAddr, path: &str, body: &str) -> io::Result<Stream> {
    let mut s = connect(addr, DEFAULT_TIMEOUT)?;
    write_request(&mut s, "POST", path, Some(body), true)?;
    let mut r = BufReader::new(s);
    let (status, headers) = read_head(&mut r)?;
    Ok(Stream { status, headers, r })
}

/// [`open_stream`] + collect every chunk until the stream ends.
pub fn stream_request(
    addr: SocketAddr,
    path: &str,
    body: &str,
) -> io::Result<(u16, Vec<Vec<u8>>)> {
    let mut st = open_stream(addr, path, body)?;
    let mut chunks = Vec::new();
    while let Some(c) = st.next_chunk()? {
        chunks.push(c);
    }
    Ok((st.status, chunks))
}

/// Split collected generate-stream chunks into (tokens, terminal
/// object): `{"token": N}` lines accumulate, the `{"done": true, ...}`
/// line is returned parsed.
pub fn split_stream(chunks: &[Vec<u8>]) -> (Vec<u32>, Option<Json>) {
    let mut toks = Vec::new();
    let mut done = None;
    for c in chunks {
        let Ok(text) = std::str::from_utf8(c) else { continue };
        for line in text.lines() {
            let Ok(v) = json::parse(line) else { continue };
            if let Some(t) = v.get("token").and_then(Json::as_f64) {
                toks.push(t as u32);
            } else if v.get("done").is_some() {
                done = Some(v);
            }
        }
    }
    (toks, done)
}

/// Pull one `name value` line out of a `/metrics` exposition.
pub fn metric(text: &str, name: &str) -> Option<usize> {
    text.lines().find_map(|l| {
        let (k, v) = l.split_once(' ')?;
        if k == name {
            v.trim().parse::<usize>().ok()
        } else {
            None
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn head_and_chunk_parsing() {
        let wire = b"HTTP/1.1 429 Too Many Requests\r\nRetry-After: 1\r\nContent-Length: 0\r\n\r\n";
        let mut r = Cursor::new(&wire[..]);
        let (status, headers) = read_head(&mut r).unwrap();
        assert_eq!(status, 429);
        let retry = headers.iter().find(|(n, _)| n == "retry-after").map(|(_, v)| v.as_str());
        assert_eq!(retry, Some("1"));

        let chunks = b"c\r\n{\"token\":5}\n\r\n0\r\n\r\n";
        let mut r = Cursor::new(&chunks[..]);
        assert_eq!(read_chunk(&mut r).unwrap().as_deref(), Some(&b"{\"token\":5}\n"[..]));
        assert_eq!(read_chunk(&mut r).unwrap(), None);
    }

    #[test]
    fn split_stream_separates_tokens_from_terminal() {
        let chunks: Vec<Vec<u8>> = vec![
            b"{\"token\":3}\n".to_vec(),
            b"{\"token\":9}\n".to_vec(),
            b"{\"done\":true,\"finish\":\"length\",\"tokens_generated\":2}\n".to_vec(),
        ];
        let (toks, done) = split_stream(&chunks);
        assert_eq!(toks, vec![3, 9]);
        assert_eq!(done.unwrap().get("finish").unwrap().as_str(), Some("length"));
    }

    #[test]
    fn metric_lookup() {
        let text = "apt_up 1\napt_engine_kv_pages_live 0\napt_http_requests_total 7\n";
        assert_eq!(metric(text, "apt_engine_kv_pages_live"), Some(0));
        assert_eq!(metric(text, "apt_http_requests_total"), Some(7));
        assert_eq!(metric(text, "apt_missing"), None);
    }
}
