//! Route layer: maps parsed HTTP requests onto engine commands.
//!
//! Three endpoints:
//! - `POST /v1/generate` — JSON body → [`Request`] (+ optional
//!   [`Deadline`]); plain mode answers one JSON object when the typed
//!   [`Completion`] arrives, `"stream": true` answers chunked
//!   transfer encoding with one NDJSON line per token and a terminal
//!   line carrying the [`FinishReason`];
//! - `GET /metrics` — plain-text exposition of the engine's
//!   [`EngineSnapshot`] and the server's HTTP [`Counters`];
//! - `GET /healthz` — liveness.
//!
//! Every worker runs [`handle_connection`] once: parse, route, answer,
//! close. A streaming client that disconnects mid-response triggers
//! `Cmd::Cancel`, so the engine reclaims the stream's K/V pages
//! immediately instead of generating for a ghost.

use std::io::BufReader;
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::Arc;

use super::http::{self, ChunkedWriter, HttpRequest, ParseError};
use super::{Cmd, Counters, StreamEvent, SubmitReply};
use crate::json::{self, Json};
use crate::serve::{
    Completion, Deadline, EngineSnapshot, ErrorKind, FinishReason, Request, RequestId,
    SamplingParams,
};

/// Everything a worker thread needs: the driver's command channel, the
/// shared counters, and the request-validation knobs captured at
/// startup.
#[derive(Clone)]
pub(crate) struct Ctx {
    pub cmd: Sender<Cmd>,
    pub counters: Arc<Counters>,
    pub vocab: usize,
    pub max_body: usize,
    pub default_max_new: usize,
    pub retry_after_s: u32,
}

/// One connection, one request, one response.
pub(crate) fn handle_connection(stream: TcpStream, ctx: &Ctx) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    let req = match http::parse_request(&mut reader, ctx.max_body) {
        Ok(r) => r,
        Err(ParseError::Closed) => return,
        Err(e) => {
            let (status, reason, msg) = http::status_for(&e);
            match status {
                413 => Counters::bump(&ctx.counters.http_413),
                _ => Counters::bump(&ctx.counters.http_400),
            }
            let _ = http::write_response(
                &mut stream,
                status,
                reason,
                "text/plain",
                &[],
                format!("{msg}\n").as_bytes(),
            );
            return;
        }
    };
    Counters::bump(&ctx.counters.http_requests);
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let _ = http::write_response(&mut stream, 200, "OK", "text/plain", &[], b"ok\n");
        }
        ("GET", "/metrics") => metrics(&mut stream, ctx),
        ("POST", "/v1/generate") => generate(&mut stream, ctx, &req),
        // known routes, wrong method: say so instead of a blanket 404
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/generate") => {
            let _ = http::write_response(
                &mut stream,
                405,
                "Method Not Allowed",
                "text/plain",
                &[],
                b"method not allowed\n",
            );
        }
        _ => {
            Counters::bump(&ctx.counters.http_404);
            let _ = http::write_response(
                &mut stream,
                404,
                "Not Found",
                "text/plain",
                &[],
                b"unknown route\n",
            );
        }
    }
}

// ------------------------------------------------------------------ metrics

/// `/metrics`: ask the driver for one consistent [`EngineSnapshot`] and
/// render it with the HTTP counters as `name value` lines (the
/// Prometheus text idiom, minus types — every value is a gauge or a
/// monotone counter, the `_total` suffix says which).
fn metrics(stream: &mut TcpStream, ctx: &Ctx) {
    let (tx, rx) = std::sync::mpsc::channel();
    if ctx.cmd.send(Cmd::Snapshot(tx)).is_err() {
        let _ = http::write_response(
            stream,
            503,
            "Service Unavailable",
            "text/plain",
            &[],
            b"engine is shut down\n",
        );
        return;
    }
    let Ok(snap) = rx.recv() else {
        let _ = http::write_response(
            stream,
            503,
            "Service Unavailable",
            "text/plain",
            &[],
            b"engine is shut down\n",
        );
        return;
    };
    let text = render_metrics(&snap, &ctx.counters);
    let _ = http::write_response(stream, 200, "OK", "text/plain", &[], text.as_bytes());
}

pub(crate) fn render_metrics(s: &EngineSnapshot, c: &Counters) -> String {
    let st = &s.stats;
    let mut out = String::with_capacity(1024);
    let mut line = |k: &str, v: usize| {
        out.push_str(k);
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    };
    line("apt_up", 1);
    // engine: gauges first, then the cumulative ledger
    line("apt_engine_queue_depth", s.queued);
    line("apt_engine_streams_active", s.active);
    line("apt_engine_kv_pages_live", s.kv_pages_live);
    line("apt_engine_kv_pages_peak", st.kv_pages_peak);
    line("apt_engine_completions_total", st.completed);
    line("apt_engine_completions_length_total", st.finished_length());
    line("apt_engine_completions_deadline_total", st.deadline_expired);
    line("apt_engine_completions_cancelled_total", st.cancelled);
    line("apt_engine_completions_error_total", st.quarantined);
    line("apt_engine_preemptions_total", st.preemptions);
    line("apt_engine_draft_fallbacks_total", st.draft_fallbacks);
    line("apt_engine_tokens_generated_total", st.tokens_generated);
    // server-side HTTP ledger
    let rel = |a: &std::sync::atomic::AtomicUsize| a.load(Ordering::Relaxed);
    line("apt_http_requests_total", rel(&c.http_requests));
    line("apt_http_responses_429_total", rel(&c.http_429));
    line("apt_http_responses_400_total", rel(&c.http_400));
    line("apt_http_responses_404_total", rel(&c.http_404));
    line("apt_http_responses_413_total", rel(&c.http_413));
    line("apt_http_stream_disconnects_total", rel(&c.stream_disconnects));
    out
}

// ----------------------------------------------------------------- generate

/// The decoded body of a `POST /v1/generate`.
struct GenSpec {
    req: Request,
    deadline: Deadline,
    stream: bool,
}

fn generate(stream: &mut TcpStream, ctx: &Ctx, req: &HttpRequest) {
    let spec = match parse_generate(&req.body, ctx) {
        Ok(s) => s,
        Err(msg) => {
            Counters::bump(&ctx.counters.http_400);
            let mut o = Json::obj();
            o.set("error", Json::Str(msg));
            let _ = http::write_response(
                stream,
                400,
                "Bad Request",
                "application/json",
                &[],
                format!("{}\n", o.to_string()).as_bytes(),
            );
            return;
        }
    };
    let (ev_tx, ev_rx) = std::sync::mpsc::channel::<StreamEvent>();
    let (rp_tx, rp_rx) = std::sync::mpsc::channel::<SubmitReply>();
    let submitted = ctx
        .cmd
        .send(Cmd::Submit { req: spec.req, deadline: spec.deadline, events: ev_tx, reply: rp_tx })
        .is_ok();
    let reply = if submitted { rp_rx.recv().ok() } else { None };
    let id = match reply {
        None => {
            let _ = http::write_response(
                stream,
                503,
                "Service Unavailable",
                "text/plain",
                &[],
                b"engine is shut down\n",
            );
            return;
        }
        Some(SubmitReply::Busy { queued }) => {
            Counters::bump(&ctx.counters.http_429);
            let retry = ctx.retry_after_s.to_string();
            let mut o = Json::obj();
            o.set("error", Json::Str(format!("pending queue is full ({queued} waiting)")));
            let _ = http::write_response(
                stream,
                429,
                "Too Many Requests",
                "application/json",
                &[("Retry-After", retry.as_str())],
                format!("{}\n", o.to_string()).as_bytes(),
            );
            return;
        }
        Some(SubmitReply::Accepted(id)) => id,
    };
    if spec.stream {
        stream_completion(stream, ctx, id, &ev_rx);
    } else {
        wait_completion(stream, &ev_rx);
    }
}

/// Plain mode: ignore token events, answer when `Done` arrives.
fn wait_completion(stream: &mut TcpStream, ev_rx: &std::sync::mpsc::Receiver<StreamEvent>) {
    loop {
        match ev_rx.recv() {
            Ok(StreamEvent::Token(_)) => {}
            Ok(StreamEvent::Done(c)) => {
                let body = format!("{}\n", completion_json(&c).to_string());
                let _ = http::write_response(
                    stream,
                    200,
                    "OK",
                    "application/json",
                    &[],
                    body.as_bytes(),
                );
                return;
            }
            Err(_) => {
                // driver gone mid-request (shutdown drains normally make
                // this unreachable; a panicked driver does not)
                let _ = http::write_response(
                    stream,
                    503,
                    "Service Unavailable",
                    "text/plain",
                    &[],
                    b"engine is shut down\n",
                );
                return;
            }
        }
    }
}

/// Streaming mode: one NDJSON chunk per token as it is sampled, then a
/// terminal chunk with the typed finish reason. A failed chunk write
/// means the client is gone: cancel the engine request (its K/V pages
/// reclaim immediately), drain the event channel to its `Done`, and
/// give up on the socket.
fn stream_completion(
    stream: &mut TcpStream,
    ctx: &Ctx,
    id: RequestId,
    ev_rx: &std::sync::mpsc::Receiver<StreamEvent>,
) {
    let Ok(mut cw) = ChunkedWriter::begin(stream, 200, "OK", "application/x-ndjson") else {
        cancel_and_drain(ctx, id, ev_rx);
        return;
    };
    loop {
        match ev_rx.recv() {
            Ok(StreamEvent::Token(t)) => {
                let mut o = Json::obj();
                o.set("token", Json::Num(t as f64));
                if cw.chunk(format!("{}\n", o.to_string()).as_bytes()).is_err() {
                    Counters::bump(&ctx.counters.stream_disconnects);
                    cancel_and_drain(ctx, id, ev_rx);
                    return;
                }
            }
            Ok(StreamEvent::Done(c)) => {
                let mut o = Json::obj();
                o.set("done", Json::Bool(true))
                    .set("id", Json::Num(c.id.0 as f64))
                    .set("finish", Json::Str(finish_str(c.finish).to_string()))
                    .set("tokens_generated", Json::Num(c.tokens.len() as f64));
                let _ = cw.chunk(format!("{}\n", o.to_string()).as_bytes());
                let _ = cw.finish();
                return;
            }
            Err(_) => return, // driver gone; nothing more will arrive
        }
    }
}

/// Disconnect path: ask the driver to cancel, then drain events until
/// the (possibly already in-flight) `Done` arrives so the driver never
/// blocks on a full channel. The completion itself is discarded — its
/// client left.
fn cancel_and_drain(ctx: &Ctx, id: RequestId, ev_rx: &std::sync::mpsc::Receiver<StreamEvent>) {
    let _ = ctx.cmd.send(Cmd::Cancel(id));
    loop {
        match ev_rx.recv() {
            Ok(StreamEvent::Done(_)) | Err(_) => return,
            Ok(StreamEvent::Token(_)) => {}
        }
    }
}

// ------------------------------------------------------------------- bodies

pub(crate) fn finish_str(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::Deadline => "deadline",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Error(ErrorKind::NonFiniteLogits) => "error:non_finite_logits",
    }
}

pub(crate) fn completion_json(c: &Completion) -> Json {
    let mut o = Json::obj();
    o.set("id", Json::Num(c.id.0 as f64))
        .set("finish", Json::Str(finish_str(c.finish).to_string()))
        .set("prompt_tokens", Json::Num(c.prompt.len() as f64))
        .set(
            "tokens",
            Json::Arr(c.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        );
    o
}

/// Decode + validate a generate body. Every defect answers with a
/// message naming it — a serving API that just says "400" wastes its
/// callers' time.
fn parse_generate(body: &[u8], ctx: &Ctx) -> Result<GenSpec, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let usize_field = |name: &str| -> Result<Option<usize>, String> {
        match v.get(name) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => {
                let n = j.as_f64().ok_or_else(|| format!("{name} must be a number"))?;
                if n.fract() != 0.0 || n < 0.0 {
                    return Err(format!("{name} must be a non-negative integer"));
                }
                Ok(Some(n as usize))
            }
        }
    };
    let prompt_json = v.get("prompt").ok_or_else(|| "missing field: prompt".to_string())?;
    let arr = prompt_json.as_arr().ok_or_else(|| "prompt must be an array".to_string())?;
    if arr.is_empty() {
        return Err("prompt must be non-empty".to_string());
    }
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let n = t.as_f64().ok_or_else(|| format!("prompt[{i}] is not a number"))?;
        if n.fract() != 0.0 || n < 0.0 || (n as usize) >= ctx.vocab {
            return Err(format!(
                "prompt[{i}] = {n} is not a token id in vocab range 0..{}",
                ctx.vocab
            ));
        }
        prompt.push(n as u32);
    }
    let max_new = usize_field("max_new_tokens")?.unwrap_or(ctx.default_max_new);
    let temperature = match v.get("temperature") {
        None | Some(Json::Null) => 0.0f32,
        Some(j) => j.as_f64().ok_or_else(|| "temperature must be a number".to_string())? as f32,
    };
    let top_k = match usize_field("top_k")? {
        Some(0) => return Err("top_k must be at least 1".to_string()),
        k => k,
    };
    let seed = usize_field("seed")?.unwrap_or(0) as u64;
    let stream = match v.get("stream") {
        None | Some(Json::Null) => false,
        Some(j) => j.as_bool().ok_or_else(|| "stream must be a boolean".to_string())?,
    };
    let deadline = Deadline {
        max_steps: usize_field("deadline_steps")?,
        max_wait_rounds: usize_field("deadline_wait_rounds")?,
    };
    let sampling = SamplingParams { temperature, top_k, seed };
    Ok(GenSpec { req: Request { prompt, max_new_tokens: max_new, sampling }, deadline, stream })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::EngineStats;

    fn ctx_for_parse(vocab: usize) -> Ctx {
        let (cmd, _rx) = std::sync::mpsc::channel();
        Ctx {
            cmd,
            counters: Arc::new(Counters::default()),
            vocab,
            max_body: 1 << 20,
            default_max_new: 32,
            retry_after_s: 1,
        }
    }

    #[test]
    fn parse_generate_full_body() {
        let ctx = ctx_for_parse(50);
        let spec = parse_generate(
            br#"{"prompt": [1, 2, 3], "max_new_tokens": 9, "temperature": 0.8,
                "top_k": 4, "seed": 11, "stream": true, "deadline_steps": 6,
                "deadline_wait_rounds": 2}"#,
            &ctx,
        )
        .unwrap();
        assert_eq!(spec.req.prompt, vec![1, 2, 3]);
        assert_eq!(spec.req.max_new_tokens, 9);
        assert!((spec.req.sampling.temperature - 0.8).abs() < 1e-6);
        assert_eq!(spec.req.sampling.top_k, Some(4));
        assert_eq!(spec.req.sampling.seed, 11);
        assert!(spec.stream);
        assert_eq!(spec.deadline.max_steps, Some(6));
        assert_eq!(spec.deadline.max_wait_rounds, Some(2));
    }

    #[test]
    fn parse_generate_defaults() {
        let ctx = ctx_for_parse(50);
        let spec = parse_generate(br#"{"prompt": [0]}"#, &ctx).unwrap();
        assert_eq!(spec.req.max_new_tokens, 32);
        assert_eq!(spec.req.sampling, SamplingParams::greedy());
        assert!(!spec.stream);
        assert_eq!(spec.deadline, Deadline::none());
    }

    #[test]
    fn parse_generate_rejects_each_defect_with_its_name() {
        let ctx = ctx_for_parse(10);
        for (body, needle) in [
            (&br#"{}"#[..], "prompt"),
            (&br#"{"prompt": 5}"#[..], "array"),
            (&br#"{"prompt": []}"#[..], "non-empty"),
            (&br#"{"prompt": [10]}"#[..], "vocab"),
            (&br#"{"prompt": [-1]}"#[..], "vocab"),
            (&br#"{"prompt": [1.5]}"#[..], "vocab"),
            (&br#"{"prompt": [1], "max_new_tokens": -2}"#[..], "max_new_tokens"),
            (&br#"{"prompt": [1], "top_k": 0}"#[..], "top_k"),
            (&br#"{"prompt": [1], "stream": "yes"}"#[..], "stream"),
            (&br#"{"prompt": [1], "deadline_steps": 1.5}"#[..], "deadline_steps"),
        ] {
            let err = parse_generate(body, &ctx).unwrap_err();
            assert!(
                err.contains(needle),
                "{}: error {err:?} should mention {needle:?}",
                String::from_utf8_lossy(body)
            );
        }
    }

    #[test]
    fn finish_strings_are_stable() {
        assert_eq!(finish_str(FinishReason::Length), "length");
        assert_eq!(finish_str(FinishReason::Deadline), "deadline");
        assert_eq!(finish_str(FinishReason::Cancelled), "cancelled");
        assert_eq!(
            finish_str(FinishReason::Error(ErrorKind::NonFiniteLogits)),
            "error:non_finite_logits"
        );
    }

    #[test]
    fn metrics_render_by_reason_sums_to_total() {
        let snap = EngineSnapshot {
            queued: 2,
            active: 3,
            kv_pages_live: 7,
            stats: EngineStats {
                completed: 10,
                deadline_expired: 2,
                cancelled: 1,
                quarantined: 1,
                preemptions: 4,
                tokens_generated: 123,
                kv_pages_peak: 9,
                draft_fallbacks: 0,
            },
        };
        let c = Counters::default();
        c.http_429.store(5, Ordering::Relaxed);
        let text = render_metrics(&snap, &c);
        for expect in [
            "apt_engine_queue_depth 2",
            "apt_engine_streams_active 3",
            "apt_engine_kv_pages_live 7",
            "apt_engine_completions_total 10",
            "apt_engine_completions_length_total 6",
            "apt_engine_completions_deadline_total 2",
            "apt_engine_completions_cancelled_total 1",
            "apt_engine_completions_error_total 1",
            "apt_engine_tokens_generated_total 123",
            "apt_http_responses_429_total 5",
        ] {
            assert!(text.contains(&format!("{expect}\n")), "missing {expect:?} in:\n{text}");
        }
    }

    #[test]
    fn completion_json_shape() {
        let c = Completion {
            id: RequestId(4),
            prompt: vec![1, 2],
            tokens: vec![7, 8, 9],
            last_logits: vec![0.0; 3],
            finish: FinishReason::Length,
        };
        let j = completion_json(&c);
        assert_eq!(j.get("id").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(j.get("prompt_tokens").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        // last_logits deliberately omitted: a serving API should not ship
        // a vocab-sized float array per response
        assert!(j.get("last_logits").is_none());
    }
}
