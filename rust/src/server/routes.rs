//! Route layer: maps parsed HTTP requests onto engine commands.
//!
//! Three endpoints:
//! - `POST /v1/generate` — JSON body → [`Request`] (+ optional
//!   [`Deadline`]); plain mode answers one JSON object when the typed
//!   [`Completion`] arrives, `"stream": true` answers chunked
//!   transfer encoding with one NDJSON line per token and a terminal
//!   line carrying the [`FinishReason`];
//! - `GET /metrics` — plain-text exposition of the engine's
//!   [`EngineSnapshot`] and the server's HTTP [`Counters`];
//! - `GET /healthz` — liveness.
//!
//! Every pool worker runs [`handle_connection`] once per connection: a
//! keep-alive loop of parse → route → answer, until the client closes,
//! sends `Connection: close`, idles past the idle timeout, or exhausts
//! the per-connection request cap. The loop is defensive end to end: a
//! request that stalls mid-read (slow loris) gets a typed `408` and the
//! connection is closed with the worker reclaimed; an idle kept-alive
//! connection yields its worker as soon as other connections are
//! waiting; a streaming client that disconnects mid-response triggers
//! `Cmd::Cancel`, so the engine reclaims the stream's K/V pages
//! immediately instead of generating for a ghost.

use std::io::{self, BufRead, BufReader};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::http::{self, ChunkedWriter, HttpRequest, ParseError};
use super::netfaults::Wire;
use super::{Cmd, ConnQueue, Counters, StreamEvent, SubmitReply};
use crate::json::{self, Json};
use crate::serve::{
    Completion, Deadline, EngineSnapshot, ErrorKind, FinishReason, Request, RequestId,
    SamplingParams,
};

/// Everything a worker thread needs: the driver's command channel, the
/// shared counters and connection queue, and the request-validation /
/// connection-policy knobs captured at startup.
#[derive(Clone)]
pub(crate) struct Ctx {
    pub cmd: Sender<Cmd>,
    pub counters: Arc<Counters>,
    /// The accept queue — read here only for its depth: an idle
    /// keep-alive connection yields its worker when others are waiting.
    pub queue: Arc<ConnQueue>,
    /// Shutdown flag: no NEW keep-alive requests once set (responses in
    /// flight still finish, and a queued connection still gets its
    /// first request served — drain, not cut).
    pub stop: Arc<AtomicBool>,
    pub vocab: usize,
    pub max_body: usize,
    pub default_max_new: usize,
    /// Server-side clamp on `max_new_tokens` (see
    /// `ServerConfig::max_new_tokens_cap`).
    pub max_new_cap: usize,
    pub retry_after_s: u32,
    /// Per-read socket timeout while a request is in flight, and the
    /// wait bound for a fresh connection's first bytes.
    pub read_timeout: Duration,
    /// How long a kept-alive connection may idle between requests.
    pub idle_timeout: Duration,
    /// Wall-clock bound on reading one whole request.
    pub header_deadline: Duration,
    pub keepalive_max_requests: usize,
    /// Pool size, exported on `/metrics` as capacity context.
    pub pool_workers: usize,
}

/// What [`await_request`] saw while waiting for a request to start.
enum Await {
    /// Bytes are buffered: a request is due — go parse it.
    Data,
    /// Nothing arrived within the idle budget (or the connection must
    /// yield: shutdown, or other connections waiting). Close silently.
    Idle,
    /// Clean EOF: the peer is done with the connection.
    Closed,
    /// The socket failed some other way; nothing sensible to answer.
    Failed,
}

/// Wait (in short slices, so shutdown and queue pressure are noticed)
/// for the next request's first bytes. The per-slice timeout plays the
/// role of a poll: data and EOF return immediately, quiet slices loop
/// until `budget` is spent. A scripted wire stall returns its timeout
/// instantly — the slice is then slept explicitly so a scripted run
/// spans the same wall-clock budget as a real one.
fn await_request(reader: &mut BufReader<Wire>, wire: &Wire, ctx: &Ctx, first: bool) -> Await {
    let budget = if first { ctx.read_timeout } else { ctx.idle_timeout };
    let slice = Duration::from_millis(50).min(budget).max(Duration::from_millis(1));
    let start = Instant::now();
    loop {
        if wire.set_read_timeout(Some(slice)).is_err() {
            return Await::Failed;
        }
        let iter = Instant::now();
        match reader.fill_buf() {
            Ok(b) if b.is_empty() => return Await::Closed,
            Ok(_) => return Await::Data,
            Err(e) if http::is_timeout(&e) => {
                let spent = iter.elapsed();
                if spent < slice {
                    std::thread::sleep(slice - spent);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return Await::Failed,
        }
        if start.elapsed() >= budget {
            return Await::Idle;
        }
        if !first && (ctx.stop.load(Ordering::SeqCst) || ctx.queue.depth() > 0) {
            // between requests is the polite place to stop: shutting
            // down, or other connections need this worker
            return Await::Idle;
        }
    }
}

/// One connection's whole life: the keep-alive request loop.
pub(crate) fn handle_connection(wire: Wire, ctx: &Ctx) {
    let Ok(read_half) = wire.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut wire = wire;
    let mut served = 0usize;
    loop {
        match await_request(&mut reader, &wire, ctx, served == 0) {
            Await::Data => {}
            Await::Idle => {
                Counters::bump(&ctx.counters.idle_closes);
                return;
            }
            Await::Closed | Await::Failed => return,
        }
        // a request has started: per-read timeout bounds each quiet
        // gap, the wall-clock deadline bounds the drip that resets it
        if wire.set_read_timeout(Some(ctx.read_timeout)).is_err() {
            return;
        }
        let deadline = Instant::now() + ctx.header_deadline;
        let req = match http::parse_request(&mut reader, ctx.max_body, Some(deadline)) {
            Ok(r) => r,
            Err(ParseError::Closed) => return,
            Err(ParseError::IdleTimeout) => {
                Counters::bump(&ctx.counters.idle_closes);
                return;
            }
            Err(e) => {
                let (status, reason, msg) = http::status_for(&e);
                match status {
                    408 => Counters::bump(&ctx.counters.http_408),
                    413 => Counters::bump(&ctx.counters.http_413),
                    _ => Counters::bump(&ctx.counters.http_400),
                }
                let _ = http::write_response(
                    &mut wire,
                    status,
                    reason,
                    "text/plain",
                    &[],
                    format!("{msg}\n").as_bytes(),
                    false,
                );
                // the broken request may still have bytes in flight;
                // take them off the socket so the close delivers our
                // response instead of resetting the connection
                wire.drain_unread(64 * 1024);
                return;
            }
        };
        served += 1;
        Counters::bump(&ctx.counters.http_requests);
        if served > 1 {
            Counters::bump(&ctx.counters.keepalive_reuses);
        }
        // the server half of the keep-alive negotiation: client said
        // keep-alive ∧ under the per-connection cap ∧ not shutting down
        let keep = req.keep_alive
            && served < ctx.keepalive_max_requests
            && !ctx.stop.load(Ordering::SeqCst);
        let io_ok = route(&mut wire, ctx, &req, keep);
        if !io_ok || !keep {
            return;
        }
    }
}

/// Dispatch one parsed request. Returns `false` when the response could
/// not be (fully) written — the connection is then closed regardless of
/// the keep-alive decision.
fn route(wire: &mut Wire, ctx: &Ctx, req: &HttpRequest, keep: bool) -> bool {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            http::write_response(wire, 200, "OK", "text/plain", &[], b"ok\n", keep).is_ok()
        }
        ("GET", "/metrics") => metrics(wire, ctx, keep),
        ("POST", "/v1/generate") => generate(wire, ctx, req, keep),
        // known routes, wrong method: say so instead of a blanket 404
        (_, "/healthz") | (_, "/metrics") | (_, "/v1/generate") => http::write_response(
            wire,
            405,
            "Method Not Allowed",
            "text/plain",
            &[],
            b"method not allowed\n",
            keep,
        )
        .is_ok(),
        _ => {
            Counters::bump(&ctx.counters.http_404);
            http::write_response(wire, 404, "Not Found", "text/plain", &[], b"unknown route\n", keep)
                .is_ok()
        }
    }
}

// ------------------------------------------------------------------ metrics

/// `/metrics`: ask the driver for one consistent [`EngineSnapshot`] and
/// render it with the HTTP counters as `name value` lines (the
/// Prometheus text idiom, minus types — every value is a gauge or a
/// monotone counter, the `_total` suffix says which).
fn metrics(wire: &mut Wire, ctx: &Ctx, keep: bool) -> bool {
    let (tx, rx) = std::sync::mpsc::channel();
    let snap = if ctx.cmd.send(Cmd::Snapshot(tx)).is_ok() { rx.recv().ok() } else { None };
    let Some(snap) = snap else {
        return http::write_response(
            wire,
            503,
            "Service Unavailable",
            "text/plain",
            &[],
            b"engine is shut down\n",
            false,
        )
        .is_ok();
    };
    let text = render_metrics(&snap, &ctx.counters, ctx.queue.depth(), ctx.pool_workers);
    http::write_response(wire, 200, "OK", "text/plain", &[], text.as_bytes(), keep).is_ok()
}

pub(crate) fn render_metrics(
    s: &EngineSnapshot,
    c: &Counters,
    conn_queue_depth: usize,
    pool_workers: usize,
) -> String {
    let st = &s.stats;
    let mut out = String::with_capacity(1536);
    let mut line = |k: &str, v: usize| {
        out.push_str(k);
        out.push(' ');
        out.push_str(&v.to_string());
        out.push('\n');
    };
    line("apt_up", 1);
    // engine: gauges first, then the cumulative ledger
    line("apt_engine_queue_depth", s.queued);
    line("apt_engine_streams_active", s.active);
    line("apt_engine_max_batch", s.max_batch);
    line("apt_engine_kv_pages_live", s.kv_pages_live);
    line("apt_engine_kv_pages_peak", st.kv_pages_peak);
    line("apt_engine_completions_total", st.completed);
    line("apt_engine_completions_length_total", st.finished_length());
    line("apt_engine_completions_deadline_total", st.deadline_expired);
    line("apt_engine_completions_cancelled_total", st.cancelled);
    line("apt_engine_completions_error_total", st.quarantined);
    line("apt_engine_preemptions_total", st.preemptions);
    line("apt_engine_draft_fallbacks_total", st.draft_fallbacks);
    line("apt_engine_tokens_generated_total", st.tokens_generated);
    // server: pool gauges, then the HTTP ledger (every degraded
    // connection — shed, refused, timed out, wire-faulted — is here)
    let rel = |a: &std::sync::atomic::AtomicUsize| a.load(Ordering::Relaxed);
    line("apt_http_pool_workers", pool_workers);
    line("apt_http_conn_queue_depth", conn_queue_depth);
    line("apt_http_conns_accepted_total", rel(&c.conns_accepted));
    line("apt_http_requests_total", rel(&c.http_requests));
    line("apt_http_keepalive_reuses_total", rel(&c.keepalive_reuses));
    line("apt_http_idle_closes_total", rel(&c.idle_closes));
    line("apt_http_responses_429_total", rel(&c.http_429));
    line("apt_http_responses_429_doomed_total", rel(&c.http_429_doomed));
    line("apt_http_responses_400_total", rel(&c.http_400));
    line("apt_http_responses_404_total", rel(&c.http_404));
    line("apt_http_responses_408_total", rel(&c.http_408));
    line("apt_http_responses_413_total", rel(&c.http_413));
    line("apt_http_responses_503_shed_total", rel(&c.http_503_shed));
    line("apt_http_stream_disconnects_total", rel(&c.stream_disconnects));
    line("apt_net_stalls_total", rel(&c.net_stalls));
    line("apt_net_disconnects_total", rel(&c.net_disconnects));
    line("apt_net_short_io_conns_total", rel(&c.net_short_io_conns));
    out
}

// ----------------------------------------------------------------- generate

/// The decoded body of a `POST /v1/generate`.
struct GenSpec {
    req: Request,
    deadline: Deadline,
    stream: bool,
}

fn generate(wire: &mut Wire, ctx: &Ctx, req: &HttpRequest, keep: bool) -> bool {
    let spec = match parse_generate(&req.body, ctx) {
        Ok(s) => s,
        Err(msg) => {
            Counters::bump(&ctx.counters.http_400);
            let mut o = Json::obj();
            o.set("error", Json::Str(msg));
            return http::write_response(
                wire,
                400,
                "Bad Request",
                "application/json",
                &[],
                format!("{}\n", o.to_string()).as_bytes(),
                keep,
            )
            .is_ok();
        }
    };
    let (ev_tx, ev_rx) = std::sync::mpsc::channel::<StreamEvent>();
    let (rp_tx, rp_rx) = std::sync::mpsc::channel::<SubmitReply>();
    let submitted = ctx
        .cmd
        .send(Cmd::Submit { req: spec.req, deadline: spec.deadline, events: ev_tx, reply: rp_tx })
        .is_ok();
    let reply = if submitted { rp_rx.recv().ok() } else { None };
    let id = match reply {
        None => {
            return http::write_response(
                wire,
                503,
                "Service Unavailable",
                "text/plain",
                &[],
                b"engine is shut down\n",
                false,
            )
            .is_ok();
        }
        Some(SubmitReply::Busy { queued, retry_after_s }) => {
            Counters::bump(&ctx.counters.http_429);
            let retry = retry_after_s.to_string();
            let mut o = Json::obj();
            o.set("error", Json::Str(format!("pending queue is full ({queued} waiting)")));
            return http::write_response(
                wire,
                429,
                "Too Many Requests",
                "application/json",
                &[("Retry-After", retry.as_str())],
                format!("{}\n", o.to_string()).as_bytes(),
                keep,
            )
            .is_ok();
        }
        Some(SubmitReply::Doomed { queued, need_rounds, allowed_rounds, retry_after_s }) => {
            Counters::bump(&ctx.counters.http_429);
            Counters::bump(&ctx.counters.http_429_doomed);
            let retry = retry_after_s.to_string();
            let mut o = Json::obj();
            o.set(
                "error",
                Json::Str(format!(
                    "deadline_wait_rounds = {allowed_rounds} cannot be met: {queued} queued \
                     requests need at least {need_rounds} admit rounds"
                )),
            );
            return http::write_response(
                wire,
                429,
                "Too Many Requests",
                "application/json",
                &[("Retry-After", retry.as_str())],
                format!("{}\n", o.to_string()).as_bytes(),
                keep,
            )
            .is_ok();
        }
        Some(SubmitReply::Accepted(id)) => id,
    };
    if spec.stream {
        stream_completion(wire, ctx, id, &ev_rx, keep)
    } else {
        wait_completion(wire, &ev_rx, keep)
    }
}

/// Plain mode: ignore token events, answer when `Done` arrives.
fn wait_completion(
    wire: &mut Wire,
    ev_rx: &std::sync::mpsc::Receiver<StreamEvent>,
    keep: bool,
) -> bool {
    loop {
        match ev_rx.recv() {
            Ok(StreamEvent::Token(_)) => {}
            Ok(StreamEvent::Done(c)) => {
                let body = format!("{}\n", completion_json(&c).to_string());
                return http::write_response(
                    wire,
                    200,
                    "OK",
                    "application/json",
                    &[],
                    body.as_bytes(),
                    keep,
                )
                .is_ok();
            }
            Err(_) => {
                // driver gone mid-request (shutdown drains normally make
                // this unreachable; a panicked driver does not)
                return http::write_response(
                    wire,
                    503,
                    "Service Unavailable",
                    "text/plain",
                    &[],
                    b"engine is shut down\n",
                    false,
                )
                .is_ok();
            }
        }
    }
}

/// Streaming mode: one NDJSON chunk per token as it is sampled, then a
/// terminal chunk with the typed finish reason. A failed chunk write
/// means the client is gone: cancel the engine request (its K/V pages
/// reclaim immediately), drain the event channel to its `Done`, and
/// give up on the socket. Chunked bodies are self-delimiting, so a
/// stream that finishes cleanly keeps the connection alive like any
/// other response.
fn stream_completion(
    wire: &mut Wire,
    ctx: &Ctx,
    id: RequestId,
    ev_rx: &std::sync::mpsc::Receiver<StreamEvent>,
    keep: bool,
) -> bool {
    let Ok(mut cw) = ChunkedWriter::begin(wire, 200, "OK", "application/x-ndjson", keep) else {
        Counters::bump(&ctx.counters.stream_disconnects);
        cancel_and_drain(ctx, id, ev_rx);
        return false;
    };
    loop {
        match ev_rx.recv() {
            Ok(StreamEvent::Token(t)) => {
                let mut o = Json::obj();
                o.set("token", Json::Num(t as f64));
                if cw.chunk(format!("{}\n", o.to_string()).as_bytes()).is_err() {
                    Counters::bump(&ctx.counters.stream_disconnects);
                    cancel_and_drain(ctx, id, ev_rx);
                    return false;
                }
            }
            Ok(StreamEvent::Done(c)) => {
                let mut o = Json::obj();
                o.set("done", Json::Bool(true))
                    .set("id", Json::Num(c.id.0 as f64))
                    .set("finish", Json::Str(finish_str(c.finish).to_string()))
                    .set("tokens_generated", Json::Num(c.tokens.len() as f64));
                let body_ok = cw.chunk(format!("{}\n", o.to_string()).as_bytes()).is_ok();
                return cw.finish().is_ok() && body_ok;
            }
            Err(_) => return false, // driver gone; nothing more will arrive
        }
    }
}

/// Disconnect path: ask the driver to cancel, then drain events until
/// the (possibly already in-flight) `Done` arrives so the driver never
/// blocks on a full channel. The completion itself is discarded — its
/// client left.
fn cancel_and_drain(ctx: &Ctx, id: RequestId, ev_rx: &std::sync::mpsc::Receiver<StreamEvent>) {
    let _ = ctx.cmd.send(Cmd::Cancel(id));
    loop {
        match ev_rx.recv() {
            Ok(StreamEvent::Done(_)) | Err(_) => return,
            Ok(StreamEvent::Token(_)) => {}
        }
    }
}

// ------------------------------------------------------------------- bodies

pub(crate) fn finish_str(f: FinishReason) -> &'static str {
    match f {
        FinishReason::Length => "length",
        FinishReason::Deadline => "deadline",
        FinishReason::Cancelled => "cancelled",
        FinishReason::Error(ErrorKind::NonFiniteLogits) => "error:non_finite_logits",
    }
}

pub(crate) fn completion_json(c: &Completion) -> Json {
    let mut o = Json::obj();
    o.set("id", Json::Num(c.id.0 as f64))
        .set("finish", Json::Str(finish_str(c.finish).to_string()))
        .set("prompt_tokens", Json::Num(c.prompt.len() as f64))
        .set(
            "tokens",
            Json::Arr(c.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        );
    o
}

/// Decode + validate a generate body. Every defect answers with a
/// message naming it — a serving API that just says "400" wastes its
/// callers' time. `max_new_tokens` is CLAMPED to the server cap rather
/// than refused: an oversized ask is a policy question, not a malformed
/// request, and the response's `tokens` length tells the caller what
/// they actually got.
fn parse_generate(body: &[u8], ctx: &Ctx) -> Result<GenSpec, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not valid UTF-8".to_string())?;
    let v = json::parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let usize_field = |name: &str| -> Result<Option<usize>, String> {
        match v.get(name) {
            None | Some(Json::Null) => Ok(None),
            Some(j) => {
                let n = j.as_f64().ok_or_else(|| format!("{name} must be a number"))?;
                if n.fract() != 0.0 || n < 0.0 {
                    return Err(format!("{name} must be a non-negative integer"));
                }
                Ok(Some(n as usize))
            }
        }
    };
    let prompt_json = v.get("prompt").ok_or_else(|| "missing field: prompt".to_string())?;
    let arr = prompt_json.as_arr().ok_or_else(|| "prompt must be an array".to_string())?;
    if arr.is_empty() {
        return Err("prompt must be non-empty".to_string());
    }
    let mut prompt = Vec::with_capacity(arr.len());
    for (i, t) in arr.iter().enumerate() {
        let n = t.as_f64().ok_or_else(|| format!("prompt[{i}] is not a number"))?;
        if n.fract() != 0.0 || n < 0.0 || (n as usize) >= ctx.vocab {
            return Err(format!(
                "prompt[{i}] = {n} is not a token id in vocab range 0..{}",
                ctx.vocab
            ));
        }
        prompt.push(n as u32);
    }
    let max_new =
        usize_field("max_new_tokens")?.unwrap_or(ctx.default_max_new).min(ctx.max_new_cap);
    let temperature = match v.get("temperature") {
        None | Some(Json::Null) => 0.0f32,
        Some(j) => j.as_f64().ok_or_else(|| "temperature must be a number".to_string())? as f32,
    };
    let top_k = match usize_field("top_k")? {
        Some(0) => return Err("top_k must be at least 1".to_string()),
        k => k,
    };
    let seed = usize_field("seed")?.unwrap_or(0) as u64;
    let stream = match v.get("stream") {
        None | Some(Json::Null) => false,
        Some(j) => j.as_bool().ok_or_else(|| "stream must be a boolean".to_string())?,
    };
    let deadline = Deadline {
        max_steps: usize_field("deadline_steps")?,
        max_wait_rounds: usize_field("deadline_wait_rounds")?,
    };
    let sampling = SamplingParams { temperature, top_k, seed };
    Ok(GenSpec { req: Request { prompt, max_new_tokens: max_new, sampling }, deadline, stream })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::EngineStats;

    fn ctx_for_parse(vocab: usize) -> Ctx {
        let (cmd, _rx) = std::sync::mpsc::channel();
        Ctx {
            cmd,
            counters: Arc::new(Counters::default()),
            queue: Arc::new(ConnQueue::new(4)),
            stop: Arc::new(AtomicBool::new(false)),
            vocab,
            max_body: 1 << 20,
            default_max_new: 32,
            max_new_cap: 4096,
            retry_after_s: 1,
            read_timeout: Duration::from_secs(5),
            idle_timeout: Duration::from_secs(5),
            header_deadline: Duration::from_secs(10),
            keepalive_max_requests: 64,
            pool_workers: 8,
        }
    }

    #[test]
    fn parse_generate_full_body() {
        let ctx = ctx_for_parse(50);
        let spec = parse_generate(
            br#"{"prompt": [1, 2, 3], "max_new_tokens": 9, "temperature": 0.8,
                "top_k": 4, "seed": 11, "stream": true, "deadline_steps": 6,
                "deadline_wait_rounds": 2}"#,
            &ctx,
        )
        .unwrap();
        assert_eq!(spec.req.prompt, vec![1, 2, 3]);
        assert_eq!(spec.req.max_new_tokens, 9);
        assert!((spec.req.sampling.temperature - 0.8).abs() < 1e-6);
        assert_eq!(spec.req.sampling.top_k, Some(4));
        assert_eq!(spec.req.sampling.seed, 11);
        assert!(spec.stream);
        assert_eq!(spec.deadline.max_steps, Some(6));
        assert_eq!(spec.deadline.max_wait_rounds, Some(2));
    }

    #[test]
    fn parse_generate_defaults() {
        let ctx = ctx_for_parse(50);
        let spec = parse_generate(br#"{"prompt": [0]}"#, &ctx).unwrap();
        assert_eq!(spec.req.max_new_tokens, 32);
        assert_eq!(spec.req.sampling, SamplingParams::greedy());
        assert!(!spec.stream);
        assert_eq!(spec.deadline, Deadline::none());
    }

    #[test]
    fn parse_generate_clamps_max_new_tokens_at_the_cap() {
        let mut ctx = ctx_for_parse(50);
        ctx.max_new_cap = 10;
        // at the cap: untouched
        let spec = parse_generate(br#"{"prompt": [1], "max_new_tokens": 10}"#, &ctx).unwrap();
        assert_eq!(spec.req.max_new_tokens, 10);
        // one past: clamped (the boundary)
        let spec = parse_generate(br#"{"prompt": [1], "max_new_tokens": 11}"#, &ctx).unwrap();
        assert_eq!(spec.req.max_new_tokens, 10);
        // hostile: clamped, not an error
        let spec =
            parse_generate(br#"{"prompt": [1], "max_new_tokens": 1000000000}"#, &ctx).unwrap();
        assert_eq!(spec.req.max_new_tokens, 10);
        // the default is clamped too, if someone configures it above
        // the cap
        ctx.default_max_new = 99;
        let spec = parse_generate(br#"{"prompt": [1]}"#, &ctx).unwrap();
        assert_eq!(spec.req.max_new_tokens, 10);
    }

    #[test]
    fn parse_generate_rejects_each_defect_with_its_name() {
        let ctx = ctx_for_parse(10);
        for (body, needle) in [
            (&br#"{}"#[..], "prompt"),
            (&br#"{"prompt": 5}"#[..], "array"),
            (&br#"{"prompt": []}"#[..], "non-empty"),
            (&br#"{"prompt": [10]}"#[..], "vocab"),
            (&br#"{"prompt": [-1]}"#[..], "vocab"),
            (&br#"{"prompt": [1.5]}"#[..], "vocab"),
            (&br#"{"prompt": [1], "max_new_tokens": -2}"#[..], "max_new_tokens"),
            (&br#"{"prompt": [1], "top_k": 0}"#[..], "top_k"),
            (&br#"{"prompt": [1], "stream": "yes"}"#[..], "stream"),
            (&br#"{"prompt": [1], "deadline_steps": 1.5}"#[..], "deadline_steps"),
        ] {
            let err = parse_generate(body, &ctx).unwrap_err();
            assert!(
                err.contains(needle),
                "{}: error {err:?} should mention {needle:?}",
                String::from_utf8_lossy(body)
            );
        }
    }

    #[test]
    fn finish_strings_are_stable() {
        assert_eq!(finish_str(FinishReason::Length), "length");
        assert_eq!(finish_str(FinishReason::Deadline), "deadline");
        assert_eq!(finish_str(FinishReason::Cancelled), "cancelled");
        assert_eq!(
            finish_str(FinishReason::Error(ErrorKind::NonFiniteLogits)),
            "error:non_finite_logits"
        );
    }

    #[test]
    fn metrics_render_by_reason_sums_to_total() {
        let snap = EngineSnapshot {
            queued: 2,
            active: 3,
            kv_pages_live: 7,
            max_batch: 8,
            stats: EngineStats {
                completed: 10,
                deadline_expired: 2,
                cancelled: 1,
                quarantined: 1,
                preemptions: 4,
                tokens_generated: 123,
                kv_pages_peak: 9,
                draft_fallbacks: 0,
            },
        };
        let c = Counters::default();
        c.http_429.store(5, Ordering::Relaxed);
        c.http_408.store(2, Ordering::Relaxed);
        c.http_503_shed.store(3, Ordering::Relaxed);
        c.net_stalls.store(1, Ordering::Relaxed);
        let text = render_metrics(&snap, &c, 4, 8);
        for expect in [
            "apt_engine_queue_depth 2",
            "apt_engine_streams_active 3",
            "apt_engine_max_batch 8",
            "apt_engine_kv_pages_live 7",
            "apt_engine_completions_total 10",
            "apt_engine_completions_length_total 6",
            "apt_engine_completions_deadline_total 2",
            "apt_engine_completions_cancelled_total 1",
            "apt_engine_completions_error_total 1",
            "apt_engine_tokens_generated_total 123",
            "apt_http_pool_workers 8",
            "apt_http_conn_queue_depth 4",
            "apt_http_responses_429_total 5",
            "apt_http_responses_408_total 2",
            "apt_http_responses_503_shed_total 3",
            "apt_net_stalls_total 1",
        ] {
            assert!(text.contains(&format!("{expect}\n")), "missing {expect:?} in:\n{text}");
        }
    }

    #[test]
    fn completion_json_shape() {
        let c = Completion {
            id: RequestId(4),
            prompt: vec![1, 2],
            tokens: vec![7, 8, 9],
            last_logits: vec![0.0; 3],
            finish: FinishReason::Length,
        };
        let j = completion_json(&c);
        assert_eq!(j.get("id").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(j.get("prompt_tokens").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        // last_logits deliberately omitted: a serving API should not ship
        // a vocab-sized float array per response
        assert!(j.get("last_logits").is_none());
    }
}
