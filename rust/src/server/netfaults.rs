//! Deterministic wire-level fault injection for the HTTP front end —
//! the [`serve::faults`](crate::serve::faults) idiom pushed down to the
//! socket.
//!
//! The engine-side `FaultPlan` proved the pattern: script the fault as
//! plain data, consult it at the code's NORMAL decision points, and the
//! faulted run exercises exactly the paths a real fault would. Here the
//! decision points are the wire layer's reads and writes: every worker
//! talks to its connection through a [`Wire`], and a scripted
//! [`ConnScript`] makes those reads trickle, stall, or the writes fail
//! — producing byte-for-byte the same `io::Error`s a slow-loris client,
//! a mid-body stall, or a mid-stream disconnect produce through the
//! kernel, minus the wall-clock wait.
//!
//! Two properties carry over from the engine harness:
//!
//! - **No test-only control flow.** An unscripted connection takes one
//!   branch per read/write and otherwise passes straight through to the
//!   socket; the production server runs with an empty plan and the very
//!   same `Wire` in the path.
//! - **Blast-radius isolation is testable.** A stalled or disconnected
//!   connection must leave every well-behaved concurrent stream
//!   byte-identical to an unfaulted run, return its K/V pages, and show
//!   up in a typed `/metrics` counter — pinned by
//!   `http_wire_fault_blast_radius_spares_clean_streams` in the
//!   integration suite.
//!
//! Plans are keyed by **accept order** (connection 0 is the first the
//! acceptor takes), which is deterministic when a test opens its
//! connections sequentially.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::Counters;

/// What one scripted connection does at the wire. Default is clean:
/// every field `None`, reads and writes pass through untouched.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConnScript {
    /// Reads return at most this many bytes per call — a client that
    /// trickles its request byte-at-a-time (`Some(1)` is the classic
    /// drip). Exercises the parser's incremental framing.
    pub read_chunk: Option<usize>,
    /// After this many request bytes have been read, every further read
    /// fails with `ErrorKind::TimedOut` — exactly what a stalled client
    /// produces through the socket read timeout. Position it inside the
    /// header block for a slow-loris, inside the body for a mid-body
    /// stall.
    pub stall_read_after: Option<usize>,
    /// Writes accept at most this many bytes per call (short writes) —
    /// exercises every `write_all` loop in the response path.
    pub write_chunk: Option<usize>,
    /// After this many response bytes have been written, every further
    /// write fails with `ErrorKind::BrokenPipe` — a client that
    /// disconnected mid-stream. The server must take its normal
    /// disconnect path: cancel the engine request, reclaim K/V pages.
    pub drop_write_after: Option<usize>,
}

impl ConnScript {
    pub fn clean() -> ConnScript {
        ConnScript::default()
    }

    pub fn is_clean(&self) -> bool {
        self.read_chunk.is_none()
            && self.stall_read_after.is_none()
            && self.write_chunk.is_none()
            && self.drop_write_after.is_none()
    }

    /// Trickle reads: at most `n` bytes per read.
    pub fn trickle(mut self, n: usize) -> ConnScript {
        assert!(n >= 1, "a zero-byte read chunk would starve the parser");
        self.read_chunk = Some(n);
        self
    }

    /// Stall: reads fail `TimedOut` once `n` bytes have been read.
    pub fn stall_after(mut self, n: usize) -> ConnScript {
        self.stall_read_after = Some(n);
        self
    }

    /// Short writes: at most `n` bytes accepted per write.
    pub fn short_writes(mut self, n: usize) -> ConnScript {
        assert!(n >= 1, "a zero-byte write chunk would loop forever");
        self.write_chunk = Some(n);
        self
    }

    /// Disconnect: writes fail `BrokenPipe` once `n` bytes have been
    /// written.
    pub fn drop_after(mut self, n: usize) -> ConnScript {
        self.drop_write_after = Some(n);
        self
    }
}

/// A scripted set of per-connection wire faults, keyed by accept order,
/// installed via [`Server::start_with_netfaults`](super::Server::
/// start_with_netfaults). The default plan is empty — the production
/// configuration.
#[derive(Clone, Debug, Default)]
pub struct NetFaultPlan {
    scripts: Vec<(usize, ConnScript)>,
}

impl NetFaultPlan {
    pub fn new() -> NetFaultPlan {
        NetFaultPlan::default()
    }

    /// Script the `conn`-th accepted connection (0-based accept order).
    pub fn on_conn(mut self, conn: usize, script: ConnScript) -> NetFaultPlan {
        self.scripts.push((conn, script));
        self
    }

    pub fn is_empty(&self) -> bool {
        self.scripts.iter().all(|(_, s)| s.is_clean())
    }

    /// The script for accept-order index `conn` (clean when unscripted;
    /// later entries for the same index win, matching builder intuition).
    pub(crate) fn script_for(&self, conn: usize) -> ConnScript {
        self.scripts
            .iter()
            .rev()
            .find(|&&(c, _)| c == conn)
            .map(|&(_, s)| s)
            .unwrap_or_default()
    }
}

/// Byte cursors + one-shot fired flags for a connection's script. Shared
/// (via `Arc<Mutex<..>>`) between the read half and the write half of a
/// [`Wire`], which live on the same worker thread.
#[derive(Debug)]
struct WireState {
    script: ConnScript,
    read_bytes: usize,
    written_bytes: usize,
    stall_fired: bool,
    drop_fired: bool,
    short_io_counted: bool,
}

/// The wire wrapper every worker reads and writes its connection
/// through. Unscripted connections pass straight through to the
/// `TcpStream`; scripted ones consult their [`ConnScript`] at each read
/// and write — the wire layer's normal decision points — and account
/// every fault that fires in the server's typed [`Counters`].
pub(crate) struct Wire {
    stream: TcpStream,
    state: Arc<Mutex<WireState>>,
    counters: Arc<Counters>,
}

impl Wire {
    pub(crate) fn new(stream: TcpStream, script: ConnScript, counters: Arc<Counters>) -> Wire {
        Wire {
            stream,
            state: Arc::new(Mutex::new(WireState {
                script,
                read_bytes: 0,
                written_bytes: 0,
                stall_fired: false,
                drop_fired: false,
                short_io_counted: false,
            })),
            counters,
        }
    }

    /// A second handle over the same socket and fault state (the read
    /// half a `BufReader` wraps while the write half answers).
    pub(crate) fn try_clone(&self) -> io::Result<Wire> {
        Ok(Wire {
            stream: self.stream.try_clone()?,
            state: self.state.clone(),
            counters: self.counters.clone(),
        })
    }

    pub(crate) fn set_read_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.stream.set_read_timeout(d)
    }

    pub(crate) fn set_write_timeout(&self, d: Option<Duration>) -> io::Result<()> {
        self.stream.set_write_timeout(d)
    }

    /// Best-effort: pull any bytes the client already sent off the
    /// socket before closing, so the kernel delivers our final response
    /// instead of resetting the connection on close-with-unread-data.
    pub(crate) fn drain_unread(&mut self, max: usize) {
        let mut buf = [0u8; 512];
        let mut left = max;
        let _ = self.stream.set_read_timeout(Some(Duration::from_millis(10)));
        while left > 0 {
            match self.stream.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(n) => left = left.saturating_sub(n),
            }
        }
    }

    fn count_short_io(&self, st: &mut WireState) {
        if !st.short_io_counted {
            st.short_io_counted = true;
            self.counters.net_short_io_conns.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl Read for Wire {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let mut st = self.state.lock().expect("wire state");
        if st.script.is_clean() {
            drop(st);
            return self.stream.read(buf);
        }
        let mut cap = buf.len();
        if let Some(n) = st.script.stall_read_after {
            if st.read_bytes >= n {
                if !st.stall_fired {
                    st.stall_fired = true;
                    self.counters.net_stalls.fetch_add(1, Ordering::Relaxed);
                }
                // the same error a stalled peer produces through the
                // socket read timeout, without the wall-clock wait
                return Err(io::Error::new(io::ErrorKind::TimedOut, "scripted read stall"));
            }
            cap = cap.min(n - st.read_bytes);
        }
        if let Some(c) = st.script.read_chunk {
            self.count_short_io(&mut st);
            cap = cap.min(c);
        }
        let cap = cap.max(1).min(buf.len());
        let got = self.stream.read(&mut buf[..cap])?;
        st.read_bytes += got;
        Ok(got)
    }
}

impl Write for Wire {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let mut st = self.state.lock().expect("wire state");
        if st.script.is_clean() {
            drop(st);
            return self.stream.write(buf);
        }
        let mut cap = buf.len();
        if let Some(n) = st.script.drop_write_after {
            if st.written_bytes >= n {
                if !st.drop_fired {
                    st.drop_fired = true;
                    self.counters.net_disconnects.fetch_add(1, Ordering::Relaxed);
                }
                // the same error a vanished peer produces on write
                return Err(io::Error::new(io::ErrorKind::BrokenPipe, "scripted disconnect"));
            }
            cap = cap.min(n - st.written_bytes);
        }
        if let Some(c) = st.script.write_chunk {
            self.count_short_io(&mut st);
            cap = cap.min(c);
        }
        let cap = cap.max(1).min(buf.len());
        let wrote = self.stream.write(&buf[..cap])?;
        st.written_bytes += wrote;
        Ok(wrote)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.stream.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_lookup_is_keyed_by_accept_order() {
        let plan = NetFaultPlan::new()
            .on_conn(0, ConnScript::clean().trickle(1))
            .on_conn(2, ConnScript::clean().stall_after(10))
            .on_conn(2, ConnScript::clean().drop_after(7));
        assert!(!plan.is_empty());
        assert_eq!(plan.script_for(0).read_chunk, Some(1));
        assert!(plan.script_for(1).is_clean(), "unscripted conns stay clean");
        // later entries for the same conn win
        let s2 = plan.script_for(2);
        assert_eq!(s2.drop_write_after, Some(7));
        assert_eq!(s2.stall_read_after, None);
        assert!(NetFaultPlan::new().is_empty());
    }

    #[test]
    fn script_builders_compose() {
        let s = ConnScript::clean().trickle(1).stall_after(20).short_writes(3).drop_after(64);
        assert_eq!(s.read_chunk, Some(1));
        assert_eq!(s.stall_read_after, Some(20));
        assert_eq!(s.write_chunk, Some(3));
        assert_eq!(s.drop_write_after, Some(64));
        assert!(!s.is_clean());
        assert!(ConnScript::clean().is_clean());
    }

    /// The fault arms are pure functions of the byte cursors, so they
    /// are testable against a loopback socket pair without a server.
    #[test]
    fn wire_faults_fire_at_exact_byte_positions() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"0123456789").unwrap();
            s.flush().unwrap();
            // keep the socket open so reads see a stall, not EOF
            let mut sink = Vec::new();
            let _ = s.read_to_end(&mut sink);
            sink
        });
        let (sock, _) = listener.accept().unwrap();
        let counters = Arc::new(Counters::default());
        let script = ConnScript::clean().trickle(3).stall_after(8).drop_after(5);
        let mut wire = Wire::new(sock, script, counters.clone());

        // trickled reads: at most 3 bytes per call, clamped to the stall
        // point at byte 8, then TimedOut
        let mut buf = [0u8; 64];
        assert_eq!(wire.read(&mut buf).unwrap(), 3);
        assert_eq!(wire.read(&mut buf).unwrap(), 3);
        assert_eq!(wire.read(&mut buf).unwrap(), 2, "clamped to the stall point");
        let e = wire.read(&mut buf).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::TimedOut);
        let e = wire.read(&mut buf).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::TimedOut, "stall persists");
        assert_eq!(counters.net_stalls.load(Ordering::Relaxed), 1, "counted once");

        // writes: 5 bytes pass, then BrokenPipe
        assert_eq!(wire.write(b"abcdefgh").unwrap(), 5, "clamped to the drop point");
        let e = wire.write(b"xyz").unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::BrokenPipe);
        assert_eq!(counters.net_disconnects.load(Ordering::Relaxed), 1);
        assert_eq!(counters.net_short_io_conns.load(Ordering::Relaxed), 1);

        drop(wire);
        assert_eq!(client.join().unwrap(), b"abcde".to_vec());
    }
}
