//! Hand-rolled HTTP/1.1 wire layer (no dependencies — the sealed build
//! environment has no hyper/tiny_http; the same vendored-shim philosophy
//! that gave us the offline `anyhow`/`log`).
//!
//! Scope is deliberately narrow: the server speaks exactly the subset a
//! serving front end needs — HTTP/1.1 keep-alive with an explicit
//! per-response `Connection` header (the route layer decides when a
//! connection has earned another request), `Content-Length` bodies on
//! the way in, fixed-length or chunked (`Transfer-Encoding: chunked`)
//! bodies on the way out. Parsing is defensive: every malformed input
//! maps to a typed [`ParseError`] so the route layer can answer with the
//! matching status code instead of dropping the connection silently,
//! both the header block and the body are size-capped so a hostile
//! client cannot balloon server memory, and the whole head+body read
//! runs under an optional wall-clock deadline so a client that drips
//! one byte per read-timeout window (the slow loris) still maps to a
//! typed [`ParseError::Timeout`] → `408` instead of pinning a worker
//! indefinitely.

use std::io::{self, BufRead, Read, Write};
use std::time::Instant;

/// Upper bound on the request line + header block, in bytes. Generous
/// for hand-written clients and curl alike; a request that exceeds it
/// is malformed or hostile, either way a 400.
pub const MAX_HEADER_BYTES: usize = 8 * 1024;

/// A parsed request: method + path verbatim from the request line,
/// header names lowercased (HTTP headers are case-insensitive), body
/// read to exactly `Content-Length` bytes.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// The client's connection preference: `true` unless it sent
    /// `Connection: close` (or spoke HTTP/1.0 without an explicit
    /// `keep-alive`). The server may still close — this is the
    /// client-side half of the negotiation.
    pub keep_alive: bool,
}

impl HttpRequest {
    /// Case-insensitive header lookup (names were lowercased at parse).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }
}

/// Every way reading a request can fail, each mapped to one response by
/// [`status_for`]. `Closed` is the clean no-request case (EOF before any
/// byte — the peer connected and left); it gets no response at all.
#[derive(Debug)]
pub enum ParseError {
    /// EOF before the first request byte: not an error, just a peer
    /// that closed without sending (another) request.
    Closed,
    /// The socket read timed out before the first request byte arrived
    /// — an idle keep-alive connection (or a peer that connected and
    /// sent nothing). Closed without a response, counted separately
    /// from the mid-request timeout below.
    IdleTimeout,
    /// The socket read timed out (or the header-read deadline passed)
    /// MID-request — a slow-loris header drip, a body stalled mid-
    /// `Content-Length`. Typed `408 Request Timeout`, then close.
    Timeout,
    /// Request line is not `METHOD SP PATH SP HTTP/1.x`.
    BadRequestLine(String),
    /// A header line without a `:` separator (or no CRLF terminator
    /// before EOF).
    BadHeader(String),
    /// Request line + headers exceeded [`MAX_HEADER_BYTES`].
    HeadersTooLarge,
    /// Body-carrying method without a parseable `Content-Length`.
    MissingLength,
    /// Declared `Content-Length` exceeds the server's body cap.
    BodyTooLarge { declared: usize, limit: usize },
    /// Socket-level failure (other than a timeout) mid-request.
    Io(io::Error),
}

/// The (status, reason, message) a [`ParseError`] answers with.
/// `Closed` and `IdleTimeout` have no response; callers skip them
/// before writing.
pub fn status_for(e: &ParseError) -> (u16, &'static str, String) {
    match e {
        ParseError::Closed | ParseError::IdleTimeout => (0, "", String::new()),
        ParseError::Timeout => {
            (408, "Request Timeout", "request timed out before it completed".to_string())
        }
        ParseError::BadRequestLine(l) => {
            (400, "Bad Request", format!("malformed request line: {l:?}"))
        }
        ParseError::BadHeader(l) => (400, "Bad Request", format!("malformed header: {l:?}")),
        ParseError::HeadersTooLarge => {
            (400, "Bad Request", format!("headers exceed {MAX_HEADER_BYTES} bytes"))
        }
        ParseError::MissingLength => {
            (400, "Bad Request", "POST requires a Content-Length header".to_string())
        }
        ParseError::BodyTooLarge { declared, limit } => {
            (413, "Payload Too Large", format!("body of {declared} bytes exceeds limit {limit}"))
        }
        ParseError::Io(e) => (400, "Bad Request", format!("read failed: {e}")),
    }
}

/// A socket read timeout surfaces as `WouldBlock` (unix) or `TimedOut`
/// (windows, and our scripted wire faults); both mean "the peer went
/// quiet", never "the peer is gone".
pub fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

/// Read one CRLF- (or bare-LF-) terminated line, counting its bytes
/// against `budget` and honoring `deadline`. Returns the line without
/// the terminator. `first_line` marks the request's opening line, where
/// a timeout before ANY byte is idleness, not a stalled request.
fn read_line(
    r: &mut impl BufRead,
    budget: &mut usize,
    deadline: Option<Instant>,
    first_line: bool,
) -> Result<String, ParseError> {
    let mut raw = Vec::new();
    loop {
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(if first_line && raw.is_empty() {
                ParseError::IdleTimeout
            } else {
                ParseError::Timeout
            });
        }
        let buf = match r.fill_buf() {
            Ok(b) => b,
            Err(e) if is_timeout(&e) => {
                return Err(if first_line && raw.is_empty() {
                    ParseError::IdleTimeout
                } else {
                    ParseError::Timeout
                });
            }
            Err(e) => return Err(ParseError::Io(e)),
        };
        if buf.is_empty() {
            if raw.is_empty() {
                return Err(ParseError::Closed);
            }
            return Err(ParseError::BadHeader(String::from_utf8_lossy(&raw).into_owned()));
        }
        let nl = buf.iter().position(|&b| b == b'\n');
        let take = nl.map_or(buf.len(), |i| i + 1);
        if take > *budget {
            return Err(ParseError::HeadersTooLarge);
        }
        *budget -= take;
        raw.extend_from_slice(&buf[..take]);
        r.consume(take);
        if nl.is_some() {
            break;
        }
    }
    raw.pop(); // the \n
    if raw.last() == Some(&b'\r') {
        raw.pop();
    }
    String::from_utf8(raw).map_err(|e| ParseError::BadHeader(format!("non-utf8 line: {e}")))
}

/// Parse one request off the stream: request line, headers, then exactly
/// `Content-Length` body bytes (capped at `max_body`). Methods that
/// carry no body (GET/HEAD/DELETE) skip the length requirement.
/// `deadline`, when set, bounds the WHOLE read wall-clock — per-read
/// socket timeouts bound each quiet gap, the deadline bounds a client
/// that drips bytes fast enough to dodge them.
pub fn parse_request(
    r: &mut impl BufRead,
    max_body: usize,
    deadline: Option<Instant>,
) -> Result<HttpRequest, ParseError> {
    let mut budget = MAX_HEADER_BYTES;
    let line = read_line(r, &mut budget, deadline, true)?;
    let mut parts = line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && p.starts_with('/') => (m, p, v),
        _ => return Err(ParseError::BadRequestLine(line.clone())),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(ParseError::BadRequestLine(line.clone()));
    }
    let http10 = version == "HTTP/1.0";
    let (method, path) = (method.to_string(), path.to_string());
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line(r, &mut budget, deadline, false) {
            Ok(l) => l,
            // EOF mid-headers is malformed, not a clean close
            Err(ParseError::Closed) => return Err(ParseError::BadHeader("<eof>".into())),
            Err(e) => return Err(e),
        };
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ParseError::BadHeader(line));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let req =
        HttpRequest { method, path, headers, body: Vec::new(), keep_alive: true };
    // HTTP/1.1 defaults to keep-alive, 1.0 to close; an explicit
    // `Connection:` token overrides either default
    let keep_alive = match req.header("connection") {
        Some(v) if v.to_ascii_lowercase().contains("close") => false,
        Some(v) if v.to_ascii_lowercase().contains("keep-alive") => true,
        _ => !http10,
    };
    let body_len = match req.header("content-length") {
        Some(v) => v.parse::<usize>().map_err(|_| ParseError::MissingLength)?,
        None if req.method == "POST" || req.method == "PUT" => {
            return Err(ParseError::MissingLength)
        }
        None => 0,
    };
    if body_len > max_body {
        return Err(ParseError::BodyTooLarge { declared: body_len, limit: max_body });
    }
    // body: read in slices so a trickled body re-checks the deadline —
    // one read_exact would let the drip outlive it
    let mut body = vec![0u8; body_len];
    let mut filled = 0usize;
    while filled < body_len {
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(ParseError::Timeout);
        }
        let take = (body_len - filled).min(8 * 1024);
        match r.read(&mut body[filled..filled + take]) {
            Ok(0) => {
                return Err(ParseError::Io(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    format!("body truncated at {filled} of {body_len} bytes"),
                )))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => return Err(ParseError::Timeout),
            Err(e) => return Err(ParseError::Io(e)),
        }
    }
    Ok(HttpRequest { body, keep_alive, ..req })
}

/// Write a complete fixed-length response (status line, standard
/// headers, `extra` headers, body) and flush. `keep_alive` picks the
/// `Connection:` header — the route layer owns that decision (client
/// preference ∧ per-connection request cap ∧ not shutting down).
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    write!(w, "Connection: {}\r\n", if keep_alive { "keep-alive" } else { "close" })?;
    for (k, v) in extra {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Chunked transfer encoding: the streaming response arm. `begin` sends
/// the header block, each `chunk` sends one length-prefixed frame and
/// FLUSHES (a streamed token must reach the client now, not when a
/// buffer fills — this flush is also how a dead client is detected
/// promptly), `finish` sends the terminal zero-length chunk. Chunked
/// bodies are self-delimiting, so a finished stream can keep its
/// connection alive like any fixed-length response.
pub struct ChunkedWriter<'w, W: Write> {
    w: &'w mut W,
}

impl<'w, W: Write> ChunkedWriter<'w, W> {
    pub fn begin(
        w: &'w mut W,
        status: u16,
        reason: &str,
        content_type: &str,
        keep_alive: bool,
    ) -> io::Result<ChunkedWriter<'w, W>> {
        write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
        write!(w, "Content-Type: {content_type}\r\n")?;
        write!(w, "Transfer-Encoding: chunked\r\n")?;
        write!(w, "Connection: {}\r\n\r\n", if keep_alive { "keep-alive" } else { "close" })?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    pub fn finish(self) -> io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;
    use std::time::Duration;

    fn parse(text: &str) -> Result<HttpRequest, ParseError> {
        parse_request(&mut Cursor::new(text.as_bytes()), 1024, None)
    }

    #[test]
    fn happy_path_post() {
        let req = parse("POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\n{\"a\": 1}\n")
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"), "lookup is case-insensitive");
        assert_eq!(req.body, b"{\"a\": 1}\n");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn happy_path_get_without_body() {
        let req = parse("GET /metrics HTTP/1.1\r\nAccept: */*\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert!(req.body.is_empty());
    }

    #[test]
    fn connection_negotiation_follows_version_and_header() {
        let req = parse("GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!req.keep_alive, "explicit close wins over the 1.1 default");
        let req = parse("GET /healthz HTTP/1.0\r\n\r\n").unwrap();
        assert!(!req.keep_alive, "HTTP/1.0 defaults to close");
        let req = parse("GET /healthz HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(req.keep_alive, "explicit keep-alive wins over the 1.0 default");
        let req = parse("GET /healthz HTTP/1.1\r\nConnection: Close\r\n\r\n").unwrap();
        assert!(!req.keep_alive, "token match is case-insensitive");
    }

    #[test]
    fn bare_lf_lines_parse_too() {
        let req = parse("GET /healthz HTTP/1.1\nHost: x\n\n").unwrap();
        assert_eq!(req.path, "/healthz");
    }

    #[test]
    fn malformed_request_line_is_400() {
        for bad in ["GARBAGE", "GET /x", "GET  HTTP/1.1", "GET noslash HTTP/1.1", "GET /x SPDY/3"] {
            let e = parse(&format!("{bad}\r\n\r\n")).unwrap_err();
            assert!(matches!(e, ParseError::BadRequestLine(_)), "{bad}: {e:?}");
            assert_eq!(status_for(&e).0, 400, "{bad}");
        }
    }

    #[test]
    fn missing_content_length_on_post_is_400() {
        let e = parse("POST /v1/generate HTTP/1.1\r\nHost: x\r\n\r\n{}").unwrap_err();
        assert!(matches!(e, ParseError::MissingLength), "{e:?}");
        assert_eq!(status_for(&e).0, 400);
        // unparseable length is the same defect
        let e = parse("POST /v1/generate HTTP/1.1\r\nContent-Length: many\r\n\r\n{}").unwrap_err();
        assert!(matches!(e, ParseError::MissingLength), "{e:?}");
    }

    #[test]
    fn oversized_body_is_413_without_reading_it() {
        // declared length over the cap rejects BEFORE any body bytes are
        // consumed — none are even present here
        let e = parse("POST /v1/generate HTTP/1.1\r\nContent-Length: 4096\r\n\r\n").unwrap_err();
        assert!(matches!(e, ParseError::BodyTooLarge { declared: 4096, limit: 1024 }), "{e:?}");
        assert_eq!(status_for(&e).0, 413);
    }

    #[test]
    fn header_without_colon_is_400() {
        let e = parse("GET /metrics HTTP/1.1\r\nBadHeader\r\n\r\n").unwrap_err();
        assert!(matches!(e, ParseError::BadHeader(_)), "{e:?}");
    }

    #[test]
    fn oversized_header_block_is_400() {
        let mut text = String::from("GET /metrics HTTP/1.1\r\n");
        for i in 0..200 {
            text.push_str(&format!("X-Pad-{i}: {}\r\n", "y".repeat(64)));
        }
        text.push_str("\r\n");
        let e = parse(&text).unwrap_err();
        assert!(matches!(e, ParseError::HeadersTooLarge), "{e:?}");
    }

    #[test]
    fn immediate_eof_is_clean_close() {
        let e = parse("").unwrap_err();
        assert!(matches!(e, ParseError::Closed), "{e:?}");
    }

    #[test]
    fn truncated_body_is_io_error() {
        let e = parse("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort").unwrap_err();
        assert!(matches!(e, ParseError::Io(_)), "{e:?}");
    }

    /// A reader that yields its script one item at a time: `Ok(bytes)`
    /// frames arrive intact, `TimedOut` simulates the socket read
    /// timeout a stalled peer produces. BufRead so it plugs straight
    /// into `parse_request` — the adversarial-framing harness.
    struct ScriptedReader {
        script: std::collections::VecDeque<Result<Vec<u8>, io::ErrorKind>>,
        cur: Vec<u8>,
    }

    impl ScriptedReader {
        fn new(script: Vec<Result<&[u8], io::ErrorKind>>) -> ScriptedReader {
            ScriptedReader {
                script: script.into_iter().map(|r| r.map(<[u8]>::to_vec)).collect(),
                cur: Vec::new(),
            }
        }
    }

    impl Read for ScriptedReader {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            let avail = self.fill_buf()?;
            let n = avail.len().min(buf.len());
            buf[..n].copy_from_slice(&avail[..n]);
            self.consume(n);
            Ok(n)
        }
    }

    impl BufRead for ScriptedReader {
        fn fill_buf(&mut self) -> io::Result<&[u8]> {
            if self.cur.is_empty() {
                match self.script.pop_front() {
                    Some(Ok(b)) => self.cur = b,
                    Some(Err(kind)) => return Err(io::Error::new(kind, "scripted")),
                    None => {} // EOF
                }
            }
            Ok(&self.cur)
        }

        fn consume(&mut self, amt: usize) {
            self.cur.drain(..amt);
        }
    }

    #[test]
    fn byte_at_a_time_trickle_still_parses() {
        // correct framing must survive maximal fragmentation: one byte
        // per read, header and body alike
        let wire = b"POST /v1/generate HTTP/1.1\r\nContent-Length: 11\r\n\r\n{\"a\": [1]}\n";
        let script: Vec<Result<&[u8], io::ErrorKind>> =
            wire.chunks(1).map(|c| Ok(c)).collect();
        let mut r = ScriptedReader::new(script);
        let req = parse_request(&mut r, 1024, None).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{\"a\": [1]}\n");
    }

    #[test]
    fn stall_mid_headers_is_408_timeout() {
        // the slow loris: part of the header block arrives, then the
        // socket read timeout fires forever after
        let mut r = ScriptedReader::new(vec![
            Ok(b"POST /v1/generate HTTP/1.1\r\nContent-Le"),
            Err(io::ErrorKind::TimedOut),
        ]);
        let e = parse_request(&mut r, 1024, None).unwrap_err();
        assert!(matches!(e, ParseError::Timeout), "{e:?}");
        assert_eq!(status_for(&e).0, 408);
    }

    #[test]
    fn stall_after_complete_headers_is_408_timeout() {
        // headers land whole, the promised body never starts
        let mut r = ScriptedReader::new(vec![
            Ok(b"POST /v1/generate HTTP/1.1\r\nContent-Length: 40\r\n\r\n"),
            Err(io::ErrorKind::TimedOut),
        ]);
        let e = parse_request(&mut r, 1024, None).unwrap_err();
        assert!(matches!(e, ParseError::Timeout), "{e:?}");
        assert_eq!(status_for(&e).0, 408);
    }

    #[test]
    fn body_split_mid_content_length_then_stall_is_408() {
        // half the declared body arrives, then the drip stops — the
        // worker must get a typed timeout, not spin or panic
        let mut r = ScriptedReader::new(vec![
            Ok(b"POST /v1/generate HTTP/1.1\r\nContent-Length: 20\r\n\r\n"),
            Ok(b"{\"prompt\": "),
            Err(io::ErrorKind::WouldBlock),
        ]);
        let e = parse_request(&mut r, 1024, None).unwrap_err();
        assert!(matches!(e, ParseError::Timeout), "{e:?}");
        assert_eq!(status_for(&e).0, 408);
    }

    #[test]
    fn timeout_before_any_byte_is_idle_not_408() {
        let mut r = ScriptedReader::new(vec![Err(io::ErrorKind::WouldBlock)]);
        let e = parse_request(&mut r, 1024, None).unwrap_err();
        assert!(matches!(e, ParseError::IdleTimeout), "{e:?}");
        assert_eq!(status_for(&e).0, 0, "idleness earns no response, just a close");
    }

    /// Delays each frame by a few ms — enough for a short wall-clock
    /// deadline to expire BETWEEN reads while bytes keep arriving.
    struct SlowReader {
        inner: ScriptedReader,
        delay: Duration,
    }

    impl Read for SlowReader {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            let avail = self.fill_buf()?;
            let n = avail.len().min(buf.len());
            buf[..n].copy_from_slice(&avail[..n]);
            self.consume(n);
            Ok(n)
        }
    }

    impl BufRead for SlowReader {
        fn fill_buf(&mut self) -> io::Result<&[u8]> {
            if self.inner.cur.is_empty() {
                std::thread::sleep(self.delay);
            }
            self.inner.fill_buf()
        }

        fn consume(&mut self, amt: usize) {
            self.inner.consume(amt);
        }
    }

    #[test]
    fn expired_deadline_is_408_even_when_bytes_keep_coming() {
        // the deadline is the defense per-read timeouts can't provide:
        // a client dripping bytes fast enough to reset the socket timer
        // still runs out of wall clock
        let wire = b"POST /v1/generate HTTP/1.1\r\nX-Drip: 1\r\nContent-Length: 4\r\n\r\nbody";
        let script: Vec<Result<&[u8], io::ErrorKind>> = wire.chunks(8).map(|c| Ok(c)).collect();
        let mut r =
            SlowReader { inner: ScriptedReader::new(script), delay: Duration::from_millis(5) };
        let deadline = Instant::now() + Duration::from_millis(8);
        let e = parse_request(&mut r, 1024, Some(deadline)).unwrap_err();
        assert!(matches!(e, ParseError::Timeout), "{e:?}");
        assert_eq!(status_for(&e).0, 408);
        // the same wire under a live deadline parses fine
        let script: Vec<Result<&[u8], io::ErrorKind>> = wire.chunks(8).map(|c| Ok(c)).collect();
        let mut r = ScriptedReader::new(script);
        let live = Instant::now() + Duration::from_secs(30);
        assert!(parse_request(&mut r, 1024, Some(live)).is_ok(), "a live deadline admits");
    }

    #[test]
    fn fixed_response_wire_format() {
        let mut out = Vec::new();
        write_response(
            &mut out,
            200,
            "OK",
            "application/json",
            &[("Retry-After", "1")],
            b"{}",
            false,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");

        let mut out = Vec::new();
        write_response(&mut out, 200, "OK", "text/plain", &[], b"ok", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"), "{text}");
    }

    #[test]
    fn chunked_response_wire_format() {
        let mut out = Vec::new();
        {
            let mut cw =
                ChunkedWriter::begin(&mut out, 200, "OK", "application/x-ndjson", false).unwrap();
            cw.chunk(b"{\"token\":5}\n").unwrap();
            cw.chunk(b"").unwrap(); // no-op, must NOT terminate the stream
            cw.chunk(b"{\"done\":true}\n").unwrap();
            cw.finish().unwrap();
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Transfer-Encoding: chunked\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.contains("c\r\n{\"token\":5}\n\r\n"), "{text}");
        assert!(text.contains("e\r\n{\"done\":true}\n\r\n"), "{text}");
        assert!(text.ends_with("0\r\n\r\n"), "{text}");
    }
}
