//! Hand-rolled HTTP/1.1 wire layer (no dependencies — the sealed build
//! environment has no hyper/tiny_http; the same vendored-shim philosophy
//! that gave us the offline `anyhow`/`log`).
//!
//! Scope is deliberately narrow: the server speaks exactly the subset a
//! serving front end needs — one request per connection (every response
//! carries `Connection: close`), `Content-Length` bodies on the way in,
//! fixed-length or chunked (`Transfer-Encoding: chunked`) bodies on the
//! way out. Parsing is defensive: every malformed input maps to a typed
//! [`ParseError`] so the route layer can answer with the matching status
//! code instead of dropping the connection silently, and both the header
//! block and the body are size-capped so a hostile client cannot balloon
//! server memory.

use std::io::{self, BufRead, Read, Write};

/// Upper bound on the request line + header block, in bytes. Generous
/// for hand-written clients and curl alike; a request that exceeds it
/// is malformed or hostile, either way a 400.
pub const MAX_HEADER_BYTES: usize = 8 * 1024;

/// A parsed request: method + path verbatim from the request line,
/// header names lowercased (HTTP headers are case-insensitive), body
/// read to exactly `Content-Length` bytes.
#[derive(Clone, Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Case-insensitive header lookup (names were lowercased at parse).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }
}

/// Every way reading a request can fail, each mapped to one response by
/// [`status_for`]. `Closed` is the clean no-request case (EOF before any
/// byte — the peer connected and left); it gets no response at all.
#[derive(Debug)]
pub enum ParseError {
    /// EOF before the first request byte: not an error, just a peer
    /// that closed without sending a request.
    Closed,
    /// Request line is not `METHOD SP PATH SP HTTP/1.x`.
    BadRequestLine(String),
    /// A header line without a `:` separator (or no CRLF terminator
    /// before EOF).
    BadHeader(String),
    /// Request line + headers exceeded [`MAX_HEADER_BYTES`].
    HeadersTooLarge,
    /// Body-carrying method without a parseable `Content-Length`.
    MissingLength,
    /// Declared `Content-Length` exceeds the server's body cap.
    BodyTooLarge { declared: usize, limit: usize },
    /// Socket-level failure (timeout included) mid-request.
    Io(io::Error),
}

/// The (status, reason, message) a [`ParseError`] answers with.
/// `Closed` has no response; callers skip it before writing.
pub fn status_for(e: &ParseError) -> (u16, &'static str, String) {
    match e {
        ParseError::Closed => (0, "", String::new()),
        ParseError::BadRequestLine(l) => {
            (400, "Bad Request", format!("malformed request line: {l:?}"))
        }
        ParseError::BadHeader(l) => (400, "Bad Request", format!("malformed header: {l:?}")),
        ParseError::HeadersTooLarge => {
            (400, "Bad Request", format!("headers exceed {MAX_HEADER_BYTES} bytes"))
        }
        ParseError::MissingLength => {
            (400, "Bad Request", "POST requires a Content-Length header".to_string())
        }
        ParseError::BodyTooLarge { declared, limit } => {
            (413, "Payload Too Large", format!("body of {declared} bytes exceeds limit {limit}"))
        }
        ParseError::Io(e) => (400, "Bad Request", format!("read failed: {e}")),
    }
}

/// Read one CRLF- (or bare-LF-) terminated line, counting its bytes
/// against `budget`. Returns the line without the terminator.
fn read_line(r: &mut impl BufRead, budget: &mut usize) -> Result<String, ParseError> {
    let mut raw = Vec::new();
    loop {
        let buf = r.fill_buf().map_err(ParseError::Io)?;
        if buf.is_empty() {
            if raw.is_empty() {
                return Err(ParseError::Closed);
            }
            return Err(ParseError::BadHeader(String::from_utf8_lossy(&raw).into_owned()));
        }
        let nl = buf.iter().position(|&b| b == b'\n');
        let take = nl.map_or(buf.len(), |i| i + 1);
        if take > *budget {
            return Err(ParseError::HeadersTooLarge);
        }
        *budget -= take;
        raw.extend_from_slice(&buf[..take]);
        r.consume(take);
        if nl.is_some() {
            break;
        }
    }
    raw.pop(); // the \n
    if raw.last() == Some(&b'\r') {
        raw.pop();
    }
    String::from_utf8(raw).map_err(|e| ParseError::BadHeader(format!("non-utf8 line: {e}")))
}

/// Parse one request off the stream: request line, headers, then exactly
/// `Content-Length` body bytes (capped at `max_body`). Methods that
/// carry no body (GET/HEAD/DELETE) skip the length requirement.
pub fn parse_request(r: &mut impl BufRead, max_body: usize) -> Result<HttpRequest, ParseError> {
    let mut budget = MAX_HEADER_BYTES;
    let line = read_line(r, &mut budget)?;
    let mut parts = line.split(' ');
    let (method, path, version) = match (parts.next(), parts.next(), parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) if !m.is_empty() && p.starts_with('/') => (m, p, v),
        _ => return Err(ParseError::BadRequestLine(line.clone())),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(ParseError::BadRequestLine(line.clone()));
    }
    let (method, path) = (method.to_string(), path.to_string());
    let mut headers: Vec<(String, String)> = Vec::new();
    loop {
        let line = match read_line(r, &mut budget) {
            Ok(l) => l,
            // EOF mid-headers is malformed, not a clean close
            Err(ParseError::Closed) => return Err(ParseError::BadHeader("<eof>".into())),
            Err(e) => return Err(e),
        };
        if line.is_empty() {
            break;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(ParseError::BadHeader(line));
        };
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let req = HttpRequest { method, path, headers, body: Vec::new() };
    let body_len = match req.header("content-length") {
        Some(v) => v.parse::<usize>().map_err(|_| ParseError::MissingLength)?,
        None if req.method == "POST" || req.method == "PUT" => {
            return Err(ParseError::MissingLength)
        }
        None => 0,
    };
    if body_len > max_body {
        return Err(ParseError::BodyTooLarge { declared: body_len, limit: max_body });
    }
    let mut body = vec![0u8; body_len];
    r.read_exact(&mut body).map_err(ParseError::Io)?;
    Ok(HttpRequest { body, ..req })
}

/// Write a complete fixed-length response (status line, standard
/// headers, `extra` headers, body) and flush. Every response closes the
/// connection — the server is strictly one-request-per-connection.
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    extra: &[(&str, &str)],
    body: &[u8],
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
    write!(w, "Content-Type: {content_type}\r\n")?;
    write!(w, "Content-Length: {}\r\n", body.len())?;
    write!(w, "Connection: close\r\n")?;
    for (k, v) in extra {
        write!(w, "{k}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Chunked transfer encoding: the streaming response arm. `begin` sends
/// the header block, each `chunk` sends one length-prefixed frame and
/// FLUSHES (a streamed token must reach the client now, not when a
/// buffer fills — this flush is also how a dead client is detected
/// promptly), `finish` sends the terminal zero-length chunk.
pub struct ChunkedWriter<'w, W: Write> {
    w: &'w mut W,
}

impl<'w, W: Write> ChunkedWriter<'w, W> {
    pub fn begin(
        w: &'w mut W,
        status: u16,
        reason: &str,
        content_type: &str,
    ) -> io::Result<ChunkedWriter<'w, W>> {
        write!(w, "HTTP/1.1 {status} {reason}\r\n")?;
        write!(w, "Content-Type: {content_type}\r\n")?;
        write!(w, "Transfer-Encoding: chunked\r\n")?;
        write!(w, "Connection: close\r\n\r\n")?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    pub fn finish(self) -> io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(text: &str) -> Result<HttpRequest, ParseError> {
        parse_request(&mut Cursor::new(text.as_bytes()), 1024)
    }

    #[test]
    fn happy_path_post() {
        let req = parse("POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 9\r\n\r\n{\"a\": 1}\n")
            .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("HOST"), Some("x"), "lookup is case-insensitive");
        assert_eq!(req.body, b"{\"a\": 1}\n");
    }

    #[test]
    fn happy_path_get_without_body() {
        let req = parse("GET /metrics HTTP/1.1\r\nAccept: */*\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/metrics");
        assert!(req.body.is_empty());
    }

    #[test]
    fn bare_lf_lines_parse_too() {
        let req = parse("GET /healthz HTTP/1.1\nHost: x\n\n").unwrap();
        assert_eq!(req.path, "/healthz");
    }

    #[test]
    fn malformed_request_line_is_400() {
        for bad in ["GARBAGE", "GET /x", "GET  HTTP/1.1", "GET noslash HTTP/1.1", "GET /x SPDY/3"] {
            let e = parse(&format!("{bad}\r\n\r\n")).unwrap_err();
            assert!(matches!(e, ParseError::BadRequestLine(_)), "{bad}: {e:?}");
            assert_eq!(status_for(&e).0, 400, "{bad}");
        }
    }

    #[test]
    fn missing_content_length_on_post_is_400() {
        let e = parse("POST /v1/generate HTTP/1.1\r\nHost: x\r\n\r\n{}").unwrap_err();
        assert!(matches!(e, ParseError::MissingLength), "{e:?}");
        assert_eq!(status_for(&e).0, 400);
        // unparseable length is the same defect
        let e = parse("POST /v1/generate HTTP/1.1\r\nContent-Length: many\r\n\r\n{}").unwrap_err();
        assert!(matches!(e, ParseError::MissingLength), "{e:?}");
    }

    #[test]
    fn oversized_body_is_413_without_reading_it() {
        // declared length over the cap rejects BEFORE any body bytes are
        // consumed — none are even present here
        let e = parse("POST /v1/generate HTTP/1.1\r\nContent-Length: 4096\r\n\r\n").unwrap_err();
        assert!(matches!(e, ParseError::BodyTooLarge { declared: 4096, limit: 1024 }), "{e:?}");
        assert_eq!(status_for(&e).0, 413);
    }

    #[test]
    fn header_without_colon_is_400() {
        let e = parse("GET /metrics HTTP/1.1\r\nBadHeader\r\n\r\n").unwrap_err();
        assert!(matches!(e, ParseError::BadHeader(_)), "{e:?}");
    }

    #[test]
    fn oversized_header_block_is_400() {
        let mut text = String::from("GET /metrics HTTP/1.1\r\n");
        for i in 0..200 {
            text.push_str(&format!("X-Pad-{i}: {}\r\n", "y".repeat(64)));
        }
        text.push_str("\r\n");
        let e = parse(&text).unwrap_err();
        assert!(matches!(e, ParseError::HeadersTooLarge), "{e:?}");
    }

    #[test]
    fn immediate_eof_is_clean_close() {
        let e = parse("").unwrap_err();
        assert!(matches!(e, ParseError::Closed), "{e:?}");
    }

    #[test]
    fn truncated_body_is_io_error() {
        let e = parse("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort").unwrap_err();
        assert!(matches!(e, ParseError::Io(_)), "{e:?}");
    }

    #[test]
    fn fixed_response_wire_format() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "OK", "application/json", &[("Retry-After", "1")], b"{}")
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("Content-Length: 2\r\n"), "{text}");
        assert!(text.contains("Connection: close\r\n"), "{text}");
        assert!(text.contains("Retry-After: 1\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
    }

    #[test]
    fn chunked_response_wire_format() {
        let mut out = Vec::new();
        {
            let mut cw = ChunkedWriter::begin(&mut out, 200, "OK", "application/x-ndjson").unwrap();
            cw.chunk(b"{\"token\":5}\n").unwrap();
            cw.chunk(b"").unwrap(); // no-op, must NOT terminate the stream
            cw.chunk(b"{\"done\":true}\n").unwrap();
            cw.finish().unwrap();
        }
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Transfer-Encoding: chunked\r\n"), "{text}");
        assert!(text.contains("c\r\n{\"token\":5}\n\r\n"), "{text}");
        assert!(text.contains("e\r\n{\"done\":true}\n\r\n"), "{text}");
        assert!(text.ends_with("0\r\n\r\n"), "{text}");
    }
}
