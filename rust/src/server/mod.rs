//! HTTP serving front end: a std-only threaded TCP server that puts a
//! socket in front of [`Engine`](crate::serve::Engine).
//!
//! The engine is single-threaded by design (one batched forward at a
//! time is what makes continuous batching fast), so the server maps many
//! concurrent connections onto it with a three-role thread layout:
//!
//! - **one driver thread** owns the model and the engine outright and is
//!   the only thread that ever calls [`Engine::step`]. It alternates
//!   between draining a command channel (submit / cancel / snapshot —
//!   each a message, never a shared lock around the engine) and stepping
//!   the batch; sampled tokens fan out through `Engine::set_on_token` to
//!   per-request event channels the moment they exist.
//! - **one acceptor thread** owns the listener and spawns a short-lived
//!   worker thread per connection (strictly one request per connection —
//!   see [`http`]); on shutdown it stops accepting and joins every
//!   worker before the driver is allowed to exit.
//! - **worker threads** parse the request, talk to the driver through
//!   the command channel, and write the response — fixed-length JSON for
//!   plain generation, chunked transfer encoding fed by the per-request
//!   event channel for `"stream": true`.
//!
//! Robustness is part of the contract, not an afterthought:
//!
//! - the pending queue is bounded ([`ServerConfig::max_pending`]):
//!   a full queue answers `429 Too Many Requests` with `Retry-After`
//!   and the engine never sees the request — no state to leak;
//! - a client that disconnects mid-stream triggers
//!   [`Engine::cancel`](crate::serve::Engine::cancel), so the stream's
//!   K/V pages reclaim immediately instead of decoding for a ghost;
//! - malformed requests get typed `400`/`413` responses (see
//!   [`http::ParseError`]), unknown routes `404`, wrong methods `405`;
//! - `GET /metrics` renders the engine's [`EngineSnapshot`] (queue
//!   depth, live streams, live K/V pages, the full [`EngineStats`]
//!   ledger) plus the server's own HTTP counters as a plain-text
//!   exposition;
//! - [`ServerHandle::shutdown`] drains: stop accepting, join workers
//!   (each holds out for its completion), then let the driver finish
//!   every queued and live stream before the thread exits.
//!
//! Endpoints: `POST /v1/generate`, `GET /metrics`, `GET /healthz`.

pub mod client;
pub mod http;
mod routes;

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, TcpListener};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::model::LanguageModel;
use crate::serve::{
    Completion, Deadline, Engine, EngineConfig, EngineSnapshot, Request, RequestId,
};

/// Server knobs, wrapping the engine's own [`EngineConfig`].
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// The engine the driver thread runs (batch size, window, K/V page
    /// budget, deadlines — all engine-side policy lives there).
    pub engine: EngineConfig,
    /// Backpressure bound: maximum requests waiting in the engine queue.
    /// A submit that would exceed it is refused with `429` +
    /// `Retry-After` before the engine ever sees it.
    pub max_pending: usize,
    /// Request body cap in bytes; a larger declared `Content-Length`
    /// answers `413` without reading the body.
    pub max_body_bytes: usize,
    /// Socket read timeout while parsing a request (a stalled or
    /// byte-dripping client cannot pin a worker forever).
    pub read_timeout_ms: u64,
    /// `max_new_tokens` when the request body doesn't set one.
    pub default_max_new_tokens: usize,
    /// Seconds advertised in the `Retry-After` header of a `429`.
    pub retry_after_s: u32,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            engine: EngineConfig::default(),
            max_pending: 64,
            max_body_bytes: 1 << 20,
            read_timeout_ms: 5_000,
            default_max_new_tokens: 32,
            retry_after_s: 1,
        }
    }
}

/// Server-side HTTP counters (the engine's own ledger lives in
/// [`EngineStats`](crate::serve::EngineStats)); rendered by `/metrics`
/// next to the engine snapshot. Plain relaxed atomics — they are
/// monotone counters, not synchronization.
#[derive(Debug, Default)]
pub struct Counters {
    /// Requests that parsed well enough to be routed.
    pub http_requests: AtomicUsize,
    /// Submissions refused by the bounded pending queue.
    pub http_429: AtomicUsize,
    /// Malformed requests (bad request line / header / JSON / prompt).
    pub http_400: AtomicUsize,
    /// Unknown routes (`405`s for known routes are not counted here).
    pub http_404: AtomicUsize,
    /// Oversized request bodies.
    pub http_413: AtomicUsize,
    /// Streaming responses abandoned by the client mid-stream; each one
    /// cancelled its engine request.
    pub stream_disconnects: AtomicUsize,
}

impl Counters {
    fn bump(c: &AtomicUsize) {
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// Outcome of a submit command: admitted with an id, or refused by the
/// bounded queue (the HTTP layer turns `Busy` into `429`).
pub(crate) enum SubmitReply {
    Accepted(RequestId),
    Busy { queued: usize },
}

/// Per-request event stream, driver → worker. Tokens arrive the moment
/// the engine samples them; `Done` carries the full typed completion
/// and is always the final event.
pub(crate) enum StreamEvent {
    Token(u32),
    Done(Completion),
}

/// Commands workers (and the handle) send the driver thread. The engine
/// is never shared — every interaction is one of these messages.
pub(crate) enum Cmd {
    Submit {
        req: Request,
        deadline: Deadline,
        events: Sender<StreamEvent>,
        reply: Sender<SubmitReply>,
    },
    Cancel(RequestId),
    Snapshot(Sender<EngineSnapshot>),
    /// Deterministic-testing hooks (see [`ServerHandle::pause_engine`]):
    /// a paused driver keeps answering commands (submits queue, metrics
    /// snapshot, cancels land) but does not step the engine.
    Pause,
    Resume,
}

/// A running server: its bound address plus the shutdown plumbing.
/// Dropping the handle shuts the server down (drain semantics — see
/// [`ServerHandle::shutdown`]).
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    cmd_tx: Option<Sender<Cmd>>,
    acceptor: Option<JoinHandle<()>>,
    driver: Option<JoinHandle<()>>,
    counters: Arc<Counters>,
}

impl ServerHandle {
    /// The bound address — with port `0` in [`Server::start`], this is
    /// where the ephemeral port lands.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The server-side HTTP counters (shared with the workers).
    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Stop stepping the engine while still answering every command:
    /// submits queue up (and the bounded-queue `429` path fires
    /// deterministically), `/metrics` keeps serving, cancels land. A
    /// testing hook — production code has no reason to pause.
    pub fn pause_engine(&self) {
        if let Some(tx) = &self.cmd_tx {
            let _ = tx.send(Cmd::Pause);
        }
    }

    /// Undo [`ServerHandle::pause_engine`].
    pub fn resume_engine(&self) {
        if let Some(tx) = &self.cmd_tx {
            let _ = tx.send(Cmd::Resume);
        }
    }

    /// Graceful shutdown, in dependency order: stop the acceptor (no
    /// new connections), join every in-flight worker (each holds out
    /// for its response — live streams drain, they are not cut), then
    /// drop the command channel so the driver finishes whatever work
    /// remains and exits. Idempotent; also runs on drop.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // all workers are joined; dropping the last external sender lets
        // the driver drain and exit
        self.cmd_tx.take();
        if let Some(h) = self.driver.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// The server constructor namespace (the running state lives in
/// [`ServerHandle`] and the three thread roles).
pub struct Server;

impl Server {
    /// Bind `addr` (use port `0` for an ephemeral port), move `model`
    /// into the driver thread, and start serving. The model is owned by
    /// the driver outright — [`Engine`] borrows it there, and no other
    /// thread ever touches it.
    pub fn start<M: LanguageModel + 'static>(
        model: M,
        addr: &str,
        cfg: ServerConfig,
    ) -> io::Result<ServerHandle> {
        assert!(cfg.max_body_bytes >= 1, "max_body_bytes must admit a body");
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let (cmd_tx, cmd_rx) = std::sync::mpsc::channel::<Cmd>();
        let vocab = model.vocab();

        let driver = {
            let max_pending = cfg.max_pending;
            std::thread::Builder::new()
                .name("apt-http-driver".into())
                .spawn(move || drive(model, cfg.engine, max_pending, cmd_rx))?
        };

        let acceptor = {
            let ctx = routes::Ctx {
                cmd: cmd_tx.clone(),
                counters: counters.clone(),
                vocab,
                max_body: cfg.max_body_bytes,
                default_max_new: cfg.default_max_new_tokens,
                retry_after_s: cfg.retry_after_s,
            };
            let stop = stop.clone();
            let read_timeout = Duration::from_millis(cfg.read_timeout_ms.max(1));
            std::thread::Builder::new()
                .name("apt-http-acceptor".into())
                .spawn(move || accept_loop(listener, ctx, stop, read_timeout))?
        };

        Ok(ServerHandle {
            addr: local,
            stop,
            cmd_tx: Some(cmd_tx),
            acceptor: Some(acceptor),
            driver: Some(driver),
            counters,
        })
    }
}

/// The acceptor role: accept until told to stop, one worker thread per
/// connection, every worker joined before this thread exits (that join
/// is what makes [`ServerHandle::shutdown`] a drain — a live stream's
/// worker holds out for its final chunk).
fn accept_loop(
    listener: TcpListener,
    ctx: routes::Ctx,
    stop: Arc<AtomicBool>,
    read_timeout: Duration,
) {
    let mut workers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // the listener is non-blocking (that's how stop is
                // polled); accepted sockets must not inherit that
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_read_timeout(Some(read_timeout));
                let _ = stream.set_nodelay(true);
                let ctx = ctx.clone();
                if let Ok(h) = std::thread::Builder::new()
                    .name("apt-http-worker".into())
                    .spawn(move || routes::handle_connection(stream, &ctx))
                {
                    workers.push(h);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
        // reap finished workers so a long-lived server doesn't
        // accumulate handles (join on a finished thread is immediate)
        if workers.len() >= 32 {
            workers = workers
                .into_iter()
                .filter_map(|h| {
                    if h.is_finished() {
                        let _ = h.join();
                        None
                    } else {
                        Some(h)
                    }
                })
                .collect();
        }
    }
    for h in workers {
        let _ = h.join();
    }
}

/// The driver role: sole owner of the model and the engine. Alternates
/// command intake with [`Engine::step`]; exits once every command
/// sender is gone AND the engine holds no work (the drain half of
/// shutdown). Blocks on the channel when idle, so an idle server burns
/// no CPU stepping an empty engine.
fn drive<M: LanguageModel>(
    model: M,
    engine_cfg: EngineConfig,
    max_pending: usize,
    rx: Receiver<Cmd>,
) {
    // token fan-out: on_token runs inside Engine::step on this thread;
    // the map is shared with the command handler below, never crossing
    // threads (Rc, not Arc — the channels do the crossing)
    let subs: Rc<std::cell::RefCell<HashMap<RequestId, Sender<StreamEvent>>>> = Rc::default();
    let mut engine = Engine::new(&model, engine_cfg);
    {
        let subs = subs.clone();
        engine.set_on_token(move |id, tok| {
            if let Some(tx) = subs.borrow().get(&id) {
                // a dead receiver (worker gone mid-stream) is fine: the
                // worker's Cancel command is already in flight
                let _ = tx.send(StreamEvent::Token(tok));
            }
        });
    }
    let mut paused = false;
    let mut disconnected = false;
    loop {
        // intake: block briefly when there is nothing to step, drain
        // opportunistically when there is
        if paused || !engine.has_work() {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(cmd) => handle_cmd(cmd, &mut engine, &subs, &mut paused, max_pending),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
        }
        while let Ok(cmd) = rx.try_recv() {
            handle_cmd(cmd, &mut engine, &subs, &mut paused, max_pending);
        }
        if disconnected {
            // shutdown drains: nothing can pause or submit anymore,
            // finish what's in flight and leave
            paused = false;
            if !engine.has_work() {
                break;
            }
        }
        if !paused && engine.has_work() {
            engine.step();
        }
        // deliver completions (cancel-driven ones included — cancel
        // pushes to the finished list outside step)
        for c in engine.take_finished() {
            if let Some(tx) = subs.borrow_mut().remove(&c.id) {
                let _ = tx.send(StreamEvent::Done(c));
            }
        }
    }
}

fn handle_cmd(
    cmd: Cmd,
    engine: &mut Engine<'_>,
    subs: &Rc<std::cell::RefCell<HashMap<RequestId, Sender<StreamEvent>>>>,
    paused: &mut bool,
    max_pending: usize,
) {
    match cmd {
        Cmd::Submit { req, deadline, events, reply } => {
            let queued = engine.queued();
            if queued >= max_pending {
                // refused before the engine sees it: nothing to leak
                let _ = reply.send(SubmitReply::Busy { queued });
                return;
            }
            let id = engine.submit_with_deadline(req, deadline);
            subs.borrow_mut().insert(id, events);
            let _ = reply.send(SubmitReply::Accepted(id));
        }
        Cmd::Cancel(id) => {
            // unknown/finished ids are fine — the completion may have
            // raced ahead of the cancel
            engine.cancel(id);
        }
        Cmd::Snapshot(reply) => {
            let _ = reply.send(engine.snapshot());
        }
        Cmd::Pause => *paused = true,
        Cmd::Resume => *paused = false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Transformer, TransformerConfig};
    use crate::serve::SamplingParams;
    use crate::util::Rng;

    fn tiny_model(seed: u64) -> Transformer {
        Transformer::init(
            TransformerConfig {
                vocab: 37,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 24,
                max_seq: 128,
            },
            &mut Rng::new(seed),
        )
    }

    fn start_tiny(cfg: ServerConfig) -> ServerHandle {
        Server::start(tiny_model(5), "127.0.0.1:0", cfg).expect("bind loopback")
    }

    fn prompt_json(len: usize) -> String {
        let toks: Vec<String> = (0..len).map(|i| ((i * 5 + 3) % 37).to_string()).collect();
        format!("[{}]", toks.join(","))
    }

    #[test]
    fn healthz_and_unknown_routes() {
        let h = start_tiny(ServerConfig::default());
        let r = client::request(h.addr(), "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body, b"ok\n");
        let r = client::request(h.addr(), "GET", "/nope", None).unwrap();
        assert_eq!(r.status, 404);
        // known route, wrong method
        let r = client::request(h.addr(), "GET", "/v1/generate", None).unwrap();
        assert_eq!(r.status, 405);
        let r = client::request(h.addr(), "POST", "/metrics", Some("{}")).unwrap();
        assert_eq!(r.status, 405);
        assert_eq!(h.counters().http_404.load(Ordering::Relaxed), 1);
        h.shutdown();
    }

    #[test]
    fn malformed_requests_get_typed_errors() {
        let mut cfg = ServerConfig::default();
        cfg.max_body_bytes = 256;
        let h = start_tiny(cfg);
        // broken JSON
        let r = client::request(h.addr(), "POST", "/v1/generate", Some("{nope")).unwrap();
        assert_eq!(r.status, 400);
        // missing prompt
        let r = client::request(h.addr(), "POST", "/v1/generate", Some("{}")).unwrap();
        assert_eq!(r.status, 400);
        // empty prompt
        let r =
            client::request(h.addr(), "POST", "/v1/generate", Some(r#"{"prompt": []}"#)).unwrap();
        assert_eq!(r.status, 400);
        // out-of-vocab token (vocab is 37)
        let r =
            client::request(h.addr(), "POST", "/v1/generate", Some(r#"{"prompt": [99]}"#)).unwrap();
        assert_eq!(r.status, 400);
        assert!(String::from_utf8_lossy(&r.body).contains("vocab"), "names the defect");
        // non-integer token
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(r#"{"prompt": [1.5]}"#))
            .unwrap();
        assert_eq!(r.status, 400);
        // oversized body -> 413 (body is never read)
        let big = format!(r#"{{"prompt": [{}]}}"#, "1,".repeat(400) + "1");
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&big)).unwrap();
        assert_eq!(r.status, 413);
        // raw malformed request line -> 400
        let status = client::raw_roundtrip_status(h.addr(), "GARBAGE\r\n\r\n").unwrap();
        assert_eq!(status, 400);
        assert!(h.counters().http_400.load(Ordering::Relaxed) >= 6);
        assert_eq!(h.counters().http_413.load(Ordering::Relaxed), 1);
        h.shutdown();
    }

    #[test]
    fn generate_plain_and_streamed_agree_with_engine() {
        let h = start_tiny(ServerConfig::default());
        let body = format!(
            r#"{{"prompt": {}, "max_new_tokens": 6, "seed": 3}}"#,
            prompt_json(5)
        );
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let v = crate::json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(v.get("finish").unwrap().as_str(), Some("length"));
        let plain: Vec<u32> = v
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_f64().unwrap() as u32)
            .collect();
        assert_eq!(plain.len(), 6);

        // library path: the same greedy request straight into an Engine
        // over an identically seeded model
        let model = tiny_model(5);
        let mut eng = Engine::new(&model, EngineConfig::default());
        let p: Vec<u32> = (0..5).map(|i| ((i * 5 + 3) % 37) as u32).collect();
        eng.submit(Request { prompt: p, max_new_tokens: 6, sampling: SamplingParams::greedy() });
        eng.run();
        let expect = eng.take_finished().pop().unwrap().tokens;
        assert_eq!(plain, expect, "HTTP path must match the library path");

        // streamed: same tokens, one per chunk, then the terminal chunk
        let sbody = format!(
            r#"{{"prompt": {}, "max_new_tokens": 6, "stream": true}}"#,
            prompt_json(5)
        );
        let (status, chunks) = client::stream_request(h.addr(), "/v1/generate", &sbody).unwrap();
        assert_eq!(status, 200);
        let (toks, done) = client::split_stream(&chunks);
        assert_eq!(toks, expect, "streamed tokens must match too");
        let done = done.expect("terminal chunk present");
        assert_eq!(done.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(done.get("tokens_generated").unwrap().as_usize(), Some(6));
        h.shutdown();
    }

    #[test]
    fn metrics_reflect_the_ledger_and_drain_to_zero_pages() {
        let h = start_tiny(ServerConfig::default());
        let body =
            format!(r#"{{"prompt": {}, "max_new_tokens": 4}}"#, prompt_json(6));
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        let m = client::request(h.addr(), "GET", "/metrics", None).unwrap();
        assert_eq!(m.status, 200);
        let text = String::from_utf8(m.body).unwrap();
        let get = |k: &str| client::metric(&text, k).unwrap_or_else(|| panic!("missing {k}"));
        assert_eq!(get("apt_engine_completions_total"), 1);
        assert_eq!(get("apt_engine_completions_length_total"), 1);
        assert_eq!(get("apt_engine_tokens_generated_total"), 4);
        assert_eq!(get("apt_engine_kv_pages_live"), 0, "drained engine holds no pages");
        assert_eq!(get("apt_engine_queue_depth"), 0);
        assert_eq!(get("apt_engine_streams_active"), 0);
        assert!(get("apt_http_requests_total") >= 1);
        h.shutdown();
    }

    #[test]
    fn deadline_fields_map_to_engine_deadlines() {
        let h = start_tiny(ServerConfig::default());
        // 2 decode steps against a 30-token ask: finishes by deadline
        // with exactly the 2-step prefix
        let body = format!(
            r#"{{"prompt": {}, "max_new_tokens": 30, "deadline_steps": 2}}"#,
            prompt_json(5)
        );
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        let v = crate::json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(v.get("finish").unwrap().as_str(), Some("deadline"));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        h.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_drains() {
        let h = start_tiny(ServerConfig::default());
        let addr = h.addr();
        let body = format!(r#"{{"prompt": {}, "max_new_tokens": 3}}"#, prompt_json(4));
        let r = client::request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        h.shutdown();
        // the listener is gone after shutdown
        assert!(client::request(addr, "GET", "/healthz", None).is_err());
    }
}
