//! HTTP serving front end: a std-only threaded TCP server that puts a
//! socket in front of [`Engine`](crate::serve::Engine).
//!
//! The engine is single-threaded by design (one batched forward at a
//! time is what makes continuous batching fast), so the server maps many
//! concurrent connections onto it with a three-role thread layout:
//!
//! - **one driver thread** owns the model and the engine outright and is
//!   the only thread that ever calls [`Engine::step`]. It alternates
//!   between draining a command channel (submit / cancel / snapshot —
//!   each a message, never a shared lock around the engine) and stepping
//!   the batch; sampled tokens fan out through `Engine::set_on_token` to
//!   per-request event channels the moment they exist. It also meters
//!   its own drain rate, so backpressure responses carry a measured
//!   `Retry-After` instead of a constant.
//! - **one acceptor thread** owns the listener and feeds accepted
//!   connections into a BOUNDED queue; when the queue is full it answers
//!   `503` + `Retry-After` right at accept time — load is shed before a
//!   hostile burst can pin anything. No thread is ever spawned per
//!   connection.
//! - **a fixed pool of worker threads** ([`ServerConfig::pool_workers`])
//!   pulls connections off the queue and runs the keep-alive request
//!   loop on each: parse (under read timeouts and a header-read
//!   deadline), route, answer, repeat until the connection closes, goes
//!   idle, or exhausts its per-connection request cap. Concurrent
//!   connection count can no longer exhaust threads by construction.
//!
//! Robustness is part of the contract, not an afterthought:
//!
//! - the pending queue is bounded ([`ServerConfig::max_pending`]): a
//!   full queue answers `429 Too Many Requests` with a `Retry-After`
//!   computed from live queue depth and the measured completion rate,
//!   and the engine never sees the request — no state to leak. A
//!   request whose own queue-wait deadline provably cannot be met is
//!   refused the same way instead of queueing doomed work;
//! - a slow-loris client (header drip, mid-body stall) is dropped with
//!   a typed `408` once its socket goes quiet past the read timeout or
//!   its request outlives the header-read deadline — either way the
//!   worker is reclaimed;
//! - a client that disconnects mid-stream triggers
//!   [`Engine::cancel`](crate::serve::Engine::cancel), so the stream's
//!   K/V pages reclaim immediately instead of decoding for a ghost;
//! - malformed requests get typed `400`/`413` responses (see
//!   [`http::ParseError`]), unknown routes `404`, wrong methods `405`;
//! - `GET /metrics` renders the engine's [`EngineSnapshot`] (queue
//!   depth, live streams, live K/V pages, the full [`EngineStats`]
//!   ledger) plus the server's own HTTP counters — every shed,
//!   timed-out and wire-faulted connection lands in a typed counter;
//! - [`ServerHandle::shutdown`] drains: stop accepting, serve whatever
//!   was already queued, join every pool worker, then let the driver
//!   finish every queued and live stream before the thread exits. The
//!   returned [`ShutdownReport`] counts the joined workers so tests can
//!   pin full thread reclamation;
//! - the wire layer is deterministically faultable: a
//!   [`netfaults::NetFaultPlan`] scripts per-connection short reads,
//!   stalls and mid-stream disconnects at the normal read/write points,
//!   so blast-radius tests can prove a hostile connection never
//!   perturbs a well-behaved one.
//!
//! Endpoints: `POST /v1/generate`, `GET /metrics`, `GET /healthz`.

pub mod client;
pub mod http;
pub mod netfaults;
mod routes;

use std::collections::{HashMap, VecDeque};
use std::io;
use std::net::{SocketAddr, TcpListener};
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::model::LanguageModel;
use crate::serve::{
    Completion, Deadline, Engine, EngineConfig, EngineSnapshot, Request, RequestId,
};
use netfaults::{NetFaultPlan, Wire};

/// Server knobs, wrapping the engine's own [`EngineConfig`].
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// The engine the driver thread runs (batch size, window, K/V page
    /// budget, deadlines — all engine-side policy lives there).
    pub engine: EngineConfig,
    /// Backpressure bound: maximum requests waiting in the engine queue.
    /// A submit that would exceed it is refused with `429` +
    /// `Retry-After` before the engine ever sees it.
    pub max_pending: usize,
    /// Request body cap in bytes; a larger declared `Content-Length`
    /// answers `413` without reading the body.
    pub max_body_bytes: usize,
    /// Per-read socket timeout while a request is in flight (a stalled
    /// client maps to a typed `408`), and the wait bound for the FIRST
    /// request of a fresh connection.
    pub read_timeout_ms: u64,
    /// Wall-clock deadline for reading one whole request (head + body).
    /// The defense `read_timeout_ms` can't provide: a slow-loris client
    /// dripping one byte per timeout window still runs out of clock.
    pub header_deadline_ms: u64,
    /// Socket write timeout — a client that stops reading its response
    /// cannot pin a worker behind a full send buffer.
    pub write_timeout_ms: u64,
    /// Keep-alive: how long a kept-alive connection may sit idle
    /// between requests before the server closes it.
    pub idle_timeout_ms: u64,
    /// Keep-alive: requests served per connection before the server
    /// closes it (`Connection: close` on the last response). Bounds how
    /// long any one client can monopolize a pool worker.
    pub keepalive_max_requests: usize,
    /// Fixed worker-pool size: the maximum number of connections being
    /// SERVED concurrently. More connections queue (bounded by
    /// `conn_backlog`) or shed with `503`.
    pub pool_workers: usize,
    /// Bound on accepted connections waiting for a free pool worker;
    /// overflow is answered `503` + `Retry-After` at accept time.
    pub conn_backlog: usize,
    /// Server-side clamp on any request's `max_new_tokens`: a hostile
    /// body asking for an unbounded decode is clamped to this (the
    /// response's `tokens` length says so — no silent truncation of
    /// well-behaved asks, which sit far below it).
    pub max_new_tokens_cap: usize,
    /// `max_new_tokens` when the request body doesn't set one.
    pub default_max_new_tokens: usize,
    /// Floor (and no-data fallback) for the `Retry-After` seconds on
    /// `429`/`503`. Once the driver has measured a drain rate, the
    /// advertised value is `queued / rate`, clamped to
    /// `[retry_after_s, 60]`.
    pub retry_after_s: u32,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            engine: EngineConfig::default(),
            max_pending: 64,
            max_body_bytes: 1 << 20,
            read_timeout_ms: 5_000,
            header_deadline_ms: 10_000,
            write_timeout_ms: 5_000,
            idle_timeout_ms: 5_000,
            keepalive_max_requests: 64,
            pool_workers: 8,
            conn_backlog: 64,
            max_new_tokens_cap: 4096,
            default_max_new_tokens: 32,
            retry_after_s: 1,
        }
    }
}

/// Server-side HTTP counters (the engine's own ledger lives in
/// [`EngineStats`](crate::serve::EngineStats)); rendered by `/metrics`
/// next to the engine snapshot. Plain relaxed atomics — they are
/// monotone counters, not synchronization. Between them, every
/// connection the server degraded on purpose — shed, timed out, refused
/// or wire-faulted — is accounted in a typed counter.
#[derive(Debug, Default)]
pub struct Counters {
    /// Connections taken off the listener (shed ones included).
    pub conns_accepted: AtomicUsize,
    /// Requests that parsed well enough to be routed.
    pub http_requests: AtomicUsize,
    /// Requests served on an already-used keep-alive connection (the
    /// second and later request of each connection).
    pub keepalive_reuses: AtomicUsize,
    /// Connections closed for idling between requests (or connecting
    /// and never sending a byte). No response is owed.
    pub idle_closes: AtomicUsize,
    /// Submissions refused by the bounded pending queue.
    pub http_429: AtomicUsize,
    /// The subset of `http_429` refused because the request's own
    /// queue-wait deadline provably could not be met at the live queue
    /// depth (doomed work shed at admission).
    pub http_429_doomed: AtomicUsize,
    /// Malformed requests (bad request line / header / JSON / prompt).
    pub http_400: AtomicUsize,
    /// Unknown routes (`405`s for known routes are not counted here).
    pub http_404: AtomicUsize,
    /// Requests that stalled mid-flight (socket timeout or header-read
    /// deadline) and were answered `408` + close.
    pub http_408: AtomicUsize,
    /// Oversized request bodies.
    pub http_413: AtomicUsize,
    /// Connections shed with `503` at accept time because the bounded
    /// connection queue was full.
    pub http_503_shed: AtomicUsize,
    /// Streaming responses abandoned by the client mid-stream; each one
    /// cancelled its engine request.
    pub stream_disconnects: AtomicUsize,
    /// Scripted wire faults that fired: read stalls.
    pub net_stalls: AtomicUsize,
    /// Scripted wire faults that fired: mid-stream disconnects.
    pub net_disconnects: AtomicUsize,
    /// Connections that ran with scripted short reads/writes.
    pub net_short_io_conns: AtomicUsize,
}

impl Counters {
    fn bump(c: &AtomicUsize) {
        c.fetch_add(1, Ordering::Relaxed);
    }
}

/// Outcome of a submit command: admitted with an id, or refused before
/// the engine saw it (the HTTP layer turns both refusals into `429`,
/// with the measured `retry_after_s` and, for `Doomed`, a body naming
/// the unmeetable deadline).
pub(crate) enum SubmitReply {
    Accepted(RequestId),
    /// The bounded pending queue is full.
    Busy { queued: usize, retry_after_s: u32 },
    /// The request's `deadline_wait_rounds` cannot be met: at the live
    /// queue depth it needs at least `need_rounds` admit rounds.
    Doomed { queued: usize, need_rounds: usize, allowed_rounds: usize, retry_after_s: u32 },
}

/// Per-request event stream, driver → worker. Tokens arrive the moment
/// the engine samples them; `Done` carries the full typed completion
/// and is always the final event.
pub(crate) enum StreamEvent {
    Token(u32),
    Done(Completion),
}

/// Commands workers (and the handle) send the driver thread. The engine
/// is never shared — every interaction is one of these messages.
pub(crate) enum Cmd {
    Submit {
        req: Request,
        deadline: Deadline,
        events: Sender<StreamEvent>,
        reply: Sender<SubmitReply>,
    },
    Cancel(RequestId),
    Snapshot(Sender<EngineSnapshot>),
    /// Deterministic-testing hooks (see [`ServerHandle::pause_engine`]):
    /// a paused driver keeps answering commands (submits queue, metrics
    /// snapshot, cancels land) but does not step the engine.
    Pause,
    Resume,
}

// ------------------------------------------------------------- conn queue

/// An accepted connection waiting for a pool worker.
pub(crate) struct Job {
    pub(crate) wire: Wire,
}

/// The bounded handoff between the acceptor and the worker pool:
/// `try_push` refuses when full (the acceptor sheds with `503`), `pop`
/// blocks until a job or close-and-empty. Depth is mirrored in an
/// atomic so `/metrics` and the keep-alive idle-yield never take the
/// lock.
pub(crate) struct ConnQueue {
    q: Mutex<(VecDeque<Job>, bool)>,
    cv: Condvar,
    cap: usize,
    depth: AtomicUsize,
}

impl ConnQueue {
    fn new(cap: usize) -> ConnQueue {
        ConnQueue {
            q: Mutex::new((VecDeque::new(), false)),
            cv: Condvar::new(),
            cap: cap.max(1),
            depth: AtomicUsize::new(0),
        }
    }

    /// Enqueue unless full. Full → the job comes back (the acceptor
    /// sheds it); a closed queue refuses too.
    fn try_push(&self, job: Job) -> Result<(), Job> {
        let mut g = self.q.lock().expect("conn queue");
        if g.1 || g.0.len() >= self.cap {
            return Err(job);
        }
        g.0.push_back(job);
        self.depth.store(g.0.len(), Ordering::Relaxed);
        self.cv.notify_one();
        Ok(())
    }

    /// Next job, blocking; `None` once the queue is closed AND drained
    /// (workers serve everything that was accepted before shutdown).
    fn pop(&self) -> Option<Job> {
        let mut g = self.q.lock().expect("conn queue");
        loop {
            if let Some(job) = g.0.pop_front() {
                self.depth.store(g.0.len(), Ordering::Relaxed);
                return Some(job);
            }
            if g.1 {
                return None;
            }
            g = self.cv.wait(g).expect("conn queue");
        }
    }

    fn close(&self) {
        self.q.lock().expect("conn queue").1 = true;
        self.cv.notify_all();
    }

    pub(crate) fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }
}

/// Pool service-time accounting, fed by the workers and read by the
/// acceptor to compute an honest `Retry-After` for accept-time sheds:
/// `depth x avg_service / workers`, clamped — a measured estimate of
/// when a slot will actually exist, not a constant.
#[derive(Debug, Default)]
pub(crate) struct PoolStats {
    served: AtomicUsize,
    busy_micros: AtomicU64,
}

impl PoolStats {
    fn record(&self, d: Duration) {
        self.served.fetch_add(1, Ordering::Relaxed);
        self.busy_micros.fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }

    /// Seconds until a queue of `depth` connections plausibly drains
    /// across `workers` — or `fallback` before any service time exists.
    fn retry_after_s(&self, depth: usize, workers: usize, fallback: u32) -> u32 {
        let served = self.served.load(Ordering::Relaxed);
        if served == 0 {
            return fallback;
        }
        let avg_s = self.busy_micros.load(Ordering::Relaxed) as f64 / served as f64 / 1e6;
        let secs = (depth.max(1) as f64 * avg_s / workers.max(1) as f64).ceil();
        (secs as u32).clamp(fallback, 60)
    }
}

/// What [`ServerHandle::shutdown`] observed on the way down — lets
/// tests pin that every pool thread was reclaimed.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShutdownReport {
    /// Pool workers joined (== `ServerConfig::pool_workers` unless a
    /// worker panicked).
    pub pool_workers_joined: usize,
}

/// A running server: its bound address plus the shutdown plumbing.
/// Dropping the handle shuts the server down (drain semantics — see
/// [`ServerHandle::shutdown`]).
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    cmd_tx: Option<Sender<Cmd>>,
    acceptor: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    driver: Option<JoinHandle<()>>,
    queue: Arc<ConnQueue>,
    counters: Arc<Counters>,
}

impl ServerHandle {
    /// The bound address — with port `0` in [`Server::start`], this is
    /// where the ephemeral port lands.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The server-side HTTP counters (shared with the workers).
    pub fn counters(&self) -> &Counters {
        &self.counters
    }

    /// Stop stepping the engine while still answering every command:
    /// submits queue up (and the bounded-queue `429` path fires
    /// deterministically), `/metrics` keeps serving, cancels land. A
    /// testing hook — production code has no reason to pause.
    pub fn pause_engine(&self) {
        if let Some(tx) = &self.cmd_tx {
            let _ = tx.send(Cmd::Pause);
        }
    }

    /// Undo [`ServerHandle::pause_engine`].
    pub fn resume_engine(&self) {
        if let Some(tx) = &self.cmd_tx {
            let _ = tx.send(Cmd::Resume);
        }
    }

    /// Graceful shutdown, in dependency order: stop the acceptor (no
    /// new connections), close the connection queue, join every pool
    /// worker (each serves out its current — and any already-queued —
    /// connection; live streams drain, they are not cut), then drop the
    /// command channel so the driver finishes whatever work remains and
    /// exits. Idempotent; also runs on drop.
    pub fn shutdown(mut self) -> ShutdownReport {
        self.shutdown_impl()
    }

    fn shutdown_impl(&mut self) -> ShutdownReport {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // no more pushes: close the queue so idle workers wake, and
        // busy ones drain what was already accepted
        self.queue.close();
        let mut joined = 0usize;
        for h in self.workers.drain(..) {
            if h.join().is_ok() {
                joined += 1;
            }
        }
        // all workers are gone; dropping the last external sender lets
        // the driver drain and exit
        self.cmd_tx.take();
        if let Some(h) = self.driver.take() {
            let _ = h.join();
        }
        ShutdownReport { pool_workers_joined: joined }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// The server constructor namespace (the running state lives in
/// [`ServerHandle`] and the three thread roles).
pub struct Server;

impl Server {
    /// Bind `addr` (use port `0` for an ephemeral port), move `model`
    /// into the driver thread, and start serving. The model is owned by
    /// the driver outright — [`Engine`] borrows it there, and no other
    /// thread ever touches it.
    pub fn start<M: LanguageModel + 'static>(
        model: M,
        addr: &str,
        cfg: ServerConfig,
    ) -> io::Result<ServerHandle> {
        Server::start_with_netfaults(model, addr, cfg, NetFaultPlan::new())
    }

    /// [`Server::start`] with a scripted [`NetFaultPlan`]: chosen
    /// connections (by accept order) get trickled reads, stalls or
    /// mid-stream disconnects injected at the wire layer's normal
    /// read/write points. The default (empty) plan is a no-op — this is
    /// the deterministic-chaos entry point for tests and the chaos
    /// smoke, on exactly the production code path.
    pub fn start_with_netfaults<M: LanguageModel + 'static>(
        model: M,
        addr: &str,
        cfg: ServerConfig,
        faults: NetFaultPlan,
    ) -> io::Result<ServerHandle> {
        assert!(cfg.max_body_bytes >= 1, "max_body_bytes must admit a body");
        assert!(cfg.pool_workers >= 1, "the pool needs at least one worker");
        assert!(cfg.keepalive_max_requests >= 1, "a connection must serve at least one request");
        assert!(cfg.max_new_tokens_cap >= 1, "a zero token cap would make every request empty");
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let counters = Arc::new(Counters::default());
        let queue = Arc::new(ConnQueue::new(cfg.conn_backlog));
        let pool_stats = Arc::new(PoolStats::default());
        let (cmd_tx, cmd_rx) = std::sync::mpsc::channel::<Cmd>();
        let vocab = model.vocab();

        let driver = {
            let max_pending = cfg.max_pending;
            let engine_cfg = cfg.engine;
            let retry_floor = cfg.retry_after_s;
            std::thread::Builder::new()
                .name("apt-http-driver".into())
                .spawn(move || drive(model, engine_cfg, max_pending, retry_floor, cmd_rx))?
        };

        let ctx = routes::Ctx {
            cmd: cmd_tx.clone(),
            counters: counters.clone(),
            queue: queue.clone(),
            stop: stop.clone(),
            vocab,
            max_body: cfg.max_body_bytes,
            default_max_new: cfg.default_max_new_tokens,
            max_new_cap: cfg.max_new_tokens_cap,
            retry_after_s: cfg.retry_after_s,
            read_timeout: Duration::from_millis(cfg.read_timeout_ms.max(1)),
            idle_timeout: Duration::from_millis(cfg.idle_timeout_ms.max(1)),
            header_deadline: Duration::from_millis(cfg.header_deadline_ms.max(1)),
            keepalive_max_requests: cfg.keepalive_max_requests,
            pool_workers: cfg.pool_workers,
        };

        let mut workers = Vec::with_capacity(cfg.pool_workers);
        for i in 0..cfg.pool_workers {
            let queue = queue.clone();
            let ctx = ctx.clone();
            let pool_stats = pool_stats.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("apt-http-worker-{i}"))
                    .spawn(move || worker_loop(&queue, &ctx, &pool_stats))?,
            );
        }

        let acceptor = {
            let stop = stop.clone();
            let queue = queue.clone();
            let counters = counters.clone();
            let a = AcceptCtx {
                faults,
                counters,
                pool_stats,
                queue,
                write_timeout: Duration::from_millis(cfg.write_timeout_ms.max(1)),
                pool_workers: cfg.pool_workers,
                retry_after_floor: cfg.retry_after_s,
            };
            std::thread::Builder::new()
                .name("apt-http-acceptor".into())
                .spawn(move || accept_loop(listener, a, stop))?
        };

        Ok(ServerHandle {
            addr: local,
            stop,
            cmd_tx: Some(cmd_tx),
            acceptor: Some(acceptor),
            workers,
            driver: Some(driver),
            queue,
            counters,
        })
    }
}

struct AcceptCtx {
    faults: NetFaultPlan,
    counters: Arc<Counters>,
    pool_stats: Arc<PoolStats>,
    queue: Arc<ConnQueue>,
    write_timeout: Duration,
    pool_workers: usize,
    retry_after_floor: u32,
}

/// The acceptor role: accept until told to stop, wrap each connection
/// in its (usually clean) fault-plan [`Wire`], and hand it to the
/// bounded queue. A full queue is LOAD SHEDDING, not an error: the
/// connection is answered `503` + a drain-rate-derived `Retry-After`
/// on a short detached thread and closed — no pool worker is touched.
fn accept_loop(listener: TcpListener, a: AcceptCtx, stop: Arc<AtomicBool>) {
    let mut conn_no = 0usize;
    let mut sheds: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                // the listener is non-blocking (that's how stop is
                // polled); accepted sockets must not inherit that
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                let _ = stream.set_write_timeout(Some(a.write_timeout));
                let script = a.faults.script_for(conn_no);
                conn_no += 1;
                Counters::bump(&a.counters.conns_accepted);
                let wire = Wire::new(stream, script, a.counters.clone());
                if let Err(job) = a.queue.try_push(Job { wire }) {
                    Counters::bump(&a.counters.http_503_shed);
                    let retry = a.pool_stats.retry_after_s(
                        a.queue.depth(),
                        a.pool_workers,
                        a.retry_after_floor,
                    );
                    if let Ok(h) = std::thread::Builder::new()
                        .name("apt-http-shed".into())
                        .spawn(move || shed_connection(job, retry))
                    {
                        sheds.push(h);
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
        // reap finished shed threads so a sustained overload doesn't
        // accumulate handles (join on a finished thread is immediate)
        if sheds.len() >= 32 {
            sheds = sheds
                .into_iter()
                .filter_map(|h| {
                    if h.is_finished() {
                        let _ = h.join();
                        None
                    } else {
                        Some(h)
                    }
                })
                .collect();
        }
    }
    for h in sheds {
        let _ = h.join();
    }
}

/// Answer a shed connection `503` and close it gently: drain whatever
/// request bytes the client already sent so the close delivers the
/// response instead of resetting the connection under it.
fn shed_connection(mut job: Job, retry_after_s: u32) {
    let retry = retry_after_s.to_string();
    let _ = http::write_response(
        &mut job.wire,
        503,
        "Service Unavailable",
        "text/plain",
        &[("Retry-After", retry.as_str())],
        b"connection queue is full\n",
        false,
    );
    job.wire.drain_unread(64 * 1024);
}

/// The worker role: pull connections off the bounded queue, run each
/// one's keep-alive loop, account its service time for the shed
/// estimator. Exits when the queue closes (shutdown) — after draining
/// any connection that was already accepted.
fn worker_loop(queue: &ConnQueue, ctx: &routes::Ctx, stats: &PoolStats) {
    while let Some(job) = queue.pop() {
        let t0 = Instant::now();
        routes::handle_connection(job.wire, ctx);
        stats.record(t0.elapsed());
    }
}

/// Sliding window of recent completion times: the drain-rate meter
/// behind `Retry-After`. Plain data on the driver thread — no atomics,
/// no locks.
struct DrainMeter {
    recent: VecDeque<Instant>,
}

impl DrainMeter {
    fn new() -> DrainMeter {
        DrainMeter { recent: VecDeque::with_capacity(64) }
    }

    fn note_completion(&mut self) {
        if self.recent.len() == 64 {
            self.recent.pop_front();
        }
        self.recent.push_back(Instant::now());
    }

    /// Completions per second over the recent window, if measurable.
    fn rate(&self) -> Option<f64> {
        let (first, last) = (self.recent.front()?, self.recent.back()?);
        let span = last.duration_since(*first).as_secs_f64();
        if self.recent.len() < 2 || span <= 0.0 {
            return None;
        }
        Some((self.recent.len() - 1) as f64 / span)
    }

    /// Seconds a newcomer behind `queued` requests should wait before
    /// retrying: measured queue depth over measured drain rate, clamped
    /// to `[floor, 60]`; `floor` when no rate has been measured yet.
    fn retry_after_s(&self, queued: usize, floor: u32) -> u32 {
        match self.rate() {
            Some(rate) if rate > 0.0 => {
                let secs = (queued.max(1) as f64 / rate).ceil();
                (secs as u32).clamp(floor, 60)
            }
            _ => floor,
        }
    }
}

/// The driver role: sole owner of the model and the engine. Alternates
/// command intake with [`Engine::step`]; exits once every command
/// sender is gone AND the engine holds no work (the drain half of
/// shutdown). Blocks on the channel when idle, so an idle server burns
/// no CPU stepping an empty engine.
fn drive<M: LanguageModel>(
    model: M,
    engine_cfg: EngineConfig,
    max_pending: usize,
    retry_floor: u32,
    rx: Receiver<Cmd>,
) {
    // token fan-out: on_token runs inside Engine::step on this thread;
    // the map is shared with the command handler below, never crossing
    // threads (Rc, not Arc — the channels do the crossing)
    let subs: Rc<std::cell::RefCell<HashMap<RequestId, Sender<StreamEvent>>>> = Rc::default();
    let mut engine = Engine::new(&model, engine_cfg);
    {
        let subs = subs.clone();
        engine.set_on_token(move |id, tok| {
            if let Some(tx) = subs.borrow().get(&id) {
                // a dead receiver (worker gone mid-stream) is fine: the
                // worker's Cancel command is already in flight
                let _ = tx.send(StreamEvent::Token(tok));
            }
        });
    }
    let mut drain = DrainMeter::new();
    let mut paused = false;
    let mut disconnected = false;
    loop {
        // intake: block briefly when there is nothing to step, drain
        // opportunistically when there is
        if paused || !engine.has_work() {
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(cmd) => {
                    handle_cmd(cmd, &mut engine, &subs, &mut paused, max_pending, retry_floor, &drain)
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => disconnected = true,
            }
        }
        while let Ok(cmd) = rx.try_recv() {
            handle_cmd(cmd, &mut engine, &subs, &mut paused, max_pending, retry_floor, &drain);
        }
        if disconnected {
            // shutdown drains: nothing can pause or submit anymore,
            // finish what's in flight and leave
            paused = false;
            if !engine.has_work() {
                break;
            }
        }
        if !paused && engine.has_work() {
            engine.step();
        }
        // deliver completions (cancel-driven ones included — cancel
        // pushes to the finished list outside step)
        for c in engine.take_finished() {
            drain.note_completion();
            if let Some(tx) = subs.borrow_mut().remove(&c.id) {
                let _ = tx.send(StreamEvent::Done(c));
            }
        }
    }
}

fn handle_cmd(
    cmd: Cmd,
    engine: &mut Engine<'_>,
    subs: &Rc<std::cell::RefCell<HashMap<RequestId, Sender<StreamEvent>>>>,
    paused: &mut bool,
    max_pending: usize,
    retry_floor: u32,
    drain: &DrainMeter,
) {
    match cmd {
        Cmd::Submit { req, deadline, events, reply } => {
            let queued = engine.queued();
            // doomed-work check first: at the live queue depth the
            // engine admits at most max_batch requests per round, so a
            // request at the back needs >= queued / max_batch rounds —
            // exact under FIFO admission (engine max_wait_rounds = 0),
            // a front-of-queue-pessimistic estimate under
            // shortest-first. Queueing it would only burn a slot on
            // work destined for FinishReason::Deadline.
            if let Some(allowed) = deadline.max_wait_rounds {
                let need = queued / engine.config().max_batch.max(1);
                if need > allowed {
                    let _ = reply.send(SubmitReply::Doomed {
                        queued,
                        need_rounds: need,
                        allowed_rounds: allowed,
                        retry_after_s: drain.retry_after_s(queued, retry_floor),
                    });
                    return;
                }
            }
            if queued >= max_pending {
                // refused before the engine sees it: nothing to leak
                let _ = reply.send(SubmitReply::Busy {
                    queued,
                    retry_after_s: drain.retry_after_s(queued, retry_floor),
                });
                return;
            }
            let id = engine.submit_with_deadline(req, deadline);
            subs.borrow_mut().insert(id, events);
            let _ = reply.send(SubmitReply::Accepted(id));
        }
        Cmd::Cancel(id) => {
            // unknown/finished ids are fine — the completion may have
            // raced ahead of the cancel
            engine.cancel(id);
        }
        Cmd::Snapshot(reply) => {
            let _ = reply.send(engine.snapshot());
        }
        Cmd::Pause => *paused = true,
        Cmd::Resume => *paused = false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Transformer, TransformerConfig};
    use crate::serve::SamplingParams;
    use crate::util::Rng;

    fn tiny_model(seed: u64) -> Transformer {
        Transformer::init(
            TransformerConfig {
                vocab: 37,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 24,
                max_seq: 128,
            },
            &mut Rng::new(seed),
        )
    }

    fn start_tiny(cfg: ServerConfig) -> ServerHandle {
        Server::start(tiny_model(5), "127.0.0.1:0", cfg).expect("bind loopback")
    }

    fn prompt_json(len: usize) -> String {
        let toks: Vec<String> = (0..len).map(|i| ((i * 5 + 3) % 37).to_string()).collect();
        format!("[{}]", toks.join(","))
    }

    #[test]
    fn healthz_and_unknown_routes() {
        let h = start_tiny(ServerConfig::default());
        let r = client::request(h.addr(), "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.body, b"ok\n");
        let r = client::request(h.addr(), "GET", "/nope", None).unwrap();
        assert_eq!(r.status, 404);
        // known route, wrong method
        let r = client::request(h.addr(), "GET", "/v1/generate", None).unwrap();
        assert_eq!(r.status, 405);
        let r = client::request(h.addr(), "POST", "/metrics", Some("{}")).unwrap();
        assert_eq!(r.status, 405);
        assert_eq!(h.counters().http_404.load(Ordering::Relaxed), 1);
        h.shutdown();
    }

    #[test]
    fn malformed_requests_get_typed_errors() {
        let mut cfg = ServerConfig::default();
        cfg.max_body_bytes = 256;
        let h = start_tiny(cfg);
        // broken JSON
        let r = client::request(h.addr(), "POST", "/v1/generate", Some("{nope")).unwrap();
        assert_eq!(r.status, 400);
        // missing prompt
        let r = client::request(h.addr(), "POST", "/v1/generate", Some("{}")).unwrap();
        assert_eq!(r.status, 400);
        // empty prompt
        let r =
            client::request(h.addr(), "POST", "/v1/generate", Some(r#"{"prompt": []}"#)).unwrap();
        assert_eq!(r.status, 400);
        // out-of-vocab token (vocab is 37)
        let r =
            client::request(h.addr(), "POST", "/v1/generate", Some(r#"{"prompt": [99]}"#)).unwrap();
        assert_eq!(r.status, 400);
        assert!(String::from_utf8_lossy(&r.body).contains("vocab"), "names the defect");
        // non-integer token
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(r#"{"prompt": [1.5]}"#))
            .unwrap();
        assert_eq!(r.status, 400);
        // oversized body -> 413 (body is never read)
        let big = format!(r#"{{"prompt": [{}]}}"#, "1,".repeat(400) + "1");
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&big)).unwrap();
        assert_eq!(r.status, 413);
        // raw malformed request line -> 400
        let status = client::raw_roundtrip_status(h.addr(), "GARBAGE\r\n\r\n").unwrap();
        assert_eq!(status, 400);
        assert!(h.counters().http_400.load(Ordering::Relaxed) >= 6);
        assert_eq!(h.counters().http_413.load(Ordering::Relaxed), 1);
        h.shutdown();
    }

    #[test]
    fn generate_plain_and_streamed_agree_with_engine() {
        let h = start_tiny(ServerConfig::default());
        let body = format!(
            r#"{{"prompt": {}, "max_new_tokens": 6, "seed": 3}}"#,
            prompt_json(5)
        );
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
        let v = crate::json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(v.get("finish").unwrap().as_str(), Some("length"));
        let plain: Vec<u32> = v
            .get("tokens")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_f64().unwrap() as u32)
            .collect();
        assert_eq!(plain.len(), 6);

        // library path: the same greedy request straight into an Engine
        // over an identically seeded model
        let model = tiny_model(5);
        let mut eng = Engine::new(&model, EngineConfig::default());
        let p: Vec<u32> = (0..5).map(|i| ((i * 5 + 3) % 37) as u32).collect();
        eng.submit(Request { prompt: p, max_new_tokens: 6, sampling: SamplingParams::greedy() });
        eng.run();
        let expect = eng.take_finished().pop().unwrap().tokens;
        assert_eq!(plain, expect, "HTTP path must match the library path");

        // streamed: same tokens, one per chunk, then the terminal chunk
        let sbody = format!(
            r#"{{"prompt": {}, "max_new_tokens": 6, "stream": true}}"#,
            prompt_json(5)
        );
        let (status, chunks) = client::stream_request(h.addr(), "/v1/generate", &sbody).unwrap();
        assert_eq!(status, 200);
        let (toks, done) = client::split_stream(&chunks);
        assert_eq!(toks, expect, "streamed tokens must match too");
        let done = done.expect("terminal chunk present");
        assert_eq!(done.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(done.get("tokens_generated").unwrap().as_usize(), Some(6));
        h.shutdown();
    }

    #[test]
    fn metrics_reflect_the_ledger_and_drain_to_zero_pages() {
        let h = start_tiny(ServerConfig::default());
        let body =
            format!(r#"{{"prompt": {}, "max_new_tokens": 4}}"#, prompt_json(6));
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        let m = client::request(h.addr(), "GET", "/metrics", None).unwrap();
        assert_eq!(m.status, 200);
        let text = String::from_utf8(m.body).unwrap();
        let get = |k: &str| client::metric(&text, k).unwrap_or_else(|| panic!("missing {k}"));
        assert_eq!(get("apt_engine_completions_total"), 1);
        assert_eq!(get("apt_engine_completions_length_total"), 1);
        assert_eq!(get("apt_engine_tokens_generated_total"), 4);
        assert_eq!(get("apt_engine_kv_pages_live"), 0, "drained engine holds no pages");
        assert_eq!(get("apt_engine_queue_depth"), 0);
        assert_eq!(get("apt_engine_streams_active"), 0);
        assert!(get("apt_http_requests_total") >= 1);
        assert_eq!(get("apt_http_pool_workers"), ServerConfig::default().pool_workers);
        assert!(get("apt_http_conns_accepted_total") >= 2);
        h.shutdown();
    }

    #[test]
    fn keepalive_serves_many_requests_on_one_connection() {
        let h = start_tiny(ServerConfig::default());
        let mut c = client::Client::new(h.addr());
        for i in 0..4 {
            let body = format!(r#"{{"prompt": {}, "max_new_tokens": 2}}"#, prompt_json(3 + i));
            let r = c.request("POST", "/v1/generate", Some(&body)).unwrap();
            assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
            assert_eq!(r.header("connection"), Some("keep-alive"));
        }
        assert_eq!(c.connects_made(), 1, "four requests rode one connection");
        drop(c);
        // the whole burst cost exactly one accepted connection, and the
        // reuse ledger saw the three follow-ups
        let reused = h.counters().keepalive_reuses.load(Ordering::Relaxed);
        assert_eq!(reused, 3);
        assert_eq!(h.counters().conns_accepted.load(Ordering::Relaxed), 1);
        h.shutdown();
    }

    #[test]
    fn keepalive_request_cap_closes_the_connection() {
        let mut cfg = ServerConfig::default();
        cfg.keepalive_max_requests = 2;
        let h = start_tiny(cfg);
        let mut c = client::Client::new(h.addr());
        let r = c.request("GET", "/healthz", None).unwrap();
        assert_eq!(r.header("connection"), Some("keep-alive"));
        // request 2 hits the cap: the server says close and means it
        let r = c.request("GET", "/healthz", None).unwrap();
        assert_eq!(r.header("connection"), Some("close"));
        // request 3 transparently reconnects
        let r = c.request("GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(c.connects_made(), 2, "cap forced exactly one reconnect");
        drop(c);
        h.shutdown();
    }

    #[test]
    fn connection_close_header_is_honored() {
        let h = start_tiny(ServerConfig::default());
        // the one-shot client sends Connection: close; the server must
        // echo the close instead of promising keep-alive
        let r = client::request(h.addr(), "GET", "/healthz", None).unwrap();
        assert_eq!(r.header("connection"), Some("close"));
        h.shutdown();
    }

    #[test]
    fn slow_loris_partial_header_times_out_with_408() {
        let mut cfg = ServerConfig::default();
        cfg.read_timeout_ms = 120;
        cfg.header_deadline_ms = 400;
        let h = start_tiny(cfg);
        // half a request line, then silence: the worker must type it
        // 408 and move on, not wait forever
        let r = client::raw_roundtrip_status(h.addr(), "POST /v1/gen").unwrap();
        assert_eq!(r, 408);
        assert_eq!(h.counters().http_408.load(Ordering::Relaxed), 1);
        // the worker is demonstrably free again
        let r = client::request(h.addr(), "GET", "/healthz", None).unwrap();
        assert_eq!(r.status, 200);
        h.shutdown();
    }

    #[test]
    fn max_new_tokens_cap_clamps_hostile_asks() {
        let mut cfg = ServerConfig::default();
        cfg.max_new_tokens_cap = 4;
        let h = start_tiny(cfg);
        // at the cap: untouched
        let body = format!(r#"{{"prompt": {}, "max_new_tokens": 4}}"#, prompt_json(3));
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.json().unwrap().get("tokens").unwrap().as_arr().unwrap().len(), 4);
        // one past the cap: clamped to it (the boundary)
        let body = format!(r#"{{"prompt": {}, "max_new_tokens": 5}}"#, prompt_json(3));
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.json().unwrap().get("tokens").unwrap().as_arr().unwrap().len(), 4);
        // a hostile unbounded ask: clamped, not refused, not decoded
        let body = format!(r#"{{"prompt": {}, "max_new_tokens": 1000000}}"#, prompt_json(3));
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        assert_eq!(r.json().unwrap().get("tokens").unwrap().as_arr().unwrap().len(), 4);
        h.shutdown();
    }

    #[test]
    fn deadline_fields_map_to_engine_deadlines() {
        let h = start_tiny(ServerConfig::default());
        // 2 decode steps against a 30-token ask: finishes by deadline
        // with exactly the 2-step prefix
        let body = format!(
            r#"{{"prompt": {}, "max_new_tokens": 30, "deadline_steps": 2}}"#,
            prompt_json(5)
        );
        let r = client::request(h.addr(), "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        let v = crate::json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(v.get("finish").unwrap().as_str(), Some("deadline"));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        h.shutdown();
    }

    #[test]
    fn doomed_wait_deadline_is_refused_at_admission() {
        let mut cfg = ServerConfig::default();
        cfg.engine = EngineConfig { max_batch: 1, max_wait_rounds: 0, ..Default::default() };
        let h = start_tiny(cfg);
        let addr = h.addr();
        h.pause_engine();
        // two requests pile up in the paused engine's queue
        let body = format!(r#"{{"prompt": {}, "max_new_tokens": 2}}"#, prompt_json(3));
        let waiters: Vec<_> = (0..2)
            .map(|_| {
                let body = body.clone();
                std::thread::spawn(move || {
                    client::request(addr, "POST", "/v1/generate", Some(&body)).unwrap()
                })
            })
            .collect();
        while client::request(addr, "GET", "/metrics", None)
            .ok()
            .and_then(|m| {
                client::metric(&String::from_utf8_lossy(&m.body), "apt_engine_queue_depth")
            })
            != Some(2)
        {
            std::thread::sleep(Duration::from_millis(10));
        }
        // queued=2, max_batch=1 (FIFO): a newcomer needs >= 2 admit
        // rounds, so a 1-round wait deadline is provably unmeetable
        let doomed = format!(
            r#"{{"prompt": {}, "max_new_tokens": 2, "deadline_wait_rounds": 1}}"#,
            prompt_json(3)
        );
        let r = client::request(addr, "POST", "/v1/generate", Some(&doomed)).unwrap();
        assert_eq!(r.status, 429);
        assert!(r.header("retry-after").is_some());
        let text = String::from_utf8_lossy(&r.body).into_owned();
        assert!(text.contains("cannot be met"), "names the refusal: {text}");
        // a roomier deadline is NOT doomed — it queues normally
        let fine = format!(
            r#"{{"prompt": {}, "max_new_tokens": 2, "deadline_wait_rounds": 10}}"#,
            prompt_json(3)
        );
        let fine_waiter = {
            let fine = fine.clone();
            std::thread::spawn(move || {
                client::request(addr, "POST", "/v1/generate", Some(&fine)).unwrap()
            })
        };
        h.resume_engine();
        for w in waiters {
            assert_eq!(w.join().unwrap().status, 200);
        }
        assert_eq!(fine_waiter.join().unwrap().status, 200);
        assert_eq!(h.counters().http_429_doomed.load(Ordering::Relaxed), 1);
        assert_eq!(h.counters().http_429.load(Ordering::Relaxed), 1, "doomed counts as a 429");
        h.shutdown();
    }

    #[test]
    fn pool_saturation_sheds_with_503_at_accept_time() {
        let mut cfg = ServerConfig::default();
        cfg.pool_workers = 2;
        cfg.conn_backlog = 1;
        let h = start_tiny(cfg);
        let addr = h.addr();
        h.pause_engine();
        // two streaming requests pin both workers (the engine is
        // paused, so their first token never arrives)...
        let sbody = format!(
            r#"{{"prompt": {}, "max_new_tokens": 4, "stream": true}}"#,
            prompt_json(3)
        );
        let s1 = client::open_stream(addr, "/v1/generate", &sbody).unwrap();
        let s2 = client::open_stream(addr, "/v1/generate", &sbody).unwrap();
        // ...a third connection parks in the single backlog slot (on a
        // thread: no worker will answer it until the engine resumes)...
        let parked = {
            let body = format!(r#"{{"prompt": {}, "max_new_tokens": 2}}"#, prompt_json(3));
            std::thread::spawn(move || {
                client::request(addr, "POST", "/v1/generate", Some(&body)).unwrap()
            })
        };
        // give the acceptor a beat to actually enqueue it
        std::thread::sleep(Duration::from_millis(100));
        // ...and the fourth is shed with 503 + Retry-After at accept
        // time, before any worker or the engine is touched
        let r = client::request(addr, "POST", "/v1/generate", Some("{}")).unwrap();
        assert_eq!(r.status, 503, "{}", String::from_utf8_lossy(&r.body));
        assert!(r.header("retry-after").is_some());
        assert_eq!(h.counters().http_503_shed.load(Ordering::Relaxed), 1);
        // resume: the pinned streams and the parked connection all
        // complete — shedding degraded the burst, it didn't break it
        h.resume_engine();
        for mut s in [s1, s2] {
            let mut toks = 0;
            while let Ok(Some(_)) = s.next_chunk() {
                toks += 1;
            }
            assert!(toks >= 4, "stream completed after resume");
        }
        assert_eq!(parked.join().unwrap().status, 200);
        let report = h.shutdown();
        assert_eq!(report.pool_workers_joined, 2, "every pool worker reclaimed");
    }

    #[test]
    fn shutdown_is_idempotent_and_drains() {
        let h = start_tiny(ServerConfig::default());
        let addr = h.addr();
        let body = format!(r#"{{"prompt": {}, "max_new_tokens": 3}}"#, prompt_json(4));
        let r = client::request(addr, "POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(r.status, 200);
        let report = h.shutdown();
        assert_eq!(report.pool_workers_joined, ServerConfig::default().pool_workers);
        // the listener is gone after shutdown
        assert!(client::request(addr, "GET", "/healthz", None).is_err());
    }

    #[test]
    fn drain_meter_measures_rate_and_clamps() {
        let mut m = DrainMeter::new();
        assert_eq!(m.retry_after_s(10, 2), 2, "no data yet: the configured floor");
        m.note_completion();
        assert_eq!(m.retry_after_s(10, 2), 2, "one sample is not a rate");
        std::thread::sleep(Duration::from_millis(30));
        m.note_completion();
        std::thread::sleep(Duration::from_millis(30));
        m.note_completion();
        let rate = m.rate().expect("two spans measured");
        assert!(rate > 5.0 && rate < 1000.0, "{rate} completions/s over ~60ms");
        // deep queue over a slow measured rate clamps at 60s
        assert_eq!(m.retry_after_s(1_000_000, 1), 60);
        // floor still wins at shallow depth
        assert!(m.retry_after_s(1, 1) >= 1);
    }

    #[test]
    fn pool_stats_shed_estimate() {
        let s = PoolStats::default();
        assert_eq!(s.retry_after_s(5, 2, 3), 3, "no service times yet: fallback");
        s.record(Duration::from_millis(400));
        s.record(Duration::from_millis(600));
        // avg 0.5s x depth 8 / 2 workers = 2s
        assert_eq!(s.retry_after_s(8, 2, 1), 2);
        assert_eq!(s.retry_after_s(1_000_000, 1, 1), 60, "clamped");
        assert_eq!(s.retry_after_s(0, 2, 1), 1, "floor");
    }
}
