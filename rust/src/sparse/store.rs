//! [`WeightStore`]: the uniform weight abstraction threaded through
//! model → coordinator → eval. A linear's weights live in exactly one of
//! four layouts — dense [`Mat`], unstructured [`Csr`], semi-structured
//! [`Packed24`], or structurally reduced [`ReducedDense`] — behind one
//! `matmul_tb`/`row`/`shape`/`bytes` surface, so the forward path
//! executes pruned checkpoints straight from the packed layout
//! (realizing the inference speedup the paper motivates) while the
//! train/backward path densifies on demand.

use std::borrow::Cow;

use anyhow::{bail, Result};

use super::{Csr, Csr16, Packed24};
use crate::prune::Sparsity;
use crate::tensor::Mat;

/// Structured pruning's output layout: a physically smaller dense
/// matrix holding only the surviving rows/columns of a logically larger
/// linear, plus the kept-index maps back into the original geometry.
///
/// Unlike the sparse layouts (which keep the logical shape and pay
/// gather overhead per nonzero), a reduced store *is* a dense matrix —
/// the model runs the fastest kernel we have, just smaller. `shape()`
/// is therefore the PHYSICAL shape (what the matmul sees), while
/// `n_params()`/`dense_bytes()` report the LOGICAL geometry so
/// compression ratios stay comparable across layouts.
#[derive(Clone, Debug, PartialEq)]
pub struct ReducedDense {
    /// Logical (pre-pruning) row count.
    pub full_rows: usize,
    /// Logical (pre-pruning) column count.
    pub full_cols: usize,
    /// Strictly increasing logical row indices that survive, or `None`
    /// when every row does.
    pub kept_rows: Option<Vec<u32>>,
    /// Strictly increasing logical column indices that survive, or
    /// `None` when every column does.
    pub kept_cols: Option<Vec<u32>>,
    /// The surviving weights, physically `kept_rows × kept_cols`.
    pub mat: Mat,
}

fn check_kept(kept: &Option<Vec<u32>>, phys: usize, full: usize, axis: &str) -> Result<()> {
    match kept {
        None => {
            if phys != full {
                bail!("reduced {axis}s {phys} != full {full} but no kept-{axis} map");
            }
        }
        Some(idx) => {
            if idx.len() != phys {
                bail!("kept-{axis} map has {} entries for {phys} physical {axis}s", idx.len());
            }
            let mut prev: Option<u32> = None;
            for &i in idx {
                if i as usize >= full {
                    bail!("kept-{axis} index {i} out of range for {full} full {axis}s");
                }
                if let Some(p) = prev {
                    if i <= p {
                        bail!("kept-{axis} map not strictly increasing at index {i}");
                    }
                }
                prev = Some(i);
            }
        }
    }
    Ok(())
}

impl ReducedDense {
    /// Validating constructor — the single entry point shared by the
    /// structured pruner and the checkpoint loader, so a malformed
    /// kept-index map fails loudly in both.
    pub fn new(
        full_rows: usize,
        full_cols: usize,
        kept_rows: Option<Vec<u32>>,
        kept_cols: Option<Vec<u32>>,
        mat: Mat,
    ) -> Result<ReducedDense> {
        check_kept(&kept_rows, mat.rows, full_rows, "row")?;
        check_kept(&kept_cols, mat.cols, full_cols, "col")?;
        Ok(ReducedDense { full_rows, full_cols, kept_rows, kept_cols, mat })
    }

    /// Slice the kept rows/columns out of a full-shape dense matrix
    /// (`None` = keep the whole axis).
    pub fn from_dense(w: &Mat, kept_rows: Option<&[u32]>, kept_cols: Option<&[u32]>) -> Result<ReducedDense> {
        let rows: Vec<usize> = match kept_rows {
            Some(k) => k.iter().map(|&i| i as usize).collect(),
            None => (0..w.rows).collect(),
        };
        let mut mat = Mat::zeros(rows.len(), kept_cols.map_or(w.cols, |k| k.len()));
        for (pr, &lr) in rows.iter().enumerate() {
            if lr >= w.rows {
                bail!("kept-row index {lr} out of range for {} full rows", w.rows);
            }
            let src = w.row(lr);
            let dst = mat.row_mut(pr);
            match kept_cols {
                None => dst.copy_from_slice(src),
                Some(cols) => {
                    for (pc, &lc) in cols.iter().enumerate() {
                        dst[pc] = src[lc as usize];
                    }
                }
            }
        }
        ReducedDense::new(
            w.rows,
            w.cols,
            kept_rows.map(|k| k.to_vec()),
            kept_cols.map(|k| k.to_vec()),
            mat,
        )
    }

    /// Scatter the physical weights back into the logical full shape
    /// (zeros at removed positions) — the masked-oracle view.
    pub fn to_full(&self) -> Mat {
        let mut full = Mat::zeros(self.full_rows, self.full_cols);
        for pr in 0..self.mat.rows {
            let lr = self.kept_rows.as_ref().map_or(pr, |k| k[pr] as usize);
            let src = self.mat.row(pr);
            let dst = full.row_mut(lr);
            match &self.kept_cols {
                None => dst.copy_from_slice(src),
                Some(cols) => {
                    for (pc, &lc) in cols.iter().enumerate() {
                        dst[lc as usize] = src[pc];
                    }
                }
            }
        }
        full
    }

    /// Index-map footprint on top of the dense payload.
    fn index_bytes(&self) -> usize {
        let n = |k: &Option<Vec<u32>>| k.as_ref().map_or(0, |v| v.len());
        (n(&self.kept_rows) + n(&self.kept_cols)) * 4
    }
}

/// One linear's weights in whichever layout the coordinator packed them.
#[derive(Clone, Debug, PartialEq)]
pub enum WeightStore {
    Dense(Mat),
    Csr(Csr),
    Csr16(Csr16),
    Packed24(Packed24),
    DenseReduced(ReducedDense),
}

impl WeightStore {
    /// Pack a pruned dense matrix into the format matching its sparsity
    /// pattern: 2:4 → [`Packed24`] (hardware-legal layout), unstructured
    /// → CSR, with u16 column indices ([`Csr16`], 6 B/nnz) whenever the
    /// column count fits and u32 ([`Csr`], 8 B/nnz) for wider matrices.
    /// Falls back to CSR if the matrix is not actually 2:4 (e.g. cols
    /// not divisible by 4), so packing never loses weights.
    ///
    /// Packing only happens when it actually shrinks the layout: below
    /// the break-even point (~38% sparsity for Csr16, ~50% for Csr) the
    /// candidate would be both larger *and* slower than dense, so the
    /// weights stay `Dense`.
    pub fn pack(w: &Mat, sparsity: Sparsity) -> WeightStore {
        let csr = |w: &Mat| {
            if w.cols <= Csr16::MAX_COLS {
                WeightStore::Csr16(Csr16::from_dense(w))
            } else {
                WeightStore::Csr(Csr::from_dense(w))
            }
        };
        let candidate = match sparsity {
            Sparsity::SemiStructured { n: 2, m: 4 } => match Packed24::from_dense(w) {
                Ok(p) => WeightStore::Packed24(p),
                Err(_) => csr(w),
            },
            _ => csr(w),
        };
        if candidate.bytes() < candidate.dense_bytes() {
            candidate
        } else {
            WeightStore::Dense(w.clone())
        }
    }

    pub fn format(&self) -> &'static str {
        match self {
            WeightStore::Dense(_) => "dense",
            WeightStore::Csr(_) => "csr",
            WeightStore::Csr16(_) => "csr16",
            WeightStore::Packed24(_) => "packed24",
            WeightStore::DenseReduced(_) => "dense_reduced",
        }
    }

    /// The shape the matmul executes. For every layout but
    /// `DenseReduced` this is also the logical shape; a reduced store
    /// reports its PHYSICAL (smaller) shape here, because that is what
    /// the forward path consumes and what downstream activations size
    /// against.
    pub fn shape(&self) -> (usize, usize) {
        match self {
            WeightStore::Dense(m) => (m.rows, m.cols),
            WeightStore::Csr(c) => (c.rows, c.cols),
            WeightStore::Csr16(c) => (c.rows, c.cols),
            WeightStore::Packed24(p) => (p.rows, p.cols),
            WeightStore::DenseReduced(r) => (r.mat.rows, r.mat.cols),
        }
    }

    pub fn rows(&self) -> usize {
        self.shape().0
    }

    pub fn cols(&self) -> usize {
        self.shape().1
    }

    /// Logical (pre-pruning) parameter count, independent of layout —
    /// the denominator for sparsity/compression reporting.
    pub fn n_params(&self) -> usize {
        match self {
            WeightStore::DenseReduced(r) => r.full_rows * r.full_cols,
            other => {
                let (r, c) = other.shape();
                r * c
            }
        }
    }

    /// y = x @ W^T dispatched to the layout's kernel. This is the single
    /// call every forward path routes through.
    pub fn matmul_tb(&self, x: &Mat) -> Mat {
        match self {
            WeightStore::Dense(m) => x.matmul_tb(m),
            WeightStore::Csr(c) => c.matmul_tb(x),
            WeightStore::Csr16(c) => c.matmul_tb(x),
            WeightStore::Packed24(p) => p.matmul_tb(x),
            // `x` is already in the reduced input space (the producing
            // linear was sliced by the same kept map), so this is a
            // plain — smaller — dense matmul.
            WeightStore::DenseReduced(r) => x.matmul_tb(&r.mat),
        }
    }

    /// Row `r` as a dense slice (borrowed for dense, decoded for sparse).
    pub fn row(&self, r: usize) -> Cow<'_, [f32]> {
        match self {
            WeightStore::Dense(m) => Cow::Borrowed(m.row(r)),
            WeightStore::Csr(c) => Cow::Owned(c.densify_row(r)),
            WeightStore::Csr16(c) => Cow::Owned(c.densify_row(r)),
            WeightStore::Packed24(p) => {
                let g = p.cols / 4;
                let mut v = vec![0.0f32; p.cols];
                for gi in 0..g {
                    let idx = r * g + gi;
                    let b = p.meta[idx];
                    v[gi * 4 + (b & 3) as usize] = p.values[idx * 2];
                    v[gi * 4 + ((b >> 2) & 3) as usize] = p.values[idx * 2 + 1];
                }
                Cow::Owned(v)
            }
            WeightStore::DenseReduced(rd) => Cow::Borrowed(rd.mat.row(r)),
        }
    }

    /// Actual memory footprint of this layout.
    pub fn bytes(&self) -> usize {
        match self {
            WeightStore::Dense(m) => m.data.len() * 4,
            WeightStore::Csr(c) => c.bytes(),
            WeightStore::Csr16(c) => c.bytes(),
            WeightStore::Packed24(p) => p.bytes(),
            WeightStore::DenseReduced(r) => r.mat.data.len() * 4 + r.index_bytes(),
        }
    }

    /// Footprint the same weights would occupy densely.
    pub fn dense_bytes(&self) -> usize {
        self.n_params() * 4
    }

    pub fn nnz(&self) -> usize {
        match self {
            WeightStore::Dense(m) => m.nnz(),
            WeightStore::Csr(c) => c.nnz(),
            WeightStore::Csr16(c) => c.nnz(),
            WeightStore::Packed24(p) => p.nnz(),
            WeightStore::DenseReduced(r) => r.mat.nnz(),
        }
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.n_params().max(1) as f64
    }

    /// Dense materialization at the EXECUTED shape: logical for the
    /// sparse layouts, physical for `DenseReduced` (use
    /// [`ReducedDense::to_full`] for the scattered full-shape view).
    pub fn to_dense(&self) -> Mat {
        match self {
            WeightStore::Dense(m) => m.clone(),
            WeightStore::Csr(c) => c.to_dense(),
            WeightStore::Csr16(c) => c.to_dense(),
            WeightStore::Packed24(p) => p.to_dense(),
            WeightStore::DenseReduced(r) => r.mat.clone(),
        }
    }

    pub fn as_dense(&self) -> Option<&Mat> {
        match self {
            WeightStore::Dense(m) => Some(m),
            _ => None,
        }
    }

    /// Dense view without mutation: borrowed for dense (the common,
    /// zero-cost case on the train/backward path), materialized for
    /// sparse layouts ("densify on demand").
    pub fn dense_view(&self) -> Cow<'_, Mat> {
        match self {
            WeightStore::Dense(m) => Cow::Borrowed(m),
            WeightStore::DenseReduced(r) => Cow::Borrowed(&r.mat),
            other => Cow::Owned(other.to_dense()),
        }
    }

    /// Mutable dense access, converting the store to `Dense` in place if
    /// needed — the trainer/gradcheck entry point.
    pub fn dense_mut(&mut self) -> &mut Mat {
        if !matches!(self, WeightStore::Dense(_)) {
            *self = WeightStore::Dense(self.to_dense());
        }
        match self {
            WeightStore::Dense(m) => m,
            _ => unreachable!("just densified"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::magnitude_prune;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    fn pruned(rows: usize, cols: usize, sparsity: Sparsity, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut w = Mat::randn(rows, cols, 1.0, &mut rng);
        magnitude_prune(&mut w, sparsity);
        w
    }

    #[test]
    fn pack_chooses_format_by_sparsity_pattern() {
        let w24 = pruned(8, 16, Sparsity::two_four(), 1);
        assert_eq!(WeightStore::pack(&w24, Sparsity::two_four()).format(), "packed24");
        // narrow matrices (cols <= 65536) auto-select the u16-index CSR
        let wu = pruned(8, 16, Sparsity::Unstructured { rate: 0.6 }, 2);
        assert_eq!(
            WeightStore::pack(&wu, Sparsity::Unstructured { rate: 0.6 }).format(),
            "csr16"
        );
        // 2:4 request on an incompatible matrix falls back to CSR (sparse
        // enough here for the layout to beat dense bytes)
        let odd = pruned(4, 6, Sparsity::Unstructured { rate: 0.8 }, 3);
        assert_eq!(WeightStore::pack(&odd, Sparsity::two_four()).format(), "csr16");
    }

    #[test]
    fn pack_keeps_dense_below_break_even() {
        // At 30% sparsity even Csr16 is larger (and slower) than dense:
        // 6 B/nnz + 4 B/row > 4 B/weight below ~38% sparsity. pack must
        // refuse to regress.
        let w = pruned(8, 16, Sparsity::Unstructured { rate: 0.3 }, 7);
        let store = WeightStore::pack(&w, Sparsity::Unstructured { rate: 0.3 });
        assert_eq!(store.format(), "dense");
        assert_eq!(store.to_dense(), w);
        // ...but Csr16 packs at 50% where u32 CSR (8 B/nnz) would not
        let w50 = pruned(8, 16, Sparsity::Unstructured { rate: 0.5 }, 9);
        let s50 = WeightStore::pack(&w50, Sparsity::Unstructured { rate: 0.5 });
        assert_eq!(s50.format(), "csr16");
        assert!(s50.bytes() < s50.dense_bytes());
        assert!(Csr::from_dense(&w50).bytes() >= s50.dense_bytes());
        // 2:4 always wins (2.25 B/weight), regardless of matrix size
        let w24 = pruned(1, 4, Sparsity::two_four(), 8);
        assert_eq!(WeightStore::pack(&w24, Sparsity::two_four()).format(), "packed24");
    }

    #[test]
    fn surface_is_uniform_across_formats() {
        let w = pruned(10, 16, Sparsity::two_four(), 4);
        let mut rng = Rng::new(5);
        let x = Mat::randn(3, 16, 1.0, &mut rng);
        let dense = WeightStore::Dense(w.clone());
        let stores = [
            dense.clone(),
            WeightStore::pack(&w, Sparsity::two_four()),
            WeightStore::Csr(Csr::from_dense(&w)),
            WeightStore::Csr16(Csr16::from_dense(&w)),
        ];
        let y_ref = dense.matmul_tb(&x);
        for s in &stores {
            assert_eq!(s.shape(), (10, 16));
            assert_eq!(s.n_params(), 160);
            assert_eq!(s.nnz(), w.nnz());
            assert_eq!(s.to_dense(), w, "{}", s.format());
            assert!(s.matmul_tb(&x).max_abs_diff(&y_ref) < 1e-5, "{}", s.format());
            for r in 0..10 {
                assert_eq!(s.row(r).as_ref(), w.row(r), "{} row {r}", s.format());
            }
            assert!(s.bytes() <= s.dense_bytes() + 10 * 4 + 4);
        }
        // 2:4 packing actually shrinks the payload: 4 B/weight -> 2.25 B
        assert!(stores[1].bytes() * 16 == stores[1].dense_bytes() * 9);
    }

    #[test]
    fn reduced_dense_surface_and_slicing() {
        let mut rng = Rng::new(11);
        let w = Mat::randn(8, 12, 1.0, &mut rng);
        // keep rows {1,4,6} and cols {0,2,3,7,10}
        let kr = [1u32, 4, 6];
        let kc = [0u32, 2, 3, 7, 10];
        let rd = ReducedDense::from_dense(&w, Some(&kr), Some(&kc)).unwrap();
        let s = WeightStore::DenseReduced(rd.clone());
        assert_eq!(s.format(), "dense_reduced");
        // physical shape executes; logical geometry reports
        assert_eq!(s.shape(), (3, 5));
        assert_eq!(s.n_params(), 96);
        assert_eq!(s.dense_bytes(), 96 * 4);
        assert_eq!(s.bytes(), 3 * 5 * 4 + (3 + 5) * 4);
        // structural sparsity: 1 - physical/logical (all kept weights nonzero)
        assert!((s.sparsity() - (1.0 - 15.0 / 96.0)).abs() < 1e-12);
        // slicing picked the right entries
        for (pr, &lr) in kr.iter().enumerate() {
            for (pc, &lc) in kc.iter().enumerate() {
                assert_eq!(s.row(pr)[pc], w.row(lr as usize)[lc as usize]);
            }
        }
        // matmul on reduced inputs == dense matmul on the sliced matrix
        let x = Mat::randn(4, 5, 1.0, &mut rng);
        assert_eq!(s.matmul_tb(&x), x.matmul_tb(&rd.mat));
        // scatter back: kept entries restored, removed entries zero
        let full = rd.to_full();
        assert_eq!(full.rows, 8);
        assert_eq!(full.cols, 12);
        assert_eq!(full.nnz(), s.nnz());
        assert_eq!(full.row(4)[7], w.row(4)[7]);
        assert_eq!(full.row(0)[0], 0.0);
        // None axes mean "whole axis kept"
        let rows_only = ReducedDense::from_dense(&w, Some(&kr), None).unwrap();
        assert_eq!(WeightStore::DenseReduced(rows_only).shape(), (3, 12));
    }

    #[test]
    fn reduced_dense_rejects_malformed_kept_maps() {
        let m = Mat::zeros(2, 3);
        // out-of-range row index
        let e = ReducedDense::new(4, 3, Some(vec![1, 9]), None, m.clone()).unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
        // duplicate (non-increasing) column index
        let e = ReducedDense::new(2, 6, None, Some(vec![2, 2, 4]), m.clone()).unwrap_err();
        assert!(e.to_string().contains("strictly increasing"), "{e}");
        // length mismatch between map and physical dim
        let e = ReducedDense::new(4, 3, Some(vec![0]), None, m.clone()).unwrap_err();
        assert!(e.to_string().contains("entries"), "{e}");
        // physical != full with no map at all
        let e = ReducedDense::new(5, 3, None, None, m).unwrap_err();
        assert!(e.to_string().contains("no kept-row map"), "{e}");
    }

    #[test]
    fn dense_mut_densifies_in_place() {
        let w = pruned(6, 12, Sparsity::Unstructured { rate: 0.5 }, 6);
        let mut s = WeightStore::Csr(Csr::from_dense(&w));
        assert_eq!(s.format(), "csr");
        s.dense_mut().data[0] = 42.0;
        assert_eq!(s.format(), "dense");
        assert_eq!(s.as_dense().unwrap().data[0], 42.0);
    }

    #[test]
    fn prop_store_forward_matches_dense() {
        // The tentpole contract, at the kernel level: for random pruned
        // weights, CSR and Packed24 stores reproduce the dense mask
        // bit-for-bit and the activations to <1e-5.
        prop_check(
            "weightstore-forward-equivalence",
            24,
            |r| {
                let rows = r.range(1, 20);
                let groups = r.range(1, 8);
                let two_four = r.below(2) == 0;
                let cols = groups * 4;
                let mut w = Mat::randn(rows, cols, 1.0, r);
                let sp = if two_four {
                    Sparsity::two_four()
                } else {
                    Sparsity::Unstructured { rate: 0.6 }
                };
                magnitude_prune(&mut w, sp);
                let x = Mat::randn(r.range(1, 6), cols, 1.0, r);
                (w, x, sp)
            },
            |(w, x, sp)| {
                let store = WeightStore::pack(w, *sp);
                let y_ref = x.matmul_tb(w);
                store.to_dense() == *w && store.matmul_tb(x).max_abs_diff(&y_ref) < 1e-5
            },
        );
    }
}
