//! [`WeightStore`]: the uniform weight abstraction threaded through
//! model → coordinator → eval. A linear's weights live in exactly one of
//! three layouts — dense [`Mat`], unstructured [`Csr`], or
//! semi-structured [`Packed24`] — behind one
//! `matmul_tb`/`row`/`shape`/`bytes` surface, so the forward path
//! executes pruned checkpoints straight from the packed layout
//! (realizing the inference speedup the paper motivates) while the
//! train/backward path densifies on demand.

use std::borrow::Cow;

use super::{Csr, Csr16, Packed24};
use crate::prune::Sparsity;
use crate::tensor::Mat;

/// One linear's weights in whichever layout the coordinator packed them.
#[derive(Clone, Debug, PartialEq)]
pub enum WeightStore {
    Dense(Mat),
    Csr(Csr),
    Csr16(Csr16),
    Packed24(Packed24),
}

impl WeightStore {
    /// Pack a pruned dense matrix into the format matching its sparsity
    /// pattern: 2:4 → [`Packed24`] (hardware-legal layout), unstructured
    /// → CSR, with u16 column indices ([`Csr16`], 6 B/nnz) whenever the
    /// column count fits and u32 ([`Csr`], 8 B/nnz) for wider matrices.
    /// Falls back to CSR if the matrix is not actually 2:4 (e.g. cols
    /// not divisible by 4), so packing never loses weights.
    ///
    /// Packing only happens when it actually shrinks the layout: below
    /// the break-even point (~38% sparsity for Csr16, ~50% for Csr) the
    /// candidate would be both larger *and* slower than dense, so the
    /// weights stay `Dense`.
    pub fn pack(w: &Mat, sparsity: Sparsity) -> WeightStore {
        let csr = |w: &Mat| {
            if w.cols <= Csr16::MAX_COLS {
                WeightStore::Csr16(Csr16::from_dense(w))
            } else {
                WeightStore::Csr(Csr::from_dense(w))
            }
        };
        let candidate = match sparsity {
            Sparsity::SemiStructured { n: 2, m: 4 } => match Packed24::from_dense(w) {
                Ok(p) => WeightStore::Packed24(p),
                Err(_) => csr(w),
            },
            _ => csr(w),
        };
        if candidate.bytes() < candidate.dense_bytes() {
            candidate
        } else {
            WeightStore::Dense(w.clone())
        }
    }

    pub fn format(&self) -> &'static str {
        match self {
            WeightStore::Dense(_) => "dense",
            WeightStore::Csr(_) => "csr",
            WeightStore::Csr16(_) => "csr16",
            WeightStore::Packed24(_) => "packed24",
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        match self {
            WeightStore::Dense(m) => (m.rows, m.cols),
            WeightStore::Csr(c) => (c.rows, c.cols),
            WeightStore::Csr16(c) => (c.rows, c.cols),
            WeightStore::Packed24(p) => (p.rows, p.cols),
        }
    }

    pub fn rows(&self) -> usize {
        self.shape().0
    }

    pub fn cols(&self) -> usize {
        self.shape().1
    }

    /// Logical parameter count (rows · cols), independent of layout.
    pub fn n_params(&self) -> usize {
        let (r, c) = self.shape();
        r * c
    }

    /// y = x @ W^T dispatched to the layout's kernel. This is the single
    /// call every forward path routes through.
    pub fn matmul_tb(&self, x: &Mat) -> Mat {
        match self {
            WeightStore::Dense(m) => x.matmul_tb(m),
            WeightStore::Csr(c) => c.matmul_tb(x),
            WeightStore::Csr16(c) => c.matmul_tb(x),
            WeightStore::Packed24(p) => p.matmul_tb(x),
        }
    }

    /// Row `r` as a dense slice (borrowed for dense, decoded for sparse).
    pub fn row(&self, r: usize) -> Cow<'_, [f32]> {
        match self {
            WeightStore::Dense(m) => Cow::Borrowed(m.row(r)),
            WeightStore::Csr(c) => Cow::Owned(c.densify_row(r)),
            WeightStore::Csr16(c) => Cow::Owned(c.densify_row(r)),
            WeightStore::Packed24(p) => {
                let g = p.cols / 4;
                let mut v = vec![0.0f32; p.cols];
                for gi in 0..g {
                    let idx = r * g + gi;
                    let b = p.meta[idx];
                    v[gi * 4 + (b & 3) as usize] = p.values[idx * 2];
                    v[gi * 4 + ((b >> 2) & 3) as usize] = p.values[idx * 2 + 1];
                }
                Cow::Owned(v)
            }
        }
    }

    /// Actual memory footprint of this layout.
    pub fn bytes(&self) -> usize {
        match self {
            WeightStore::Dense(m) => m.data.len() * 4,
            WeightStore::Csr(c) => c.bytes(),
            WeightStore::Csr16(c) => c.bytes(),
            WeightStore::Packed24(p) => p.bytes(),
        }
    }

    /// Footprint the same weights would occupy densely.
    pub fn dense_bytes(&self) -> usize {
        self.n_params() * 4
    }

    pub fn nnz(&self) -> usize {
        match self {
            WeightStore::Dense(m) => m.nnz(),
            WeightStore::Csr(c) => c.nnz(),
            WeightStore::Csr16(c) => c.nnz(),
            WeightStore::Packed24(p) => p.nnz(),
        }
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.n_params().max(1) as f64
    }

    pub fn to_dense(&self) -> Mat {
        match self {
            WeightStore::Dense(m) => m.clone(),
            WeightStore::Csr(c) => c.to_dense(),
            WeightStore::Csr16(c) => c.to_dense(),
            WeightStore::Packed24(p) => p.to_dense(),
        }
    }

    pub fn as_dense(&self) -> Option<&Mat> {
        match self {
            WeightStore::Dense(m) => Some(m),
            _ => None,
        }
    }

    /// Dense view without mutation: borrowed for dense (the common,
    /// zero-cost case on the train/backward path), materialized for
    /// sparse layouts ("densify on demand").
    pub fn dense_view(&self) -> Cow<'_, Mat> {
        match self {
            WeightStore::Dense(m) => Cow::Borrowed(m),
            other => Cow::Owned(other.to_dense()),
        }
    }

    /// Mutable dense access, converting the store to `Dense` in place if
    /// needed — the trainer/gradcheck entry point.
    pub fn dense_mut(&mut self) -> &mut Mat {
        if !matches!(self, WeightStore::Dense(_)) {
            *self = WeightStore::Dense(self.to_dense());
        }
        match self {
            WeightStore::Dense(m) => m,
            _ => unreachable!("just densified"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::magnitude_prune;
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    fn pruned(rows: usize, cols: usize, sparsity: Sparsity, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut w = Mat::randn(rows, cols, 1.0, &mut rng);
        magnitude_prune(&mut w, sparsity);
        w
    }

    #[test]
    fn pack_chooses_format_by_sparsity_pattern() {
        let w24 = pruned(8, 16, Sparsity::two_four(), 1);
        assert_eq!(WeightStore::pack(&w24, Sparsity::two_four()).format(), "packed24");
        // narrow matrices (cols <= 65536) auto-select the u16-index CSR
        let wu = pruned(8, 16, Sparsity::Unstructured { rate: 0.6 }, 2);
        assert_eq!(
            WeightStore::pack(&wu, Sparsity::Unstructured { rate: 0.6 }).format(),
            "csr16"
        );
        // 2:4 request on an incompatible matrix falls back to CSR (sparse
        // enough here for the layout to beat dense bytes)
        let odd = pruned(4, 6, Sparsity::Unstructured { rate: 0.8 }, 3);
        assert_eq!(WeightStore::pack(&odd, Sparsity::two_four()).format(), "csr16");
    }

    #[test]
    fn pack_keeps_dense_below_break_even() {
        // At 30% sparsity even Csr16 is larger (and slower) than dense:
        // 6 B/nnz + 4 B/row > 4 B/weight below ~38% sparsity. pack must
        // refuse to regress.
        let w = pruned(8, 16, Sparsity::Unstructured { rate: 0.3 }, 7);
        let store = WeightStore::pack(&w, Sparsity::Unstructured { rate: 0.3 });
        assert_eq!(store.format(), "dense");
        assert_eq!(store.to_dense(), w);
        // ...but Csr16 packs at 50% where u32 CSR (8 B/nnz) would not
        let w50 = pruned(8, 16, Sparsity::Unstructured { rate: 0.5 }, 9);
        let s50 = WeightStore::pack(&w50, Sparsity::Unstructured { rate: 0.5 });
        assert_eq!(s50.format(), "csr16");
        assert!(s50.bytes() < s50.dense_bytes());
        assert!(Csr::from_dense(&w50).bytes() >= s50.dense_bytes());
        // 2:4 always wins (2.25 B/weight), regardless of matrix size
        let w24 = pruned(1, 4, Sparsity::two_four(), 8);
        assert_eq!(WeightStore::pack(&w24, Sparsity::two_four()).format(), "packed24");
    }

    #[test]
    fn surface_is_uniform_across_formats() {
        let w = pruned(10, 16, Sparsity::two_four(), 4);
        let mut rng = Rng::new(5);
        let x = Mat::randn(3, 16, 1.0, &mut rng);
        let dense = WeightStore::Dense(w.clone());
        let stores = [
            dense.clone(),
            WeightStore::pack(&w, Sparsity::two_four()),
            WeightStore::Csr(Csr::from_dense(&w)),
            WeightStore::Csr16(Csr16::from_dense(&w)),
        ];
        let y_ref = dense.matmul_tb(&x);
        for s in &stores {
            assert_eq!(s.shape(), (10, 16));
            assert_eq!(s.n_params(), 160);
            assert_eq!(s.nnz(), w.nnz());
            assert_eq!(s.to_dense(), w, "{}", s.format());
            assert!(s.matmul_tb(&x).max_abs_diff(&y_ref) < 1e-5, "{}", s.format());
            for r in 0..10 {
                assert_eq!(s.row(r).as_ref(), w.row(r), "{} row {r}", s.format());
            }
            assert!(s.bytes() <= s.dense_bytes() + 10 * 4 + 4);
        }
        // 2:4 packing actually shrinks the payload: 4 B/weight -> 2.25 B
        assert!(stores[1].bytes() * 16 == stores[1].dense_bytes() * 9);
    }

    #[test]
    fn dense_mut_densifies_in_place() {
        let w = pruned(6, 12, Sparsity::Unstructured { rate: 0.5 }, 6);
        let mut s = WeightStore::Csr(Csr::from_dense(&w));
        assert_eq!(s.format(), "csr");
        s.dense_mut().data[0] = 42.0;
        assert_eq!(s.format(), "dense");
        assert_eq!(s.as_dense().unwrap().data[0], 42.0);
    }

    #[test]
    fn prop_store_forward_matches_dense() {
        // The tentpole contract, at the kernel level: for random pruned
        // weights, CSR and Packed24 stores reproduce the dense mask
        // bit-for-bit and the activations to <1e-5.
        prop_check(
            "weightstore-forward-equivalence",
            24,
            |r| {
                let rows = r.range(1, 20);
                let groups = r.range(1, 8);
                let two_four = r.below(2) == 0;
                let cols = groups * 4;
                let mut w = Mat::randn(rows, cols, 1.0, r);
                let sp = if two_four {
                    Sparsity::two_four()
                } else {
                    Sparsity::Unstructured { rate: 0.6 }
                };
                magnitude_prune(&mut w, sp);
                let x = Mat::randn(r.range(1, 6), cols, 1.0, r);
                (w, x, sp)
            },
            |(w, x, sp)| {
                let store = WeightStore::pack(w, *sp);
                let y_ref = x.matmul_tb(w);
                store.to_dense() == *w && store.matmul_tb(x).max_abs_diff(&y_ref) < 1e-5
            },
        );
    }
}
