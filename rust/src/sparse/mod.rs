//! Sparse weight formats + kernels: CSR for unstructured masks, a packed
//! 2:4 layout for semi-structured masks, and sparse x dense products. The
//! coordinator packs pruned checkpoints into these formats and the eval
//! layer can run the sparse fast path (`csr_matmul_tb`) to realize the
//! inference speedup the paper motivates.

use crate::tensor::Mat;

/// Compressed sparse rows over f32 (row-major origin).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn from_dense(m: &Mat) -> Csr {
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    indices.push(c as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        Csr { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
            for i in s..e {
                out[(r, self.indices[i] as usize)] = self.values[i];
            }
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Memory footprint in bytes (values + indices + indptr).
    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.indices.len() * 4 + self.indptr.len() * 4
    }

    /// y = x @ W^T for sparse W (n_out, m): the pruned-linear fast path.
    /// x: (t, m) dense -> (t, n_out).
    pub fn matmul_tb(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols, self.cols);
        let mut out = Mat::zeros(x.rows, self.rows);
        for t in 0..x.rows {
            let xrow = x.row(t);
            let orow = out.row_mut(t);
            for r in 0..self.rows {
                let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
                let mut acc = 0.0f32;
                for i in s..e {
                    acc += self.values[i] * xrow[self.indices[i] as usize];
                }
                orow[r] = acc;
            }
        }
        out
    }
}

/// Packed 2:4: per 4-group, 2 values + 2x 2-bit indices (byte-packed).
/// This is the format NVIDIA sparse tensor cores consume; here it proves
/// the mask is hardware-legal and measures the exact memory saving.
#[derive(Clone, Debug, PartialEq)]
pub struct Packed24 {
    pub rows: usize,
    pub cols: usize,
    /// 2 survivors per group, row-major: rows * cols/2 values.
    pub values: Vec<f32>,
    /// packed indices: one byte per group = (i1 << 2) | i0, i0 < i1.
    pub meta: Vec<u8>,
}

impl Packed24 {
    /// Pack a dense 2:4 matrix. Errors if any group has >2 nonzeros.
    pub fn from_dense(m: &Mat) -> Result<Packed24, String> {
        if m.cols % 4 != 0 {
            return Err(format!("cols {} not divisible by 4", m.cols));
        }
        let g = m.cols / 4;
        let mut values = Vec::with_capacity(m.rows * g * 2);
        let mut meta = Vec::with_capacity(m.rows * g);
        for r in 0..m.rows {
            let row = m.row(r);
            for gi in 0..g {
                let grp = &row[gi * 4..gi * 4 + 4];
                let nz: Vec<usize> = (0..4).filter(|&i| grp[i] != 0.0).collect();
                if nz.len() > 2 {
                    return Err(format!("row {r} group {gi} has {} nonzeros", nz.len()));
                }
                let i0 = nz.first().copied().unwrap_or(0);
                let i1 = nz.get(1).copied().unwrap_or(if i0 == 3 { 2 } else { 3 });
                values.push(grp[i0]);
                values.push(grp[i1]);
                meta.push(((i1 as u8) << 2) | i0 as u8);
            }
        }
        Ok(Packed24 { rows: m.rows, cols: m.cols, values, meta })
    }

    pub fn to_dense(&self) -> Mat {
        let g = self.cols / 4;
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for gi in 0..g {
                let idx = r * g + gi;
                let b = self.meta[idx];
                let (i0, i1) = ((b & 3) as usize, ((b >> 2) & 3) as usize);
                out[(r, gi * 4 + i0)] = self.values[idx * 2];
                out[(r, gi * 4 + i1)] = self.values[idx * 2 + 1];
            }
        }
        out
    }

    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.meta.len()
    }

    /// Dense-equivalent bytes for the compression-ratio stat.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::{magnitude_prune, Sparsity};
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn csr_roundtrip() {
        let mut rng = Rng::new(1);
        let mut m = Mat::randn(8, 12, 1.0, &mut rng);
        magnitude_prune(&mut m, Sparsity::Unstructured { rate: 0.6 });
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.to_dense(), m);
        assert!((csr.sparsity() - 0.6).abs() < 0.05);
    }

    #[test]
    fn csr_matmul_matches_dense() {
        let mut rng = Rng::new(2);
        let mut w = Mat::randn(10, 16, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.5 });
        let x = Mat::randn(4, 16, 1.0, &mut rng);
        let dense = x.matmul_tb(&w);
        let sparse = Csr::from_dense(&w).matmul_tb(&x);
        assert!(dense.max_abs_diff(&sparse) < 1e-5);
    }

    #[test]
    fn packed24_roundtrip() {
        let mut rng = Rng::new(3);
        let mut w = Mat::randn(6, 16, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::two_four());
        let packed = Packed24::from_dense(&w).unwrap();
        assert_eq!(packed.to_dense(), w);
        // values are exactly half the dense payload; meta adds 1B/group
        assert_eq!(packed.values.len(), 6 * 8);
        assert_eq!(packed.bytes(), packed.dense_bytes() / 2 + 6 * 4);
        assert!((packed.bytes() as f64) < packed.dense_bytes() as f64 * 0.7);
    }

    #[test]
    fn packed24_rejects_dense_groups() {
        let m = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 0.0]);
        assert!(Packed24::from_dense(&m).is_err());
    }

    #[test]
    fn prop_csr_roundtrip_random_sparsity() {
        prop_check(
            "csr-roundtrip",
            24,
            |r| {
                let rows = r.range(1, 10);
                let cols = r.range(1, 20);
                let mut m = Mat::randn(rows, cols, 1.0, r);
                for v in m.data.iter_mut() {
                    if r.uniform() < 0.7 {
                        *v = 0.0;
                    }
                }
                m
            },
            |m| Csr::from_dense(m).to_dense() == *m,
        );
    }
}
