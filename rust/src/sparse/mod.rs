//! Sparse weight formats + kernels: CSR for unstructured masks, a packed
//! 2:4 layout for semi-structured masks, and sparse x dense products. The
//! coordinator packs pruned checkpoints into these formats (behind the
//! [`WeightStore`] abstraction) and the model forward path executes the
//! sparse kernels directly, realizing the inference speedup and memory
//! saving the paper motivates.
//!
//! Both `matmul_tb` kernels parallelize over chunks of W rows with the
//! repo's scoped worker-pool idiom (each worker owns a disjoint column
//! range of every output row) and run a 4-chain FMA inner loop like the
//! dense `tensor::dot`.

pub mod store;

pub use store::{ReducedDense, WeightStore};

use crate::tensor::Mat;
use crate::util::num_threads;

/// Compressed sparse rows over f32 (row-major origin), generic over the
/// column-index width. The two instantiations are [`Csr`] (u32 indices,
/// the wide-matrix fallback) and [`Csr16`] (u16 indices, halved index
/// bytes when the column count fits) — one container/accessor body for
/// both, so the layouts can't drift apart. The field layout is public
/// and identical to the pre-generic structs: io and the benches build
/// these by struct literal.
#[derive(Clone, Debug, PartialEq)]
pub struct CsrBase<I> {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<u32>,
    pub indices: Vec<I>,
    pub values: Vec<f32>,
}

/// CSR with u32 column indices — the general (wide-matrix) layout.
pub type Csr = CsrBase<u32>;

/// CSR with u16 column indices: for layers with cols <= 65536 (every
/// linear in this repo's model zoo, and most real LLM projections),
/// index storage halves vs [`Csr`] — 6 B/nnz instead of 8 B/nnz, which
/// also moves the pack-vs-dense break-even down to ~38% sparsity. The
/// coordinator's packing step auto-selects this layout when the column
/// count fits; [`Csr`] remains the wide-matrix fallback.
pub type Csr16 = CsrBase<u16>;

impl<I: ColIdx> CsrBase<I> {
    /// Max column count this index width can address (index max ⇒
    /// max + 1 columns, e.g. 65536 for [`Csr16`]).
    pub const MAX_COLS: usize = I::MAX_COLS;

    pub fn from_dense(m: &Mat) -> CsrBase<I> {
        assert!(
            m.cols <= I::MAX_COLS,
            "{} cols {} exceed {} index range",
            I::TAG,
            m.cols,
            I::IDX
        );
        let mut indptr = Vec::with_capacity(m.rows + 1);
        let mut indices = Vec::new();
        let mut values = Vec::new();
        indptr.push(0u32);
        for r in 0..m.rows {
            for (c, &v) in m.row(r).iter().enumerate() {
                if v != 0.0 {
                    indices.push(I::from_col(c));
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        CsrBase { rows: m.rows, cols: m.cols, indptr, indices, values }
    }

    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r] as usize, self.indptr[r + 1] as usize);
            for i in s..e {
                out[(r, self.indices[i].at())] = self.values[i];
            }
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols) as f64
    }

    /// Memory footprint in bytes (f32 values + I-width indices + u32
    /// indptr).
    pub fn bytes(&self) -> usize {
        self.values.len() * 4
            + self.indices.len() * std::mem::size_of::<I>()
            + self.indptr.len() * 4
    }

    /// Dense-equivalent bytes for the compression-ratio stat.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// y = x @ W^T for sparse W (n_out, m): the pruned-linear fast path.
    /// x: (t, m) dense -> (t, n_out). The [`csr_matmul_tb`] kernel body
    /// (nnz-balanced worker partitioning, 4-chain FMA gather-dot) is
    /// shared across index widths.
    pub fn matmul_tb(&self, x: &Mat) -> Mat {
        csr_matmul_tb(self.rows, self.cols, &self.indptr, &self.indices, &self.values, x)
    }

    /// Row `r` densified into a fresh buffer (zeros in pruned slots).
    pub(crate) fn densify_row(&self, r: usize) -> Vec<f32> {
        densify_csr_row(self.cols, &self.indptr, &self.indices, &self.values, r)
    }
}

/// The CSR × dense kernel body, generic over the column-index width so
/// [`Csr`] and [`Csr16`] can't drift apart (one unsafe block to audit).
///
/// Parallelized over chunks of W rows — not over x rows — so the
/// single-token decode shape (t = 1) still uses the whole pool. Chunk
/// boundaries are drawn by cumulative nnz, not row count, so a few
/// skewed dense-ish rows no longer serialize one worker. Each worker
/// owns the output columns of its W-row chunk across every output row;
/// the inner loop is a 4-chain FMA gather-dot.
fn csr_matmul_tb<I: ColIdx>(
    rows: usize,
    cols: usize,
    indptr: &[u32],
    indices: &[I],
    values: &[f32],
    x: &Mat,
) -> Mat {
    assert_eq!(x.cols, cols, "csr matmul_tb: x cols {} != W cols {}", x.cols, cols);
    let (t, n) = (x.rows, rows);
    let mut out = Mat::zeros(t, n);
    let chunks = nnz_balanced_chunks(indptr, num_threads());
    let base = out.data.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        for (r0, r1) in chunks {
            s.spawn(move || {
                for ti in 0..t {
                    let xrow = x.row(ti);
                    // SAFETY: workers write disjoint column ranges
                    // [r0, r1) of each output row; `out` outlives the
                    // scope and is not otherwise touched inside it.
                    let orow: &mut [f32] = unsafe {
                        std::slice::from_raw_parts_mut(
                            (base as *mut f32).add(ti * n + r0),
                            r1 - r0,
                        )
                    };
                    for (o, r) in orow.iter_mut().zip(r0..r1) {
                        let (s0, e0) = (indptr[r] as usize, indptr[r + 1] as usize);
                        *o = gather_dot(&values[s0..e0], &indices[s0..e0], xrow);
                    }
                }
            });
        }
    });
    out
}

/// Densify one CSR row (either index width) into a zeroed buffer — the
/// single scatter loop behind `WeightStore::row` for both CSR layouts.
fn densify_csr_row<I: ColIdx>(
    cols: usize,
    indptr: &[u32],
    indices: &[I],
    values: &[f32],
    r: usize,
) -> Vec<f32> {
    let mut v = vec![0.0f32; cols];
    let (s, e) = (indptr[r] as usize, indptr[r + 1] as usize);
    for i in s..e {
        v[indices[i].at()] = values[i];
    }
    v
}

/// Contiguous row ranges covering `0..rows` with ~equal cumulative nnz
/// (at most `nw` of them). Each remaining worker takes an equal share of
/// the *remaining* nnz, so one pathological row can't drag the split off
/// for everyone after it; all-empty matrices fall back to an even row
/// split. Worker ownership of output columns stays contiguous/disjoint.
fn nnz_balanced_chunks(indptr: &[u32], nw: usize) -> Vec<(usize, usize)> {
    let rows = indptr.len() - 1;
    if rows == 0 {
        return Vec::new();
    }
    let nw = nw.min(rows).max(1);
    let total = indptr[rows] as usize;
    if total == 0 {
        let chunk = rows.div_ceil(nw);
        return (0..nw)
            .map(|w| (w * chunk, ((w + 1) * chunk).min(rows)))
            .filter(|(a, b)| a < b)
            .collect();
    }
    let mut chunks = Vec::with_capacity(nw);
    let mut start = 0usize;
    for w in 0..nw {
        if start >= rows {
            break;
        }
        let end = if w == nw - 1 {
            rows
        } else {
            let done = indptr[start] as usize;
            let cut = done + (total - done).div_ceil(nw - w);
            let mut e = start + 1; // every worker takes at least one row
            while e < rows && (indptr[e] as usize) < cut {
                e += 1;
            }
            e
        };
        chunks.push((start, end));
        start = end;
    }
    chunks
}

/// Column-index storage a CSR container/kernel can gather through: u32
/// for the general layout, u16 for [`Csr16`]'s halved index bytes.
/// `Sync` so index slices can be shared across the worker pool.
pub trait ColIdx: Copy + Sync {
    /// Column counts this width can address (index max + 1).
    const MAX_COLS: usize;
    /// Layout tag for diagnostics ("csr" / "csr16").
    const TAG: &'static str;
    /// Index-type name for diagnostics ("u32" / "u16").
    const IDX: &'static str;
    fn at(self) -> usize;
    /// Narrow a column position into this width (callers check
    /// `MAX_COLS` first).
    fn from_col(c: usize) -> Self;
}

impl ColIdx for u32 {
    const MAX_COLS: usize = u32::MAX as usize + 1;
    const TAG: &'static str = "csr";
    const IDX: &'static str = "u32";
    #[inline]
    fn at(self) -> usize {
        self as usize
    }
    #[inline]
    fn from_col(c: usize) -> u32 {
        c as u32
    }
}

impl ColIdx for u16 {
    const MAX_COLS: usize = u16::MAX as usize + 1;
    const TAG: &'static str = "csr16";
    const IDX: &'static str = "u16";
    #[inline]
    fn at(self) -> usize {
        self as usize
    }
    #[inline]
    fn from_col(c: usize) -> u16 {
        c as u16
    }
}

/// Σ values[i] · x[indices[i]] with 4 independent FMA chains (same shape
/// as `tensor::dot`; the gathers bound throughput, the chains keep the
/// FMAs off the dependency critical path). Generic over the index width
/// so `Csr` and `Csr16` share one kernel body.
#[inline]
fn gather_dot<I: ColIdx>(values: &[f32], indices: &[I], x: &[f32]) -> f32 {
    let n = values.len().min(indices.len());
    let split = n - n % 4;
    let (vc, vr) = values[..n].split_at(split);
    let (ic, ir) = indices[..n].split_at(split);
    let mut acc = [0.0f32; 4];
    for (vk, ik) in vc.chunks_exact(4).zip(ic.chunks_exact(4)) {
        acc[0] = vk[0].mul_add(x[ik[0].at()], acc[0]);
        acc[1] = vk[1].mul_add(x[ik[1].at()], acc[1]);
        acc[2] = vk[2].mul_add(x[ik[2].at()], acc[2]);
        acc[3] = vk[3].mul_add(x[ik[3].at()], acc[3]);
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (&v, &i) in vr.iter().zip(ir) {
        s = v.mul_add(x[i.at()], s);
    }
    s
}

/// Packed 2:4: per 4-group, 2 values + 2x 2-bit indices (byte-packed).
/// This is the format NVIDIA sparse tensor cores consume; here it proves
/// the mask is hardware-legal and measures the exact memory saving.
#[derive(Clone, Debug, PartialEq)]
pub struct Packed24 {
    pub rows: usize,
    pub cols: usize,
    /// 2 survivors per group, row-major: rows * cols/2 values. Groups
    /// with fewer than 2 nonzeros carry 0.0 in the filler slots.
    pub values: Vec<f32>,
    /// packed indices: one byte per group = (i1 << 2) | i0. The two
    /// indices are always distinct; i0 < i1 except in the
    /// lone-nonzero-at-index-3 filler case (see [`Packed24::from_dense`]).
    pub meta: Vec<u8>,
}

impl Packed24 {
    /// Pack a dense 2:4 matrix. Errors if any group has >2 nonzeros.
    ///
    /// Filler-index convention: the layout always stores exactly two
    /// (value, index) slots per 4-group, so groups with 0–1 nonzeros are
    /// padded with *filler* slots that point at zero-valued positions:
    ///
    /// - 0 nonzeros: `i0 = 0`, `i1 = 3`, both values 0.0;
    /// - 1 nonzero at index `i`: `i0 = i`, and `i1 = 3` unless `i == 3`,
    ///   in which case `i1 = 2`. In that one case `i0 > i1` — decoders
    ///   must not assume the indices are sorted, only that they are
    ///   distinct;
    /// - 2 nonzeros at `i0 < i1`: stored in ascending order.
    ///
    /// Because filler values are exactly 0.0, `to_dense` and `matmul_tb`
    /// are exact no matter which zero position a filler points at.
    pub fn from_dense(m: &Mat) -> Result<Packed24, String> {
        if m.cols % 4 != 0 {
            return Err(format!("cols {} not divisible by 4", m.cols));
        }
        let g = m.cols / 4;
        let mut values = Vec::with_capacity(m.rows * g * 2);
        let mut meta = Vec::with_capacity(m.rows * g);
        for r in 0..m.rows {
            let row = m.row(r);
            for gi in 0..g {
                let grp = &row[gi * 4..gi * 4 + 4];
                let nz: Vec<usize> = (0..4).filter(|&i| grp[i] != 0.0).collect();
                if nz.len() > 2 {
                    return Err(format!("row {r} group {gi} has {} nonzeros", nz.len()));
                }
                let i0 = nz.first().copied().unwrap_or(0);
                let i1 = nz.get(1).copied().unwrap_or(if i0 == 3 { 2 } else { 3 });
                values.push(grp[i0]);
                values.push(grp[i1]);
                meta.push(((i1 as u8) << 2) | i0 as u8);
            }
        }
        Ok(Packed24 { rows: m.rows, cols: m.cols, values, meta })
    }

    pub fn to_dense(&self) -> Mat {
        let g = self.cols / 4;
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for gi in 0..g {
                let idx = r * g + gi;
                let b = self.meta[idx];
                let (i0, i1) = ((b & 3) as usize, ((b >> 2) & 3) as usize);
                out[(r, gi * 4 + i0)] = self.values[idx * 2];
                out[(r, gi * 4 + i1)] = self.values[idx * 2 + 1];
            }
        }
        out
    }

    pub fn bytes(&self) -> usize {
        self.values.len() * 4 + self.meta.len()
    }

    /// Dense-equivalent bytes for the compression-ratio stat.
    pub fn dense_bytes(&self) -> usize {
        self.rows * self.cols * 4
    }

    /// Stored nonzeros (filler slots hold exactly 0.0 and don't count).
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// y = x @ W^T executed directly from the packed layout — no
    /// densify. Per 4-group: two FMAs against the two survivors, i.e.
    /// half the dense FLOPs. Filler slots hold 0.0 and contribute
    /// nothing even though their index points at a live x element.
    /// The inner loop processes TWO 4-groups per iteration (four
    /// independent FMA chains) so each meta-byte decode is amortized
    /// over more arithmetic. Same worker-pool row partitioning as the
    /// dense kernels.
    pub fn matmul_tb(&self, x: &Mat) -> Mat {
        assert_eq!(
            x.cols, self.cols,
            "packed24 matmul_tb: x cols {} != W cols {}",
            x.cols, self.cols
        );
        let (t, n, g) = (x.rows, self.rows, self.cols / 4);
        let mut out = Mat::zeros(t, n);
        let nt = num_threads().min(n.max(1));
        let chunk = n.div_ceil(nt.max(1)).max(1);
        let base = out.data.as_mut_ptr() as usize;
        std::thread::scope(|s| {
            for w in 0..nt {
                let (r0, r1) = (w * chunk, ((w + 1) * chunk).min(n));
                if r0 >= r1 {
                    break;
                }
                s.spawn(move || {
                    for ti in 0..t {
                        let xrow = x.row(ti);
                        // SAFETY: workers write disjoint column ranges
                        // [r0, r1) of each output row; `out` outlives the
                        // scope and is not otherwise touched inside it.
                        let orow: &mut [f32] = unsafe {
                            std::slice::from_raw_parts_mut(
                                (base as *mut f32).add(ti * n + r0),
                                r1 - r0,
                            )
                        };
                        for (o, r) in orow.iter_mut().zip(r0..r1) {
                            let vals = &self.values[r * g * 2..(r + 1) * g * 2];
                            let meta = &self.meta[r * g..(r + 1) * g];
                            let (mut a0, mut a1, mut a2, mut a3) =
                                (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                            let pairs = g - g % 2;
                            for gi in (0..pairs).step_by(2) {
                                let (m0, m1) = (meta[gi], meta[gi + 1]);
                                let vk = &vals[gi * 2..gi * 2 + 4];
                                let xg = &xrow[gi * 4..gi * 4 + 8];
                                a0 = vk[0].mul_add(xg[(m0 & 3) as usize], a0);
                                a1 = vk[1].mul_add(xg[((m0 >> 2) & 3) as usize], a1);
                                a2 = vk[2].mul_add(xg[4 + (m1 & 3) as usize], a2);
                                a3 = vk[3].mul_add(xg[4 + ((m1 >> 2) & 3) as usize], a3);
                            }
                            if pairs < g {
                                let m = meta[pairs];
                                let xg = &xrow[pairs * 4..pairs * 4 + 4];
                                a0 = vals[pairs * 2].mul_add(xg[(m & 3) as usize], a0);
                                a1 = vals[pairs * 2 + 1]
                                    .mul_add(xg[((m >> 2) & 3) as usize], a1);
                            }
                            *o = (a0 + a1) + (a2 + a3);
                        }
                    }
                });
            }
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::{magnitude_prune, Sparsity};
    use crate::util::prop::prop_check;
    use crate::util::Rng;

    #[test]
    fn csr_roundtrip() {
        let mut rng = Rng::new(1);
        let mut m = Mat::randn(8, 12, 1.0, &mut rng);
        magnitude_prune(&mut m, Sparsity::Unstructured { rate: 0.6 });
        let csr = Csr::from_dense(&m);
        assert_eq!(csr.to_dense(), m);
        assert!((csr.sparsity() - 0.6).abs() < 0.05);
    }

    #[test]
    fn csr_matmul_matches_dense() {
        let mut rng = Rng::new(2);
        let mut w = Mat::randn(10, 16, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.5 });
        let x = Mat::randn(4, 16, 1.0, &mut rng);
        let dense = x.matmul_tb(&w);
        let sparse = Csr::from_dense(&w).matmul_tb(&x);
        assert!(dense.max_abs_diff(&sparse) < 1e-5);
    }

    #[test]
    fn csr16_roundtrip_and_matmul_match_csr() {
        let mut rng = Rng::new(61);
        let mut w = Mat::randn(23, 40, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.6 });
        let c16 = Csr16::from_dense(&w);
        let c32 = Csr::from_dense(&w);
        assert_eq!(c16.to_dense(), w);
        assert_eq!(c16.nnz(), c32.nnz());
        // index bytes halve: 6 B/nnz vs 8 B/nnz (+ shared indptr)
        assert_eq!(c16.bytes() + 2 * c16.nnz(), c32.bytes());
        for t in [1usize, 5] {
            let x = Mat::randn(t, 40, 1.0, &mut rng);
            let dense = x.matmul_tb(&w);
            assert!(c16.matmul_tb(&x).max_abs_diff(&dense) < 1e-5, "t={t}");
            // identical kernel body => identical results to u32 CSR
            assert_eq!(c16.matmul_tb(&x), c32.matmul_tb(&x), "t={t}");
        }
    }

    #[test]
    fn csr16_skewed_and_empty_rows_match_dense() {
        // same edge shapes the Csr kernel is pinned on: all-zero rows and
        // one near-dense row through the nnz-balanced partitioning
        let mut rng = Rng::new(62);
        let mut w = Mat::randn(19, 24, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.7 });
        for r in [0usize, 7, 18] {
            for v in w.row_mut(r) {
                *v = 0.0;
            }
        }
        for v in w.row_mut(3) {
            *v = 1.5; // near-dense row
        }
        let c = Csr16::from_dense(&w);
        let x = Mat::randn(1, 24, 1.0, &mut rng);
        assert!(c.matmul_tb(&x).max_abs_diff(&x.matmul_tb(&w)) < 1e-4);
        for r in [0usize, 7, 18] {
            assert_eq!(c.matmul_tb(&x)[(0, r)], 0.0);
        }
    }

    #[test]
    #[should_panic(expected = "exceed u16 index range")]
    fn csr16_rejects_wide_matrices() {
        let w = Mat::zeros(1, Csr16::MAX_COLS + 4);
        let _ = Csr16::from_dense(&w);
    }

    #[test]
    fn csr_base_widths_agree_on_every_accessor() {
        // One generic container body behind both index widths: every
        // accessor must agree between Csr and Csr16 on the same matrix,
        // and the byte accounting must reflect exactly the index-width
        // difference (2 B/nnz).
        let mut rng = Rng::new(63);
        let mut w = Mat::randn(11, 28, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.55 });
        let c32 = Csr::from_dense(&w);
        let c16 = Csr16::from_dense(&w);
        assert_eq!(c32.to_dense(), c16.to_dense());
        assert_eq!(c32.nnz(), c16.nnz());
        assert_eq!(c32.sparsity(), c16.sparsity());
        assert_eq!(c32.dense_bytes(), c16.dense_bytes());
        assert_eq!(c32.bytes(), c16.bytes() + 2 * c16.nnz());
        for r in 0..11 {
            assert_eq!(c32.densify_row(r), c16.densify_row(r), "row {r}");
        }
        assert_eq!(Csr16::MAX_COLS, u16::MAX as usize + 1);
        assert!(Csr::MAX_COLS > Csr16::MAX_COLS);
    }

    #[test]
    fn packed24_roundtrip() {
        let mut rng = Rng::new(3);
        let mut w = Mat::randn(6, 16, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::two_four());
        let packed = Packed24::from_dense(&w).unwrap();
        assert_eq!(packed.to_dense(), w);
        // values are exactly half the dense payload; meta adds 1B/group
        assert_eq!(packed.values.len(), 6 * 8);
        assert_eq!(packed.bytes(), packed.dense_bytes() / 2 + 6 * 4);
        assert!((packed.bytes() as f64) < packed.dense_bytes() as f64 * 0.7);
    }

    #[test]
    fn packed24_rejects_dense_groups() {
        let m = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 0.0]);
        assert!(Packed24::from_dense(&m).is_err());
    }

    #[test]
    fn packed24_matmul_matches_dense() {
        let mut rng = Rng::new(21);
        let mut w = Mat::randn(37, 64, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::two_four());
        let packed = Packed24::from_dense(&w).unwrap();
        for t in [1usize, 5, 16] {
            let x = Mat::randn(t, 64, 1.0, &mut rng);
            let dense = x.matmul_tb(&w);
            let sparse = packed.matmul_tb(&x);
            assert!(dense.max_abs_diff(&sparse) < 1e-5, "t={t}");
        }
    }

    #[test]
    fn csr_matmul_single_row_and_empty_rows() {
        // Decode shape (t = 1) plus all-zero W rows: the parallel kernel
        // must still produce exact zeros there and match dense elsewhere.
        let mut rng = Rng::new(22);
        let mut w = Mat::randn(19, 24, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.7 });
        for r in [0usize, 7, 18] {
            for v in w.row_mut(r) {
                *v = 0.0;
            }
        }
        let csr = Csr::from_dense(&w);
        let x = Mat::randn(1, 24, 1.0, &mut rng);
        let dense = x.matmul_tb(&w);
        let sparse = csr.matmul_tb(&x);
        assert!(dense.max_abs_diff(&sparse) < 1e-5);
        for r in [0usize, 7, 18] {
            assert_eq!(sparse[(0, r)], 0.0);
        }
    }

    #[test]
    fn nnz_balanced_chunks_cover_disjoint_and_balance() {
        // skewed nnz: one huge row up front, many light rows after — a
        // row-count split would give worker 0 nearly all the work.
        let mut indptr = vec![0u32, 1000];
        for r in 0..31 {
            indptr.push(1000 + (r + 1) * 10);
        }
        let rows = indptr.len() - 1;
        let total = *indptr.last().unwrap() as usize;
        for nw in [1usize, 2, 4, 8, 32, 100] {
            let chunks = nnz_balanced_chunks(&indptr, nw);
            assert!(chunks.len() <= nw.min(rows));
            // exact cover, contiguous + disjoint
            assert_eq!(chunks[0].0, 0);
            assert_eq!(chunks.last().unwrap().1, rows);
            for w in chunks.windows(2) {
                assert_eq!(w[0].1, w[1].0);
            }
            // the heavy row sits alone once there are enough workers
            if nw >= 4 {
                assert_eq!(chunks[0], (0, 1), "nw={nw}: {chunks:?}");
                // and no later chunk exceeds ~2x the fair share of the rest
                let fair = (total - 1000).div_ceil(nw - 1);
                for &(r0, r1) in &chunks[1..] {
                    let nnz = (indptr[r1] - indptr[r0]) as usize;
                    assert!(nnz <= 2 * fair + 10, "nw={nw} chunk {r0}..{r1}: {nnz}");
                }
            }
        }
        // all-empty rows fall back to an even row split that still covers
        let empty = vec![0u32; 9];
        let chunks = nnz_balanced_chunks(&empty, 3);
        assert_eq!(chunks.first().unwrap().0, 0);
        assert_eq!(chunks.last().unwrap().1, 8);
    }

    #[test]
    fn csr_matmul_skewed_rows_match_dense() {
        // One near-dense row among very sparse ones: exercises the
        // nnz-balanced partitioning against the dense reference.
        let mut rng = Rng::new(40);
        let mut w = Mat::randn(33, 64, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.9 });
        for v in w.row_mut(0) {
            *v = 1.5; // row 0 fully dense
        }
        let csr = Csr::from_dense(&w);
        for t in [1usize, 4] {
            let x = Mat::randn(t, 64, 1.0, &mut rng);
            assert!(csr.matmul_tb(&x).max_abs_diff(&x.matmul_tb(&w)) < 1e-4, "t={t}");
        }
    }

    #[test]
    fn packed24_matmul_odd_group_count_matches_dense() {
        // g = 3 (odd): the two-group inner loop must handle the tail
        // group via the scalar epilogue.
        let mut rng = Rng::new(41);
        let mut w = Mat::randn(9, 12, 1.0, &mut rng);
        magnitude_prune(&mut w, Sparsity::two_four());
        let p = Packed24::from_dense(&w).unwrap();
        for t in [1usize, 3] {
            let x = Mat::randn(t, 12, 1.0, &mut rng);
            assert!(p.matmul_tb(&x).max_abs_diff(&x.matmul_tb(&w)) < 1e-5, "t={t}");
        }
        // g = 1: pairs == 0, epilogue only
        let mut w1 = Mat::randn(5, 4, 1.0, &mut rng);
        magnitude_prune(&mut w1, Sparsity::two_four());
        let p1 = Packed24::from_dense(&w1).unwrap();
        let x1 = Mat::randn(2, 4, 1.0, &mut rng);
        assert!(p1.matmul_tb(&x1).max_abs_diff(&x1.matmul_tb(&w1)) < 1e-5);
    }

    #[test]
    fn packed24_edge_groups_roundtrip() {
        // Groups with 0 and 1 nonzeros, including the lone nonzero at
        // index 3 whose filler index wraps downward (i0 > i1).
        #[rustfmt::skip]
        let m = Mat::from_vec(2, 8, vec![
            0.0, 0.0, 0.0, 0.0,   0.0, 0.0, 0.0, 7.0,
            5.0, 0.0, 0.0, 0.0,   0.0, 2.0, 3.0, 0.0,
        ]);
        let p = Packed24::from_dense(&m).unwrap();
        assert_eq!(p.to_dense(), m);
        assert_eq!(p.nnz(), 4);
        // the two indices of every group are distinct
        for &b in &p.meta {
            assert_ne!(b & 3, (b >> 2) & 3);
        }
        // empty group: (i0, i1) = (0, 3)
        assert_eq!((p.meta[0] & 3, (p.meta[0] >> 2) & 3), (0, 3));
        // lone nonzero at 3: i0 = 3, filler i1 = 2 (unsorted pair)
        assert_eq!((p.meta[1] & 3, (p.meta[1] >> 2) & 3), (3, 2));
        // lone nonzero at 0: i0 = 0, filler i1 = 3
        assert_eq!((p.meta[2] & 3, (p.meta[2] >> 2) & 3), (0, 3));
        // matmul agrees on the edge groups too
        let mut rng = Rng::new(23);
        let x = Mat::randn(3, 8, 1.0, &mut rng);
        assert!(p.matmul_tb(&x).max_abs_diff(&x.matmul_tb(&m)) < 1e-6);
    }

    #[test]
    fn prop_packed24_roundtrip_sparse_groups() {
        // Random occupancy 0..=2 per group (the from_dense legal range),
        // with the nonzero positions drawn uniformly — exercises every
        // filler combination, not just magnitude-pruned 2:4 masks.
        prop_check(
            "packed24-roundtrip-edge-groups",
            32,
            |r| {
                let rows = r.range(1, 6);
                let groups = r.range(1, 6);
                let mut m = Mat::zeros(rows, groups * 4);
                for row in 0..rows {
                    for g in 0..groups {
                        let k = r.below(3); // 0, 1 or 2 nonzeros
                        let mut cols: Vec<usize> = (0..4).collect();
                        for i in 0..k {
                            let j = i + r.below(4 - i);
                            cols.swap(i, j);
                            m[(row, g * 4 + cols[i])] = r.normal_f32(3.0, 1.0);
                        }
                    }
                }
                m
            },
            |m| {
                let p = Packed24::from_dense(m).expect("legal 2:4");
                p.to_dense() == *m
            },
        );
    }

    #[test]
    fn prop_csr_roundtrip_random_sparsity() {
        prop_check(
            "csr-roundtrip",
            24,
            |r| {
                let rows = r.range(1, 10);
                let cols = r.range(1, 20);
                let mut m = Mat::randn(rows, cols, 1.0, r);
                for v in m.data.iter_mut() {
                    if r.uniform() < 0.7 {
                        *v = 0.0;
                    }
                }
                m
            },
            |m| Csr::from_dense(m).to_dense() == *m,
        );
    }
}
