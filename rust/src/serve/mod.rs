//! The serving engine: batched continuous decoding over any
//! [`LanguageModel`].
//!
//! [`DecodeSession`](crate::model::DecodeSession) is a strictly B = 1
//! API: every concurrent stream re-reads the full `WeightStore` per
//! token, so serving N users costs N sweeps over the (sparse) weights.
//! The [`Engine`] redesigns that surface around continuous batching:
//!
//! - [`Engine::submit`] queues a [`Request`] and returns a
//!   [`RequestId`];
//! - each [`Engine::step`] admits queued requests up to `max_batch` —
//!   ALL prompts admitted together prefill as ONE padded batch through
//!   the threaded Full-attention arm (`prefill_batch`), so a bursty
//!   arrival pays a single sweep over the weights — then samples one
//!   token per active stream and runs ALL streams through one batched
//!   forward: every linear executes a single (B, d) `matmul_tb` over
//!   the stacked queries, amortizing each sparse weight read (CSR /
//!   packed 2:4 row decode) across B streams, with per-stream attention
//!   threaded across the pool once B·T clears a break-even;
//! - streams carry per-request K/V caches or recurrent state, absolute
//!   position offsets, and a seeded [`SamplingParams`] RNG, so batch
//!   composition never changes a stream's tokens (batch invariance is
//!   pinned by `engine_batch_matches_independent_sessions` in the
//!   integration suite);
//! - finished streams retire to [`Engine::take_finished`] and their
//!   slots refill from the queue mid-flight (continuous batching, not
//!   static batching); [`Engine::set_on_token`] streams each sampled
//!   token to the caller the moment it exists;
//! - an optional `max_seq` sliding-window bound evicts the oldest K/V
//!   rows — O(1) per step through the paged cache layout — so
//!   long-running streams hold bounded memory.
//!
//! [`score_continuations`] is the eval-side consumer: all candidate
//! continuations of a zero-shot task score as one batch from a single
//! shared prefill.
//!
//! [`Engine::speculative`] swaps the one-token-per-step decode loop for
//! draft-propose / target-verify rounds over a pruned draft model (see
//! [`speculative`]) — greedy streams emit several tokens per target
//! sweep, bit-identical to plain decoding.
//!
//! The engine also degrades gracefully instead of corrupting or
//! aborting (the resilience layer):
//!
//! - every [`Completion`] carries a typed [`FinishReason`]; callers can
//!   always tell "ran its budget" from "gave up";
//! - [`Engine::submit_with_deadline`] bounds a request's decode steps
//!   and queue wait; [`Engine::cancel`] removes it outright — either
//!   way the stream's K/V pages return through the paged freelist;
//! - [`EngineConfig::max_kv_pages`] caps total live K/V pages: admission
//!   stops filling when an estimate would exceed it, and decode growth
//!   past it preempts the YOUNGEST stream vLLM-style (evict its K/V,
//!   re-queue for re-prefill carrying output + RNG — an unwindowed
//!   greedy stream resumes bit-identically);
//! - non-finite (NaN/Inf) logits quarantine exactly the poisoned stream
//!   with `FinishReason::Error(NonFiniteLogits)` while the rest of the
//!   batch keeps decoding; a speculative draft that goes non-finite
//!   falls back to plain target decode for that stream;
//! - [`Engine::stats`] counts completions, preemptions, expirations,
//!   cancellations, quarantines and the live-page peak;
//! - every path above is driven deterministically by the seeded
//!   fault-injection harness in [`faults`].

pub mod faults;
pub mod speculative;

use std::collections::VecDeque;

use crate::model::{log_softmax_at, DecodeState, LanguageModel};
use crate::tensor::Mat;
use crate::util::Rng;

// ---------------------------------------------------------------------------
// sampling
// ---------------------------------------------------------------------------

/// Per-request sampling policy. `temperature <= 0` is greedy argmax
/// (the RNG is never consulted, matching `DecodeSession::generate`);
/// otherwise tokens draw from the temperature-scaled softmax, optionally
/// restricted to the `top_k` highest logits. `seed` starts the request's
/// private [`Rng`] stream: the same seed always reproduces the same
/// tokens, independent of what else is in the batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    pub temperature: f32,
    pub top_k: Option<usize>,
    pub seed: u64,
}

impl SamplingParams {
    pub fn greedy() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: None, seed: 0 }
    }

    pub fn temperature(t: f32, seed: u64) -> SamplingParams {
        SamplingParams { temperature: t, top_k: None, seed }
    }

    pub fn top_k(k: usize, t: f32, seed: u64) -> SamplingParams {
        SamplingParams { temperature: t, top_k: Some(k), seed }
    }
}

impl Default for SamplingParams {
    fn default() -> SamplingParams {
        SamplingParams::greedy()
    }
}

/// Why a stream was quarantined. Carried inside
/// [`FinishReason::Error`] so callers can react per kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// The model produced NaN/Inf logits for this stream — aggressively
    /// pruned weights can overflow, and a non-finite row poisons every
    /// downstream softmax. The stream retires with whatever it generated
    /// before the poison; the rest of the batch continues.
    NonFiniteLogits,
}

/// Draw one token from `logits` under `params`. Greedy ties break to the
/// lowest index (same rule as `argmax_last`); top-k ties at the boundary
/// also break to the lowest index so the candidate set is deterministic.
///
/// This sits on the per-stream per-step hot path, so the full-vocab case
/// iterates the logits slice directly (no index allocation) and top-k
/// uses an O(V) selection instead of a full sort. The softmax runs over
/// logit/T in f64, max-subtracted (the perplexity-path convention) so
/// extreme temperatures stay finite.
///
/// Panics on non-finite logits — an earlier version silently emitted
/// the last vocab token there, which turns one NaN into an endless
/// stream of plausible-looking garbage. Callers that must survive
/// poisoned logits (the engine's quarantine path) use
/// [`try_sample_token`] instead.
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    match try_sample_token(logits, params, rng) {
        Ok(t) => t,
        Err(e) => panic!(
            "sample_token over non-finite logits ({e:?}); \
             use try_sample_token or the engine's quarantine path"
        ),
    }
}

/// [`sample_token`] with the non-finite case surfaced as a typed error
/// instead of a panic: `Err(ErrorKind::NonFiniteLogits)` when any logit
/// is NaN/Inf (nothing is drawn, the RNG is not consumed).
pub fn try_sample_token(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
) -> Result<u32, ErrorKind> {
    try_sample_token_with(logits, params, rng, &mut SampleScratch::default())
}

/// Reusable sampling buffers (top-k index selection + softmax weights)
/// so the engine's per-stream per-step sampling allocates nothing and
/// computes each exp exactly once.
#[derive(Default)]
struct SampleScratch {
    idx: Vec<usize>,
    w: Vec<f64>,
}

/// [`try_sample_token`] over caller-owned scratch buffers — the engine
/// threads one [`SampleScratch`] across streams and steps.
///
/// The finiteness pre-check is what makes the CDF-walk fallbacks below
/// sound: with every logit finite, the max-subtracted weights include
/// exp(0) = 1 at the max, so the total is >= 1 and the walk can only
/// miss by the floating-point tail (r within rounding of the total) —
/// where the last candidate IS the correct boundary token. Before this
/// check, all-NaN logits produced NaN weights, the walk never fired,
/// and the fallback silently emitted the last vocab token forever.
fn try_sample_token_with(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) -> Result<u32, ErrorKind> {
    if logits.iter().any(|v| !v.is_finite()) {
        return Err(ErrorKind::NonFiniteLogits);
    }
    if params.temperature <= 0.0 {
        return Ok(crate::model::decode::argmax(logits) as u32);
    }
    let inv_t = 1.0 / params.temperature as f64;
    // CDF walk over cached weights: each exp computed exactly once
    let draw = |w: &[f64], rng: &mut Rng| -> Option<usize> {
        let total: f64 = w.iter().sum();
        let mut r = rng.uniform() * total;
        for (j, &wj) in w.iter().enumerate() {
            r -= wj;
            if r <= 0.0 {
                return Some(j);
            }
        }
        None // fp tail: r stayed (barely) positive
    };
    Ok(match params.top_k {
        None => {
            let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
            scratch.w.clear();
            scratch.w.extend(logits.iter().map(|&v| ((v as f64 - mx) * inv_t).exp()));
            let j = draw(&scratch.w, rng).unwrap_or(logits.len() - 1);
            j as u32
        }
        Some(k) => {
            let k = k.max(1).min(logits.len());
            scratch.idx.clear();
            scratch.idx.extend(0..logits.len());
            // total order (logit desc, index asc) makes the selected SET
            // deterministic; the walk order below is the deterministic
            // (if unsorted) selection output, so same seed => same token
            let cmp = |a: &usize, b: &usize| {
                logits[*b].partial_cmp(&logits[*a]).expect("finite logits").then(a.cmp(b))
            };
            scratch.idx.select_nth_unstable_by(k - 1, cmp);
            scratch.idx.truncate(k);
            let mx = scratch
                .idx
                .iter()
                .map(|&i| logits[i])
                .fold(f32::NEG_INFINITY, f32::max) as f64;
            scratch.w.clear();
            scratch
                .w
                .extend(scratch.idx.iter().map(|&i| ((logits[i] as f64 - mx) * inv_t).exp()));
            let j = draw(&scratch.w, rng).unwrap_or(scratch.idx.len() - 1);
            scratch.idx[j] as u32
        }
    })
}

// ---------------------------------------------------------------------------
// requests
// ---------------------------------------------------------------------------

/// One generation request: a prompt, a budget of new tokens, and a
/// sampling policy.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
}

impl Request {
    /// Greedy request — the common serving default.
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request { prompt, max_new_tokens, sampling: SamplingParams::greedy() }
    }
}

/// Handle returned by [`Engine::submit`]; matches the `id` on the
/// eventual [`Completion`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u64);

/// Why a request finished — the completion taxonomy. Only `Length` is
/// the happy path; everything else is a typed degradation a serving
/// front end can surface instead of silently returning short output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Emitted its full `max_new_tokens` budget (zero-budget
    /// prompt-logits requests finish here too).
    Length,
    /// A per-request [`Deadline`] expired — decode steps or admit-wait
    /// rounds; `tokens` holds whatever was generated in time.
    Deadline,
    /// [`Engine::cancel`] removed it; partial output is kept.
    Cancelled,
    /// Quarantined with a typed error; partial output is kept.
    Error(ErrorKind),
}

impl FinishReason {
    pub fn is_error(&self) -> bool {
        matches!(self, FinishReason::Error(_))
    }
}

/// Per-request deadline, attached via [`Engine::submit_with_deadline`].
/// Both bounds are independent and optional; the default bounds nothing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Deadline {
    /// Engine steps this request may spend decoding (a speculative
    /// round counts as one step). Survives preemption — the counter
    /// carries through re-queuing, so a preempted stream cannot reset
    /// its clock.
    pub max_steps: Option<usize>,
    /// Admit rounds it may be passed over in the queue per stint
    /// (re-counted from zero after a preemption, which re-queues
    /// through no fault of the request). Exceeding it finishes the
    /// request with [`FinishReason::Deadline`] instead of admitting.
    pub max_wait_rounds: Option<usize>,
}

impl Deadline {
    /// No bounds — what plain [`Engine::submit`] attaches.
    pub fn none() -> Deadline {
        Deadline::default()
    }

    pub fn steps(n: usize) -> Deadline {
        Deadline { max_steps: Some(n), max_wait_rounds: None }
    }

    pub fn wait_rounds(n: usize) -> Deadline {
        Deadline { max_steps: None, max_wait_rounds: Some(n) }
    }
}

/// A finished request: the generated tokens, the logits at the final
/// position (so scoring-style consumers don't re-run the model), and
/// why it finished. `last_logits` is empty for requests that never
/// prefilled (cancelled or expired while still queued).
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub tokens: Vec<u32>,
    pub last_logits: Vec<f32>,
    pub finish: FinishReason,
}

/// Engine knobs. `max_batch` bounds concurrent streams (queued requests
/// wait); `max_seq`, when set, applies the sliding-window K/V bound to
/// every stream; `max_wait_rounds` bounds how many admit rounds a
/// request can be passed over by shortest-first admission before it
/// jumps the sort (see [`Engine::admit`]); `max_kv_pages` caps the
/// total K/V pages live across all streams.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub max_seq: Option<usize>,
    /// After waiting this many admit rounds, a queued request is aged:
    /// it admits ahead of every fresh request, FIFO among aged ones, so
    /// sustained streams of short arrivals cannot starve a long prompt.
    /// `0` disables shortest-first entirely (pure FIFO admission).
    pub max_wait_rounds: usize,
    /// Global K/V memory budget in pages (see
    /// [`crate::tensor::PagedKv`]; [`Engine::kv_pages_live`] is the
    /// measured side). `None` = unbounded. When set, admission stops
    /// filling once the page estimate would exceed it, and decode
    /// growth past it preempts the youngest stream (recompute
    /// preemption — see [`Engine`] docs) rather than aborting anything.
    /// A lone stream is always allowed to run, so one oversized request
    /// degrades to solo decoding instead of deadlocking. Mamba states
    /// hold no pages and are exempt.
    pub max_kv_pages: Option<usize>,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig { max_batch: 8, max_seq: None, max_wait_rounds: 8, max_kv_pages: None }
    }
}

/// Cumulative resilience counters, mirrored per engine (the
/// `spec_stats` idiom): one snapshot answers "did anything degrade, and
/// how often" without scanning completions.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Completions of every kind (equals `take_finished` output counts).
    pub completed: usize,
    /// Recompute preemptions (budget-driven or fault-injected). Not a
    /// completion kind: a preempted stream re-queues and finishes later.
    pub preemptions: usize,
    /// Completions with [`FinishReason::Deadline`].
    pub deadline_expired: usize,
    /// Completions with [`FinishReason::Cancelled`].
    pub cancelled: usize,
    /// Completions with [`FinishReason::Error`].
    pub quarantined: usize,
    /// Speculative streams whose draft went non-finite and fell back to
    /// plain target decoding.
    pub draft_fallbacks: usize,
    /// Highest live K/V page count observed (target + draft states).
    pub kv_pages_peak: usize,
    /// Tokens emitted across every stream so far (streamed through
    /// `on_token` and accumulated into completions alike) — the
    /// numerator of any tokens/s measurement over the engine.
    pub tokens_generated: usize,
}

impl EngineStats {
    /// Completions that ran their full budget — the happy path. Derived
    /// (not stored) so the by-reason counts always sum to `completed`.
    pub fn finished_length(&self) -> usize {
        self.completed - self.deadline_expired - self.cancelled - self.quarantined
    }
}

/// One read-only view of everything a monitoring surface needs:
/// the queue, the active batch, live K/V pages and the cumulative
/// [`EngineStats`] ledger. Taken atomically between steps via
/// [`Engine::snapshot`], so a `/metrics` endpoint (or any other
/// observer) never reaches into engine internals mid-step.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineSnapshot {
    /// Requests waiting for a batch slot ([`Engine::queued`]).
    pub queued: usize,
    /// Streams actively decoding ([`Engine::active`]).
    pub active: usize,
    /// K/V pages currently held ([`Engine::kv_pages_live`]).
    pub kv_pages_live: usize,
    /// The engine's batch-slot bound (`EngineConfig::max_batch`) —
    /// capacity context for the queue depth above, so a monitoring
    /// surface (or an admission-control consumer) can tell "2 queued"
    /// behind 1 slot from "2 queued" behind 64.
    pub max_batch: usize,
    /// The cumulative counters ([`Engine::stats`]).
    pub stats: EngineStats,
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

struct Stream {
    id: RequestId,
    prompt: Vec<u32>,
    last_logits: Vec<f32>,
    out: Vec<u32>,
    max_new: usize,
    sampling: SamplingParams,
    rng: Rng,
    deadline: Deadline,
    /// Engine steps this request has spent decoding, across preemptions
    /// (carried through the queue so the deadline clock never resets).
    steps_used: usize,
    /// Admission order tiebreaker: the budget enforcer preempts the
    /// stream with the HIGHEST admit_seq (youngest — least sunk prefill
    /// work), the vLLM recompute-preemption victim policy.
    admit_seq: u64,
}

impl Stream {
    /// Absolute position of the NEXT token: everything consumed so far.
    /// Derived (not stored) so RoPE positions can never desync from the
    /// prompt + generated history.
    fn pos(&self) -> usize {
        self.prompt.len() + self.out.len()
    }
}

/// A request waiting for a batch slot — either a fresh submission or a
/// preempted stream awaiting re-prefill (recompute preemption).
/// `out`/`rng`/`steps_used` carry a preempted stream's mid-flight state
/// so it resumes exactly where it stopped; for fresh submissions `out`
/// is empty and `rng` is the seed-fresh sampling stream.
struct Queued {
    id: RequestId,
    prompt: Vec<u32>,
    out: Vec<u32>,
    max_new: usize,
    sampling: SamplingParams,
    rng: Rng,
    deadline: Deadline,
    steps_used: usize,
    /// Admit rounds passed over in THIS queue stint (resets when a
    /// preemption re-queues the request) — the aging counter that
    /// bounds shortest-first starvation and the clock for
    /// `Deadline::max_wait_rounds`.
    waited: usize,
    /// Aged entries admit ahead of every fresh one, FIFO by id. Set
    /// when `waited` crosses `EngineConfig::max_wait_rounds`, and
    /// immediately on preemption so preempted work re-admits promptly.
    aged: bool,
}

impl Queued {
    /// Tokens the next prefill must feed: the prompt plus everything
    /// generated before a preemption.
    fn ctx_len(&self) -> usize {
        self.prompt.len() + self.out.len()
    }
}

/// Continuous-batching decode engine over a borrowed model.
///
/// ```text
/// let mut eng = Engine::new(&model, EngineConfig::default());
/// let id = eng.submit(Request::greedy(prompt, 32));
/// eng.run();
/// let done = eng.take_finished();   // Completion { id, tokens, .. }
/// ```
pub struct Engine<'m> {
    model: &'m dyn LanguageModel,
    cfg: EngineConfig,
    next_id: u64,
    queue: VecDeque<Queued>,
    /// Active streams; `states[i]` is `streams[i]`'s decode state (kept
    /// as a parallel contiguous slice so `decode_step_batch` can take
    /// `&mut [DecodeState]` directly).
    streams: Vec<Stream>,
    states: Vec<DecodeState>,
    finished: Vec<Completion>,
    /// Sampling scratch (top-k indices + softmax weights), reused
    /// across streams and steps.
    sample_scratch: SampleScratch,
    /// Streaming hook: called with (request, token) the moment each new
    /// token is sampled, instead of only at completion.
    on_token: Option<Box<dyn FnMut(RequestId, u32) + 'm>>,
    /// Speculative mode: the pruned draft model and the proposal depth
    /// `k`. `None` = plain one-token-per-step decoding.
    spec: Option<(&'m dyn LanguageModel, usize)>,
    /// Per-stream draft state + pending token, parallel to `streams`
    /// (speculative mode only; built lazily after admission).
    spec_cursors: Vec<speculative::SpecCursor>,
    /// Acceptance accounting across every stream, including retired
    /// ones.
    spec_stats: speculative::SpecStats,
    /// Resilience counters (completions, preemptions, quarantines, …).
    stats: EngineStats,
    /// Scripted fault injections; empty by default (no-op).
    faults: faults::FaultPlan,
    /// 0-based index of the CURRENT engine step (incremented after each
    /// `step`); the clock `FaultPlan::clamp_budget` schedules against.
    step_no: usize,
    /// Next value of `Stream::admit_seq`.
    admit_seq: u64,
    /// An empty decode-state template probed once at construction: the
    /// admission gate sizes page estimates off its block/page geometry
    /// without allocating anything.
    page_shape: DecodeState,
}

impl<'m> Engine<'m> {
    pub fn new(model: &'m dyn LanguageModel, cfg: EngineConfig) -> Engine<'m> {
        assert!(cfg.max_batch >= 1, "max_batch must admit at least one stream");
        if let Some(w) = cfg.max_seq {
            assert!(w >= 1, "max_seq window must hold at least one position");
        }
        Engine {
            model,
            cfg,
            next_id: 0,
            queue: VecDeque::new(),
            streams: Vec::new(),
            states: Vec::new(),
            finished: Vec::new(),
            sample_scratch: SampleScratch::default(),
            on_token: None,
            spec: None,
            spec_cursors: Vec::new(),
            spec_stats: speculative::SpecStats::default(),
            stats: EngineStats::default(),
            faults: faults::FaultPlan::default(),
            step_no: 0,
            admit_seq: 0,
            page_shape: model.decode_state(),
        }
    }

    /// Speculative-decoding engine: same continuous batching, admission
    /// packing and windowing, but each stream decodes in
    /// draft-propose / target-verify rounds (see [`speculative`]) so one
    /// target sweep can emit up to `k + 1` tokens. Greedy requests only
    /// — lossless verification is an argmax identity — and the output is
    /// bit-identical to [`Engine::new`] over `model` alone.
    pub fn speculative(
        model: &'m dyn LanguageModel,
        draft: &'m dyn LanguageModel,
        k: usize,
        cfg: EngineConfig,
    ) -> Engine<'m> {
        assert!(k >= 1, "speculation depth k must be at least 1");
        assert_eq!(
            model.vocab(),
            draft.vocab(),
            "draft and target must share a vocabulary"
        );
        let mut eng = Engine::new(model, cfg);
        eng.spec = Some((draft, k));
        eng
    }

    /// Aggregated speculative acceptance stats (every round of every
    /// stream, including retired ones). All zeros outside speculative
    /// mode.
    pub fn spec_stats(&self) -> speculative::SpecStats {
        self.spec_stats
    }

    /// Register a streaming token callback: `f(id, token)` fires the
    /// moment a stream samples each new token (batch-slot order within a
    /// step), so callers see tokens as they are generated instead of
    /// only at completion. Tokens still accumulate into the eventual
    /// [`Completion`]; the hook observes, it does not consume.
    pub fn set_on_token(&mut self, f: impl FnMut(RequestId, u32) + 'm) {
        self.on_token = Some(Box::new(f));
    }

    /// Queue a request; it becomes active when a batch slot frees up.
    pub fn submit(&mut self, req: Request) -> RequestId {
        self.submit_with_deadline(req, Deadline::none())
    }

    /// [`Engine::submit`] with a per-request [`Deadline`]: the request
    /// finishes with [`FinishReason::Deadline`] (keeping whatever it
    /// generated in time) once it exceeds its decode-step or queue-wait
    /// bound, and its K/V pages are reclaimed.
    pub fn submit_with_deadline(&mut self, req: Request, deadline: Deadline) -> RequestId {
        assert!(!req.prompt.is_empty(), "request needs a non-empty prompt");
        if self.spec.is_some() {
            assert!(
                req.sampling.temperature <= 0.0,
                "speculative mode serves greedy requests only \
                 (lossless verification is an argmax identity)"
            );
        }
        let id = RequestId(self.next_id);
        self.next_id += 1;
        self.queue.push_back(Queued {
            id,
            prompt: req.prompt,
            out: Vec::new(),
            max_new: req.max_new_tokens,
            sampling: req.sampling,
            rng: Rng::new(req.sampling.seed),
            deadline,
            steps_used: 0,
            waited: 0,
            aged: false,
        });
        id
    }

    /// Cancel a request wherever it is: still queued (it never runs) or
    /// actively decoding (its K/V pages are reclaimed immediately —
    /// dropping the decode state returns every page through the paged
    /// freelist). Either way a [`Completion`] with
    /// [`FinishReason::Cancelled`] and any partial output is delivered
    /// through [`Engine::take_finished`]. Returns `false` when the id is
    /// unknown or already finished.
    pub fn cancel(&mut self, id: RequestId) -> bool {
        if let Some(pos) = self.queue.iter().position(|q| q.id == id) {
            let q = self.queue.remove(pos).expect("position came from this queue");
            self.push_finished(Completion {
                id: q.id,
                prompt: q.prompt,
                tokens: q.out,
                last_logits: Vec::new(),
                finish: FinishReason::Cancelled,
            });
            return true;
        }
        if let Some(i) = self.streams.iter().position(|s| s.id == id) {
            let s = self.remove_stream(i);
            self.push_finished(Completion {
                id: s.id,
                prompt: s.prompt,
                tokens: s.out,
                last_logits: s.last_logits,
                finish: FinishReason::Cancelled,
            });
            return true;
        }
        false
    }

    /// Resilience counters so far (a `Copy` snapshot, like
    /// [`Engine::spec_stats`]).
    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// Everything a monitoring surface reads, in one consistent view:
    /// queue depth, live stream count, live K/V pages and the stats
    /// ledger. The HTTP server's `/metrics` endpoint is the consumer —
    /// it sees only this snapshot, never engine internals.
    pub fn snapshot(&self) -> EngineSnapshot {
        EngineSnapshot {
            queued: self.queued(),
            active: self.active(),
            kv_pages_live: self.kv_pages_live(),
            max_batch: self.cfg.max_batch,
            stats: self.stats,
        }
    }

    /// The configuration this engine runs under (read-only — knobs are
    /// fixed at construction).
    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// K/V pages currently held across every active stream — target
    /// decode states plus, in speculative mode, the per-stream draft
    /// states. The measured side of `EngineConfig::max_kv_pages`.
    pub fn kv_pages_live(&self) -> usize {
        self.states.iter().map(|st| st.kv_pages_live()).sum::<usize>()
            + self.spec_cursors.iter().map(|c| c.d_state.kv_pages_live()).sum::<usize>()
    }

    /// Install a scripted [`faults::FaultPlan`]. Injections fire at the
    /// engine's normal decision points, so a faulted run exercises
    /// exactly the code a real fault would.
    pub fn set_fault_plan(&mut self, plan: faults::FaultPlan) {
        self.faults = plan;
    }

    /// Streams currently decoding.
    pub fn active(&self) -> usize {
        self.streams.len()
    }

    /// Requests waiting for a batch slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Decode states of the active streams (batch-slot order) — cache
    /// introspection for window monitoring and the long-context smoke.
    pub fn states(&self) -> &[DecodeState] {
        &self.states
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.streams.is_empty()
    }

    /// Admit queued requests into free batch slots. All prompts admitted
    /// in one call prefill as ONE padded batch through the Full-arm
    /// threaded attention (`prefill_batch`), so a bursty arrival of B
    /// prompts pays a single threaded sweep over the weights instead of
    /// B separate passes — followed by one (B, V) logits matmul. With a
    /// `max_seq` window, prompts longer than the window fall back to the
    /// per-request windowed prefill (window-sized chunks with paged
    /// eviction between them, shared with windowed `DecodeSession`s), so
    /// one long prompt can't blow past the memory bound at admission;
    /// prompts within the window still pack. Length-skewed bursts are
    /// peeled to a ≥50% fill ratio so the padded pass never does more
    /// than 2x the useful prefill work.
    ///
    /// `step` calls this automatically; it is public so callers (and the
    /// serve benches) can pay the prefill cost eagerly, separate from
    /// the decode loop.
    pub fn admit(&mut self) {
        // Queue-wait deadlines first: a request passed over more rounds
        // than its deadline allows expires HERE, before this round could
        // admit it — bounded wait means bounded, not "unless a slot
        // happened to open".
        let mut expired: Vec<Completion> = Vec::new();
        self.queue.retain(|q| match q.deadline.max_wait_rounds {
            Some(limit) if q.waited > limit => {
                expired.push(Completion {
                    id: q.id,
                    prompt: q.prompt.clone(),
                    tokens: q.out.clone(),
                    last_logits: Vec::new(),
                    finish: FinishReason::Deadline,
                });
                false
            }
            _ => true,
        });
        for c in expired {
            self.push_finished(c);
        }
        // Shortest-first admission with aging: sort the WHOLE pending
        // queue before slots are filled, so the ≥50%-fill peeling below
        // sees length-sorted candidates and mixed-length bursts pack
        // tightly instead of pairing a long straggler with whatever
        // arrived next. The sort is stable — equal-length requests keep
        // submission order. Under sustained skew pure shortest-first
        // starves: a long prompt loses to every fresh short arrival,
        // forever. So any request passed over for `max_wait_rounds`
        // admit rounds is AGED (preempted re-queues arrive pre-aged):
        // aged requests sort ahead of every fresh one, FIFO among
        // themselves (by id = submission order), which bounds queue
        // wait at O(max_wait_rounds) regardless of what keeps arriving.
        let max_wait = self.cfg.max_wait_rounds;
        for q in self.queue.iter_mut() {
            if q.waited >= max_wait {
                q.aged = true;
            }
        }
        self.queue.make_contiguous().sort_by_key(|q| {
            if q.aged {
                (false, q.id.0 as usize) // aged: FIFO, ahead of fresh
            } else {
                (true, q.ctx_len()) // fresh: shortest-first
            }
        });
        self.admit_sorted();
        // everything still queued was passed over this round
        for q in self.queue.iter_mut() {
            q.waited += 1;
        }
    }

    /// The slot-filling half of [`Engine::admit`], consuming the queue
    /// in its already-sorted order. With a page budget set, each
    /// candidate's need is estimated from its (window-clamped) context
    /// length and the fill stops at the first candidate that would push
    /// live + planned pages past the budget — head-of-line blocking is
    /// deliberate: admitting someone BEHIND the blocked head would
    /// subvert the priority order aging just established. The one
    /// exception: when nothing is running at all, one stream always
    /// admits, so an oversized lone request degrades to solo decoding
    /// instead of deadlocking the queue. Preempted entries re-prefill
    /// prompt + generated-so-far and resume on their carried RNG, so an
    /// unwindowed stream continues bit-identically.
    fn admit_sorted(&mut self) {
        let budget = self.effective_budget();
        loop {
            let free = self.cfg.max_batch - self.streams.len();
            let mut batch: Vec<Queued> = Vec::with_capacity(free);
            let mut planned = self.kv_pages_live();
            while batch.len() < free {
                let Some(q) = self.queue.pop_front() else { break };
                if let Some(b) = budget {
                    let eff = match self.cfg.max_seq {
                        Some(w) => q.ctx_len().min(w),
                        None => q.ctx_len(),
                    };
                    let need = self.page_shape.kv_pages_for(eff);
                    if planned + need > b && !(self.streams.is_empty() && batch.is_empty()) {
                        self.queue.push_front(q);
                        break;
                    }
                    planned += need;
                }
                batch.push(q);
            }
            if batch.is_empty() {
                return;
            }
            // context each entry prefills: the prompt, plus everything a
            // preempted stream had already generated (fresh: out empty)
            let ctxs: Vec<Vec<u32>> = batch
                .iter()
                .map(|q| {
                    let mut c = q.prompt.clone();
                    c.extend_from_slice(&q.out);
                    c
                })
                .collect();
            // contexts the one-shot packed pass can take whole: window
            // unset, or context within the window (a single chunk of the
            // windowed prefill — identical math, no eviction mid-prompt)
            let mut packable: Vec<usize> = (0..batch.len())
                .filter(|&i| match self.cfg.max_seq {
                    None => true,
                    Some(w) => ctxs[i].len() <= w,
                })
                .collect();
            // Bound padding waste: the packed pass costs n·max(len), so
            // one long prompt among short ones would make the burst pay
            // mostly padding. Peel the longest prompts off to the
            // per-request path until the set packs at least half full
            // (Σ len ≥ n·max/2); skew within the set is then ≤ 2x.
            packable.sort_by_key(|&i| ctxs[i].len());
            while packable.len() >= 2 {
                let max = ctxs[*packable.last().unwrap()].len();
                let sum: usize = packable.iter().map(|&i| ctxs[i].len()).sum();
                if sum * 2 >= packable.len() * max {
                    break;
                }
                packable.pop();
            }
            let mut states: Vec<Option<DecodeState>> = (0..batch.len()).map(|_| None).collect();
            let mut logits: Vec<Option<Vec<f32>>> = vec![None; batch.len()];
            if packable.len() >= 2 {
                let mut sts: Vec<DecodeState> =
                    packable.iter().map(|_| self.model.decode_state()).collect();
                let prompts: Vec<&[u32]> =
                    packable.iter().map(|&i| ctxs[i].as_slice()).collect();
                let h = self.model.prefill_batch(&mut sts, &prompts);
                let lg = self.model.logits(&h);
                for (j, (&i, st)) in packable.iter().zip(sts).enumerate() {
                    states[i] = Some(st);
                    logits[i] = Some(lg.row(j).to_vec());
                }
            }
            for (i, q) in batch.into_iter().enumerate() {
                let (state, lg) = match (states[i].take(), logits[i].take()) {
                    (Some(s), Some(l)) => (s, l),
                    _ => {
                        // singleton admission or a context longer than
                        // the window: the per-request path
                        let mut state = self.model.decode_state();
                        let h = match self.cfg.max_seq {
                            Some(w) => crate::model::decode::prefill_windowed(
                                self.model,
                                &mut state,
                                0,
                                &ctxs[i],
                                w,
                            ),
                            None => self.model.prefill_append(&mut state, 0, &ctxs[i]),
                        };
                        (state, self.model.logits_row(&h))
                    }
                };
                if q.out.len() >= q.max_new {
                    // zero-budget request: completes with prompt logits
                    self.push_finished(Completion {
                        id: q.id,
                        prompt: q.prompt,
                        tokens: q.out,
                        last_logits: lg,
                        finish: FinishReason::Length,
                    });
                    continue;
                }
                self.streams.push(Stream {
                    id: q.id,
                    last_logits: lg,
                    out: q.out,
                    max_new: q.max_new,
                    rng: q.rng,
                    sampling: q.sampling,
                    prompt: q.prompt,
                    deadline: q.deadline,
                    steps_used: q.steps_used,
                    admit_seq: self.admit_seq,
                });
                self.admit_seq += 1;
                self.states.push(state);
            }
            // zero-budget completions freed their slots: admit again
            if self.streams.len() >= self.cfg.max_batch || self.queue.is_empty() {
                return;
            }
        }
    }

    /// One continuous-batching step: admit queued requests, quarantine
    /// any stream holding non-finite logits, sample one token per
    /// surviving stream, run all B streams through ONE batched forward
    /// (a single (B, d) matmul per linear plus one (B, V) logits
    /// matmul), then retire finished/expired streams and enforce the
    /// page budget so slots and pages refill next step. Returns the
    /// number of tokens generated.
    pub fn step(&mut self) -> usize {
        let n = if self.spec.is_some() { self.spec_step() } else { self.plain_step() };
        self.step_no += 1;
        n
    }

    fn plain_step(&mut self) -> usize {
        self.admit();
        self.note_pages_peak();
        self.inject_nan_faults();
        self.quarantine_nonfinite();
        if self.streams.is_empty() {
            return 0;
        }
        let mut toks: Vec<u32> = Vec::with_capacity(self.streams.len());
        for s in self.streams.iter_mut() {
            let tok = try_sample_token_with(
                &s.last_logits,
                &s.sampling,
                &mut s.rng,
                &mut self.sample_scratch,
            )
            .expect("non-finite logits were quarantined above");
            if let Some(cb) = self.on_token.as_mut() {
                cb(s.id, tok);
            }
            toks.push(tok);
        }
        let poss: Vec<usize> = self.streams.iter().map(|s| s.pos()).collect();
        let h = self.model.decode_step_batch(&mut self.states, &poss, &toks);
        let logits = self.model.logits(&h);
        for (i, s) in self.streams.iter_mut().enumerate() {
            s.out.push(toks[i]);
            s.steps_used += 1;
            s.last_logits = logits.row(i).to_vec();
            if let Some(w) = self.cfg.max_seq {
                self.states[i].enforce_window(w);
            }
        }
        self.stats.tokens_generated += toks.len();
        // retire first: finished streams free pages, which may satisfy
        // the budget without preempting anyone
        self.retire_finished();
        self.apply_forced_preempts();
        self.enforce_budget();
        self.note_pages_peak();
        toks.len()
    }

    /// One speculative continuous-batching step: admit queued requests
    /// (the target still prefills through the packed path), lazily
    /// prefill the draft for newly admitted streams, quarantine poisoned
    /// streams, then run ONE propose/verify round per surviving stream —
    /// each emits between 1 and `k + 1` tokens. Returns the number of
    /// tokens emitted.
    fn spec_step(&mut self) -> usize {
        let (draft, k) = self.spec.expect("spec_step outside speculative mode");
        self.admit();
        // new streams: prefill the draft over prompt + any output a
        // preemption carried over, and lift the target's context argmax
        // into the pending slot (exactly the token the plain engine
        // would sample next)
        for i in self.spec_cursors.len()..self.streams.len() {
            let s = &self.streams[i];
            let ctx: Vec<u32> = s.prompt.iter().chain(s.out.iter()).copied().collect();
            let mut d_state = draft.decode_state();
            speculative::feed(draft, &mut d_state, 0, &ctx, self.cfg.max_seq);
            self.spec_cursors.push(speculative::SpecCursor {
                d_state,
                d_pos: ctx.len(),
                pending: crate::model::decode::argmax(&s.last_logits) as u32,
                draft_dead: false,
            });
        }
        self.note_pages_peak();
        // quarantine before the rounds: the pending token derives from
        // last_logits, so a poisoned stream retires before it can emit
        // from garbage (in spec mode quarantine lands on round
        // boundaries — mid-round poison is caught next step)
        self.inject_nan_faults();
        self.quarantine_nonfinite();
        if self.streams.is_empty() {
            return 0;
        }
        let mut total = 0usize;
        let mut poisoned: Vec<RequestId> = Vec::new();
        for i in 0..self.streams.len() {
            let budget = self.streams[i].max_new - self.streams[i].out.len();
            let k_eff = k.min(budget - 1);
            let history: Vec<u32> = {
                let s = &self.streams[i];
                s.prompt.iter().chain(s.out.iter()).copied().collect()
            };
            let was_dead = self.spec_cursors[i].draft_dead;
            let o = speculative::spec_round(
                self.model,
                draft,
                self.cfg.max_seq,
                k_eff,
                &mut self.states[i],
                &mut self.spec_cursors[i],
                &history,
            );
            if !was_dead && self.spec_cursors[i].draft_dead {
                self.stats.draft_fallbacks += 1;
            }
            self.spec_stats.absorb(&o);
            let s = &mut self.streams[i];
            if let Some(cb) = self.on_token.as_mut() {
                for &t in &o.emitted {
                    cb(s.id, t);
                }
            }
            s.out.extend_from_slice(&o.emitted);
            s.steps_used += 1;
            s.last_logits = o.last_logits;
            total += o.emitted.len();
            if o.poisoned {
                poisoned.push(s.id);
            }
        }
        // a poisoned TARGET verify row means the next pending token
        // would come from garbage: quarantine those streams now, before
        // the budget/deadline retire pass
        for i in (0..self.streams.len()).rev() {
            if poisoned.contains(&self.streams[i].id) {
                let s = self.remove_stream(i);
                self.push_finished(Completion {
                    id: s.id,
                    prompt: s.prompt,
                    tokens: s.out,
                    last_logits: s.last_logits,
                    finish: FinishReason::Error(ErrorKind::NonFiniteLogits),
                });
            }
        }
        self.stats.tokens_generated += total;
        self.retire_finished();
        self.apply_forced_preempts();
        self.enforce_budget();
        self.note_pages_peak();
        total
    }

    /// Drop stream `i` from the active set, keeping `streams`, `states`
    /// and (in speculative mode) `spec_cursors` parallel. The decode
    /// state drops with it — every K/V page returns to the allocator.
    fn remove_stream(&mut self, i: usize) -> Stream {
        let s = self.streams.swap_remove(i);
        self.states.swap_remove(i);
        if i < self.spec_cursors.len() {
            self.spec_cursors.swap_remove(i);
        }
        s
    }

    /// Single retirement choke point: every completion passes through
    /// here, so the typed counters can never drift from the finished
    /// list.
    fn push_finished(&mut self, c: Completion) {
        self.stats.completed += 1;
        match c.finish {
            FinishReason::Length => {}
            FinishReason::Deadline => self.stats.deadline_expired += 1,
            FinishReason::Cancelled => self.stats.cancelled += 1,
            FinishReason::Error(_) => self.stats.quarantined += 1,
        }
        self.finished.push(c);
    }

    /// Scripted NaN injections: poisoning `last_logits` upstream of the
    /// quarantine scan means the injected fault flows through exactly
    /// the detection path a real non-finite forward would.
    fn inject_nan_faults(&mut self) {
        for s in self.streams.iter_mut() {
            if self.faults.take_nan(s.id, s.out.len()) {
                for v in s.last_logits.iter_mut() {
                    *v = f32::NAN;
                }
            }
        }
    }

    /// Retire every stream whose `last_logits` holds NaN/Inf with a
    /// typed error — only the poisoned stream leaves; the rest of the
    /// batch keeps decoding.
    fn quarantine_nonfinite(&mut self) {
        let mut i = 0;
        while i < self.streams.len() {
            if self.streams[i].last_logits.iter().all(|v| v.is_finite()) {
                i += 1;
                continue;
            }
            let s = self.remove_stream(i);
            self.push_finished(Completion {
                id: s.id,
                prompt: s.prompt,
                tokens: s.out,
                last_logits: s.last_logits,
                finish: FinishReason::Error(ErrorKind::NonFiniteLogits),
            });
        }
    }

    /// Retire streams that hit their token budget or step deadline,
    /// back-to-front so swap_remove leaves earlier indices valid, then
    /// flipped so same-step completions land in slot order.
    fn retire_finished(&mut self) {
        let mut retired = Vec::new();
        for i in (0..self.streams.len()).rev() {
            let s = &self.streams[i];
            let finish = if s.out.len() >= s.max_new {
                FinishReason::Length
            } else if s.deadline.max_steps.is_some_and(|m| s.steps_used >= m) {
                FinishReason::Deadline
            } else {
                continue;
            };
            let s = self.remove_stream(i);
            retired.push(Completion {
                id: s.id,
                prompt: s.prompt,
                tokens: s.out,
                last_logits: s.last_logits,
                finish,
            });
        }
        retired.reverse();
        for c in retired {
            self.push_finished(c);
        }
    }

    /// Scripted forced preemptions — same reclamation/re-queue path the
    /// budget enforcer takes, at a chosen point. Streams about to retire
    /// this step are exempt (preempting finished work is pure waste).
    fn apply_forced_preempts(&mut self) {
        for i in (0..self.streams.len()).rev() {
            let (id, emitted) = (self.streams[i].id, self.streams[i].out.len());
            if self.faults.take_preempt(id, emitted) {
                self.preempt_stream(i);
            }
        }
    }

    /// vLLM-style recompute preemption: evict the stream's K/V entirely
    /// (its decode state drops — pages return through the freelist) and
    /// re-queue it pre-AGED so admission ordering re-admits it promptly.
    /// The queued entry carries prompt + generated tokens + the
    /// mid-stream sampling RNG, so re-prefill resumes the exact stream:
    /// unwindowed, bit-identically (the packed/solo prefill paths are
    /// pinned to match stepping); windowed, the chunked re-prefill is
    /// the same approximation admission applies to any long prompt.
    fn preempt_stream(&mut self, i: usize) {
        let s = self.remove_stream(i);
        self.stats.preemptions += 1;
        self.queue.push_back(Queued {
            id: s.id,
            prompt: s.prompt,
            out: s.out,
            max_new: s.max_new,
            sampling: s.sampling,
            rng: s.rng,
            deadline: s.deadline,
            steps_used: s.steps_used,
            waited: 0,
            aged: true,
        });
    }

    /// The page budget currently in force: the config bound, tightened
    /// by any fault-injected clamp active at this step.
    fn effective_budget(&self) -> Option<usize> {
        match (self.cfg.max_kv_pages, self.faults.budget_clamp(self.step_no)) {
            (None, None) => None,
            (a, b) => Some(a.unwrap_or(usize::MAX).min(b.unwrap_or(usize::MAX))),
        }
    }

    /// Decode-time budget enforcement: admission estimates get outgrown
    /// (every generated token appends K/V rows; crossing a page boundary
    /// allocates). Preempt the YOUNGEST stream — latest admitted, least
    /// sunk prefill work — until live pages fit, but never the last
    /// stream standing: a lone stream must be allowed to run or an
    /// oversized request could never finish.
    fn enforce_budget(&mut self) {
        let Some(budget) = self.effective_budget() else { return };
        while self.kv_pages_live() > budget && self.streams.len() > 1 {
            let victim = self
                .streams
                .iter()
                .enumerate()
                .max_by_key(|(_, s)| s.admit_seq)
                .map(|(i, _)| i)
                .expect("streams is non-empty in this loop");
            self.preempt_stream(victim);
        }
    }

    fn note_pages_peak(&mut self) {
        let live = self.kv_pages_live();
        if live > self.stats.kv_pages_peak {
            self.stats.kv_pages_peak = live;
        }
    }

    /// Drive until every queued and active request completes; returns
    /// the total number of generated tokens.
    pub fn run(&mut self) -> usize {
        let mut total = 0;
        while self.has_work() {
            total += self.step();
        }
        total
    }

    /// Drain completed requests: ordered by completion step, batch-slot
    /// order within a step. That is NOT submission order under mixed
    /// workloads — match results to requests by [`Completion::id`].
    pub fn take_finished(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.finished)
    }
}

// ---------------------------------------------------------------------------
// batched scoring (the zero-shot eval path)
// ---------------------------------------------------------------------------

/// Sum log-prob of every candidate continuation after `context`, scored
/// as ONE batch: the context is prefilled once through the threaded
/// Full-attention arm, the state is cloned per candidate, and each step
/// runs all still-live candidates through a single batched forward.
/// Candidates may have different lengths — finished ones drop out of the
/// batch. An empty candidate scores 0.0 (the `choice_accuracy`
/// convention). Results match per-candidate
/// [`DecodeSession::continuation_logprob`](crate::model::DecodeSession)
/// runs to within 1e-5 (bit-for-bit in practice: the batched arms run
/// the same per-row kernels in the same order).
pub fn score_continuations(
    model: &dyn LanguageModel,
    context: &[u32],
    candidates: &[Vec<u32>],
) -> Vec<f64> {
    assert!(!context.is_empty(), "scoring needs a non-empty context");
    let mut base = model.decode_state();
    let h = model.prefill_append(&mut base, 0, context);
    let base_logits = model.logits_row(&h);
    let mut lps = vec![0.0f64; candidates.len()];
    for (i, cand) in candidates.iter().enumerate() {
        if let Some(&first) = cand.first() {
            lps[i] = log_softmax_at(&base_logits, first as usize);
        }
    }
    // streams only for candidates that still need decode steps
    let mut who: Vec<usize> = (0..candidates.len()).filter(|&i| candidates[i].len() >= 2).collect();
    let mut states: Vec<DecodeState> = who.iter().map(|_| base.clone()).collect();
    let mut t = 0usize;
    while !who.is_empty() {
        let toks: Vec<u32> = who.iter().map(|&i| candidates[i][t]).collect();
        let poss: Vec<usize> = vec![context.len() + t; who.len()];
        let h = model.decode_step_batch(&mut states, &poss, &toks);
        let logits: Mat = model.logits(&h);
        for (j, &i) in who.iter().enumerate() {
            lps[i] += log_softmax_at(logits.row(j), candidates[i][t + 1] as usize);
        }
        t += 1;
        for j in (0..who.len()).rev() {
            if candidates[who[j]].len() <= t + 1 {
                who.swap_remove(j);
                states.swap_remove(j);
            }
        }
    }
    lps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{
        DecodeSession, Mamba, MambaConfig, Transformer, TransformerConfig,
    };

    fn tiny_transformer(seed: u64) -> Transformer {
        Transformer::init(
            TransformerConfig {
                vocab: 37,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 24,
                max_seq: 64,
            },
            &mut Rng::new(seed),
        )
    }

    fn tiny_mamba(seed: u64) -> Mamba {
        Mamba::init(
            MambaConfig { vocab: 37, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 64 },
            &mut Rng::new(seed),
        )
    }

    fn prompt(len: usize, salt: usize) -> Vec<u32> {
        (0..len).map(|i| ((i * 5 + salt * 3) % 37) as u32).collect()
    }

    #[test]
    fn greedy_engine_matches_sessions_both_archs() {
        for (name, model) in [
            ("microllama", Box::new(tiny_transformer(1)) as Box<dyn LanguageModel>),
            ("micromamba", Box::new(tiny_mamba(2)) as Box<dyn LanguageModel>),
        ] {
            let mut eng = Engine::new(model.as_ref(), EngineConfig::default());
            let ids: Vec<RequestId> = (0..3)
                .map(|i| eng.submit(Request::greedy(prompt(4 + 3 * i, i), 5 + i)))
                .collect();
            eng.run();
            assert!(!eng.has_work());
            let mut done = eng.take_finished();
            done.sort_by_key(|c| c.id);
            assert_eq!(done.len(), 3, "{name}");
            for (i, (c, id)) in done.iter().zip(&ids).enumerate() {
                assert_eq!(c.id, *id, "{name}");
                let mut s = DecodeSession::new(model.as_ref());
                s.prefill(&prompt(4 + 3 * i, i));
                let expect = s.generate(5 + i);
                assert_eq!(c.tokens, expect, "{name} stream {i}");
                let d = c
                    .last_logits
                    .iter()
                    .zip(s.last_logits())
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(d < 1e-5, "{name} stream {i}: logits diverge by {d}");
            }
        }
    }

    #[test]
    fn continuous_batching_refills_slots_from_queue() {
        let m = tiny_transformer(3);
        // 5 requests through 2 slots: every completion must still match
        // an isolated session despite mid-flight admissions
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 2, ..Default::default() });
        for i in 0..5usize {
            eng.submit(Request::greedy(prompt(3 + i, i), 3 + (i % 3)));
        }
        assert_eq!(eng.queued(), 5);
        eng.step();
        assert_eq!(eng.active(), 2, "only max_batch streams admitted");
        assert_eq!(eng.queued(), 3);
        eng.run();
        let mut done = eng.take_finished();
        assert_eq!(done.len(), 5);
        done.sort_by_key(|c| c.id);
        for (i, c) in done.iter().enumerate() {
            let mut s = DecodeSession::new(&m);
            s.prefill(&prompt(3 + i, i));
            assert_eq!(c.tokens, s.generate(3 + (i % 3)), "request {i}");
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic_and_seed_sensitive() {
        let m = tiny_transformer(4);
        let gen = |seed: u64| -> Vec<u32> {
            let mut eng = Engine::new(&m, EngineConfig::default());
            eng.submit(Request {
                prompt: prompt(6, 1),
                max_new_tokens: 12,
                sampling: SamplingParams::temperature(1.5, seed),
            });
            eng.run();
            eng.take_finished().remove(0).tokens
        };
        assert_eq!(gen(7), gen(7), "same seed must reproduce the stream");
        assert_ne!(gen(7), gen(8), "different seeds should diverge at T=1.5");
        // batch composition must not perturb a seeded stream
        let solo = gen(7);
        let mut eng = Engine::new(&m, EngineConfig::default());
        eng.submit(Request {
            prompt: prompt(6, 1),
            max_new_tokens: 12,
            sampling: SamplingParams::temperature(1.5, 7),
        });
        eng.submit(Request::greedy(prompt(9, 2), 12));
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done[0].tokens, solo, "batch mate changed a seeded stream");
    }

    #[test]
    fn top_k_one_is_greedy_and_topk_restricts_support() {
        let m = tiny_transformer(5);
        let run = |sampling: SamplingParams| -> Vec<u32> {
            let mut eng = Engine::new(&m, EngineConfig::default());
            eng.submit(Request { prompt: prompt(5, 3), max_new_tokens: 8, sampling });
            eng.run();
            eng.take_finished().remove(0).tokens
        };
        let greedy = run(SamplingParams::greedy());
        assert_eq!(run(SamplingParams::top_k(1, 0.8, 11)), greedy);
        // top-k sampling only ever emits tokens inside the current top-k
        let logits: Vec<f32> = vec![0.1, 2.0, -1.0, 1.5, 0.3];
        let mut rng = Rng::new(12);
        for _ in 0..200 {
            let t = sample_token(&logits, &SamplingParams::top_k(2, 1.0, 0), &mut rng);
            assert!(t == 1 || t == 3, "token {t} outside top-2");
        }
    }

    #[test]
    fn sliding_window_bounds_cache_and_matches_unbounded_when_short() {
        let m = tiny_transformer(6);
        let p = prompt(10, 4);
        // window larger than prompt+gen: identical to unbounded
        let run = |max_seq: Option<usize>| -> Completion {
            let mut eng = Engine::new(&m, EngineConfig { max_batch: 4, max_seq, ..Default::default() });
            eng.submit(Request::greedy(p.clone(), 6));
            eng.run();
            eng.take_finished().remove(0)
        };
        let unbounded = run(None);
        let wide = run(Some(64));
        assert_eq!(unbounded.tokens, wide.tokens);
        assert_eq!(unbounded.last_logits, wide.last_logits);
        // tight window: still decodes, and the cache stays bounded
        let w = 8;
        let mut eng =
            Engine::new(&m, EngineConfig { max_batch: 4, max_seq: Some(w), ..Default::default() });
        eng.submit(Request::greedy(p.clone(), 12));
        while eng.has_work() {
            eng.step();
            for st in &eng.states {
                assert!(st.cached_len().unwrap_or(0) <= w, "window exceeded");
            }
        }
        let c = eng.take_finished().remove(0);
        assert_eq!(c.tokens.len(), 12);
        assert!(c.tokens.iter().all(|&t| (t as usize) < 37));
        // windowed DecodeSession agrees with the windowed engine
        let mut s = DecodeSession::with_window(&m, w);
        s.prefill(&p);
        assert_eq!(s.generate(12), c.tokens);
    }

    #[test]
    fn zero_budget_request_completes_with_prompt_logits() {
        let m = tiny_mamba(7);
        let mut eng = Engine::new(&m, EngineConfig::default());
        let p = prompt(5, 5);
        eng.submit(Request::greedy(p.clone(), 0));
        eng.run();
        let done = eng.take_finished();
        assert_eq!(done.len(), 1);
        assert!(done[0].tokens.is_empty());
        let mut s = DecodeSession::new(&m);
        s.prefill(&p);
        assert_eq!(done[0].last_logits, s.last_logits());
    }

    #[test]
    fn score_continuations_matches_session_forks() {
        for model in [
            Box::new(tiny_transformer(8)) as Box<dyn LanguageModel>,
            Box::new(tiny_mamba(9)) as Box<dyn LanguageModel>,
        ] {
            let ctx = prompt(7, 6);
            let cands: Vec<Vec<u32>> =
                vec![vec![1, 2, 3], vec![4], vec![], vec![5, 6], vec![7, 8, 9, 10]];
            let batched = score_continuations(model.as_ref(), &ctx, &cands);
            let mut base = DecodeSession::new(model.as_ref());
            base.prefill(&ctx);
            for (i, cand) in cands.iter().enumerate() {
                let lp = base.fork().continuation_logprob(cand);
                assert!(
                    (batched[i] - lp).abs() < 1e-5,
                    "{} cand {i}: {} vs {lp}",
                    model.arch(),
                    batched[i]
                );
            }
        }
    }

    #[test]
    fn skewed_burst_peels_long_prompt_and_still_matches_sessions() {
        // One long prompt among shorts would make the padded pack mostly
        // padding; admit peels it to the per-request path. Either way,
        // every stream must reproduce its independent session.
        let m = tiny_transformer(13);
        let lens = [2usize, 2, 2, 40];
        let mut eng = Engine::new(&m, EngineConfig::default());
        for (i, &len) in lens.iter().enumerate() {
            eng.submit(Request::greedy(prompt(len, i), 5));
        }
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), lens.len());
        for (i, &len) in lens.iter().enumerate() {
            let mut s = DecodeSession::new(&m);
            s.prefill(&prompt(len, i));
            assert_eq!(done[i].tokens, s.generate(5), "stream {i} (len {len})");
        }
    }

    #[test]
    fn skewed_burst_admission_sorts_queue_shortest_first() {
        // More pending requests than slots: the whole queue is sorted by
        // prompt length before admission, so the three SHORTEST prompts
        // go first (packing tightly) and the long straggler waits —
        // regardless of arrival order. Results still match solo
        // sessions per id.
        let m = tiny_transformer(12);
        let lens = [40usize, 2, 3, 2, 5];
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 3, ..Default::default() });
        let mut ids = Vec::new();
        for (i, &len) in lens.iter().enumerate() {
            ids.push(eng.submit(Request::greedy(prompt(len, i), 4)));
        }
        eng.step();
        assert_eq!(eng.active(), 3, "three slots filled");
        assert_eq!(eng.queued(), 2, "len-40 and len-5 wait behind the shorts");
        // the admitted streams are exactly the three shortest prompts
        let active_lens: Vec<usize> =
            eng.streams.iter().map(|s| s.prompt.len()).collect();
        assert!(active_lens.iter().all(|&l| l <= 3), "active: {active_lens:?}");
        // stable sort: the two len-2 prompts keep submission order
        assert_eq!(eng.streams[0].id, ids[1]);
        assert_eq!(eng.streams[1].id, ids[3]);
        assert_eq!(eng.streams[2].id, ids[2]);
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), lens.len());
        for (i, &len) in lens.iter().enumerate() {
            let mut s = DecodeSession::new(&m);
            s.prefill(&prompt(len, i));
            assert_eq!(done[i].tokens, s.generate(4), "request {i} (len {len})");
        }
    }

    #[test]
    fn aged_request_jumps_shortest_first_admission() {
        // A perpetual stream of fresh short prompts against one slot:
        // pure shortest-first would pass the long prompt over on every
        // admit round, forever. Aging bounds its wait.
        let m = tiny_transformer(14);
        let drive = |max_wait_rounds: usize, steps: usize| -> (bool, Vec<Completion>) {
            let mut eng = Engine::new(
                &m,
                EngineConfig { max_batch: 1, max_wait_rounds, ..Default::default() },
            );
            let long_id = eng.submit(Request::greedy(prompt(20, 0), 2));
            let mut done = Vec::new();
            for salt in 1..=steps {
                // a fresh, shorter rival arrives before every step
                eng.submit(Request::greedy(prompt(2, salt), 2));
                eng.step();
                done.extend(eng.take_finished());
                if done.iter().any(|c| c.id == long_id) {
                    return (true, done);
                }
            }
            (false, done)
        };
        // starvation really happens without the bound...
        let (finished, _) = drive(usize::MAX, 24);
        assert!(!finished, "long prompt should starve under pure shortest-first");
        // ...and aging ends it within ~max_wait_rounds + one stream span
        let (finished, done) = drive(3, 24);
        assert!(finished, "aged long prompt must admit despite fresh short arrivals");
        // the aged stream still reproduces its independent session
        let long = done.iter().find(|c| c.prompt.len() == 20).unwrap();
        let mut s = DecodeSession::new(&m);
        s.prefill(&prompt(20, 0));
        assert_eq!(long.tokens, s.generate(2));
        // max_wait_rounds = 0 is documented as pure FIFO: the long
        // prompt (submitted first) admits on the very first step
        let (finished, _) = drive(0, 2);
        assert!(finished, "max_wait_rounds = 0 must admit in submission order");
    }

    #[test]
    fn on_token_streams_every_token_in_order() {
        use std::cell::RefCell;
        use std::collections::BTreeMap;
        use std::rc::Rc;

        let m = tiny_transformer(11);
        let streamed: Rc<RefCell<BTreeMap<RequestId, Vec<u32>>>> =
            Rc::new(RefCell::new(BTreeMap::new()));
        let sink = streamed.clone();
        // 3 requests through 2 slots: tokens must stream for refilled
        // slots too, in generation order per request
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 2, ..Default::default() });
        eng.set_on_token(move |id, tok| sink.borrow_mut().entry(id).or_default().push(tok));
        for i in 0..3usize {
            eng.submit(Request::greedy(prompt(4 + i, i), 3 + i));
        }
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3);
        let streamed = streamed.borrow();
        for c in &done {
            assert_eq!(
                streamed.get(&c.id).map(|v| v.as_slice()),
                Some(c.tokens.as_slice()),
                "streamed tokens must equal the completion for {:?}",
                c.id
            );
        }
    }

    #[test]
    #[should_panic(expected = "non-empty prompt")]
    fn empty_prompt_rejected() {
        let m = tiny_transformer(10);
        Engine::new(&m, EngineConfig::default())
            .submit(Request::greedy(vec![], 4));
    }

    // -----------------------------------------------------------------
    // resilience: typed sampling errors, deadlines, cancel, page budget,
    // fault injection
    // -----------------------------------------------------------------

    use super::faults::FaultPlan;

    /// `tiny_transformer` with headroom past position 64, for tests that
    /// must decode across the first page boundary (`KV_PAGE_ROWS` = 64).
    fn roomy_transformer(seed: u64) -> Transformer {
        Transformer::init(
            TransformerConfig {
                vocab: 37,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 24,
                max_seq: 128,
            },
            &mut Rng::new(seed),
        )
    }

    #[test]
    fn try_sample_token_types_nonfinite_on_every_arm() {
        let mut rng = Rng::new(1);
        let finite: Vec<f32> = vec![0.1, 2.0, -1.0, 1.5];
        let arms = [
            SamplingParams::greedy(),
            SamplingParams::temperature(0.9, 3),
            SamplingParams::top_k(2, 1.0, 4),
        ];
        for params in &arms {
            assert!(try_sample_token(&finite, params, &mut rng).is_ok(), "{params:?}");
            for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                let mut poisoned = finite.clone();
                poisoned[2] = bad;
                assert_eq!(
                    try_sample_token(&poisoned, params, &mut rng),
                    Err(ErrorKind::NonFiniteLogits),
                    "{params:?} must reject {bad}"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "non-finite")]
    fn sample_token_panics_instead_of_emitting_garbage() {
        // The pre-resilience behavior silently emitted the LAST vocab
        // token from all-NaN logits, forever. Panicking here is the
        // contract that keeps that bug from coming back.
        let mut rng = Rng::new(2);
        sample_token(&[f32::NAN; 4], &SamplingParams::temperature(1.0, 0), &mut rng);
    }

    #[test]
    fn cancel_reclaims_pages_and_leaves_batchmates_untouched() {
        let m = tiny_transformer(21);
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 2, ..Default::default() });
        let keep = eng.submit(Request::greedy(prompt(5, 0), 8));
        let mid = eng.submit(Request::greedy(prompt(6, 1), 8));
        let queued = eng.submit(Request::greedy(prompt(7, 2), 8));
        // cancel the still-queued request before it ever prefills
        assert!(eng.cancel(queued));
        for _ in 0..3 {
            eng.step();
        }
        let before = eng.kv_pages_live();
        assert!(before > 0);
        // cancel a mid-flight stream: its pages return immediately
        assert!(eng.cancel(mid));
        assert!(eng.kv_pages_live() < before, "cancelled stream must free pages");
        assert!(!eng.cancel(mid), "double-cancel must report unknown");
        assert!(!eng.cancel(RequestId(999)), "unknown id must report false");
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3);
        // the survivor is oblivious to both cancellations
        assert_eq!(done[0].id, keep);
        assert_eq!(done[0].finish, FinishReason::Length);
        let mut s = DecodeSession::new(&m);
        s.prefill(&prompt(5, 0));
        assert_eq!(done[0].tokens, s.generate(8));
        // mid-flight cancel keeps the partial output (3 steps = 3 tokens)
        assert_eq!(done[1].id, mid);
        assert_eq!(done[1].finish, FinishReason::Cancelled);
        let mut s = DecodeSession::new(&m);
        s.prefill(&prompt(6, 1));
        assert_eq!(done[1].tokens, s.generate(3), "partial output kept on cancel");
        // the queued cancel never ran: no tokens, no logits
        assert_eq!(done[2].id, queued);
        assert_eq!(done[2].finish, FinishReason::Cancelled);
        assert!(done[2].tokens.is_empty() && done[2].last_logits.is_empty());
        let st = eng.stats();
        assert_eq!(st.cancelled, 2);
        assert_eq!(st.completed, 3);
        assert_eq!(eng.kv_pages_live(), 0, "drained engine must hold zero pages");
    }

    #[test]
    fn step_deadline_retires_with_partial_output() {
        let m = tiny_transformer(22);
        let mut eng = Engine::new(&m, EngineConfig::default());
        let bounded =
            eng.submit_with_deadline(Request::greedy(prompt(4, 0), 10), Deadline::steps(3));
        let free = eng.submit(Request::greedy(prompt(5, 1), 6));
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, bounded);
        assert_eq!(done[0].finish, FinishReason::Deadline);
        let mut s = DecodeSession::new(&m);
        s.prefill(&prompt(4, 0));
        assert_eq!(done[0].tokens, s.generate(3), "deadline keeps the in-time prefix");
        assert_eq!(done[1].id, free);
        assert_eq!(done[1].finish, FinishReason::Length);
        let mut s = DecodeSession::new(&m);
        s.prefill(&prompt(5, 1));
        assert_eq!(done[1].tokens, s.generate(6), "batch mate must be unaffected");
        assert_eq!(eng.stats().deadline_expired, 1);
    }

    #[test]
    fn queue_wait_deadline_expires_without_running() {
        // One slot, hogged for 12 steps: a waiter bounded to 2 admit
        // rounds must expire in the queue (empty output, typed reason)
        // long before the slot frees.
        let m = tiny_transformer(23);
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 1, ..Default::default() });
        let hog = eng.submit(Request::greedy(prompt(3, 0), 12));
        let waiter =
            eng.submit_with_deadline(Request::greedy(prompt(4, 1), 5), Deadline::wait_rounds(2));
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 2);
        assert_eq!(done[0].id, hog);
        assert_eq!(done[0].finish, FinishReason::Length);
        let mut s = DecodeSession::new(&m);
        s.prefill(&prompt(3, 0));
        assert_eq!(done[0].tokens, s.generate(12), "the hog is oblivious");
        assert_eq!(done[1].id, waiter);
        assert_eq!(done[1].finish, FinishReason::Deadline);
        assert!(done[1].tokens.is_empty() && done[1].last_logits.is_empty());
        assert_eq!(eng.stats().deadline_expired, 1);
    }

    #[test]
    fn kv_budget_gates_admission_and_serializes_streams() {
        // Each tiny_transformer stream holds 4 pages under position 64
        // (2 layers x K+V x 1 page), so a 4-page budget serializes the
        // workload to one stream at a time — by ADMISSION gating alone,
        // no preemption needed.
        let m = tiny_transformer(24);
        let mut eng = Engine::new(
            &m,
            EngineConfig { max_batch: 4, max_kv_pages: Some(4), ..Default::default() },
        );
        for i in 0..3usize {
            eng.submit(Request::greedy(prompt(4 + i, i), 5));
        }
        while eng.has_work() {
            eng.step();
            assert!(eng.active() <= 1, "4-page budget admits one stream at a time");
            assert!(eng.kv_pages_live() <= 4, "budget exceeded");
        }
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3);
        for (i, c) in done.iter().enumerate() {
            assert_eq!(c.finish, FinishReason::Length);
            let mut s = DecodeSession::new(&m);
            s.prefill(&prompt(4 + i, i));
            assert_eq!(c.tokens, s.generate(5), "stream {i}");
        }
        let st = eng.stats();
        assert_eq!(st.preemptions, 0, "admission gating should avoid preemption");
        assert_eq!(st.kv_pages_peak, 4);
    }

    #[test]
    fn kv_budget_growth_preempts_youngest_and_resumes_lossless() {
        // Two streams prefill under budget (4 pages each below position
        // 64) but decode across the page boundary (8 pages each past it):
        // 16 > 12 forces one recompute preemption of the youngest. The
        // preempted stream re-queues aged, waits out the survivor, then
        // re-prefills prompt + generated-so-far — and must still produce
        // exactly its solo-session output.
        let m = roomy_transformer(25);
        let mut eng = Engine::new(
            &m,
            EngineConfig { max_batch: 2, max_kv_pages: Some(12), ..Default::default() },
        );
        let a = eng.submit(Request::greedy(prompt(60, 0), 10));
        let b = eng.submit(Request::greedy(prompt(61, 1), 10));
        while eng.has_work() {
            eng.step();
            assert!(eng.kv_pages_live() <= 12, "budget exceeded after enforcement");
        }
        assert_eq!(eng.stats().preemptions, 1, "exactly one growth preemption expected");
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 2);
        for (c, (id, len, salt)) in done.iter().zip([(a, 60, 0), (b, 61, 1)]) {
            assert_eq!(c.id, id);
            assert_eq!(c.finish, FinishReason::Length);
            let mut s = DecodeSession::new(&m);
            s.prefill(&prompt(len, salt));
            assert_eq!(c.tokens, s.generate(10), "stream {id:?} diverged after preemption");
        }
    }

    #[test]
    fn nan_fault_quarantines_only_the_poisoned_stream() {
        for (name, model) in [
            ("microllama", Box::new(tiny_transformer(26)) as Box<dyn LanguageModel>),
            ("micromamba", Box::new(tiny_mamba(27)) as Box<dyn LanguageModel>),
        ] {
            let run = |plan: FaultPlan| -> (Vec<Completion>, EngineStats) {
                let mut eng = Engine::new(model.as_ref(), EngineConfig::default());
                for i in 0..3usize {
                    eng.submit(Request::greedy(prompt(4 + i, i), 6));
                }
                eng.set_fault_plan(plan);
                eng.run();
                let mut done = eng.take_finished();
                done.sort_by_key(|c| c.id);
                (done, eng.stats())
            };
            let (base, base_st) = run(FaultPlan::new());
            assert_eq!(base_st.quarantined, 0, "{name}");
            let victim = base[1].id;
            let (done, st) = run(FaultPlan::new().nan_logits(victim, 3));
            assert_eq!(st.quarantined, 1, "{name}");
            assert_eq!(done.len(), 3);
            // blast radius: untouched streams are bit-identical
            for i in [0usize, 2] {
                assert_eq!(done[i].tokens, base[i].tokens, "{name} stream {i} tokens");
                assert_eq!(done[i].last_logits, base[i].last_logits, "{name} stream {i}");
                assert_eq!(done[i].finish, FinishReason::Length, "{name}");
            }
            // the victim keeps its pre-poison prefix under a typed error
            assert_eq!(
                done[1].finish,
                FinishReason::Error(ErrorKind::NonFiniteLogits),
                "{name}"
            );
            assert_eq!(done[1].tokens[..], base[1].tokens[..3], "{name} victim prefix");
            assert!(
                done[1].last_logits.iter().any(|v| !v.is_finite()),
                "{name}: the poisoned evidence rides out in the completion"
            );
        }
    }

    #[test]
    fn forced_preemption_is_invisible_in_every_output() {
        // A scripted preemption mid-decode (same path the budget enforcer
        // takes) must not change ANY stream's output — including a
        // temperature-sampled stream, whose mid-flight RNG rides through
        // the re-queue.
        for (name, model) in [
            ("microllama", Box::new(tiny_transformer(28)) as Box<dyn LanguageModel>),
            ("micromamba", Box::new(tiny_mamba(29)) as Box<dyn LanguageModel>),
        ] {
            let run = |plan: FaultPlan| -> (Vec<Completion>, EngineStats) {
                let mut eng = Engine::new(model.as_ref(), EngineConfig::default());
                eng.submit(Request::greedy(prompt(5, 0), 8));
                eng.submit(Request {
                    prompt: prompt(6, 1),
                    max_new_tokens: 8,
                    sampling: SamplingParams::temperature(1.2, 40),
                });
                eng.set_fault_plan(plan);
                eng.run();
                let mut done = eng.take_finished();
                done.sort_by_key(|c| c.id);
                (done, eng.stats())
            };
            let (base, base_st) = run(FaultPlan::new());
            assert_eq!(base_st.preemptions, 0, "{name}");
            let (done, st) = run(FaultPlan::new().force_preempt(base[1].id, 3));
            assert_eq!(st.preemptions, 1, "{name}");
            assert_eq!(done.len(), base.len());
            for (c, b) in done.iter().zip(&base) {
                assert_eq!(c.tokens, b.tokens, "{name}: preemption changed {:?}", c.id);
                assert_eq!(c.finish, FinishReason::Length, "{name}");
            }
            // the untouched stream is bit-identical down to its logits
            assert_eq!(done[0].last_logits, base[0].last_logits, "{name}");
        }
    }

    #[test]
    fn page_accounting_survives_cancel_deadline_and_preempt() {
        // Regression guard for every reclamation path at once: live pages
        // must always equal the count implied by each stream's cached
        // positions (nothing leaks through swap_remove retirement), and
        // must return to zero once the engine drains.
        fn check(eng: &Engine<'_>) {
            let implied: usize = eng
                .states()
                .iter()
                .map(|st| st.kv_pages_for(st.cached_len().unwrap_or(0)))
                .sum();
            assert_eq!(eng.kv_pages_live(), implied, "live pages drifted from cache contents");
        }
        let m = tiny_transformer(30);
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 3, ..Default::default() });
        let a = eng.submit(Request::greedy(prompt(3, 0), 8));
        let b = eng.submit(Request::greedy(prompt(4, 1), 8));
        eng.submit_with_deadline(Request::greedy(prompt(5, 2), 8), Deadline::steps(2));
        eng.submit(Request::greedy(prompt(6, 3), 8));
        eng.set_fault_plan(FaultPlan::new().force_preempt(b, 2));
        let mut cancelled = false;
        while eng.has_work() {
            eng.step();
            if !cancelled && eng.streams.iter().any(|s| s.id == a && s.out.len() >= 3) {
                assert!(eng.cancel(a));
                cancelled = true;
            }
            check(&eng);
        }
        assert!(cancelled, "the cancel branch must actually run");
        assert_eq!(eng.kv_pages_live(), 0, "drained engine must hold zero pages");
        let st = eng.stats();
        assert_eq!(st.completed, 4);
        assert_eq!(st.cancelled, 1);
        assert_eq!(st.deadline_expired, 1);
        assert_eq!(st.preemptions, 1);
    }
}
