//! The serving engine: batched continuous decoding over any
//! [`LanguageModel`].
//!
//! [`DecodeSession`](crate::model::DecodeSession) is a strictly B = 1
//! API: every concurrent stream re-reads the full `WeightStore` per
//! token, so serving N users costs N sweeps over the (sparse) weights.
//! The [`Engine`] redesigns that surface around continuous batching:
//!
//! - [`Engine::submit`] queues a [`Request`] and returns a
//!   [`RequestId`];
//! - each [`Engine::step`] admits queued requests up to `max_batch` —
//!   ALL prompts admitted together prefill as ONE padded batch through
//!   the threaded Full-attention arm (`prefill_batch`), so a bursty
//!   arrival pays a single sweep over the weights — then samples one
//!   token per active stream and runs ALL streams through one batched
//!   forward: every linear executes a single (B, d) `matmul_tb` over
//!   the stacked queries, amortizing each sparse weight read (CSR /
//!   packed 2:4 row decode) across B streams, with per-stream attention
//!   threaded across the pool once B·T clears a break-even;
//! - streams carry per-request K/V caches or recurrent state, absolute
//!   position offsets, and a seeded [`SamplingParams`] RNG, so batch
//!   composition never changes a stream's tokens (batch invariance is
//!   pinned by `engine_batch_matches_independent_sessions` in the
//!   integration suite);
//! - finished streams retire to [`Engine::take_finished`] and their
//!   slots refill from the queue mid-flight (continuous batching, not
//!   static batching); [`Engine::set_on_token`] streams each sampled
//!   token to the caller the moment it exists;
//! - an optional `max_seq` sliding-window bound evicts the oldest K/V
//!   rows — O(1) per step through the paged cache layout — so
//!   long-running streams hold bounded memory.
//!
//! [`score_continuations`] is the eval-side consumer: all candidate
//! continuations of a zero-shot task score as one batch from a single
//! shared prefill.
//!
//! [`Engine::speculative`] swaps the one-token-per-step decode loop for
//! draft-propose / target-verify rounds over a pruned draft model (see
//! [`speculative`]) — greedy streams emit several tokens per target
//! sweep, bit-identical to plain decoding.

pub mod speculative;

use std::collections::VecDeque;

use crate::model::{log_softmax_at, DecodeState, LanguageModel};
use crate::tensor::Mat;
use crate::util::Rng;

// ---------------------------------------------------------------------------
// sampling
// ---------------------------------------------------------------------------

/// Per-request sampling policy. `temperature <= 0` is greedy argmax
/// (the RNG is never consulted, matching `DecodeSession::generate`);
/// otherwise tokens draw from the temperature-scaled softmax, optionally
/// restricted to the `top_k` highest logits. `seed` starts the request's
/// private [`Rng`] stream: the same seed always reproduces the same
/// tokens, independent of what else is in the batch.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SamplingParams {
    pub temperature: f32,
    pub top_k: Option<usize>,
    pub seed: u64,
}

impl SamplingParams {
    pub fn greedy() -> SamplingParams {
        SamplingParams { temperature: 0.0, top_k: None, seed: 0 }
    }

    pub fn temperature(t: f32, seed: u64) -> SamplingParams {
        SamplingParams { temperature: t, top_k: None, seed }
    }

    pub fn top_k(k: usize, t: f32, seed: u64) -> SamplingParams {
        SamplingParams { temperature: t, top_k: Some(k), seed }
    }
}

impl Default for SamplingParams {
    fn default() -> SamplingParams {
        SamplingParams::greedy()
    }
}

/// Draw one token from `logits` under `params`. Greedy ties break to the
/// lowest index (same rule as `argmax_last`); top-k ties at the boundary
/// also break to the lowest index so the candidate set is deterministic.
///
/// This sits on the per-stream per-step hot path, so the full-vocab case
/// iterates the logits slice directly (no index allocation) and top-k
/// uses an O(V) selection instead of a full sort. The softmax runs over
/// logit/T in f64, max-subtracted (the perplexity-path convention) so
/// extreme temperatures stay finite.
pub fn sample_token(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    sample_token_with(logits, params, rng, &mut SampleScratch::default())
}

/// Reusable sampling buffers (top-k index selection + softmax weights)
/// so the engine's per-stream per-step sampling allocates nothing and
/// computes each exp exactly once.
#[derive(Default)]
struct SampleScratch {
    idx: Vec<usize>,
    w: Vec<f64>,
}

/// [`sample_token`] over caller-owned scratch buffers — the engine
/// threads one [`SampleScratch`] across streams and steps.
fn sample_token_with(
    logits: &[f32],
    params: &SamplingParams,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) -> u32 {
    if params.temperature <= 0.0 {
        return crate::model::decode::argmax(logits) as u32;
    }
    let inv_t = 1.0 / params.temperature as f64;
    // CDF walk over cached weights: each exp computed exactly once
    let draw = |w: &[f64], rng: &mut Rng| -> Option<usize> {
        let total: f64 = w.iter().sum();
        let mut r = rng.uniform() * total;
        for (j, &wj) in w.iter().enumerate() {
            r -= wj;
            if r <= 0.0 {
                return Some(j);
            }
        }
        None // fp tail: r stayed (barely) positive
    };
    match params.top_k {
        None => {
            let mx = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
            scratch.w.clear();
            scratch.w.extend(logits.iter().map(|&v| ((v as f64 - mx) * inv_t).exp()));
            let j = draw(&scratch.w, rng).unwrap_or(logits.len() - 1);
            j as u32
        }
        Some(k) => {
            let k = k.max(1).min(logits.len());
            scratch.idx.clear();
            scratch.idx.extend(0..logits.len());
            // total order (logit desc, index asc) makes the selected SET
            // deterministic; the walk order below is the deterministic
            // (if unsorted) selection output, so same seed => same token
            let cmp = |a: &usize, b: &usize| {
                logits[*b].partial_cmp(&logits[*a]).expect("finite logits").then(a.cmp(b))
            };
            scratch.idx.select_nth_unstable_by(k - 1, cmp);
            scratch.idx.truncate(k);
            let mx = scratch
                .idx
                .iter()
                .map(|&i| logits[i])
                .fold(f32::NEG_INFINITY, f32::max) as f64;
            scratch.w.clear();
            scratch
                .w
                .extend(scratch.idx.iter().map(|&i| ((logits[i] as f64 - mx) * inv_t).exp()));
            let j = draw(&scratch.w, rng).unwrap_or(scratch.idx.len() - 1);
            scratch.idx[j] as u32
        }
    }
}

// ---------------------------------------------------------------------------
// requests
// ---------------------------------------------------------------------------

/// One generation request: a prompt, a budget of new tokens, and a
/// sampling policy.
#[derive(Clone, Debug)]
pub struct Request {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
}

impl Request {
    /// Greedy request — the common serving default.
    pub fn greedy(prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request { prompt, max_new_tokens, sampling: SamplingParams::greedy() }
    }
}

/// Handle returned by [`Engine::submit`]; matches the `id` on the
/// eventual [`Completion`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u64);

/// A finished request: the generated tokens plus the logits at the final
/// position (so scoring-style consumers don't re-run the model).
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub tokens: Vec<u32>,
    pub last_logits: Vec<f32>,
}

/// Engine knobs. `max_batch` bounds concurrent streams (queued requests
/// wait); `max_seq`, when set, applies the sliding-window K/V bound to
/// every stream; `max_wait_rounds` bounds how many admit rounds a
/// request can be passed over by shortest-first admission before it
/// jumps the sort (see [`Engine::admit`]).
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub max_batch: usize,
    pub max_seq: Option<usize>,
    /// After waiting this many admit rounds, a queued request is aged:
    /// it admits ahead of every fresh request, FIFO among aged ones, so
    /// sustained streams of short arrivals cannot starve a long prompt.
    /// `0` disables shortest-first entirely (pure FIFO admission).
    pub max_wait_rounds: usize,
}

impl Default for EngineConfig {
    fn default() -> EngineConfig {
        EngineConfig { max_batch: 8, max_seq: None, max_wait_rounds: 8 }
    }
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

struct Stream {
    id: RequestId,
    prompt: Vec<u32>,
    last_logits: Vec<f32>,
    out: Vec<u32>,
    max_new: usize,
    sampling: SamplingParams,
    rng: Rng,
}

impl Stream {
    /// Absolute position of the NEXT token: everything consumed so far.
    /// Derived (not stored) so RoPE positions can never desync from the
    /// prompt + generated history.
    fn pos(&self) -> usize {
        self.prompt.len() + self.out.len()
    }
}

/// A request waiting for a batch slot, plus how many admit rounds it
/// has already been passed over — the aging counter that bounds
/// shortest-first starvation.
struct Queued {
    id: RequestId,
    req: Request,
    waited: usize,
}

/// Continuous-batching decode engine over a borrowed model.
///
/// ```text
/// let mut eng = Engine::new(&model, EngineConfig::default());
/// let id = eng.submit(Request::greedy(prompt, 32));
/// eng.run();
/// let done = eng.take_finished();   // Completion { id, tokens, .. }
/// ```
pub struct Engine<'m> {
    model: &'m dyn LanguageModel,
    cfg: EngineConfig,
    next_id: u64,
    queue: VecDeque<Queued>,
    /// Active streams; `states[i]` is `streams[i]`'s decode state (kept
    /// as a parallel contiguous slice so `decode_step_batch` can take
    /// `&mut [DecodeState]` directly).
    streams: Vec<Stream>,
    states: Vec<DecodeState>,
    finished: Vec<Completion>,
    /// Sampling scratch (top-k indices + softmax weights), reused
    /// across streams and steps.
    sample_scratch: SampleScratch,
    /// Streaming hook: called with (request, token) the moment each new
    /// token is sampled, instead of only at completion.
    on_token: Option<Box<dyn FnMut(RequestId, u32) + 'm>>,
    /// Speculative mode: the pruned draft model and the proposal depth
    /// `k`. `None` = plain one-token-per-step decoding.
    spec: Option<(&'m dyn LanguageModel, usize)>,
    /// Per-stream draft state + pending token, parallel to `streams`
    /// (speculative mode only; built lazily after admission).
    spec_cursors: Vec<speculative::SpecCursor>,
    /// Acceptance accounting across every stream, including retired
    /// ones.
    spec_stats: speculative::SpecStats,
}

impl<'m> Engine<'m> {
    pub fn new(model: &'m dyn LanguageModel, cfg: EngineConfig) -> Engine<'m> {
        assert!(cfg.max_batch >= 1, "max_batch must admit at least one stream");
        if let Some(w) = cfg.max_seq {
            assert!(w >= 1, "max_seq window must hold at least one position");
        }
        Engine {
            model,
            cfg,
            next_id: 0,
            queue: VecDeque::new(),
            streams: Vec::new(),
            states: Vec::new(),
            finished: Vec::new(),
            sample_scratch: SampleScratch::default(),
            on_token: None,
            spec: None,
            spec_cursors: Vec::new(),
            spec_stats: speculative::SpecStats::default(),
        }
    }

    /// Speculative-decoding engine: same continuous batching, admission
    /// packing and windowing, but each stream decodes in
    /// draft-propose / target-verify rounds (see [`speculative`]) so one
    /// target sweep can emit up to `k + 1` tokens. Greedy requests only
    /// — lossless verification is an argmax identity — and the output is
    /// bit-identical to [`Engine::new`] over `model` alone.
    pub fn speculative(
        model: &'m dyn LanguageModel,
        draft: &'m dyn LanguageModel,
        k: usize,
        cfg: EngineConfig,
    ) -> Engine<'m> {
        assert!(k >= 1, "speculation depth k must be at least 1");
        assert_eq!(
            model.vocab(),
            draft.vocab(),
            "draft and target must share a vocabulary"
        );
        let mut eng = Engine::new(model, cfg);
        eng.spec = Some((draft, k));
        eng
    }

    /// Aggregated speculative acceptance stats (every round of every
    /// stream, including retired ones). All zeros outside speculative
    /// mode.
    pub fn spec_stats(&self) -> speculative::SpecStats {
        self.spec_stats
    }

    /// Register a streaming token callback: `f(id, token)` fires the
    /// moment a stream samples each new token (batch-slot order within a
    /// step), so callers see tokens as they are generated instead of
    /// only at completion. Tokens still accumulate into the eventual
    /// [`Completion`]; the hook observes, it does not consume.
    pub fn set_on_token(&mut self, f: impl FnMut(RequestId, u32) + 'm) {
        self.on_token = Some(Box::new(f));
    }

    /// Queue a request; it becomes active when a batch slot frees up.
    pub fn submit(&mut self, req: Request) -> RequestId {
        assert!(!req.prompt.is_empty(), "request needs a non-empty prompt");
        if self.spec.is_some() {
            assert!(
                req.sampling.temperature <= 0.0,
                "speculative mode serves greedy requests only \
                 (lossless verification is an argmax identity)"
            );
        }
        let id = RequestId(self.next_id);
        self.next_id += 1;
        self.queue.push_back(Queued { id, req, waited: 0 });
        id
    }

    /// Streams currently decoding.
    pub fn active(&self) -> usize {
        self.streams.len()
    }

    /// Requests waiting for a batch slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Decode states of the active streams (batch-slot order) — cache
    /// introspection for window monitoring and the long-context smoke.
    pub fn states(&self) -> &[DecodeState] {
        &self.states
    }

    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.streams.is_empty()
    }

    /// Admit queued requests into free batch slots. All prompts admitted
    /// in one call prefill as ONE padded batch through the Full-arm
    /// threaded attention (`prefill_batch`), so a bursty arrival of B
    /// prompts pays a single threaded sweep over the weights instead of
    /// B separate passes — followed by one (B, V) logits matmul. With a
    /// `max_seq` window, prompts longer than the window fall back to the
    /// per-request windowed prefill (window-sized chunks with paged
    /// eviction between them, shared with windowed `DecodeSession`s), so
    /// one long prompt can't blow past the memory bound at admission;
    /// prompts within the window still pack. Length-skewed bursts are
    /// peeled to a ≥50% fill ratio so the padded pass never does more
    /// than 2x the useful prefill work.
    ///
    /// `step` calls this automatically; it is public so callers (and the
    /// serve benches) can pay the prefill cost eagerly, separate from
    /// the decode loop.
    pub fn admit(&mut self) {
        // Shortest-first admission with aging: sort the WHOLE pending
        // queue before slots are filled, so the ≥50%-fill peeling below
        // sees length-sorted candidates and mixed-length bursts pack
        // tightly instead of pairing a long straggler with whatever
        // arrived next. The sort is stable — equal-length requests keep
        // submission order. Under sustained skew pure shortest-first
        // starves: a long prompt loses to every fresh short arrival,
        // forever. So any request passed over for `max_wait_rounds`
        // admit rounds is AGED: aged requests sort ahead of every fresh
        // one, FIFO among themselves (by id = submission order), which
        // bounds queue wait at O(max_wait_rounds) regardless of what
        // keeps arriving.
        let max_wait = self.cfg.max_wait_rounds;
        self.queue.make_contiguous().sort_by_key(|q| {
            if q.waited >= max_wait {
                (false, q.id.0 as usize) // aged: FIFO, ahead of fresh
            } else {
                (true, q.req.prompt.len()) // fresh: shortest-first
            }
        });
        self.admit_sorted();
        // everything still queued was passed over this round
        for q in self.queue.iter_mut() {
            q.waited += 1;
        }
    }

    /// The slot-filling half of [`Engine::admit`], consuming the queue
    /// in its already-sorted order.
    fn admit_sorted(&mut self) {
        loop {
            let free = self.cfg.max_batch - self.streams.len();
            let mut batch: Vec<(RequestId, Request)> = Vec::with_capacity(free);
            while batch.len() < free {
                let Some(q) = self.queue.pop_front() else { break };
                batch.push((q.id, q.req));
            }
            if batch.is_empty() {
                return;
            }
            // prompts the one-shot packed pass can take whole: window
            // unset, or prompt within the window (a single chunk of the
            // windowed prefill — identical math, no eviction mid-prompt)
            let mut packable: Vec<usize> = (0..batch.len())
                .filter(|&i| match self.cfg.max_seq {
                    None => true,
                    Some(w) => batch[i].1.prompt.len() <= w,
                })
                .collect();
            // Bound padding waste: the packed pass costs n·max(len), so
            // one long prompt among short ones would make the burst pay
            // mostly padding. Peel the longest prompts off to the
            // per-request path until the set packs at least half full
            // (Σ len ≥ n·max/2); skew within the set is then ≤ 2x.
            packable.sort_by_key(|&i| batch[i].1.prompt.len());
            while packable.len() >= 2 {
                let max = batch[*packable.last().unwrap()].1.prompt.len();
                let sum: usize = packable.iter().map(|&i| batch[i].1.prompt.len()).sum();
                if sum * 2 >= packable.len() * max {
                    break;
                }
                packable.pop();
            }
            let mut states: Vec<Option<DecodeState>> = (0..batch.len()).map(|_| None).collect();
            let mut logits: Vec<Option<Vec<f32>>> = vec![None; batch.len()];
            if packable.len() >= 2 {
                let mut sts: Vec<DecodeState> =
                    packable.iter().map(|_| self.model.decode_state()).collect();
                let prompts: Vec<&[u32]> =
                    packable.iter().map(|&i| batch[i].1.prompt.as_slice()).collect();
                let h = self.model.prefill_batch(&mut sts, &prompts);
                let lg = self.model.logits(&h);
                for (j, (&i, st)) in packable.iter().zip(sts).enumerate() {
                    states[i] = Some(st);
                    logits[i] = Some(lg.row(j).to_vec());
                }
            }
            for (i, (id, req)) in batch.into_iter().enumerate() {
                let (state, lg) = match (states[i].take(), logits[i].take()) {
                    (Some(s), Some(l)) => (s, l),
                    _ => {
                        // singleton admission or a prompt longer than the
                        // window: the per-request path
                        let mut state = self.model.decode_state();
                        let h = match self.cfg.max_seq {
                            Some(w) => crate::model::decode::prefill_windowed(
                                self.model,
                                &mut state,
                                0,
                                &req.prompt,
                                w,
                            ),
                            None => self.model.prefill_append(&mut state, 0, &req.prompt),
                        };
                        (state, self.model.logits_row(&h))
                    }
                };
                if req.max_new_tokens == 0 {
                    self.finished.push(Completion {
                        id,
                        prompt: req.prompt,
                        tokens: Vec::new(),
                        last_logits: lg,
                    });
                    continue;
                }
                self.streams.push(Stream {
                    id,
                    last_logits: lg,
                    out: Vec::with_capacity(req.max_new_tokens),
                    max_new: req.max_new_tokens,
                    rng: Rng::new(req.sampling.seed),
                    sampling: req.sampling,
                    prompt: req.prompt,
                });
                self.states.push(state);
            }
            // zero-budget completions freed their slots: admit again
            if self.streams.len() >= self.cfg.max_batch || self.queue.is_empty() {
                return;
            }
        }
    }

    /// One continuous-batching step: admit queued requests, sample one
    /// token per active stream, run all B streams through ONE batched
    /// forward (a single (B, d) matmul per linear plus one (B, V) logits
    /// matmul), then retire finished streams so their slots refill next
    /// step. Returns the number of tokens generated.
    pub fn step(&mut self) -> usize {
        if self.spec.is_some() {
            return self.spec_step();
        }
        self.admit();
        if self.streams.is_empty() {
            return 0;
        }
        let mut toks: Vec<u32> = Vec::with_capacity(self.streams.len());
        for s in self.streams.iter_mut() {
            let tok = sample_token_with(
                &s.last_logits,
                &s.sampling,
                &mut s.rng,
                &mut self.sample_scratch,
            );
            if let Some(cb) = self.on_token.as_mut() {
                cb(s.id, tok);
            }
            toks.push(tok);
        }
        let poss: Vec<usize> = self.streams.iter().map(|s| s.pos()).collect();
        let h = self.model.decode_step_batch(&mut self.states, &poss, &toks);
        let logits = self.model.logits(&h);
        for (i, s) in self.streams.iter_mut().enumerate() {
            s.out.push(toks[i]);
            s.last_logits = logits.row(i).to_vec();
            if let Some(w) = self.cfg.max_seq {
                self.states[i].enforce_window(w);
            }
        }
        // retire back-to-front so swap_remove leaves earlier indices
        // valid, then flip so same-step completions land in slot order
        let mut retired = Vec::new();
        for i in (0..self.streams.len()).rev() {
            if self.streams[i].out.len() >= self.streams[i].max_new {
                let s = self.streams.swap_remove(i);
                self.states.swap_remove(i);
                retired.push(Completion {
                    id: s.id,
                    prompt: s.prompt,
                    tokens: s.out,
                    last_logits: s.last_logits,
                });
            }
        }
        retired.reverse();
        self.finished.extend(retired);
        toks.len()
    }

    /// One speculative continuous-batching step: admit queued requests
    /// (the target still prefills through the packed path), lazily
    /// prefill the draft for newly admitted streams, then run ONE
    /// propose/verify round per active stream — each emits between 1
    /// and `k + 1` tokens. Returns the number of tokens emitted.
    fn spec_step(&mut self) -> usize {
        let (draft, k) = self.spec.expect("spec_step outside speculative mode");
        self.admit();
        // new streams: prefill the draft and lift the target's prompt
        // argmax into the pending slot (exactly the token the plain
        // engine would sample first)
        for i in self.spec_cursors.len()..self.streams.len() {
            let s = &self.streams[i];
            let mut d_state = draft.decode_state();
            speculative::feed(draft, &mut d_state, 0, &s.prompt, self.cfg.max_seq);
            self.spec_cursors.push(speculative::SpecCursor {
                d_state,
                d_pos: s.prompt.len(),
                pending: crate::model::decode::argmax(&s.last_logits) as u32,
            });
        }
        if self.streams.is_empty() {
            return 0;
        }
        let mut total = 0usize;
        for i in 0..self.streams.len() {
            let budget = self.streams[i].max_new - self.streams[i].out.len();
            let k_eff = k.min(budget - 1);
            let history: Vec<u32> = {
                let s = &self.streams[i];
                s.prompt.iter().chain(s.out.iter()).copied().collect()
            };
            let o = speculative::spec_round(
                self.model,
                draft,
                self.cfg.max_seq,
                k_eff,
                &mut self.states[i],
                &mut self.spec_cursors[i],
                &history,
            );
            self.spec_stats.absorb(&o);
            let s = &mut self.streams[i];
            if let Some(cb) = self.on_token.as_mut() {
                for &t in &o.emitted {
                    cb(s.id, t);
                }
            }
            s.out.extend_from_slice(&o.emitted);
            s.last_logits = o.last_logits;
            total += o.emitted.len();
        }
        // retire exactly like the plain step, keeping cursors in sync
        let mut retired = Vec::new();
        for i in (0..self.streams.len()).rev() {
            if self.streams[i].out.len() >= self.streams[i].max_new {
                let s = self.streams.swap_remove(i);
                self.states.swap_remove(i);
                self.spec_cursors.swap_remove(i);
                retired.push(Completion {
                    id: s.id,
                    prompt: s.prompt,
                    tokens: s.out,
                    last_logits: s.last_logits,
                });
            }
        }
        retired.reverse();
        self.finished.extend(retired);
        total
    }

    /// Drive until every queued and active request completes; returns
    /// the total number of generated tokens.
    pub fn run(&mut self) -> usize {
        let mut total = 0;
        while self.has_work() {
            total += self.step();
        }
        total
    }

    /// Drain completed requests: ordered by completion step, batch-slot
    /// order within a step. That is NOT submission order under mixed
    /// workloads — match results to requests by [`Completion::id`].
    pub fn take_finished(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.finished)
    }
}

// ---------------------------------------------------------------------------
// batched scoring (the zero-shot eval path)
// ---------------------------------------------------------------------------

/// Sum log-prob of every candidate continuation after `context`, scored
/// as ONE batch: the context is prefilled once through the threaded
/// Full-attention arm, the state is cloned per candidate, and each step
/// runs all still-live candidates through a single batched forward.
/// Candidates may have different lengths — finished ones drop out of the
/// batch. An empty candidate scores 0.0 (the `choice_accuracy`
/// convention). Results match per-candidate
/// [`DecodeSession::continuation_logprob`](crate::model::DecodeSession)
/// runs to within 1e-5 (bit-for-bit in practice: the batched arms run
/// the same per-row kernels in the same order).
pub fn score_continuations(
    model: &dyn LanguageModel,
    context: &[u32],
    candidates: &[Vec<u32>],
) -> Vec<f64> {
    assert!(!context.is_empty(), "scoring needs a non-empty context");
    let mut base = model.decode_state();
    let h = model.prefill_append(&mut base, 0, context);
    let base_logits = model.logits_row(&h);
    let mut lps = vec![0.0f64; candidates.len()];
    for (i, cand) in candidates.iter().enumerate() {
        if let Some(&first) = cand.first() {
            lps[i] = log_softmax_at(&base_logits, first as usize);
        }
    }
    // streams only for candidates that still need decode steps
    let mut who: Vec<usize> = (0..candidates.len()).filter(|&i| candidates[i].len() >= 2).collect();
    let mut states: Vec<DecodeState> = who.iter().map(|_| base.clone()).collect();
    let mut t = 0usize;
    while !who.is_empty() {
        let toks: Vec<u32> = who.iter().map(|&i| candidates[i][t]).collect();
        let poss: Vec<usize> = vec![context.len() + t; who.len()];
        let h = model.decode_step_batch(&mut states, &poss, &toks);
        let logits: Mat = model.logits(&h);
        for (j, &i) in who.iter().enumerate() {
            lps[i] += log_softmax_at(logits.row(j), candidates[i][t + 1] as usize);
        }
        t += 1;
        for j in (0..who.len()).rev() {
            if candidates[who[j]].len() <= t + 1 {
                who.swap_remove(j);
                states.swap_remove(j);
            }
        }
    }
    lps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{
        DecodeSession, Mamba, MambaConfig, Transformer, TransformerConfig,
    };

    fn tiny_transformer(seed: u64) -> Transformer {
        Transformer::init(
            TransformerConfig {
                vocab: 37,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 24,
                max_seq: 64,
            },
            &mut Rng::new(seed),
        )
    }

    fn tiny_mamba(seed: u64) -> Mamba {
        Mamba::init(
            MambaConfig { vocab: 37, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 64 },
            &mut Rng::new(seed),
        )
    }

    fn prompt(len: usize, salt: usize) -> Vec<u32> {
        (0..len).map(|i| ((i * 5 + salt * 3) % 37) as u32).collect()
    }

    #[test]
    fn greedy_engine_matches_sessions_both_archs() {
        for (name, model) in [
            ("microllama", Box::new(tiny_transformer(1)) as Box<dyn LanguageModel>),
            ("micromamba", Box::new(tiny_mamba(2)) as Box<dyn LanguageModel>),
        ] {
            let mut eng = Engine::new(model.as_ref(), EngineConfig::default());
            let ids: Vec<RequestId> = (0..3)
                .map(|i| eng.submit(Request::greedy(prompt(4 + 3 * i, i), 5 + i)))
                .collect();
            eng.run();
            assert!(!eng.has_work());
            let mut done = eng.take_finished();
            done.sort_by_key(|c| c.id);
            assert_eq!(done.len(), 3, "{name}");
            for (i, (c, id)) in done.iter().zip(&ids).enumerate() {
                assert_eq!(c.id, *id, "{name}");
                let mut s = DecodeSession::new(model.as_ref());
                s.prefill(&prompt(4 + 3 * i, i));
                let expect = s.generate(5 + i);
                assert_eq!(c.tokens, expect, "{name} stream {i}");
                let d = c
                    .last_logits
                    .iter()
                    .zip(s.last_logits())
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(d < 1e-5, "{name} stream {i}: logits diverge by {d}");
            }
        }
    }

    #[test]
    fn continuous_batching_refills_slots_from_queue() {
        let m = tiny_transformer(3);
        // 5 requests through 2 slots: every completion must still match
        // an isolated session despite mid-flight admissions
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 2, ..Default::default() });
        for i in 0..5usize {
            eng.submit(Request::greedy(prompt(3 + i, i), 3 + (i % 3)));
        }
        assert_eq!(eng.queued(), 5);
        eng.step();
        assert_eq!(eng.active(), 2, "only max_batch streams admitted");
        assert_eq!(eng.queued(), 3);
        eng.run();
        let mut done = eng.take_finished();
        assert_eq!(done.len(), 5);
        done.sort_by_key(|c| c.id);
        for (i, c) in done.iter().enumerate() {
            let mut s = DecodeSession::new(&m);
            s.prefill(&prompt(3 + i, i));
            assert_eq!(c.tokens, s.generate(3 + (i % 3)), "request {i}");
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic_and_seed_sensitive() {
        let m = tiny_transformer(4);
        let gen = |seed: u64| -> Vec<u32> {
            let mut eng = Engine::new(&m, EngineConfig::default());
            eng.submit(Request {
                prompt: prompt(6, 1),
                max_new_tokens: 12,
                sampling: SamplingParams::temperature(1.5, seed),
            });
            eng.run();
            eng.take_finished().remove(0).tokens
        };
        assert_eq!(gen(7), gen(7), "same seed must reproduce the stream");
        assert_ne!(gen(7), gen(8), "different seeds should diverge at T=1.5");
        // batch composition must not perturb a seeded stream
        let solo = gen(7);
        let mut eng = Engine::new(&m, EngineConfig::default());
        eng.submit(Request {
            prompt: prompt(6, 1),
            max_new_tokens: 12,
            sampling: SamplingParams::temperature(1.5, 7),
        });
        eng.submit(Request::greedy(prompt(9, 2), 12));
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done[0].tokens, solo, "batch mate changed a seeded stream");
    }

    #[test]
    fn top_k_one_is_greedy_and_topk_restricts_support() {
        let m = tiny_transformer(5);
        let run = |sampling: SamplingParams| -> Vec<u32> {
            let mut eng = Engine::new(&m, EngineConfig::default());
            eng.submit(Request { prompt: prompt(5, 3), max_new_tokens: 8, sampling });
            eng.run();
            eng.take_finished().remove(0).tokens
        };
        let greedy = run(SamplingParams::greedy());
        assert_eq!(run(SamplingParams::top_k(1, 0.8, 11)), greedy);
        // top-k sampling only ever emits tokens inside the current top-k
        let logits: Vec<f32> = vec![0.1, 2.0, -1.0, 1.5, 0.3];
        let mut rng = Rng::new(12);
        for _ in 0..200 {
            let t = sample_token(&logits, &SamplingParams::top_k(2, 1.0, 0), &mut rng);
            assert!(t == 1 || t == 3, "token {t} outside top-2");
        }
    }

    #[test]
    fn sliding_window_bounds_cache_and_matches_unbounded_when_short() {
        let m = tiny_transformer(6);
        let p = prompt(10, 4);
        // window larger than prompt+gen: identical to unbounded
        let run = |max_seq: Option<usize>| -> Completion {
            let mut eng = Engine::new(&m, EngineConfig { max_batch: 4, max_seq, ..Default::default() });
            eng.submit(Request::greedy(p.clone(), 6));
            eng.run();
            eng.take_finished().remove(0)
        };
        let unbounded = run(None);
        let wide = run(Some(64));
        assert_eq!(unbounded.tokens, wide.tokens);
        assert_eq!(unbounded.last_logits, wide.last_logits);
        // tight window: still decodes, and the cache stays bounded
        let w = 8;
        let mut eng =
            Engine::new(&m, EngineConfig { max_batch: 4, max_seq: Some(w), ..Default::default() });
        eng.submit(Request::greedy(p.clone(), 12));
        while eng.has_work() {
            eng.step();
            for st in &eng.states {
                assert!(st.cached_len().unwrap_or(0) <= w, "window exceeded");
            }
        }
        let c = eng.take_finished().remove(0);
        assert_eq!(c.tokens.len(), 12);
        assert!(c.tokens.iter().all(|&t| (t as usize) < 37));
        // windowed DecodeSession agrees with the windowed engine
        let mut s = DecodeSession::with_window(&m, w);
        s.prefill(&p);
        assert_eq!(s.generate(12), c.tokens);
    }

    #[test]
    fn zero_budget_request_completes_with_prompt_logits() {
        let m = tiny_mamba(7);
        let mut eng = Engine::new(&m, EngineConfig::default());
        let p = prompt(5, 5);
        eng.submit(Request::greedy(p.clone(), 0));
        eng.run();
        let done = eng.take_finished();
        assert_eq!(done.len(), 1);
        assert!(done[0].tokens.is_empty());
        let mut s = DecodeSession::new(&m);
        s.prefill(&p);
        assert_eq!(done[0].last_logits, s.last_logits());
    }

    #[test]
    fn score_continuations_matches_session_forks() {
        for model in [
            Box::new(tiny_transformer(8)) as Box<dyn LanguageModel>,
            Box::new(tiny_mamba(9)) as Box<dyn LanguageModel>,
        ] {
            let ctx = prompt(7, 6);
            let cands: Vec<Vec<u32>> =
                vec![vec![1, 2, 3], vec![4], vec![], vec![5, 6], vec![7, 8, 9, 10]];
            let batched = score_continuations(model.as_ref(), &ctx, &cands);
            let mut base = DecodeSession::new(model.as_ref());
            base.prefill(&ctx);
            for (i, cand) in cands.iter().enumerate() {
                let lp = base.fork().continuation_logprob(cand);
                assert!(
                    (batched[i] - lp).abs() < 1e-5,
                    "{} cand {i}: {} vs {lp}",
                    model.arch(),
                    batched[i]
                );
            }
        }
    }

    #[test]
    fn skewed_burst_peels_long_prompt_and_still_matches_sessions() {
        // One long prompt among shorts would make the padded pack mostly
        // padding; admit peels it to the per-request path. Either way,
        // every stream must reproduce its independent session.
        let m = tiny_transformer(13);
        let lens = [2usize, 2, 2, 40];
        let mut eng = Engine::new(&m, EngineConfig::default());
        for (i, &len) in lens.iter().enumerate() {
            eng.submit(Request::greedy(prompt(len, i), 5));
        }
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), lens.len());
        for (i, &len) in lens.iter().enumerate() {
            let mut s = DecodeSession::new(&m);
            s.prefill(&prompt(len, i));
            assert_eq!(done[i].tokens, s.generate(5), "stream {i} (len {len})");
        }
    }

    #[test]
    fn skewed_burst_admission_sorts_queue_shortest_first() {
        // More pending requests than slots: the whole queue is sorted by
        // prompt length before admission, so the three SHORTEST prompts
        // go first (packing tightly) and the long straggler waits —
        // regardless of arrival order. Results still match solo
        // sessions per id.
        let m = tiny_transformer(12);
        let lens = [40usize, 2, 3, 2, 5];
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 3, ..Default::default() });
        let mut ids = Vec::new();
        for (i, &len) in lens.iter().enumerate() {
            ids.push(eng.submit(Request::greedy(prompt(len, i), 4)));
        }
        eng.step();
        assert_eq!(eng.active(), 3, "three slots filled");
        assert_eq!(eng.queued(), 2, "len-40 and len-5 wait behind the shorts");
        // the admitted streams are exactly the three shortest prompts
        let active_lens: Vec<usize> =
            eng.streams.iter().map(|s| s.prompt.len()).collect();
        assert!(active_lens.iter().all(|&l| l <= 3), "active: {active_lens:?}");
        // stable sort: the two len-2 prompts keep submission order
        assert_eq!(eng.streams[0].id, ids[1]);
        assert_eq!(eng.streams[1].id, ids[3]);
        assert_eq!(eng.streams[2].id, ids[2]);
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), lens.len());
        for (i, &len) in lens.iter().enumerate() {
            let mut s = DecodeSession::new(&m);
            s.prefill(&prompt(len, i));
            assert_eq!(done[i].tokens, s.generate(4), "request {i} (len {len})");
        }
    }

    #[test]
    fn aged_request_jumps_shortest_first_admission() {
        // A perpetual stream of fresh short prompts against one slot:
        // pure shortest-first would pass the long prompt over on every
        // admit round, forever. Aging bounds its wait.
        let m = tiny_transformer(14);
        let drive = |max_wait_rounds: usize, steps: usize| -> (bool, Vec<Completion>) {
            let mut eng = Engine::new(
                &m,
                EngineConfig { max_batch: 1, max_seq: None, max_wait_rounds },
            );
            let long_id = eng.submit(Request::greedy(prompt(20, 0), 2));
            let mut done = Vec::new();
            for salt in 1..=steps {
                // a fresh, shorter rival arrives before every step
                eng.submit(Request::greedy(prompt(2, salt), 2));
                eng.step();
                done.extend(eng.take_finished());
                if done.iter().any(|c| c.id == long_id) {
                    return (true, done);
                }
            }
            (false, done)
        };
        // starvation really happens without the bound...
        let (finished, _) = drive(usize::MAX, 24);
        assert!(!finished, "long prompt should starve under pure shortest-first");
        // ...and aging ends it within ~max_wait_rounds + one stream span
        let (finished, done) = drive(3, 24);
        assert!(finished, "aged long prompt must admit despite fresh short arrivals");
        // the aged stream still reproduces its independent session
        let long = done.iter().find(|c| c.prompt.len() == 20).unwrap();
        let mut s = DecodeSession::new(&m);
        s.prefill(&prompt(20, 0));
        assert_eq!(long.tokens, s.generate(2));
        // max_wait_rounds = 0 is documented as pure FIFO: the long
        // prompt (submitted first) admits on the very first step
        let (finished, _) = drive(0, 2);
        assert!(finished, "max_wait_rounds = 0 must admit in submission order");
    }

    #[test]
    fn on_token_streams_every_token_in_order() {
        use std::cell::RefCell;
        use std::collections::BTreeMap;
        use std::rc::Rc;

        let m = tiny_transformer(11);
        let streamed: Rc<RefCell<BTreeMap<RequestId, Vec<u32>>>> =
            Rc::new(RefCell::new(BTreeMap::new()));
        let sink = streamed.clone();
        // 3 requests through 2 slots: tokens must stream for refilled
        // slots too, in generation order per request
        let mut eng = Engine::new(&m, EngineConfig { max_batch: 2, ..Default::default() });
        eng.set_on_token(move |id, tok| sink.borrow_mut().entry(id).or_default().push(tok));
        for i in 0..3usize {
            eng.submit(Request::greedy(prompt(4 + i, i), 3 + i));
        }
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3);
        let streamed = streamed.borrow();
        for c in &done {
            assert_eq!(
                streamed.get(&c.id).map(|v| v.as_slice()),
                Some(c.tokens.as_slice()),
                "streamed tokens must equal the completion for {:?}",
                c.id
            );
        }
    }

    #[test]
    #[should_panic(expected = "non-empty prompt")]
    fn empty_prompt_rejected() {
        let m = tiny_transformer(10);
        Engine::new(&m, EngineConfig::default())
            .submit(Request::greedy(vec![], 4));
    }
}
