//! Deterministic fault injection for the serving engine.
//!
//! Resilience paths are exactly the code a happy-path test never runs:
//! NaN quarantine needs numerically poisoned weights, preemption needs
//! a precisely-timed memory squeeze. A [`FaultPlan`] scripts those
//! conditions at exact, reproducible points instead — poison a chosen
//! stream's logits once it has emitted `n` tokens, force-preempt a
//! stream at a chosen point, clamp the live-page budget from a chosen
//! engine step onward.
//!
//! Two properties make the harness trustworthy:
//!
//! - **No test-only control flow.** Every injection is data the engine
//!   consults at its normal decision points (NaN lands in
//!   `last_logits` upstream of the quarantine scan; a forced preempt
//!   calls the same reclamation/re-queue path the budget enforcer
//!   does), so a faulted run exercises exactly the code a real fault
//!   would.
//! - **Blast-radius isolation is testable.** Streams the plan never
//!   touches must produce bit-identical tokens to a fault-free run —
//!   pinned by `resilience_fault_grid_spares_untouched_streams` in the
//!   integration suite across both model families and all weight
//!   layouts.
//!
//! Plans are deterministic by construction (plain data, no clocks);
//! [`FaultPlan::seeded`] derives a random-looking but reproducible plan
//! from a seed for grid/soak tests.

use super::RequestId;
use crate::util::Rng;

/// A scripted set of faults, installed via
/// [`Engine::set_fault_plan`](super::Engine::set_fault_plan).
/// Builder-style:
///
/// ```text
/// let plan = FaultPlan::new()
///     .nan_logits(id_b, 2)      // poison stream b after 2 tokens
///     .force_preempt(id_c, 1)   // evict + re-queue c after 1 token
///     .clamp_budget(4, 8);      // at most 8 live pages from step 4 on
/// engine.set_fault_plan(plan);
/// ```
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// One-shot (stream, emitted-count) triggers: fire at the first
    /// decision point where the stream has emitted >= n tokens.
    nan_at: Vec<(RequestId, usize)>,
    preempt_at: Vec<(RequestId, usize)>,
    /// (from_step, pages) clamps: from engine step `from_step` (0-based)
    /// onward the live-page budget is at most `pages`. The tightest
    /// active clamp wins, and composes with `EngineConfig::max_kv_pages`
    /// (minimum of the two).
    clamps: Vec<(usize, usize)>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Poison `id`'s logits to all-NaN once it has emitted
    /// `after_tokens` tokens. The engine's quarantine path must then
    /// retire exactly that stream with
    /// `FinishReason::Error(NonFiniteLogits)`. Fires once. In
    /// speculative mode the trigger is checked on round boundaries, so
    /// the stream may carry a few tokens past `after_tokens` before the
    /// quarantine lands — deterministically so.
    pub fn nan_logits(mut self, id: RequestId, after_tokens: usize) -> FaultPlan {
        self.nan_at.push((id, after_tokens));
        self
    }

    /// Force a recompute preemption of `id` once it has emitted
    /// `after_tokens` tokens, regardless of the real page budget — same
    /// evict/re-queue path, chosen timing. Fires once; streams retiring
    /// that same step are exempt (nothing left to preempt).
    pub fn force_preempt(mut self, id: RequestId, after_tokens: usize) -> FaultPlan {
        self.preempt_at.push((id, after_tokens));
        self
    }

    /// Clamp the engine's live K/V page budget to `pages` from engine
    /// step `from_step` (0-based) onward — simulated memory pressure
    /// arriving mid-run. Admission and the decode-growth enforcer both
    /// honor it.
    pub fn clamp_budget(mut self, from_step: usize, pages: usize) -> FaultPlan {
        self.clamps.push((from_step, pages));
        self
    }

    /// A reproducible pseudo-random plan: `nans` NaN injections and
    /// `preempts` forced preemptions scattered over `ids` at trigger
    /// points below `horizon` tokens. A pure function of its arguments
    /// — the same seed always builds the same plan, so soak tests can
    /// replay any failure.
    pub fn seeded(
        seed: u64,
        ids: &[RequestId],
        horizon: usize,
        nans: usize,
        preempts: usize,
    ) -> FaultPlan {
        let mut plan = FaultPlan::new();
        if ids.is_empty() || horizon == 0 {
            return plan;
        }
        let mut rng = Rng::new(seed ^ 0xFA17_1417_0000_0000);
        for _ in 0..nans {
            let id = ids[rng.below(ids.len())];
            plan = plan.nan_logits(id, rng.below(horizon));
        }
        for _ in 0..preempts {
            let id = ids[rng.below(ids.len())];
            plan = plan.force_preempt(id, rng.below(horizon));
        }
        plan
    }

    /// True when nothing is scheduled (the default plan: a no-op).
    pub fn is_empty(&self) -> bool {
        self.nan_at.is_empty() && self.preempt_at.is_empty() && self.clamps.is_empty()
    }

    /// Streams with at least one NaN or preempt trigger — the set whose
    /// outputs a blast-radius test must NOT pin against the fault-free
    /// run.
    pub fn touched(&self) -> Vec<RequestId> {
        let mut ids: Vec<RequestId> = self
            .nan_at
            .iter()
            .chain(self.preempt_at.iter())
            .map(|&(id, _)| id)
            .collect();
        ids.sort();
        ids.dedup();
        ids
    }

    pub(crate) fn take_nan(&mut self, id: RequestId, emitted: usize) -> bool {
        take(&mut self.nan_at, id, emitted)
    }

    pub(crate) fn take_preempt(&mut self, id: RequestId, emitted: usize) -> bool {
        take(&mut self.preempt_at, id, emitted)
    }

    pub(crate) fn budget_clamp(&self, step: usize) -> Option<usize> {
        self.clamps.iter().filter(|&&(s, _)| s <= step).map(|&(_, p)| p).min()
    }
}

/// One-shot trigger check: removing the entry on fire is what makes
/// ">= n emitted" fire exactly once even when the count is re-checked
/// every step (or jumps past `n` in one speculative round).
fn take(list: &mut Vec<(RequestId, usize)>, id: RequestId, emitted: usize) -> bool {
    match list.iter().position(|&(i, n)| i == id && emitted >= n) {
        Some(p) => {
            list.remove(p);
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triggers_fire_once_at_threshold() {
        let a = RequestId(1);
        let b = RequestId(2);
        let mut plan = FaultPlan::new().nan_logits(a, 3).force_preempt(b, 0);
        assert!(!plan.is_empty());
        assert_eq!(plan.touched(), vec![a, b]);
        // below threshold: nothing fires
        assert!(!plan.take_nan(a, 2));
        assert!(!plan.take_nan(b, 10), "wrong stream must not fire");
        // at/after threshold: fires exactly once
        assert!(plan.take_nan(a, 3));
        assert!(!plan.take_nan(a, 4), "one-shot trigger fired twice");
        assert!(plan.take_preempt(b, 0));
        assert!(!plan.take_preempt(b, 5));
        assert!(plan.is_empty());
    }

    #[test]
    fn budget_clamp_applies_from_step_and_tightest_wins() {
        let plan = FaultPlan::new().clamp_budget(3, 10).clamp_budget(6, 4);
        assert_eq!(plan.budget_clamp(0), None);
        assert_eq!(plan.budget_clamp(2), None);
        assert_eq!(plan.budget_clamp(3), Some(10));
        assert_eq!(plan.budget_clamp(5), Some(10));
        assert_eq!(plan.budget_clamp(6), Some(4));
        assert_eq!(plan.budget_clamp(100), Some(4));
    }

    #[test]
    fn seeded_plans_are_reproducible_and_seed_sensitive() {
        let ids: Vec<RequestId> = (0..4).map(RequestId).collect();
        let p1 = FaultPlan::seeded(7, &ids, 10, 3, 2);
        let p2 = FaultPlan::seeded(7, &ids, 10, 3, 2);
        assert_eq!(format!("{p1:?}"), format!("{p2:?}"), "same seed, same plan");
        assert_eq!(p1.nan_at.len(), 3);
        assert_eq!(p1.preempt_at.len(), 2);
        let p3 = FaultPlan::seeded(8, &ids, 10, 3, 2);
        assert_ne!(format!("{p1:?}"), format!("{p3:?}"), "seeds must differ");
        // degenerate inputs build an empty (no-op) plan
        assert!(FaultPlan::seeded(7, &[], 10, 3, 2).is_empty());
        assert!(FaultPlan::seeded(7, &ids, 0, 3, 2).is_empty());
    }
}
