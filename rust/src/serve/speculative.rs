//! Self-speculative decoding: the pruned model proposes, the dense model
//! verifies — in one batched forward.
//!
//! The pruning pipeline leaves us holding *both* the dense model and a
//! pruned variant of it from the same run. That pruned variant is a
//! uniquely cheap **draft**: it needed no separate training, it shares
//! the tokenizer/vocab by construction, and its greedy continuations
//! agree with the dense model often enough to propose with. A
//! [`SpecSession`] turns that agreement into a serving speedup:
//!
//! 1. *Propose*: the draft greedily decodes `k` tokens one step at a
//!    time (cheap — it runs from the packed sparse layouts).
//! 2. *Verify*: the target feeds the pending token plus all `k`
//!    proposals through ONE batched incremental forward
//!    ([`LanguageModel::decode_append_full`]) — `k + 1` positions for
//!    one sweep over the dense weights — and takes its own argmax at
//!    every position.
//! 3. *Accept*: the longest prefix of proposals matching the target's
//!    argmaxes is emitted, plus the target's own token at the first
//!    divergence (or a bonus token when everything matched). Overshot
//!    target K/V rolls back through the paged tail cursor
//!    ([`DecodeState::truncate_to`], O(1), pages recycled); mamba's
//!    irreversible recurrent state rolls back by restoring a pre-round
//!    clone snapshot (the `fork` idiom) and re-scanning the accepted
//!    prefix.
//!
//! **Greedy verification is losslessly exact**: every emitted token is a
//! target argmax over logits computed at the same absolute position with
//! the same per-row kernels as plain decoding (the incremental arms
//! append the whole chunk's K/V first, then attend row `i` against
//! exactly `pos + i + 1` rows), so the output stream is bit-identical
//! token-for-token to dense [`DecodeSession::generate`] — pinned across
//! both families, all draft layouts and every `k` by
//! `speculative_generate_matches_plain_greedy` in the integration suite.
//! One carve-out: a sliding-window (`max_seq`) *transformer* target
//! evicts between every token, so a batched append would let mid-batch
//! queries attend rows plain decoding had already evicted; windowed
//! transformer targets therefore verify token-by-token (still lossless,
//! no batching win), while mamba targets batch under any window (its
//! state never evicts).
//!
//! **Resilience**: a draft whose logits go non-finite (pruning can
//! overflow) is marked dead and the stream falls back to plain target
//! decode — output unaffected, since verification never trusted the
//! draft ([`SpecSession::draft_fell_back`], engine stat
//! `draft_fallbacks`). A non-finite TARGET verify row poisons the
//! round: the already-verified prefix is emitted and the engine
//! quarantines the stream with a typed error (a bare session panics).
//!
//! Break-even model (PERF.md iteration 8): with acceptance rate `a` per
//! proposal, a round emits `1 + a·k` tokens (expected) for `1` target
//! sweep plus `k` draft steps, so
//! `speedup ≈ (accepted/round) / (k · cost_draft/cost_target + 1)` —
//! speculation pays exactly when the draft is cheap (high sparsity)
//! and agreeable (modest sparsity). [`spec_serve_report`] measures both
//! sides end-to-end.
//!
//! [`LanguageModel::decode_append_full`]: crate::model::LanguageModel::decode_append_full
//! [`DecodeState::truncate_to`]: crate::model::DecodeState::truncate_to
//! [`DecodeSession::generate`]: crate::model::DecodeSession::generate

use crate::model::decode::{argmax, prefill_windowed};
use crate::model::{DecodeState, LanguageModel};
use crate::util::Timer;

use super::{Engine, EngineConfig, Request};

/// Acceptance accounting across rounds (one session or a whole engine).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecStats {
    /// Verification rounds run.
    pub rounds: usize,
    /// Draft tokens proposed.
    pub proposed: usize,
    /// Draft tokens accepted by the target.
    pub accepted: usize,
    /// Tokens emitted (accepted drafts + one target token per round).
    pub emitted: usize,
}

impl SpecStats {
    /// Fraction of proposed draft tokens the target accepted.
    pub fn acceptance_rate(&self) -> f64 {
        self.accepted as f64 / self.proposed.max(1) as f64
    }

    /// Mean tokens emitted per verification round (1.0 = no win).
    pub fn tokens_per_round(&self) -> f64 {
        self.emitted as f64 / self.rounds.max(1) as f64
    }

    pub(crate) fn absorb(&mut self, o: &RoundOutcome) {
        self.rounds += 1;
        self.proposed += o.proposed;
        self.accepted += o.accepted;
        self.emitted += o.emitted.len();
    }
}

/// Per-stream speculative bookkeeping beyond the target's own decode
/// state: the draft's state/cursor and the pending token (emitted to the
/// caller, not yet fed to either model).
pub(crate) struct SpecCursor {
    pub(crate) d_state: DecodeState,
    /// True tokens the draft has consumed (a prefix of the history —
    /// the draft may lag after a rollback and resyncs lazily).
    pub(crate) d_pos: usize,
    /// Next output token: a target argmax, determined but not yet fed.
    pub(crate) pending: u32,
    /// The draft produced non-finite logits: aggressively pruned
    /// weights can overflow, and a poisoned proposal stream would never
    /// verify. Once set, rounds skip the draft entirely (pure target
    /// decode — every round emits exactly one token) and its state is
    /// dropped so a page-budgeted engine reclaims the memory. The
    /// TARGET stays correct throughout: verification never trusted the
    /// draft, so emitted tokens are unaffected.
    pub(crate) draft_dead: bool,
}

/// What one propose/verify/accept round produced.
pub(crate) struct RoundOutcome {
    /// Tokens emitted this round: the old pending token, then every
    /// accepted proposal. All are fed to the target by round end.
    pub(crate) emitted: Vec<u32>,
    /// Target logits after the last emitted token (the position that
    /// produced the new pending token).
    pub(crate) last_logits: Vec<f32>,
    pub(crate) proposed: usize,
    pub(crate) accepted: usize,
    /// The TARGET produced a non-finite verify row: everything in
    /// `emitted` was verified by earlier (finite) rows and is good, but
    /// no further token can be derived — the stream must be quarantined
    /// (`last_logits` holds the poisoned row for diagnosis, and
    /// `cursor.pending` is left stale).
    pub(crate) poisoned: bool,
}

/// Append `tokens` the way a (possibly windowed) `DecodeSession` would:
/// windowed feeds chunk-and-evict through the shared `prefill_windowed`,
/// unbounded takes the prefill fast path. Returns the final hidden row.
pub(crate) fn feed(
    model: &dyn LanguageModel,
    state: &mut DecodeState,
    pos0: usize,
    tokens: &[u32],
    window: Option<usize>,
) -> Vec<f32> {
    match window {
        Some(w) => prefill_windowed(model, state, pos0, tokens, w),
        None => model.prefill_append(state, pos0, tokens),
    }
}

/// One speculative round over explicit state (shared by [`SpecSession`]
/// and the engine's per-stream speculative mode).
///
/// `history` is every true token the TARGET has consumed (prompt plus
/// previously emitted tokens); `cursor.pending` sits at absolute
/// position `history.len()` and is fed this round. Emits between 1 and
/// `k_eff + 1` tokens and leaves both models consistent with exactly
/// `history + emitted` consumed, with a fresh pending token in the
/// cursor.
pub(crate) fn spec_round(
    target: &dyn LanguageModel,
    draft: &dyn LanguageModel,
    window: Option<usize>,
    k_eff: usize,
    t_state: &mut DecodeState,
    cursor: &mut SpecCursor,
    history: &[u32],
) -> RoundOutcome {
    let p0 = history.len();
    let pending = cursor.pending;

    // ---- propose: draft decodes up to k_eff tokens greedily, one at a
    // time. A dead draft (non-finite logits, this round or earlier) is
    // skipped entirely: the round degrades to pure target decode.
    let mut proposals: Vec<u32> = Vec::with_capacity(k_eff);
    let mut d_snapshot: Option<(DecodeState, usize)> = None;
    if k_eff > 0 && !cursor.draft_dead {
        // resync: feed every true token the draft hasn't seen yet, ending
        // with the pending one, as a single chunk (chunk boundaries never
        // change the incremental arms' math)
        let mut chunk: Vec<u32> = history[cursor.d_pos..].to_vec();
        chunk.push(pending);
        let h = feed(draft, &mut cursor.d_state, cursor.d_pos, &chunk, window);
        cursor.d_pos = p0 + 1;
        // rollback plan for rejected proposal feeds: a mamba draft folds
        // tokens irreversibly and a windowed draft may evict past the
        // rollback point, so both snapshot here (post-resync: only
        // proposal feeds can be wrong); an unbounded transformer draft
        // rolls back through the paged tail cursor instead.
        if window.is_some() || matches!(cursor.d_state, DecodeState::Mamba(_)) {
            d_snapshot = Some((cursor.d_state.clone(), cursor.d_pos));
        }
        let mut lg = draft.logits_row(&h);
        loop {
            if lg.iter().any(|v| !v.is_finite()) {
                // draft went non-finite mid-propose: keep the (finite)
                // proposals already made, mark the draft dead, and drop
                // its state — it is never consulted again
                cursor.draft_dead = true;
                cursor.d_state = draft.decode_state();
                cursor.d_pos = 0;
                break;
            }
            proposals.push(argmax(&lg) as u32);
            if proposals.len() == k_eff {
                break;
            }
            let last = proposals[proposals.len() - 1];
            let h = feed(draft, &mut cursor.d_state, cursor.d_pos, &[last], window);
            cursor.d_pos += 1;
            lg = draft.logits_row(&h);
        }
    }
    // proposals actually on the table: shorter than k_eff when the
    // draft died mid-propose (or 0 for a dead/skipped draft) — the
    // verify below sizes to kp, never to the requested depth
    let kp = proposals.len();

    // ---- verify: target scores all kp + 1 positions
    let mut batch: Vec<u32> = Vec::with_capacity(kp + 1);
    batch.push(pending);
    batch.extend_from_slice(&proposals);

    let accepted: usize;
    let new_pending: u32;
    let last_logits: Vec<f32>;
    let poisoned: bool;
    let windowed_tf_target =
        window.is_some() && matches!(t_state, DecodeState::Transformer(_));
    if windowed_tf_target {
        // A windowed transformer evicts after EVERY token, so a batched
        // append would attend rows plain decoding had already evicted.
        // Verify token-by-token (append, evict, argmax) — identical op
        // order to the plain windowed session, stopping at the first
        // divergence so nothing overshoots.
        let w = window.expect("windowed arm");
        let mut i = 0usize;
        loop {
            let h = target.decode_append(t_state, p0 + i, &batch[i..i + 1]);
            t_state.enforce_window(w);
            let lg = target.logits_row(&h);
            if lg.iter().any(|v| !v.is_finite()) {
                // rows before this one verified batch[..=i] finite and
                // matching, so the emitted prefix stands; only the NEXT
                // token is unknowable. State is consistent (p0 + i + 1
                // fed), no rollback needed.
                accepted = i;
                new_pending = 0;
                last_logits = lg;
                poisoned = true;
                break;
            }
            let t = argmax(&lg) as u32;
            if i < kp && t == proposals[i] {
                i += 1;
            } else {
                accepted = i;
                new_pending = t;
                last_logits = lg;
                poisoned = false;
                break;
            }
        }
    } else {
        // ONE batched incremental forward over the pending token + all
        // proposals: kp + 1 positions for a single sweep over the
        // dense weights. Per-row hidden states (and hence logits_row)
        // are bit-identical to sequential single-token appends.
        let t_snapshot = (kp > 0 && matches!(t_state, DecodeState::Mamba(_)))
            .then(|| t_state.clone());
        let full = target.decode_append_full(t_state, p0, &batch);
        let mut a = 0usize;
        let (np, ll, pz) = loop {
            let lg = target.logits_row(full.row(a));
            if lg.iter().any(|v| !v.is_finite()) {
                break (0, lg, true);
            }
            let t = argmax(&lg) as u32;
            if a < kp && t == proposals[a] {
                a += 1;
            } else {
                break (t, lg, false);
            }
        };
        if a < kp {
            // roll back the overshot positions
            match t_snapshot {
                // mamba: restore the pre-round snapshot, re-scan the
                // accepted prefix (sequential scan ≡ per-token feeds)
                Some(snap) => {
                    *t_state = snap;
                    target.decode_append(t_state, p0, &batch[..a + 1]);
                }
                // transformer: move the paged K/V tail cursor back —
                // O(1), freed pages return to the freelist
                None => t_state.truncate_to(p0 + 1 + a),
            }
        }
        accepted = a;
        new_pending = np;
        last_logits = ll;
        poisoned = pz;
    }

    // ---- draft rollback: proposal feeds beyond the accepted prefix
    // consumed tokens that never became true (a dead draft was already
    // dropped — nothing to roll back)
    if kp > 0 && !cursor.draft_dead {
        let d_valid = p0 + 1 + accepted.min(kp - 1);
        if cursor.d_pos > d_valid {
            match d_snapshot.take() {
                Some((snap, pos)) => {
                    cursor.d_state = snap;
                    cursor.d_pos = pos;
                }
                None => {
                    cursor.d_state.truncate_to(d_valid);
                    cursor.d_pos = d_valid;
                }
            }
        }
    }

    let mut emitted = Vec::with_capacity(1 + accepted);
    emitted.push(pending);
    emitted.extend_from_slice(&proposals[..accepted]);
    if !poisoned {
        cursor.pending = new_pending;
    }
    RoundOutcome { emitted, last_logits, proposed: kp, accepted, poisoned }
}

/// A single-stream speculative decode session: draft proposes `k`
/// greedy tokens, target verifies them in one batched pass. Output is
/// bit-identical to plain greedy [`DecodeSession::generate`] over the
/// target alone.
///
/// ```text
/// let mut s = SpecSession::new(&dense, &pruned, 4);
/// s.prefill(&prompt);
/// let toks = s.generate(64);          // == dense-only greedy decode
/// let rate = s.stats().acceptance_rate();
/// ```
///
/// [`DecodeSession::generate`]: crate::model::DecodeSession::generate
pub struct SpecSession<'m> {
    target: &'m dyn LanguageModel,
    draft: &'m dyn LanguageModel,
    k: usize,
    window: Option<usize>,
    t_state: DecodeState,
    cursor: Option<SpecCursor>,
    /// Prompt + emitted tokens — exactly what the target has consumed.
    history: Vec<u32>,
    stats: SpecStats,
}

impl<'m> SpecSession<'m> {
    pub fn new(
        target: &'m dyn LanguageModel,
        draft: &'m dyn LanguageModel,
        k: usize,
    ) -> SpecSession<'m> {
        SpecSession::build(target, draft, k, None)
    }

    /// Session with the sliding-window K/V bound applied to both models
    /// (a windowed transformer target verifies token-by-token; see the
    /// module docs).
    pub fn with_window(
        target: &'m dyn LanguageModel,
        draft: &'m dyn LanguageModel,
        k: usize,
        window: usize,
    ) -> SpecSession<'m> {
        assert!(window >= 1, "window must hold at least one position");
        SpecSession::build(target, draft, k, Some(window))
    }

    fn build(
        target: &'m dyn LanguageModel,
        draft: &'m dyn LanguageModel,
        k: usize,
        window: Option<usize>,
    ) -> SpecSession<'m> {
        assert!(k >= 1, "speculation depth k must be at least 1");
        assert_eq!(
            target.vocab(),
            draft.vocab(),
            "draft and target must share a vocabulary"
        );
        SpecSession {
            target,
            draft,
            k,
            window,
            t_state: target.decode_state(),
            cursor: None,
            history: Vec::new(),
            stats: SpecStats::default(),
        }
    }

    /// Feed the prompt through BOTH models and determine the first
    /// output token (the target's argmax, same as plain greedy).
    pub fn prefill(&mut self, prompt: &[u32]) {
        assert!(!prompt.is_empty(), "prefill needs at least one token");
        assert!(self.cursor.is_none(), "prefill once per session");
        let h = feed(self.target, &mut self.t_state, 0, prompt, self.window);
        let lg = self.target.logits_row(&h);
        let mut d_state = self.draft.decode_state();
        feed(self.draft, &mut d_state, 0, prompt, self.window);
        self.cursor = Some(SpecCursor {
            d_state,
            d_pos: prompt.len(),
            pending: argmax(&lg) as u32,
            draft_dead: false,
        });
        self.history = prompt.to_vec();
    }

    /// True once the draft's logits went non-finite and the session
    /// fell back to plain target decoding for good (rounds emit one
    /// target token each; output is unaffected — verification never
    /// trusted the draft).
    pub fn draft_fell_back(&self) -> bool {
        self.cursor.as_ref().is_some_and(|c| c.draft_dead)
    }

    /// Tokens consumed so far by the target (prompt + emitted).
    pub fn len(&self) -> usize {
        self.history.len()
    }

    pub fn is_empty(&self) -> bool {
        self.history.is_empty()
    }

    /// Generate exactly `n` tokens in speculative rounds. The proposal
    /// depth adapts down near the budget edge (`k_eff = min(k, n -
    /// emitted - 1)`) so a round never overshoots the request. Output is
    /// bit-identical to the target's own greedy decode.
    pub fn generate(&mut self, n: usize) -> Vec<u32> {
        let cursor = self.cursor.as_mut().expect("prefill before generate");
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let budget = n - out.len();
            let k_eff = self.k.min(budget - 1);
            let o = spec_round(
                self.target,
                self.draft,
                self.window,
                k_eff,
                &mut self.t_state,
                cursor,
                &self.history,
            );
            self.stats.absorb(&o);
            self.history.extend_from_slice(&o.emitted);
            out.extend_from_slice(&o.emitted);
            // a session has no quarantine to retire into — fail loudly
            // (the Engine path turns the same condition into
            // FinishReason::Error and keeps serving the other streams)
            assert!(
                !o.poisoned,
                "target logits went non-finite at position {}: the stream \
                 cannot continue (the serving Engine quarantines this as \
                 FinishReason::Error(NonFiniteLogits))",
                self.history.len()
            );
        }
        out
    }

    /// Acceptance accounting across every round so far.
    pub fn stats(&self) -> &SpecStats {
        &self.stats
    }
}

/// End-to-end "prune → keep both → serve speculatively" measurement:
/// runs the same greedy workload through a plain dense [`Engine`] and a
/// speculative one, asserts the outputs are bit-identical (the lossless
/// gate), and reports acceptance rate + tokens/s on both sides.
#[derive(Clone, Copy, Debug)]
pub struct SpecServeReport {
    pub k: usize,
    pub streams: usize,
    pub total_tokens: usize,
    pub rounds: usize,
    pub acceptance_rate: f64,
    pub tokens_per_round: f64,
    pub dense_ms: f64,
    pub spec_ms: f64,
    pub dense_tokens_per_s: f64,
    pub spec_tokens_per_s: f64,
    /// dense_ms / spec_ms (>1 = speculation wins).
    pub speedup: f64,
}

pub fn spec_serve_report(
    target: &dyn LanguageModel,
    draft: &dyn LanguageModel,
    prompts: &[Vec<u32>],
    max_new: usize,
    k: usize,
    cfg: EngineConfig,
) -> SpecServeReport {
    assert!(!prompts.is_empty(), "report needs at least one prompt");
    let timer = Timer::start();
    let mut dense = Engine::new(target, cfg);
    for p in prompts {
        dense.submit(Request::greedy(p.clone(), max_new));
    }
    let dense_tokens = dense.run();
    let dense_ms = timer.elapsed_ms();
    let mut dense_done = dense.take_finished();
    dense_done.sort_by_key(|c| c.id);

    let timer = Timer::start();
    let mut spec = Engine::speculative(target, draft, k, cfg);
    for p in prompts {
        spec.submit(Request::greedy(p.clone(), max_new));
    }
    let spec_tokens = spec.run();
    let spec_ms = timer.elapsed_ms();
    let mut spec_done = spec.take_finished();
    spec_done.sort_by_key(|c| c.id);

    assert_eq!(dense_tokens, spec_tokens, "token budgets must agree");
    for (d, s) in dense_done.iter().zip(&spec_done) {
        assert_eq!(
            d.tokens, s.tokens,
            "lossless gate: speculative output must be bit-identical to dense greedy"
        );
    }
    let stats = spec.spec_stats();
    SpecServeReport {
        k,
        streams: prompts.len(),
        total_tokens: spec_tokens,
        rounds: stats.rounds,
        acceptance_rate: stats.acceptance_rate(),
        tokens_per_round: stats.tokens_per_round(),
        dense_ms,
        spec_ms,
        dense_tokens_per_s: dense_tokens as f64 / (dense_ms / 1e3).max(1e-9),
        spec_tokens_per_s: spec_tokens as f64 / (spec_ms / 1e3).max(1e-9),
        speedup: dense_ms / spec_ms.max(1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{
        DecodeSession, Mamba, MambaConfig, Transformer, TransformerConfig,
    };
    use crate::util::Rng;

    fn tiny_transformer(seed: u64) -> Transformer {
        Transformer::init(
            TransformerConfig {
                vocab: 37,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ff: 24,
                max_seq: 128,
            },
            &mut Rng::new(seed),
        )
    }

    fn tiny_mamba(seed: u64) -> Mamba {
        Mamba::init(
            MambaConfig { vocab: 37, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 128 },
            &mut Rng::new(seed),
        )
    }

    fn prompt(len: usize, salt: usize) -> Vec<u32> {
        (0..len).map(|i| ((i * 5 + salt * 3) % 37) as u32).collect()
    }

    #[test]
    fn draft_equals_target_gives_full_acceptance() {
        // Self-speculation sanity: when the draft IS the target, every
        // proposal matches the verifier's argmax, so acceptance is 100%
        // and every round emits k + 1 tokens.
        for (name, model) in [
            ("microllama", Box::new(tiny_transformer(1)) as Box<dyn LanguageModel>),
            ("micromamba", Box::new(tiny_mamba(2)) as Box<dyn LanguageModel>),
        ] {
            let k = 4;
            let mut s = SpecSession::new(model.as_ref(), model.as_ref(), k);
            s.prefill(&prompt(8, 1));
            let toks = s.generate(20);
            let mut plain = DecodeSession::new(model.as_ref());
            plain.prefill(&prompt(8, 1));
            assert_eq!(toks, plain.generate(20), "{name}");
            let st = s.stats();
            assert_eq!(st.accepted, st.proposed, "{name}: all proposals must be accepted");
            assert!(st.proposed > 0, "{name}");
            assert!((st.acceptance_rate() - 1.0).abs() < 1e-12, "{name}");
            assert_eq!(st.emitted, 20, "{name}");
            // 20 tokens at k = 4: rounds of 5, so exactly 4 rounds
            assert_eq!(st.rounds, 4, "{name}");
            assert_eq!(st.tokens_per_round(), 5.0, "{name}");
        }
    }

    #[test]
    fn hostile_draft_still_lossless() {
        // A freshly-initialized (untrained, unrelated) draft diverges
        // almost immediately — including at position 0 — yet the output
        // must stay bit-identical to plain greedy decoding.
        for (name, target, draft) in [
            (
                "microllama",
                Box::new(tiny_transformer(3)) as Box<dyn LanguageModel>,
                Box::new(tiny_transformer(99)) as Box<dyn LanguageModel>,
            ),
            (
                "micromamba",
                Box::new(tiny_mamba(4)) as Box<dyn LanguageModel>,
                Box::new(tiny_mamba(77)) as Box<dyn LanguageModel>,
            ),
        ] {
            for k in [1usize, 2, 4, 8] {
                let mut s = SpecSession::new(target.as_ref(), draft.as_ref(), k);
                s.prefill(&prompt(6, 2));
                let toks = s.generate(16);
                let mut plain = DecodeSession::new(target.as_ref());
                plain.prefill(&prompt(6, 2));
                assert_eq!(toks, plain.generate(16), "{name} k={k}");
                assert_eq!(s.stats().emitted, 16, "{name} k={k}");
            }
        }
    }

    #[test]
    fn cross_family_draft_is_lossless_too() {
        // Nothing requires the draft to share the target's architecture —
        // only the vocabulary. A mamba draft proposing for a transformer
        // target must keep the lossless gate.
        let target = tiny_transformer(5);
        let draft = tiny_mamba(6);
        let mut s = SpecSession::new(&target, &draft, 3);
        s.prefill(&prompt(7, 3));
        let toks = s.generate(14);
        let mut plain = DecodeSession::new(&target);
        plain.prefill(&prompt(7, 3));
        assert_eq!(toks, plain.generate(14));
    }

    #[test]
    fn k_longer_than_budget_adapts_down() {
        // k = 8 against a 3-token budget: rounds clamp k_eff so the
        // output is exactly n tokens, still bit-identical.
        for (name, model) in [
            ("microllama", Box::new(tiny_transformer(7)) as Box<dyn LanguageModel>),
            ("micromamba", Box::new(tiny_mamba(8)) as Box<dyn LanguageModel>),
        ] {
            let mut s = SpecSession::new(model.as_ref(), model.as_ref(), 8);
            s.prefill(&prompt(5, 4));
            let toks = s.generate(3);
            assert_eq!(toks.len(), 3, "{name}");
            let mut plain = DecodeSession::new(model.as_ref());
            plain.prefill(&prompt(5, 4));
            assert_eq!(toks, plain.generate(3), "{name}");
            // generate(1) must also work (k_eff = 0: pure verify round)
            let more = s.generate(1);
            let expect = plain.generate(1);
            assert_eq!(more, expect, "{name}: continuation after budget-clamped round");
        }
    }

    #[test]
    fn windowed_target_stays_lossless() {
        // Sliding-window targets: the windowed-transformer per-token
        // arm and the windowed-mamba batched arm must both reproduce
        // the plain windowed session exactly — including once real
        // eviction kicks in (prompt + gen ≫ window).
        for (name, target, draft) in [
            (
                "microllama",
                Box::new(tiny_transformer(9)) as Box<dyn LanguageModel>,
                Box::new(tiny_transformer(55)) as Box<dyn LanguageModel>,
            ),
            (
                "micromamba",
                Box::new(tiny_mamba(10)) as Box<dyn LanguageModel>,
                Box::new(tiny_mamba(56)) as Box<dyn LanguageModel>,
            ),
        ] {
            for w in [8usize, 64] {
                let mut s = SpecSession::with_window(target.as_ref(), draft.as_ref(), 4, w);
                s.prefill(&prompt(12, 5));
                let toks = s.generate(18);
                let mut plain = DecodeSession::with_window(target.as_ref(), w);
                plain.prefill(&prompt(12, 5));
                assert_eq!(toks, plain.generate(18), "{name} window={w}");
            }
        }
    }

    #[test]
    fn engine_speculative_matches_plain_engine() {
        let target = tiny_transformer(11);
        let draft = tiny_transformer(12);
        let prompts: Vec<Vec<u32>> = (0..5).map(|i| prompt(3 + i * 2, i)).collect();
        let cfg = EngineConfig { max_batch: 3, ..Default::default() };
        let report = spec_serve_report(&target, &draft, &prompts, 9, 4, cfg);
        assert_eq!(report.streams, 5);
        assert_eq!(report.total_tokens, 45);
        assert!(report.rounds > 0);
        assert!(report.acceptance_rate >= 0.0 && report.acceptance_rate <= 1.0);
        assert!(report.tokens_per_round >= 1.0);
    }

    #[test]
    fn engine_speculative_streams_tokens_and_reports_stats() {
        use std::cell::RefCell;
        use std::collections::BTreeMap;
        use std::rc::Rc;
        let model = tiny_mamba(13);
        let streamed: Rc<RefCell<BTreeMap<super::super::RequestId, Vec<u32>>>> =
            Rc::new(RefCell::new(BTreeMap::new()));
        let sink = streamed.clone();
        let mut eng = Engine::speculative(&model, &model, 3, EngineConfig::default());
        eng.set_on_token(move |id, tok| sink.borrow_mut().entry(id).or_default().push(tok));
        for i in 0..3usize {
            eng.submit(Request::greedy(prompt(4 + i, i), 7));
        }
        eng.run();
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), 3);
        for c in &done {
            assert_eq!(c.tokens.len(), 7);
            assert_eq!(
                streamed.borrow().get(&c.id),
                Some(&c.tokens),
                "on_token stream must match the completion"
            );
        }
        // draft == target: every round emits k + 1 (or the budget tail)
        let st = eng.spec_stats();
        assert_eq!(st.accepted, st.proposed);
        assert_eq!(st.emitted, 21);
    }

    #[test]
    #[should_panic(expected = "greedy requests only")]
    fn speculative_engine_rejects_sampled_requests() {
        let m = tiny_transformer(14);
        let mut eng = Engine::speculative(&m, &m, 2, EngineConfig::default());
        eng.submit(Request {
            prompt: prompt(4, 0),
            max_new_tokens: 4,
            sampling: super::super::SamplingParams::temperature(0.8, 1),
        });
    }

    #[test]
    #[should_panic(expected = "share a vocabulary")]
    fn vocab_mismatch_rejected() {
        let t = tiny_transformer(15);
        let other = Transformer::init(
            TransformerConfig {
                vocab: 12,
                d_model: 16,
                n_layers: 1,
                n_heads: 2,
                d_ff: 24,
                max_seq: 32,
            },
            &mut Rng::new(16),
        );
        SpecSession::new(&t, &other, 2);
    }

    // -----------------------------------------------------------------
    // resilience: draft fallback, quarantine and preemption in spec mode
    // -----------------------------------------------------------------

    use crate::serve::{faults::FaultPlan, ErrorKind, FinishReason};

    #[test]
    fn poisoned_draft_falls_back_to_plain_target_decode() {
        // One NaN weight element kills the whole draft forward from the
        // first touched position — numerically the worst case aggressive
        // pruning can produce. The session must notice at propose time,
        // retire the draft for good, and keep emitting the target's own
        // greedy stream.
        let target = tiny_transformer(17);
        let mut plain = DecodeSession::new(&target);
        plain.prefill(&prompt(6, 1));
        let expect = plain.generate(12);

        let mut bad_t = tiny_transformer(18);
        bad_t.weight_mut(0, "w1").dense_mut().row_mut(0)[0] = f32::NAN;
        let mut bad_m = tiny_mamba(19);
        bad_m.weight_mut(0, "out_proj").dense_mut().row_mut(0)[0] = f32::NAN;
        for (name, draft) in [
            ("poisoned llama draft", Box::new(bad_t) as Box<dyn LanguageModel>),
            ("poisoned mamba draft", Box::new(bad_m) as Box<dyn LanguageModel>),
        ] {
            let mut s = SpecSession::new(&target, draft.as_ref(), 3);
            s.prefill(&prompt(6, 1));
            let toks = s.generate(12);
            assert!(s.draft_fell_back(), "{name}: fallback flag must latch");
            assert_eq!(toks, expect, "{name}: fallback must equal plain greedy");
            // a dead draft proposes nothing: rounds emit one target token
            assert_eq!(s.stats().proposed, 0, "{name}: dead draft cannot propose");
            assert_eq!(s.stats().emitted, 12, "{name}");
        }
    }

    #[test]
    fn engine_counts_draft_fallbacks_and_stays_lossless() {
        let target = tiny_transformer(20);
        let mut bad = tiny_transformer(21);
        bad.weight_mut(0, "w1").dense_mut().row_mut(0)[0] = f32::NAN;
        let cfg = EngineConfig::default();
        let mut plain_eng = Engine::new(&target, cfg);
        let mut eng = Engine::speculative(&target, &bad, 3, cfg);
        for i in 0..3usize {
            plain_eng.submit(Request::greedy(prompt(4 + i, i), 7));
            eng.submit(Request::greedy(prompt(4 + i, i), 7));
        }
        plain_eng.run();
        eng.run();
        let mut base = plain_eng.take_finished();
        base.sort_by_key(|c| c.id);
        let mut done = eng.take_finished();
        done.sort_by_key(|c| c.id);
        assert_eq!(done.len(), base.len());
        for (c, b) in done.iter().zip(&base) {
            assert_eq!(c.tokens, b.tokens, "dead-draft engine must match plain engine");
            assert_eq!(c.finish, FinishReason::Length);
        }
        assert_eq!(eng.stats().draft_fallbacks, 3, "every stream's draft dies once");
    }

    #[test]
    fn spec_engine_quarantines_nan_stream_and_spares_the_rest() {
        let model = tiny_transformer(22);
        let run = |plan: FaultPlan| {
            let mut eng = Engine::speculative(&model, &model, 2, EngineConfig::default());
            for i in 0..3usize {
                eng.submit(Request::greedy(prompt(4 + i, i), 9));
            }
            eng.set_fault_plan(plan);
            eng.run();
            let mut done = eng.take_finished();
            done.sort_by_key(|c| c.id);
            (done, eng.stats())
        };
        let (base, base_st) = run(FaultPlan::new());
        assert_eq!(base_st.quarantined, 0);
        let victim = base[1].id;
        let (done, st) = run(FaultPlan::new().nan_logits(victim, 3));
        assert_eq!(st.quarantined, 1);
        assert_eq!(done.len(), 3);
        for i in [0usize, 2] {
            assert_eq!(done[i].tokens, base[i].tokens, "untouched stream {i}");
            assert_eq!(done[i].finish, FinishReason::Length, "stream {i}");
        }
        assert_eq!(done[1].finish, FinishReason::Error(ErrorKind::NonFiniteLogits));
        // spec quarantine lands on a round boundary: at least the trigger
        // count, strictly less than the full budget
        let n = done[1].tokens.len();
        assert!((3..9).contains(&n), "quarantine point out of range: {n}");
        assert_eq!(done[1].tokens[..], base[1].tokens[..n], "pre-poison prefix");
    }

    #[test]
    fn spec_engine_preemption_is_lossless() {
        // A forced recompute preemption mid-round-sequence drops both the
        // target state AND the draft cursor; re-admission rebuilds both
        // from prompt + emitted. Greedy spec output must be unchanged.
        let target = tiny_transformer(23);
        let draft = tiny_transformer(24);
        let run = |plan: FaultPlan| {
            let mut eng = Engine::speculative(&target, &draft, 3, EngineConfig::default());
            for i in 0..2usize {
                eng.submit(Request::greedy(prompt(5 + i, i), 8));
            }
            eng.set_fault_plan(plan);
            eng.run();
            let mut done = eng.take_finished();
            done.sort_by_key(|c| c.id);
            (done, eng.stats())
        };
        let (base, base_st) = run(FaultPlan::new());
        assert_eq!(base_st.preemptions, 0);
        let (done, st) = run(FaultPlan::new().force_preempt(base[1].id, 2));
        assert_eq!(st.preemptions, 1);
        assert_eq!(done.len(), base.len());
        for (c, b) in done.iter().zip(&base) {
            assert_eq!(c.tokens, b.tokens, "spec preemption changed {:?}", c.id);
            assert_eq!(c.finish, FinishReason::Length);
        }
    }
}
