//! Dense row-major f32 matrices with threaded blocked GEMM, plus an f64
//! twin used by the second-order pruning math (Hessian work needs the
//! extra mantissa; see DESIGN.md).
//!
//! No BLAS is available offline; `matmul` is a cache-blocked, row-parallel
//! kernel tuned in the perf pass (EXPERIMENTS.md §Perf).

use crate::util::{num_threads, Rng};

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn randn(rows: usize, cols: usize, sigma: f32, rng: &mut Rng) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        rng.fill_normal(&mut m.data, sigma);
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// C = A @ B  (threaded over row-chunks of A).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape {:?}x{:?}", self.shape(), b.shape());
        let mut out = Mat::zeros(self.rows, b.cols);
        matmul_into(self, b, &mut out);
        out
    }

    /// C = A @ B^T (avoids materializing the transpose).
    pub fn matmul_tb(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_tb shape {:?}x{:?}", self.shape(), b.shape());
        let (n, k, m) = (self.rows, self.cols, b.rows);
        let mut out = Mat::zeros(n, m);
        let nt = num_threads().min(n.max(1));
        let chunk = n.div_ceil(nt);
        let a = &self.data;
        let bd = &b.data;
        std::thread::scope(|s| {
            for (ci, orows) in out.data.chunks_mut(chunk * m).enumerate() {
                let r0 = ci * chunk;
                s.spawn(move || {
                    for (ri, orow) in orows.chunks_mut(m).enumerate() {
                        let arow = &a[(r0 + ri) * k..(r0 + ri + 1) * k];
                        for (j, o) in orow.iter_mut().enumerate() {
                            let brow = &bd[j * k..(j + 1) * k];
                            *o = dot(arow, brow);
                        }
                    }
                });
            }
        });
        out
    }

    pub fn add_assign(&mut self, b: &Mat) {
        assert_eq!(self.shape(), b.shape());
        for (a, &x) in self.data.iter_mut().zip(&b.data) {
            *a += x;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn frob(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    pub fn sparsity(&self) -> f64 {
        1.0 - self.nnz() as f64 / self.data.len() as f64
    }

    /// Columns [c0, c1) as a new matrix (block pruning operates on these).
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Mat {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut out = Mat::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    pub fn set_cols(&mut self, c0: usize, block: &Mat) {
        assert_eq!(block.rows, self.rows);
        assert!(c0 + block.cols <= self.cols);
        for r in 0..self.rows {
            self.row_mut(r)[c0..c0 + block.cols].copy_from_slice(block.row(r));
        }
    }

    pub fn to_f64(&self) -> MatF64 {
        MatF64 {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x as f64).collect(),
        }
    }

    pub fn max_abs_diff(&self, b: &Mat) -> f32 {
        assert_eq!(self.shape(), b.shape());
        self.data
            .iter()
            .zip(&b.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Append the rows of `other` below this matrix (same column count).
    /// (The decode-session K/V caches now grow through [`PagedKv`];
    /// this remains the general contiguous-growth primitive.)
    pub fn append_rows(&mut self, other: &Mat) {
        assert_eq!(
            self.cols, other.cols,
            "append_rows: cols {} != {}",
            self.cols, other.cols
        );
        self.reserve_amortized(other.data.len());
        self.data.extend_from_slice(&other.data);
        self.rows += other.rows;
    }

    /// Append one row (the per-stream K/V append in batched decode).
    pub fn append_row(&mut self, row: &[f32]) {
        assert_eq!(self.cols, row.len(), "append_row: cols {} != {}", self.cols, row.len());
        self.reserve_amortized(row.len());
        self.data.extend_from_slice(row);
        self.rows += 1;
    }

    /// Explicit doubling capacity growth: a T-step decode does O(log T)
    /// reallocations (O(T) elements moved in total). `Vec`'s own growth
    /// is already amortized; this pins the policy in OUR code so cache
    /// growth can't regress with libstd/allocator changes, and documents
    /// the contract that callers must never assume pointer stability
    /// across appends — the buffer moves whenever capacity is outgrown.
    fn reserve_amortized(&mut self, add: usize) {
        let need = self.data.len() + add;
        if need > self.data.capacity() {
            let target = need.max(self.data.capacity() * 2);
            self.data.reserve_exact(target - self.data.len());
        }
    }

    /// Drop the first `n` rows in place. Keeps the allocation; the
    /// remaining rows shift to the front — O(rows·cols). The decode
    /// K/V caches no longer evict through this (see [`PagedKv`], whose
    /// cursor eviction is O(1)); it remains the contiguous-layout
    /// primitive and the shift-eviction bench baseline.
    pub fn drop_leading_rows(&mut self, n: usize) {
        assert!(n <= self.rows, "drop_leading_rows: {n} > {}", self.rows);
        self.data.drain(..n * self.cols);
        self.rows -= n;
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

// ---------------------------------------------------------------------------
// paged K/V storage (the decode-session cache layout)
// ---------------------------------------------------------------------------

/// Default rows per K/V page. One page of a d=4096 cache is 1 MiB; small
/// enough that the over-retention window (`< page` rows past the logical
/// window) stays negligible, large enough that page bookkeeping vanishes
/// against the attention work over the page.
pub const KV_PAGE_ROWS: usize = 64;

/// Paged row store for decode-session K/V caches.
///
/// A contiguous `Mat` cache makes sliding-window eviction O(W·cols) per
/// step: dropping the oldest row shifts the whole live window down.
/// `PagedKv` stores rows in fixed-size pages and evicts by advancing a
/// `head` cursor — a whole page is freed (onto a reuse list) only when
/// every row in it has slid out of the window, so per-step eviction does
/// **no row copying** and steady-state decode allocates nothing.
///
/// Logical row `i` (0 = oldest live row) lives at physical slot
/// `head + i`; [`PagedKv::row_slices`] walks the live rows page by page
/// in logical order, so attention consumers see exactly the sequence a
/// contiguous layout would hand them.
#[derive(Debug)]
pub struct PagedKv {
    cols: usize,
    page_rows: usize,
    pages: std::collections::VecDeque<Box<[f32]>>,
    /// Offset of the first live row within `pages[0]` (0..page_rows).
    head: usize,
    /// Live rows.
    len: usize,
    /// Evicted pages kept for reuse (capacity recycling).
    free: Vec<Box<[f32]>>,
    /// Pages ever allocated (not recycled) — pinned by tests/benches to
    /// prove steady-state eviction is allocation-free.
    allocated: usize,
}

/// Manual clone: copies only the LIVE pages. The freelist holds dead
/// recycled pages — copying it would make every session fork (per-
/// candidate scoring, `DecodeSession::fork`) duplicate memory that
/// contains no data.
impl Clone for PagedKv {
    fn clone(&self) -> PagedKv {
        PagedKv {
            cols: self.cols,
            page_rows: self.page_rows,
            pages: self.pages.clone(),
            head: self.head,
            len: self.len,
            free: Vec::new(),
            allocated: self.pages.len(),
        }
    }
}

impl PagedKv {
    pub fn new(cols: usize) -> PagedKv {
        PagedKv::with_page_rows(cols, KV_PAGE_ROWS)
    }

    /// Custom page granularity — the boundary-case tests (window == page,
    /// window not a multiple of the page) and page-size-invariance checks
    /// use this; production callers take [`PagedKv::new`].
    pub fn with_page_rows(cols: usize, page_rows: usize) -> PagedKv {
        assert!(page_rows >= 1, "page must hold at least one row");
        PagedKv {
            cols,
            page_rows,
            pages: std::collections::VecDeque::new(),
            head: 0,
            len: 0,
            free: Vec::new(),
            allocated: 0,
        }
    }

    /// Live rows (logical length after eviction).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn page_rows(&self) -> usize {
        self.page_rows
    }

    /// Pages ever allocated fresh (recycled evictions don't count).
    pub fn pages_allocated(&self) -> usize {
        self.allocated
    }

    /// Pages currently holding live rows.
    pub fn pages_live(&self) -> usize {
        self.pages.len()
    }

    /// Logical row `i` (0 = oldest live row).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.len, "row {i} out of {} live rows", self.len);
        let slot = self.head + i;
        let page = &self.pages[slot / self.page_rows];
        let off = (slot % self.page_rows) * self.cols;
        &page[off..off + self.cols]
    }

    /// Append one row at the logical end, reusing an evicted page when
    /// the tail page is full.
    pub fn append_row(&mut self, row: &[f32]) {
        assert_eq!(self.cols, row.len(), "append_row: cols {} != {}", self.cols, row.len());
        let slot = self.head + self.len;
        if slot == self.pages.len() * self.page_rows {
            let page = self.free.pop().unwrap_or_else(|| {
                self.allocated += 1;
                vec![0.0f32; self.page_rows * self.cols].into_boxed_slice()
            });
            self.pages.push_back(page);
        }
        let page = self.pages.back_mut().expect("tail page exists");
        let off = (slot % self.page_rows) * self.cols;
        page[off..off + self.cols].copy_from_slice(row);
        self.len += 1;
    }

    /// Append every row of `m` (the prefill bulk append).
    pub fn append_rows(&mut self, m: &Mat) {
        assert_eq!(self.cols, m.cols, "append_rows: cols {} != {}", self.cols, m.cols);
        for r in 0..m.rows {
            self.append_row(m.row(r));
        }
    }

    /// Slide the window: keep only the newest `window` rows. Eviction
    /// advances the head cursor and frees whole leading pages onto the
    /// reuse list — O(1) per call (amortized, and never copies a row),
    /// vs the O(W·cols) shift of a contiguous layout.
    pub fn evict_to(&mut self, window: usize) {
        assert!(window >= 1, "window must hold at least one position");
        if self.len <= window {
            return;
        }
        self.head += self.len - window;
        self.len = window;
        while self.head >= self.page_rows {
            let page = self.pages.pop_front().expect("head page exists");
            self.free.push(page);
            self.head -= self.page_rows;
        }
    }

    /// Roll back the logical end: keep only the OLDEST `len` rows
    /// (the dual of [`PagedKv::evict_to`], which keeps the newest).
    /// Speculative decoding uses this to discard K/V rows appended for
    /// draft tokens the target rejected. Whole dead TAIL pages return to
    /// the reuse list — O(1) amortized, never copies a row — which also
    /// keeps [`PagedKv::append_row`]'s tail-page invariant intact
    /// (`head + len` must land inside the last live page or exactly at
    /// the next page boundary).
    pub fn truncate_to(&mut self, len: usize) {
        if len >= self.len {
            return;
        }
        self.len = len;
        while !self.pages.is_empty()
            && (self.pages.len() - 1) * self.page_rows >= self.head + self.len
        {
            let page = self.pages.pop_back().expect("tail page exists");
            self.free.push(page);
        }
    }

    /// Iterate the first `lim` live rows in logical order, page by page.
    /// This is the attention hot loop's accessor: per-page slicing keeps
    /// the per-row cost at one pointer bump (no div/mod per row) while
    /// visiting rows in exactly the order `row(0..lim)` would.
    pub fn row_slices(&self, lim: usize) -> impl Iterator<Item = &[f32]> + '_ {
        debug_assert!(lim <= self.len, "row_slices: {lim} > {} live rows", self.len);
        let (pr, cols, head) = (self.page_rows, self.cols, self.head);
        let end = head + lim;
        self.pages.iter().enumerate().flat_map(move |(pi, page)| {
            let p0 = pi * pr;
            let hi = end.saturating_sub(p0).min(pr);
            let lo = head.saturating_sub(p0).min(hi);
            page[lo * cols..hi * cols].chunks_exact(cols)
        })
    }
}

/// Length-matched dot product; also the inner kernel of `matmul_tb`, so
/// single-row callers (decode-session attention, `logits_last`) reproduce
/// the full-matrix products bit-for-bit.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    // 4 independent fma chains over exact chunks: no bounds checks in the
    // body, and with target-cpu=native (see .cargo/config.toml) mul_add
    // lowers to vfmadd, which LLVM then widens to full vector width.
    let n = a.len().min(b.len());
    let mut acc = [0f32; 4];
    let (ac, ar) = a[..n].split_at(n - n % 4);
    let (bc, br) = b[..n].split_at(n - n % 4);
    for (ak, bk) in ac.chunks_exact(4).zip(bc.chunks_exact(4)) {
        acc[0] = ak[0].mul_add(bk[0], acc[0]);
        acc[1] = ak[1].mul_add(bk[1], acc[1]);
        acc[2] = ak[2].mul_add(bk[2], acc[2]);
        acc[3] = ak[3].mul_add(bk[3], acc[3]);
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for (&x, &y) in ar.iter().zip(br) {
        s = x.mul_add(y, s);
    }
    s
}

/// K-dimension cache tile for [`matmul_into`], from `APT_GEMM_K_TILE`
/// (re-read per call, like `APT_BATCH_ATTN_THRESHOLD`). Default 128
/// rows of B: at m ≈ 1k f32 columns that is ~512 KiB of B per tile —
/// L2-resident — so every output row of a worker's chunk re-reads the
/// SAME B rows instead of streaming all of B from memory per output
/// row. Set it at or above K (e.g. 99999999) for the untiled baseline
/// the `gemm_k_tiling_speedup` bench key compares against.
fn gemm_k_tile() -> usize {
    std::env::var("APT_GEMM_K_TILE")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t > 0)
        .unwrap_or(128)
}

/// C = A @ B written into `out` (must be zeroed or pre-filled; we add).
/// i-k-j loop order: each A element broadcasts over a contiguous B row,
/// so the inner loop is a SIMD-friendly axpy. The k loop is tiled (see
/// [`gemm_k_tile`]) so a tile of B rows stays cache-hot across all
/// output rows of the worker's chunk.
pub fn matmul_into(a: &Mat, b: &Mat, out: &mut Mat) {
    matmul_into_tiled(a, b, out, gemm_k_tile());
}

/// [`matmul_into`] with an explicit K tile. Any tile size produces
/// bit-identical output: each output element accumulates its k terms in
/// ascending order whether or not the loop is tiled (tiles are visited
/// ascending, and a given output row meets each k exactly once), so
/// this is a pure traversal-order change — pinned by
/// `matmul_k_tiling_is_bitwise_invariant`.
pub fn matmul_into_tiled(a: &Mat, b: &Mat, out: &mut Mat, tile: usize) {
    let (n, k, m) = (a.rows, a.cols, b.cols);
    assert_eq!(out.shape(), (n, m));
    assert!(tile > 0, "K tile must be non-zero");
    let nt = num_threads().min(n.max(1));
    let chunk = n.div_ceil(nt);
    let ad = &a.data;
    let bd = &b.data;
    std::thread::scope(|s| {
        for (ci, orows) in out.data.chunks_mut(chunk * m).enumerate() {
            let r0 = ci * chunk;
            s.spawn(move || {
                for k0 in (0..k).step_by(tile) {
                    let k1 = k0.saturating_add(tile).min(k);
                    for (ri, orow) in orows.chunks_mut(m).enumerate() {
                        let arow = &ad[(r0 + ri) * k + k0..(r0 + ri) * k + k1];
                        for (kk, &av) in arow.iter().enumerate() {
                            if av == 0.0 {
                                continue; // pruned-weight fast path
                            }
                            let brow = &bd[(k0 + kk) * m..(k0 + kk + 1) * m];
                            axpy(av, brow, orow);
                        }
                    }
                }
            });
        }
    });
}

/// y += a·x (f32), vectorization-friendly: exact 8-wide chunks with fused
/// multiply-adds, scalar tail.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    let n = x.len().min(y.len());
    let split = n - n % 8;
    let (xc, xr) = x[..n].split_at(split);
    let (yc, yr) = y[..n].split_at_mut(split);
    for (yk, xk) in yc.chunks_exact_mut(8).zip(xc.chunks_exact(8)) {
        yk[0] = xk[0].mul_add(a, yk[0]);
        yk[1] = xk[1].mul_add(a, yk[1]);
        yk[2] = xk[2].mul_add(a, yk[2]);
        yk[3] = xk[3].mul_add(a, yk[3]);
        yk[4] = xk[4].mul_add(a, yk[4]);
        yk[5] = xk[5].mul_add(a, yk[5]);
        yk[6] = xk[6].mul_add(a, yk[6]);
        yk[7] = xk[7].mul_add(a, yk[7]);
    }
    for (yi, &xi) in yr.iter_mut().zip(xr) {
        *yi = xi.mul_add(a, *yi);
    }
}

/// y += a·x (f64) over the common prefix — the axpy behind the MRP row
/// updates and the SparseGPT sweep. Same chunks_exact + mul_add shape as
/// the f32 variant.
#[inline]
pub fn axpy_f64(a: f64, x: &[f64], y: &mut [f64]) {
    let n = x.len().min(y.len());
    let split = n - n % 4;
    let (xc, xr) = x[..n].split_at(split);
    let (yc, yr) = y[..n].split_at_mut(split);
    for (yk, xk) in yc.chunks_exact_mut(4).zip(xc.chunks_exact(4)) {
        yk[0] = xk[0].mul_add(a, yk[0]);
        yk[1] = xk[1].mul_add(a, yk[1]);
        yk[2] = xk[2].mul_add(a, yk[2]);
        yk[3] = xk[3].mul_add(a, yk[3]);
    }
    for (yi, &xi) in yr.iter_mut().zip(xr) {
        *yi = xi.mul_add(a, *yi);
    }
}

// ---------------------------------------------------------------------------
// f64 twin (pruning math)
// ---------------------------------------------------------------------------

/// Row-major f64 matrix for second-order computations.
#[derive(Clone, Debug, PartialEq)]
pub struct MatF64 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl MatF64 {
    pub fn zeros(rows: usize, cols: usize) -> MatF64 {
        MatF64 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> MatF64 {
        let mut m = MatF64::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn to_f32(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| x as f32).collect(),
        }
    }

    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    /// Symmetric rank-T update: self += 2 * X^T X for X:(t, m) f32 rows.
    /// This is the Hessian accumulation hot path (threaded over columns).
    ///
    /// §Perf iteration 1 (EXPERIMENTS.md): accumulate only the lower
    /// triangle (row i touches columns 0..=i) and mirror once at the end —
    /// halves the FLOPs vs the naive full-matrix update. Threads are given
    /// interleaved rows (stride = nt) so the triangular work stays
    /// balanced across the pool.
    pub fn syrk_add_2xtx(&mut self, x_rows: &[&[f32]]) {
        let m = self.cols;
        assert_eq!(self.rows, m);
        let nt = num_threads().min(m.max(1));
        let data = &mut self.data;
        // Interleaved row ownership via unsafe-free trick: each worker
        // owns rows where (row % nt == worker); rows are disjoint slices,
        // carved out of one mutable pass.
        let base = data.as_mut_ptr() as usize;
        std::thread::scope(|s| {
            for worker in 0..nt {
                s.spawn(move || {
                    let mut i = worker;
                    while i < m {
                        // SAFETY: rows are disjoint across workers
                        // (i % nt == worker) and live for the scope.
                        let hrow: &mut [f64] = unsafe {
                            std::slice::from_raw_parts_mut(
                                (base as *mut f64).add(i * m),
                                i + 1,
                            )
                        };
                        for xr in x_rows {
                            let xi = 2.0 * xr[i] as f64;
                            if xi == 0.0 {
                                continue;
                            }
                            // chunks_exact + mul_add keeps the f32->f64
                            // widening off the dependency chain and lets
                            // LLVM vectorize the row update.
                            let cols = hrow.len();
                            let split = cols - cols % 4;
                            let (hc, hr) = hrow.split_at_mut(split);
                            let (xc, xtail) = xr[..cols].split_at(split);
                            for (hk, xk) in hc.chunks_exact_mut(4).zip(xc.chunks_exact(4)) {
                                hk[0] = (xk[0] as f64).mul_add(xi, hk[0]);
                                hk[1] = (xk[1] as f64).mul_add(xi, hk[1]);
                                hk[2] = (xk[2] as f64).mul_add(xi, hk[2]);
                                hk[3] = (xk[3] as f64).mul_add(xi, hk[3]);
                            }
                            for (h, &xj) in hr.iter_mut().zip(xtail) {
                                *h = (xj as f64).mul_add(xi, *h);
                            }
                        }
                        i += nt;
                    }
                });
            }
        });
        // mirror the triangle
        for i in 0..m {
            for j in i + 1..m {
                self.data[i * m + j] = self.data[j * m + i];
            }
        }
    }

    /// f64-input variant of `syrk_add_2xtx` (SSPerf iteration 2).
    pub fn syrk_add_2xtx_f64(&mut self, x_rows: &[Vec<f64>]) {
        let m = self.cols;
        assert_eq!(self.rows, m);
        let nt = num_threads().min(m.max(1));
        let base = self.data.as_mut_ptr() as usize;
        std::thread::scope(|s| {
            for worker in 0..nt {
                s.spawn(move || {
                    let mut i = worker;
                    while i < m {
                        // SAFETY: rows disjoint across workers (i % nt).
                        let hrow: &mut [f64] = unsafe {
                            std::slice::from_raw_parts_mut(
                                (base as *mut f64).add(i * m),
                                i + 1,
                            )
                        };
                        for xr in x_rows {
                            let xi = 2.0 * xr[i];
                            if xi == 0.0 {
                                continue;
                            }
                            axpy_f64(xi, xr, hrow);
                        }
                        i += nt;
                    }
                });
            }
        });
        for i in 0..m {
            for j in i + 1..m {
                self.data[i * m + j] = self.data[j * m + i];
            }
        }
    }

    pub fn sub(&self, rows: &[usize], cols: &[usize]) -> MatF64 {
        let mut out = MatF64::zeros(rows.len(), cols.len());
        for (i, &r) in rows.iter().enumerate() {
            for (j, &c) in cols.iter().enumerate() {
                out[(i, j)] = self[(r, c)];
            }
        }
        out
    }

    pub fn max_abs_diff(&self, b: &MatF64) -> f64 {
        assert_eq!(self.shape(), b.shape());
        self.data
            .iter()
            .zip(&b.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for MatF64 {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for MatF64 {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0f64;
                for k in 0..a.cols {
                    s += a[(i, k)] as f64 * b[(k, j)] as f64;
                }
                c[(i, j)] = s as f32;
            }
        }
        c
    }

    #[test]
    fn append_rows_grows_and_preserves() {
        let mut r = Rng::new(77);
        let a = Mat::randn(3, 5, 1.0, &mut r);
        let b = Mat::randn(2, 5, 1.0, &mut r);
        let mut grown = Mat::zeros(0, 5);
        grown.append_rows(&a);
        grown.append_rows(&b);
        assert_eq!(grown.shape(), (5, 5));
        for i in 0..3 {
            assert_eq!(grown.row(i), a.row(i));
        }
        for i in 0..2 {
            assert_eq!(grown.row(3 + i), b.row(i));
        }
    }

    #[test]
    fn append_rows_amortized_growth() {
        // 1024 single-row appends must trigger only O(log n) reallocations,
        // and correctness must never depend on the buffer staying put.
        let cols = 7;
        let mut m = Mat::zeros(0, cols);
        let mut caps = Vec::new();
        let mut moved = 0usize;
        let mut last_ptr = m.data.as_ptr();
        for i in 0..1024usize {
            let row: Vec<f32> = (0..cols).map(|c| (i * cols + c) as f32).collect();
            m.append_row(&row);
            if m.data.as_ptr() != last_ptr {
                moved += 1;
                last_ptr = m.data.as_ptr();
            }
            if caps.last() != Some(&m.data.capacity()) {
                caps.push(m.data.capacity());
            }
        }
        assert_eq!(m.shape(), (1024, cols));
        // doubling growth: ~log2(1024*7) distinct capacities, not ~1024
        assert!(caps.len() <= 16, "capacity changed {} times: {caps:?}", caps.len());
        assert!(moved <= 16, "buffer moved {moved} times");
        // contents survive every move — no pointer stability assumed
        for i in 0..1024 {
            for c in 0..cols {
                assert_eq!(m[(i, c)], (i * cols + c) as f32);
            }
        }
    }

    #[test]
    fn append_rows_matches_append_row() {
        let mut r = Rng::new(78);
        let chunk = Mat::randn(4, 6, 1.0, &mut r);
        let mut a = Mat::zeros(0, 6);
        a.append_rows(&chunk);
        let mut b = Mat::zeros(0, 6);
        for i in 0..chunk.rows {
            b.append_row(chunk.row(i));
        }
        assert_eq!(a, b);
    }

    #[test]
    fn drop_leading_rows_slides_window() {
        let mut r = Rng::new(79);
        let m0 = Mat::randn(6, 5, 1.0, &mut r);
        let mut m = m0.clone();
        m.drop_leading_rows(2);
        assert_eq!(m.shape(), (4, 5));
        for i in 0..4 {
            assert_eq!(m.row(i), m0.row(i + 2));
        }
        m.drop_leading_rows(0);
        assert_eq!(m.shape(), (4, 5));
        m.drop_leading_rows(4);
        assert_eq!(m.shape(), (0, 5));
    }

    /// Naive reference for PagedKv: a Vec of rows with shift eviction.
    struct NaiveKv {
        rows: Vec<Vec<f32>>,
    }

    impl NaiveKv {
        fn push(&mut self, r: &[f32]) {
            self.rows.push(r.to_vec());
        }
        fn evict_to(&mut self, w: usize) {
            while self.rows.len() > w {
                self.rows.remove(0);
            }
        }
        fn truncate(&mut self, len: usize) {
            self.rows.truncate(len);
        }
    }

    #[test]
    fn paged_kv_matches_naive_across_page_sizes() {
        // page sizes around the window: smaller, equal, non-divisor,
        // larger — row contents and order must be invariant to paging.
        let cols = 5;
        for &page in &[1usize, 3, 8, 11, 64] {
            for &window in &[3usize, 8, 10] {
                let mut p = PagedKv::with_page_rows(cols, page);
                let mut n = NaiveKv { rows: Vec::new() };
                let mut r = Rng::new(100 + page as u64 * 7 + window as u64);
                for step in 0..200 {
                    let row: Vec<f32> = (0..cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
                    p.append_row(&row);
                    n.push(&row);
                    if step % 3 != 0 {
                        p.evict_to(window);
                        n.evict_to(window);
                    }
                    assert_eq!(p.len(), n.rows.len(), "page={page} window={window}");
                    for i in 0..p.len() {
                        assert_eq!(p.row(i), &n.rows[i][..], "page={page} w={window} row {i}");
                    }
                    let iterated: Vec<&[f32]> = p.row_slices(p.len()).collect();
                    assert_eq!(iterated.len(), p.len());
                    for (i, s) in iterated.iter().enumerate() {
                        assert_eq!(*s, &n.rows[i][..], "iter page={page} w={window} row {i}");
                    }
                    // partial lim (a mid-chunk decode query's view)
                    let lim = p.len() / 2;
                    assert_eq!(p.row_slices(lim).count(), lim);
                }
            }
        }
    }

    #[test]
    fn paged_kv_truncate_matches_naive_across_page_sizes() {
        // Interleave appends, window evictions and tail truncations (the
        // speculative-rollback pattern): bitwise row contents and order
        // must be invariant to the page size throughout.
        let cols = 4;
        for &page in &[1usize, 3, 8, 11, 64] {
            let mut p = PagedKv::with_page_rows(cols, page);
            let mut n = NaiveKv { rows: Vec::new() };
            let mut r = Rng::new(500 + page as u64);
            for step in 0..300 {
                let row: Vec<f32> = (0..cols).map(|_| r.normal_f32(0.0, 1.0)).collect();
                p.append_row(&row);
                n.push(&row);
                match step % 5 {
                    // drop a speculative tail (0..=3 rows)
                    1 | 3 => {
                        let keep = p.len().saturating_sub(step % 4);
                        p.truncate_to(keep);
                        n.truncate(keep);
                    }
                    // slide the window from the front
                    2 => {
                        p.evict_to(7);
                        n.evict_to(7);
                    }
                    _ => {}
                }
                assert_eq!(p.len(), n.rows.len(), "page={page} step={step}");
                for i in 0..p.len() {
                    assert_eq!(p.row(i), &n.rows[i][..], "page={page} step={step} row {i}");
                }
                let iterated: Vec<&[f32]> = p.row_slices(p.len()).collect();
                assert_eq!(iterated.len(), p.len());
                for (i, s) in iterated.iter().enumerate() {
                    assert_eq!(*s, &n.rows[i][..], "iter page={page} step={step} row {i}");
                }
            }
        }
        // truncate past the end is a no-op; truncate to 0 empties
        let mut p = PagedKv::with_page_rows(2, 4);
        p.append_row(&[1.0, 2.0]);
        p.truncate_to(10);
        assert_eq!(p.len(), 1);
        p.truncate_to(0);
        assert!(p.is_empty());
        p.append_row(&[3.0, 4.0]);
        assert_eq!(p.row(0), &[3.0, 4.0]);
    }

    #[test]
    fn paged_kv_truncate_recycles_tail_pages() {
        // The spec-decode round trip — overshoot k rows, roll back —
        // must recycle freed tail pages through the freelist, never
        // allocate in steady state, and leave append_row's tail-page
        // invariant intact after every rollback depth.
        let (cols, page) = (4usize, 3usize);
        let mut p = PagedKv::with_page_rows(cols, page);
        let row = vec![1.0f32; cols];
        for _ in 0..10 {
            p.append_row(&row);
        }
        let base = p.len();
        let ceiling = (base + 8).div_ceil(page) + 1;
        for round in 0..5_000usize {
            let k = round % 8 + 1;
            for _ in 0..k {
                p.append_row(&row);
            }
            p.truncate_to(base);
            assert_eq!(p.len(), base);
            assert!(p.pages_allocated() <= ceiling, "allocated {}", p.pages_allocated());
            assert!(p.pages_live() <= ceiling);
        }
        // rollback composes with head eviction: pages freed from both
        // ends land on the same freelist
        p.evict_to(4);
        for _ in 0..6 {
            p.append_row(&row);
        }
        p.truncate_to(5);
        assert_eq!(p.len(), 5);
        assert!(p.pages_allocated() <= ceiling + 1);
    }

    #[test]
    fn paged_kv_eviction_is_allocation_free_in_steady_state() {
        // Sliding a window forever must recycle pages, not allocate:
        // after the first window's pages exist, `pages_allocated` stays
        // flat no matter how many steps run.
        let (cols, page, window) = (4usize, 8usize, 20usize);
        let mut p = PagedKv::with_page_rows(cols, page);
        let row = vec![1.0f32; cols];
        for _ in 0..window {
            p.append_row(&row);
        }
        // one extra page may be in flight beyond the window's own pages
        let ceiling = window.div_ceil(page) + 2;
        for _ in 0..10_000 {
            p.append_row(&row);
            p.evict_to(window);
            assert!(p.pages_allocated() <= ceiling, "allocated {}", p.pages_allocated());
            assert!(p.pages_live() <= ceiling);
            assert_eq!(p.len(), window);
        }
    }

    #[test]
    fn paged_kv_window_equals_page_and_bulk_append() {
        // window == page size: eviction frees exactly one page per page
        // of progress; bulk append matches row-by-row.
        let (cols, page) = (3usize, 4usize);
        let mut a = PagedKv::with_page_rows(cols, page);
        let mut b = PagedKv::with_page_rows(cols, page);
        let mut r = Rng::new(7);
        let chunk = Mat::randn(10, cols, 1.0, &mut r);
        a.append_rows(&chunk);
        for i in 0..chunk.rows {
            b.append_row(chunk.row(i));
        }
        for i in 0..10 {
            assert_eq!(a.row(i), b.row(i));
        }
        a.evict_to(page);
        assert_eq!(a.len(), page);
        for i in 0..page {
            assert_eq!(a.row(i), chunk.row(10 - page + i));
        }
        // clones are independent (the session fork path) and carry only
        // LIVE pages — the dead freelist is not duplicated
        let mut c = a.clone();
        assert_eq!(c.pages_allocated(), c.pages_live());
        for i in 0..page {
            assert_eq!(c.row(i), a.row(i));
        }
        c.append_row(chunk.row(0));
        assert_eq!(a.len(), page);
        assert_eq!(c.len(), page + 1);
    }

    #[test]
    fn matmul_identity() {
        let mut r = Rng::new(1);
        let a = Mat::randn(7, 5, 1.0, &mut r);
        assert_eq!(a.matmul(&Mat::eye(5)), a);
    }

    #[test]
    fn matmul_matches_naive() {
        let mut r = Rng::new(2);
        for &(n, k, m) in &[(3, 4, 5), (16, 16, 16), (33, 17, 9), (1, 64, 1)] {
            let a = Mat::randn(n, k, 1.0, &mut r);
            let b = Mat::randn(k, m, 1.0, &mut r);
            assert!(a.matmul(&b).max_abs_diff(&naive_matmul(&a, &b)) < 1e-3);
        }
    }

    #[test]
    fn matmul_k_tiling_is_bitwise_invariant() {
        // Tiling only reorders the traversal, never the per-element
        // accumulation order, so every tile size must agree with the
        // untiled kernel to the bit — including tiles that don't divide
        // K and a tile of 1.
        let mut r = Rng::new(21);
        for &(n, k, m) in &[(5, 64, 9), (3, 7, 11), (16, 33, 16)] {
            let a = Mat::randn(n, k, 1.0, &mut r);
            let b = Mat::randn(k, m, 1.0, &mut r);
            let mut base = Mat::zeros(n, m);
            matmul_into_tiled(&a, &b, &mut base, usize::MAX);
            for tile in [1usize, 3, 8, 32, 128] {
                let mut out = Mat::zeros(n, m);
                matmul_into_tiled(&a, &b, &mut out, tile);
                assert_eq!(out, base, "({n},{k},{m}) tile {tile}");
            }
        }
    }

    #[test]
    fn matmul_tb_matches_transpose() {
        let mut r = Rng::new(3);
        let a = Mat::randn(9, 12, 1.0, &mut r);
        let b = Mat::randn(7, 12, 1.0, &mut r);
        assert!(a.matmul_tb(&b).max_abs_diff(&a.matmul(&b.t())) < 1e-4);
    }

    #[test]
    fn transpose_involution() {
        let mut r = Rng::new(4);
        let a = Mat::randn(6, 11, 1.0, &mut r);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn slice_set_cols_roundtrip() {
        let mut r = Rng::new(5);
        let a = Mat::randn(5, 10, 1.0, &mut r);
        let block = a.slice_cols(3, 7);
        assert_eq!(block.shape(), (5, 4));
        let mut b = Mat::zeros(5, 10);
        b.set_cols(3, &block);
        assert_eq!(b.slice_cols(3, 7), block);
        assert_eq!(b[(0, 0)], 0.0);
    }

    #[test]
    fn syrk_matches_explicit() {
        let mut r = Rng::new(6);
        let x = Mat::randn(20, 8, 1.0, &mut r);
        let mut h = MatF64::zeros(8, 8);
        let rows: Vec<&[f32]> = (0..20).map(|i| x.row(i)).collect();
        h.syrk_add_2xtx(&rows);
        let explicit = x.t().matmul(&x); // X^T X in f32
        for i in 0..8 {
            for j in 0..8 {
                assert!(
                    (h[(i, j)] - 2.0 * explicit[(i, j)] as f64).abs() < 1e-2,
                    "({i},{j})"
                );
            }
        }
        // symmetry
        for i in 0..8 {
            for j in 0..8 {
                assert!((h[(i, j)] - h[(j, i)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn sparsity_and_nnz() {
        let mut m = Mat::zeros(4, 4);
        m[(0, 0)] = 1.0;
        m[(3, 3)] = -2.0;
        assert_eq!(m.nnz(), 2);
        assert!((m.sparsity() - 14.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn prop_matmul_linear_in_a() {
        prop_check(
            "matmul-linearity",
            16,
            |r| {
                let n = r.range(1, 12);
                let k = r.range(1, 12);
                let m = r.range(1, 12);
                let a = Mat::randn(n, k, 1.0, r);
                let b = Mat::randn(k, m, 1.0, r);
                (a, b)
            },
            |(a, b)| {
                let mut a2 = a.clone();
                a2.scale(2.0);
                let mut lhs = a.matmul(b);
                lhs.scale(2.0);
                a2.matmul(b).max_abs_diff(&lhs) < 1e-3
            },
        );
    }

    #[test]
    fn prop_submatrix_consistent() {
        prop_check(
            "f64-submatrix",
            16,
            |r| {
                let n = r.range(2, 10);
                let mut m = MatF64::zeros(n, n);
                for v in m.data.iter_mut() {
                    *v = r.normal();
                }
                let i = r.below(n);
                let j = r.below(n);
                (m, i, j)
            },
            |(m, i, j)| {
                let s = m.sub(&[*i], &[*j]);
                s[(0, 0)] == m[(*i, *j)]
            },
        );
    }
}
