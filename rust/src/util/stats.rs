//! Streaming statistics (Welford) and quantile summaries for metrics/benches.

/// Online mean/variance accumulator (Welford's algorithm).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean = mean;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact quantiles over a retained sample buffer (fine for bench sizes).
#[derive(Clone, Debug, Default)]
pub struct Quantiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Quantiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// q in [0,1]; linear interpolation between order statistics.
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!(!self.xs.is_empty());
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q.clamp(0.0, 1.0) * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_merge_equals_single_pass() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let (mut a, mut b) = (Welford::new(), Welford::new());
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-10);
        assert!((a.variance() - whole.variance()).abs() < 1e-10);
    }

    #[test]
    fn quantiles_basic() {
        let mut q = Quantiles::new();
        for i in 0..=100 {
            q.push(i as f64);
        }
        assert!((q.median() - 50.0).abs() < 1e-12);
        assert!((q.quantile(0.0) - 0.0).abs() < 1e-12);
        assert!((q.quantile(1.0) - 100.0).abs() < 1e-12);
        assert!((q.quantile(0.95) - 95.0).abs() < 1e-9);
    }
}
