//! Shared utilities: PRNG, statistics, timing, property testing.

pub mod prop;
pub mod rng;
pub mod stats;
pub mod time;

pub use rng::Rng;
pub use stats::{Quantiles, Welford};
pub use time::{profile, profile_report, profile_reset, Timer};

/// Number of worker threads used by threaded kernels (half the cores,
/// overridable via APT_THREADS).
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("APT_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    })
}
