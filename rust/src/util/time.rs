//! Wall-clock timers and a tiny scoped-section profiler for the perf pass.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// RAII stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Global named-section accumulator: `profile("hessian", || ...)`.
/// Dumped by `profile_report()` at the end of pipeline runs.
static SECTIONS: Mutex<BTreeMap<&'static str, (u64, Duration)>> = Mutex::new(BTreeMap::new());

pub fn profile<T>(name: &'static str, f: impl FnOnce() -> T) -> T {
    let t = Instant::now();
    let out = f();
    let dt = t.elapsed();
    let mut map = SECTIONS.lock().unwrap();
    let e = map.entry(name).or_insert((0, Duration::ZERO));
    e.0 += 1;
    e.1 += dt;
    out
}

/// Formatted per-section totals (count, total ms, mean ms), sorted by total.
pub fn profile_report() -> String {
    let map = SECTIONS.lock().unwrap();
    let mut rows: Vec<_> = map.iter().map(|(k, v)| (*k, v.0, v.1)).collect();
    rows.sort_by(|a, b| b.2.cmp(&a.2));
    let mut s = String::from("section                          calls   total_ms    mean_ms\n");
    for (name, calls, total) in rows {
        let tms = total.as_secs_f64() * 1e3;
        s.push_str(&format!(
            "{name:<32} {calls:>5} {tms:>10.2} {:>10.3}\n",
            tms / calls.max(1) as f64
        ));
    }
    s
}

pub fn profile_reset() {
    SECTIONS.lock().unwrap().clear();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_something() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn profile_accumulates() {
        profile_reset();
        for _ in 0..3 {
            profile("unit-test-section", || std::thread::sleep(Duration::from_millis(1)));
        }
        let rep = profile_report();
        assert!(rep.contains("unit-test-section"), "{rep}");
        assert!(rep.contains("    3"), "{rep}");
    }
}
