//! Mini property-testing framework (proptest is unavailable offline).
//!
//! `prop_check(name, cases, gen, prop)` generates `cases` random inputs
//! from `gen`, asserts `prop` on each, and on failure reports the seed and
//! a greedy shrink (halving numeric fields via the `Shrink` trait when
//! implemented). Deterministic per (name, case-index) so failures replay.

use super::rng::Rng;

/// Run a property over `cases` generated inputs; panics with the failing
/// seed + debug repr on the first violation (after attempting a shrink).
pub fn prop_check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let base = fxhash(name);
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x})\ninput: {input:#?}"
            );
        }
    }
}

/// Like `prop_check` but the property returns Result with a reason.
pub fn prop_check_msg<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base = fxhash(name);
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        prop_check("add-commutes", 64, |r| (r.below(1000), r.below(1000)), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_reports() {
        prop_check("always-false", 4, |r| r.below(10), |_| false);
    }

    #[test]
    fn deterministic_inputs_per_name() {
        let mut seen1 = Vec::new();
        prop_check("det", 8, |r| r.next_u64(), |&x| {
            seen1.push(x);
            true
        });
        let mut seen2 = Vec::new();
        prop_check("det", 8, |r| r.next_u64(), |&x| {
            seen2.push(x);
            true
        });
        assert_eq!(seen1, seen2);
    }
}
