//! Deterministic PRNG: xoshiro256** (no external `rand` crate offline).
//!
//! Every stochastic component in the repo (data generation, model init,
//! calibration sampling, property tests) threads one of these through, so
//! every table in EXPERIMENTS.md is bit-reproducible from its seed.

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/sequential seeds decorrelate.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// N(mu, sigma^2) as f32.
    pub fn normal_f32(&mut self, mu: f32, sigma: f32) -> f32 {
        mu + sigma * self.normal() as f32
    }

    /// Fill a slice with N(0, sigma^2) samples.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, sigma);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Split off an independent child stream (for per-worker determinism).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(4);
        let mean: f64 = (0..100_000).map(|_| r.uniform()).sum::<f64>() / 100_000.0;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(6);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(7);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Rng::new(8);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "{frac2}");
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Rng::new(9);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
