//! f64 symmetric linear algebra for the second-order pruning math:
//! Cholesky factorization, triangular solves, SPD inverse.
//!
//! All Hessian-side computation runs in f64 (the paper works at fp16/fp32
//! on GPU but relies on well-conditioned H; at our small calibration sizes
//! f64 removes the conditioning confound entirely — DESIGN.md SS7).

use crate::tensor::MatF64;

/// Lower-triangular Cholesky factor L with A = L L^T.
/// Returns None if A is not (numerically) positive definite.
pub fn cholesky(a: &MatF64) -> Option<MatF64> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols);
    let mut l = MatF64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Solve L y = b for lower-triangular L.
pub fn solve_lower(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = b.to_vec();
    for i in 0..n {
        let mut s = y[i];
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] * y[k];
        }
        y[i] = s / row[i];
    }
    y
}

/// Solve L^T x = y for lower-triangular L.
pub fn solve_lower_t(l: &MatF64, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
pub fn solve_spd(a: &MatF64, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky(a)?;
    Some(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Solve A X = B column-wise for SPD A, B given as rows of a matrix
/// (i.e. returns X with X.cols == B.cols). Reuses one factorization.
pub fn solve_spd_multi(a: &MatF64, b: &MatF64) -> Option<MatF64> {
    let l = cholesky(a)?;
    let n = a.rows;
    assert_eq!(b.rows, n);
    let mut out = MatF64::zeros(n, b.cols);
    let mut col = vec![0.0; n];
    for j in 0..b.cols {
        for i in 0..n {
            col[i] = b[(i, j)];
        }
        let x = solve_lower_t(&l, &solve_lower(&l, &col));
        for i in 0..n {
            out[(i, j)] = x[i];
        }
    }
    Some(out)
}

/// SPD inverse via Cholesky: A^-1 = L^-T L^-1.
pub fn inv_spd(a: &MatF64) -> Option<MatF64> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Invert L (lower triangular) in place into linv.
    let mut linv = MatF64::zeros(n, n);
    for j in 0..n {
        linv[(j, j)] = 1.0 / l[(j, j)];
        for i in j + 1..n {
            let mut s = 0.0;
            for k in j..i {
                s -= l[(i, k)] * linv[(k, j)];
            }
            linv[(i, j)] = s / l[(i, i)];
        }
    }
    // A^-1 = L^-T L^-1 (only lower part computed, then mirrored).
    let mut inv = MatF64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0;
            for k in i..n {
                s += linv[(k, i)] * linv[(k, j)];
            }
            inv[(i, j)] = s;
            inv[(j, i)] = s;
        }
    }
    Some(inv)
}

/// Upper Cholesky factor U of A with A = U^T U (SparseGPT sweep wants the
/// upper factor of Hinv). U = transpose of the lower factor.
pub fn cholesky_upper(a: &MatF64) -> Option<MatF64> {
    let l = cholesky(a)?;
    let n = l.rows;
    let mut u = MatF64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            u[(j, i)] = l[(i, j)];
        }
    }
    Some(u)
}

/// ||A x - b||_inf residual check helper.
pub fn residual_inf(a: &MatF64, x: &[f64], b: &[f64]) -> f64 {
    let n = a.rows;
    let mut worst = 0.0f64;
    for i in 0..n {
        let mut s = 0.0;
        let row = a.row(i);
        for k in 0..n {
            s += row[k] * x[k];
        }
        worst = worst.max((s - b[i]).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check_msg;
    use crate::util::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> MatF64 {
        // A = B B^T + n*I, well-conditioned by construction.
        let mut b = MatF64::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut a = MatF64::eye(n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[(i, k)] * b[(j, k)];
                }
                a[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut r = Rng::new(10);
        let a = random_spd(12, &mut r);
        let l = cholesky(&a).unwrap();
        for i in 0..12 {
            for j in 0..12 {
                let mut s = 0.0;
                for k in 0..12 {
                    s += l[(i, k)] * l[(j, k)];
                }
                assert!((s - a[(i, j)]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = MatF64::eye(3);
        a[(1, 1)] = -2.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_small_residual() {
        let mut r = Rng::new(11);
        let a = random_spd(20, &mut r);
        let b: Vec<f64> = (0..20).map(|_| r.normal()).collect();
        let x = solve_spd(&a, &b).unwrap();
        assert!(residual_inf(&a, &x, &b) < 1e-8);
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let mut r = Rng::new(12);
        let a = random_spd(16, &mut r);
        let inv = inv_spd(&a).unwrap();
        for i in 0..16 {
            for j in 0..16 {
                let mut s = 0.0;
                for k in 0..16 {
                    s += inv[(i, k)] * a[(k, j)];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < 1e-8, "({i},{j}) {s}");
            }
        }
    }

    #[test]
    fn upper_factor_matches() {
        let mut r = Rng::new(13);
        let a = random_spd(10, &mut r);
        let u = cholesky_upper(&a).unwrap();
        for i in 0..10 {
            for j in 0..10 {
                let mut s = 0.0;
                for k in 0..10 {
                    s += u[(k, i)] * u[(k, j)];
                }
                assert!((s - a[(i, j)]).abs() < 1e-9);
            }
        }
        // strictly lower part is zero
        for i in 1..10 {
            for j in 0..i {
                assert_eq!(u[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn solve_multi_matches_single() {
        let mut r = Rng::new(14);
        let a = random_spd(8, &mut r);
        let mut b = MatF64::zeros(8, 3);
        for v in b.data.iter_mut() {
            *v = r.normal();
        }
        let x = solve_spd_multi(&a, &b).unwrap();
        for j in 0..3 {
            let col: Vec<f64> = (0..8).map(|i| b[(i, j)]).collect();
            let xj = solve_spd(&a, &col).unwrap();
            for i in 0..8 {
                assert!((x[(i, j)] - xj[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn prop_solve_random_spd() {
        prop_check_msg(
            "solve-spd-residual",
            24,
            |r| {
                let n = r.range(1, 24);
                let a = random_spd(n, r);
                let b: Vec<f64> = (0..n).map(|_| r.normal() * 10.0).collect();
                (a, b)
            },
            |(a, b)| {
                let x = solve_spd(a, b).ok_or("not SPD?")?;
                let res = residual_inf(a, &x, b);
                if res < 1e-7 {
                    Ok(())
                } else {
                    Err(format!("residual {res}"))
                }
            },
        );
    }
}
