//! f64 symmetric linear algebra for the second-order pruning math:
//! Cholesky factorization (unblocked, blocked-parallel, and incremental),
//! triangular solves, SPD inverse.
//!
//! All Hessian-side computation runs in f64 (the paper works at fp16/fp32
//! on GPU but relies on well-conditioned H; at our small calibration sizes
//! f64 removes the conditioning confound entirely — DESIGN.md SS7).
//!
//! The incremental pieces ([`GrowingCholesky`], [`cholesky_append`]) exist
//! for the MRP hot path: blockwise pruning only ever *adds* columns to a
//! row's pruned set, so the factor of `Hinv[P, P]` can be rank-extended in
//! O(|ΔP|·|P|²) instead of re-factored from scratch in O(|P|³) per block
//! (see PERF.md for the math and measurements).

use crate::tensor::MatF64;
use crate::util::num_threads;

/// Size at which [`cholesky`] switches to the blocked-parallel kernel.
const CHOLESKY_BLOCK_THRESHOLD: usize = 128;
/// Panel width of the blocked kernel.
const CHOLESKY_BLOCK: usize = 64;

/// Lower-triangular Cholesky factor L with A = L L^T.
/// Returns None if A is not (numerically) positive definite.
/// Dispatches to the blocked-parallel kernel for large matrices.
pub fn cholesky(a: &MatF64) -> Option<MatF64> {
    if a.rows >= CHOLESKY_BLOCK_THRESHOLD {
        cholesky_blocked(a, CHOLESKY_BLOCK)
    } else {
        cholesky_unblocked(a)
    }
}

/// Scalar three-loop Cholesky (the reference kernel; right size for the
/// small per-row systems of the pruning math).
pub fn cholesky_unblocked(a: &MatF64) -> Option<MatF64> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols);
    let mut l = MatF64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Some(l)
}

/// Blocked right-looking Cholesky with a thread-parallel panel solve and
/// trailing update. Same result as [`cholesky_unblocked`] up to rounding;
/// the trailing update is where ~all the FLOPs are, and it parallelizes
/// over row chunks.
pub fn cholesky_blocked(a: &MatF64, block: usize) -> Option<MatF64> {
    let n = a.rows;
    assert_eq!(a.rows, a.cols);
    let b = block.max(8);
    let mut l = a.clone();
    let nt = num_threads();
    let mut k0 = 0;
    while k0 < n {
        let k1 = (k0 + b).min(n);
        let bw = k1 - k0;
        // 1) unblocked factor of the diagonal block, in place. Earlier
        //    panels' contributions were already subtracted by trailing
        //    updates, so only columns [k0, k1) participate.
        for i in k0..k1 {
            for j in k0..=i {
                let ri = i * n + k0;
                let rj = j * n + k0;
                let mut s = l.data[ri + (j - k0)];
                for t in 0..(j - k0) {
                    s -= l.data[ri + t] * l.data[rj + t];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return None;
                    }
                    l.data[ri + (j - k0)] = s.sqrt();
                } else {
                    l.data[ri + (j - k0)] = s / l.data[rj + (j - k0)];
                }
            }
        }
        if k1 < n {
            // Snapshot the factored diagonal block so worker threads can
            // read it while mutating their own rows.
            let mut diag = vec![0.0f64; bw * bw];
            for i in 0..bw {
                for j in 0..bw {
                    diag[i * bw + j] = l.data[(k0 + i) * n + k0 + j];
                }
            }
            let diag = &diag;
            let rows_below = n - k1;
            let chunk = rows_below.div_ceil(nt.min(rows_below));
            // 2) panel solve: L[i, k0..k1] = A'[i, k0..k1] · L_kk^{-T},
            //    row-parallel (each row only reads `diag` + itself).
            {
                let trailing = &mut l.data[k1 * n..];
                std::thread::scope(|s| {
                    for rows in trailing.chunks_mut(chunk * n) {
                        s.spawn(move || {
                            for row in rows.chunks_mut(n) {
                                for j in 0..bw {
                                    let mut v = row[k0 + j];
                                    for t in 0..j {
                                        v -= row[k0 + t] * diag[j * bw + t];
                                    }
                                    row[k0 + j] = v / diag[j * bw + j];
                                }
                            }
                        });
                    }
                });
            }
            // 3) trailing update A'[i, j] -= Σ_t L[i, t] L[j, t] over the
            //    lower triangle j ≤ i, t ∈ [k0, k1). Workers write only
            //    their own rows and read the shared panel snapshot.
            //    Row gi costs gi+1 dot products, so contiguous chunks
            //    would leave the last worker ~2× the average work;
            //    interleaved ownership (gi % nw == worker, the
            //    `syrk_add_2xtx` idiom) keeps the triangle balanced.
            let mut panel = vec![0.0f64; rows_below * bw];
            for (pi, i) in (k1..n).enumerate() {
                panel[pi * bw..(pi + 1) * bw]
                    .copy_from_slice(&l.data[i * n + k0..i * n + k1]);
            }
            let panel = &panel;
            let trailing = &mut l.data[k1 * n..];
            let nw = nt.min(rows_below);
            let base = trailing.as_mut_ptr() as usize;
            std::thread::scope(|s| {
                for worker in 0..nw {
                    s.spawn(move || {
                        let mut gi = worker;
                        while gi < rows_below {
                            // SAFETY: trailing rows are disjoint across
                            // workers (gi % nw == worker) and live for
                            // the scope.
                            let row: &mut [f64] = unsafe {
                                std::slice::from_raw_parts_mut(
                                    (base as *mut f64).add(gi * n),
                                    n,
                                )
                            };
                            let prow = &panel[gi * bw..(gi + 1) * bw];
                            for gj in 0..=gi {
                                let pj = &panel[gj * bw..(gj + 1) * bw];
                                let mut s2 = 0.0;
                                for t in 0..bw {
                                    s2 = prow[t].mul_add(pj[t], s2);
                                }
                                row[k1 + gj] -= s2;
                            }
                            gi += nw;
                        }
                    });
                }
            });
        }
        k0 = k1;
    }
    // The algorithm only maintains the lower triangle; zero the rest.
    for i in 0..n {
        for j in i + 1..n {
            l.data[i * n + j] = 0.0;
        }
    }
    Some(l)
}

/// Given the lower factor `l` of SPD A (n×n) and the bordering blocks of
/// the extended matrix
///     A' = [[A, B], [Bᵀ, C]]    (B: n×k, C: k×k),
/// return the lower factor of A' in O(k·n² + k²·n + k³) instead of
/// re-factoring from scratch in O((n+k)³):
///     L' = [[L, 0], [Y, L22]],  Y = Bᵀ L^{-T},  L22 = chol(C - Y Yᵀ).
/// Returns None if the extension is not positive definite.
pub fn cholesky_append(l: &MatF64, b: &MatF64, c: &MatF64) -> Option<MatF64> {
    let n = l.rows;
    let k = c.rows;
    assert_eq!(l.cols, n);
    assert_eq!((b.rows, b.cols), (n, k));
    assert_eq!(c.cols, k);
    let mut out = MatF64::zeros(n + k, n + k);
    for i in 0..n {
        out.row_mut(i)[..=i].copy_from_slice(&l.row(i)[..=i]);
    }
    // Rows of Y: forward-substitute each column of B through L.
    for j in 0..k {
        for i in 0..n {
            let mut s = b[(i, j)];
            let lrow = l.row(i);
            for t in 0..i {
                s -= lrow[t] * out[(n + j, t)];
            }
            out[(n + j, i)] = s / lrow[i];
        }
    }
    // Factor the Schur complement C - Y Yᵀ into the bottom-right corner.
    for i in 0..k {
        for j in 0..=i {
            let mut s = c[(i, j)];
            for t in 0..n {
                s -= out[(n + i, t)] * out[(n + j, t)];
            }
            for t in 0..j {
                s -= out[(n + i, n + t)] * out[(n + j, n + t)];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                out[(n + i, n + i)] = s.sqrt();
            } else {
                out[(n + i, n + j)] = s / out[(n + j, n + j)];
            }
        }
    }
    Some(out)
}

/// Incrementally grown Cholesky factor, packed row-major lower-triangular
/// (row i occupies `i+1` entries at offset `i(i+1)/2`).
///
/// This is the MRP solver's per-row state: each blockwise pruning step
/// appends the block's newly pruned columns via [`GrowingCholesky::push`]
/// (O(n²) each), so factoring a row's final pruned set across all blocks
/// costs one O(|P|³/3) total instead of O(blocks · |P|³/3).
#[derive(Clone, Debug, Default)]
pub struct GrowingCholesky {
    l: Vec<f64>,
    n: usize,
}

impl GrowingCholesky {
    pub fn new() -> Self {
        GrowingCholesky { l: Vec::new(), n: 0 }
    }

    /// Pre-allocate for an expected final dimension.
    pub fn with_capacity(dim: usize) -> Self {
        GrowingCholesky { l: Vec::with_capacity(dim * (dim + 1) / 2), n: 0 }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Row i of the factor (length i+1).
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        let off = i * (i + 1) / 2;
        &self.l[off..off + i + 1]
    }

    /// Extend the factored matrix by one row/column: `a_row[k]` must hold
    /// A[new, k] against the `len()` existing indices, `a_diag` = A[new, new].
    /// Returns None (leaving the factor unchanged) if the extension is not
    /// positive definite.
    pub fn push(&mut self, a_row: &[f64], a_diag: f64) -> Option<()> {
        let n = self.n;
        assert_eq!(a_row.len(), n);
        let off = self.l.len();
        debug_assert_eq!(off, n * (n + 1) / 2);
        // Forward-substitute y = L⁻¹ a_row in place at the tail.
        self.l.extend_from_slice(a_row);
        for i in 0..n {
            let (head, tail) = self.l.split_at_mut(off);
            let roff = i * (i + 1) / 2;
            let lrow = &head[roff..roff + i + 1];
            let mut s = tail[i];
            for (k, &lik) in lrow[..i].iter().enumerate() {
                s -= lik * tail[k];
            }
            tail[i] = s / lrow[i];
        }
        let mut d = a_diag;
        for &y in &self.l[off..] {
            d -= y * y;
        }
        if d <= 0.0 || !d.is_finite() {
            self.l.truncate(off);
            return None;
        }
        self.l.push(d.sqrt());
        self.n = n + 1;
        Some(())
    }

    /// Solve (L Lᵀ) x = rhs into `out`.
    pub fn solve_into(&self, rhs: &[f64], out: &mut Vec<f64>) {
        self.solve_prefix_sparse(rhs, 0, out);
    }

    /// Solve (L Lᵀ) x = rhs where `rhs[..zero_prefix]` is exactly zero.
    ///
    /// Forward substitution then provably yields y[..zero_prefix] == 0
    /// (y₀ = 0 and inductively yᵢ = (0 - Σ Lᵢₖ·0)/Lᵢᵢ = 0), so the forward
    /// pass skips the prefix entirely: O(|Δ|·n) instead of O(n²), where
    /// |Δ| = n - zero_prefix. The backward pass is dense, O(n²).
    pub fn solve_prefix_sparse(&self, rhs: &[f64], zero_prefix: usize, out: &mut Vec<f64>) {
        let n = self.n;
        assert_eq!(rhs.len(), n);
        let z = zero_prefix.min(n);
        debug_assert!(rhs[..z].iter().all(|&v| v == 0.0), "prefix must be exactly zero");
        out.clear();
        out.extend_from_slice(rhs);
        for i in z..n {
            let row = self.row(i);
            let mut s = out[i];
            for k in z..i {
                s -= row[k] * out[k];
            }
            out[i] = s / row[i];
        }
        for i in (0..n).rev() {
            let mut s = out[i];
            // Column i of L below the diagonal: L[k, i] for k > i lives at
            // packed offset k(k+1)/2 + i; consecutive k differ by k+1.
            let mut idx = (i + 1) * (i + 2) / 2 + i;
            for k in i + 1..n {
                s -= self.l[idx] * out[k];
                idx += k + 1;
            }
            out[i] = s / self.row(i)[i];
        }
    }
}

/// Solve L y = b for lower-triangular L.
pub fn solve_lower(l: &MatF64, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut y = b.to_vec();
    for i in 0..n {
        let mut s = y[i];
        let row = l.row(i);
        for k in 0..i {
            s -= row[k] * y[k];
        }
        y[i] = s / row[i];
    }
    y
}

/// Solve L^T x = y for lower-triangular L.
pub fn solve_lower_t(l: &MatF64, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    let mut x = y.to_vec();
    for i in (0..n).rev() {
        let mut s = x[i];
        for k in i + 1..n {
            s -= l[(k, i)] * x[k];
        }
        x[i] = s / l[(i, i)];
    }
    x
}

/// Solve A x = b for SPD A via Cholesky.
///
/// Always uses the serial kernel: this runs per-row inside the pruning
/// solvers' already-parallel worker pools, where the blocked kernel's
/// nested `thread::scope` spawns would oversubscribe the machine.
pub fn solve_spd(a: &MatF64, b: &[f64]) -> Option<Vec<f64>> {
    let l = cholesky_unblocked(a)?;
    Some(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Solve A X = B column-wise for SPD A, B given as rows of a matrix
/// (i.e. returns X with X.cols == B.cols). Reuses one factorization.
pub fn solve_spd_multi(a: &MatF64, b: &MatF64) -> Option<MatF64> {
    let l = cholesky(a)?;
    let n = a.rows;
    assert_eq!(b.rows, n);
    let mut out = MatF64::zeros(n, b.cols);
    let mut col = vec![0.0; n];
    for j in 0..b.cols {
        for i in 0..n {
            col[i] = b[(i, j)];
        }
        let x = solve_lower_t(&l, &solve_lower(&l, &col));
        for i in 0..n {
            out[(i, j)] = x[i];
        }
    }
    Some(out)
}

/// SPD inverse via Cholesky: A^-1 = L^-T L^-1.
pub fn inv_spd(a: &MatF64) -> Option<MatF64> {
    let l = cholesky(a)?;
    let n = a.rows;
    // Invert L (lower triangular) in place into linv.
    let mut linv = MatF64::zeros(n, n);
    for j in 0..n {
        linv[(j, j)] = 1.0 / l[(j, j)];
        for i in j + 1..n {
            let mut s = 0.0;
            for k in j..i {
                s -= l[(i, k)] * linv[(k, j)];
            }
            linv[(i, j)] = s / l[(i, i)];
        }
    }
    // A^-1 = L^-T L^-1 (only lower part computed, then mirrored).
    let mut inv = MatF64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = 0.0;
            for k in i..n {
                s += linv[(k, i)] * linv[(k, j)];
            }
            inv[(i, j)] = s;
            inv[(j, i)] = s;
        }
    }
    Some(inv)
}

/// Upper Cholesky factor U of A with A = U^T U (SparseGPT sweep wants the
/// upper factor of Hinv). U = transpose of the lower factor.
pub fn cholesky_upper(a: &MatF64) -> Option<MatF64> {
    let l = cholesky(a)?;
    let n = l.rows;
    let mut u = MatF64::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            u[(j, i)] = l[(i, j)];
        }
    }
    Some(u)
}

/// ||A x - b||_inf residual check helper.
pub fn residual_inf(a: &MatF64, x: &[f64], b: &[f64]) -> f64 {
    let n = a.rows;
    let mut worst = 0.0f64;
    for i in 0..n {
        let mut s = 0.0;
        let row = a.row(i);
        for k in 0..n {
            s += row[k] * x[k];
        }
        worst = worst.max((s - b[i]).abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_check_msg;
    use crate::util::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> MatF64 {
        // A = B B^T + n*I, well-conditioned by construction.
        let mut b = MatF64::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut a = MatF64::eye(n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b[(i, k)] * b[(j, k)];
                }
                a[(i, j)] = s + if i == j { n as f64 } else { 0.0 };
            }
        }
        a
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut r = Rng::new(10);
        let a = random_spd(12, &mut r);
        let l = cholesky(&a).unwrap();
        for i in 0..12 {
            for j in 0..12 {
                let mut s = 0.0;
                for k in 0..12 {
                    s += l[(i, k)] * l[(j, k)];
                }
                assert!((s - a[(i, j)]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = MatF64::eye(3);
        a[(1, 1)] = -2.0;
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_spd_small_residual() {
        let mut r = Rng::new(11);
        let a = random_spd(20, &mut r);
        let b: Vec<f64> = (0..20).map(|_| r.normal()).collect();
        let x = solve_spd(&a, &b).unwrap();
        assert!(residual_inf(&a, &x, &b) < 1e-8);
    }

    #[test]
    fn inverse_times_a_is_identity() {
        let mut r = Rng::new(12);
        let a = random_spd(16, &mut r);
        let inv = inv_spd(&a).unwrap();
        for i in 0..16 {
            for j in 0..16 {
                let mut s = 0.0;
                for k in 0..16 {
                    s += inv[(i, k)] * a[(k, j)];
                }
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((s - expect).abs() < 1e-8, "({i},{j}) {s}");
            }
        }
    }

    #[test]
    fn upper_factor_matches() {
        let mut r = Rng::new(13);
        let a = random_spd(10, &mut r);
        let u = cholesky_upper(&a).unwrap();
        for i in 0..10 {
            for j in 0..10 {
                let mut s = 0.0;
                for k in 0..10 {
                    s += u[(k, i)] * u[(k, j)];
                }
                assert!((s - a[(i, j)]).abs() < 1e-9);
            }
        }
        // strictly lower part is zero
        for i in 1..10 {
            for j in 0..i {
                assert_eq!(u[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn solve_multi_matches_single() {
        let mut r = Rng::new(14);
        let a = random_spd(8, &mut r);
        let mut b = MatF64::zeros(8, 3);
        for v in b.data.iter_mut() {
            *v = r.normal();
        }
        let x = solve_spd_multi(&a, &b).unwrap();
        for j in 0..3 {
            let col: Vec<f64> = (0..8).map(|i| b[(i, j)]).collect();
            let xj = solve_spd(&a, &col).unwrap();
            for i in 0..8 {
                assert!((x[(i, j)] - xj[i]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn blocked_matches_unblocked() {
        let mut r = Rng::new(15);
        // Deliberately not a multiple of the panel width, and large enough
        // to cross several panels.
        for n in [1, 7, 100, 150] {
            let a = random_spd(n, &mut r);
            let lu = cholesky_unblocked(&a).unwrap();
            let lb = cholesky_blocked(&a, 32).unwrap();
            assert!(lu.max_abs_diff(&lb) < 1e-8, "n={n}: {}", lu.max_abs_diff(&lb));
        }
    }

    #[test]
    fn blocked_rejects_indefinite() {
        let mut r = Rng::new(16);
        let mut a = random_spd(40, &mut r);
        a[(25, 25)] = -1.0;
        assert!(cholesky_blocked(&a, 16).is_none());
    }

    #[test]
    fn dispatcher_uses_blocked_above_threshold() {
        let mut r = Rng::new(17);
        let a = random_spd(CHOLESKY_BLOCK_THRESHOLD + 5, &mut r);
        let l = cholesky(&a).unwrap();
        let lu = cholesky_unblocked(&a).unwrap();
        assert!(l.max_abs_diff(&lu) < 1e-8);
    }

    #[test]
    fn append_matches_full_factor() {
        let mut r = Rng::new(18);
        let a = random_spd(20, &mut r);
        let (n0, k) = (14, 6);
        let idx: Vec<usize> = (0..n0).collect();
        let l0 = cholesky_unblocked(&a.sub(&idx, &idx)).unwrap();
        let mut b = MatF64::zeros(n0, k);
        let mut c = MatF64::zeros(k, k);
        for i in 0..n0 {
            for j in 0..k {
                b[(i, j)] = a[(i, n0 + j)];
            }
        }
        for i in 0..k {
            for j in 0..k {
                c[(i, j)] = a[(n0 + i, n0 + j)];
            }
        }
        let lx = cholesky_append(&l0, &b, &c).unwrap();
        let lf = cholesky_unblocked(&a).unwrap();
        assert!(lx.max_abs_diff(&lf) < 1e-9, "{}", lx.max_abs_diff(&lf));
    }

    #[test]
    fn growing_factor_matches_batch() {
        let mut r = Rng::new(19);
        let a = random_spd(24, &mut r);
        let mut g = GrowingCholesky::with_capacity(24);
        for i in 0..24 {
            let row: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            g.push(&row, a[(i, i)]).expect("SPD extension");
        }
        assert_eq!(g.len(), 24);
        let l = cholesky_unblocked(&a).unwrap();
        for i in 0..24 {
            for (j, &v) in g.row(i).iter().enumerate() {
                assert!((v - l[(i, j)]).abs() < 1e-9, "({i},{j})");
            }
        }
    }

    #[test]
    fn growing_push_rejects_indefinite_and_recovers() {
        let mut g = GrowingCholesky::new();
        g.push(&[], 4.0).unwrap();
        // A = [[4, 4], [4, 1]] has det < 0: must be rejected...
        assert!(g.push(&[4.0], 1.0).is_none());
        assert_eq!(g.len(), 1);
        // ...while leaving the factor usable for a valid extension.
        g.push(&[1.0], 9.0).unwrap();
        assert_eq!(g.len(), 2);
        let mut out = Vec::new();
        g.solve_into(&[4.0, 9.25], &mut out);
        // A = [[4, 1], [1, 9]]; x = A⁻¹ b with b = (4, 9.25) -> x = (0.75, 1.0)... check residual instead
        let (r0, r1) = (4.0 * out[0] + 1.0 * out[1] - 4.0, 1.0 * out[0] + 9.0 * out[1] - 9.25);
        assert!(r0.abs() < 1e-12 && r1.abs() < 1e-12, "{out:?}");
    }

    #[test]
    fn growing_solve_matches_solve_spd_with_zero_prefix() {
        let mut r = Rng::new(20);
        let a = random_spd(16, &mut r);
        let mut g = GrowingCholesky::new();
        for i in 0..16 {
            let row: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            g.push(&row, a[(i, i)]).unwrap();
        }
        let mut b: Vec<f64> = (0..16).map(|_| r.normal()).collect();
        for v in b.iter_mut().take(10) {
            *v = 0.0;
        }
        let mut fast = Vec::new();
        g.solve_prefix_sparse(&b, 10, &mut fast);
        let mut dense = Vec::new();
        g.solve_into(&b, &mut dense);
        let reference = solve_spd(&a, &b).unwrap();
        for i in 0..16 {
            assert!((fast[i] - dense[i]).abs() < 1e-12, "sparse vs dense at {i}");
            assert!((fast[i] - reference[i]).abs() < 1e-9, "vs solve_spd at {i}");
        }
    }

    #[test]
    fn prop_solve_random_spd() {
        prop_check_msg(
            "solve-spd-residual",
            24,
            |r| {
                let n = r.range(1, 24);
                let a = random_spd(n, r);
                let b: Vec<f64> = (0..n).map(|_| r.normal() * 10.0).collect();
                (a, b)
            },
            |(a, b)| {
                let x = solve_spd(a, b).ok_or("not SPD?")?;
                let res = residual_inf(a, &x, b);
                if res < 1e-7 {
                    Ok(())
                } else {
                    Err(format!("residual {res}"))
                }
            },
        );
    }
}
