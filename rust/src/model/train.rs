//! AdamW trainer with cosine LR schedule and gradient clipping.
//!
//! Used once per experiment to produce the "well-trained dense model" that
//! post-training pruning assumes (the paper prunes released checkpoints;
//! we train our stand-ins from scratch — DESIGN.md SS2).

use std::collections::BTreeMap;

use crate::data::Dataset;
use crate::io::{ParamStore, TensorStore};
use crate::model::LanguageModel;
use crate::tensor::Mat;
use crate::util::{Rng, Timer};

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub lr: f64,
    pub warmup: usize,
    pub weight_decay: f64,
    pub clip: f64,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 300,
            batch: 8,
            seq_len: 64,
            lr: 3e-3,
            warmup: 30,
            weight_decay: 0.01,
            clip: 1.0,
            log_every: 50,
            seed: 1234,
        }
    }
}

struct AdamState {
    m: BTreeMap<String, Vec<f32>>,
    v: BTreeMap<String, Vec<f32>>,
    t: usize,
}

/// Train in place; returns the per-log-interval mean loss curve.
pub fn train(model: &mut dyn LanguageModel, data: &Dataset, cfg: &TrainConfig) -> Vec<f64> {
    let mut rng = Rng::new(cfg.seed);
    let mut adam = AdamState { m: BTreeMap::new(), v: BTreeMap::new(), t: 0 };
    let (b1, b2, eps) = (0.9f64, 0.95f64, 1e-8f64);
    let mut curve = Vec::new();
    let mut window = Vec::new();
    let timer = Timer::start();

    for step in 0..cfg.steps {
        // sample a batch of windows
        let mut tokens = Vec::with_capacity(cfg.batch * cfg.seq_len);
        for _ in 0..cfg.batch {
            let s = rng.below(data.tokens.len() - cfg.seq_len);
            tokens.extend_from_slice(&data.tokens[s..s + cfg.seq_len]);
        }
        let (loss, grads) = model.loss_and_grads(&tokens, (cfg.batch, cfg.seq_len));
        window.push(loss);

        // global grad-norm clip
        let mut norm2 = 0f64;
        for (_, g) in grads.tensors.iter() {
            norm2 += g.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        }
        let gnorm = norm2.sqrt();
        let clip_scale = if gnorm > cfg.clip { cfg.clip / gnorm } else { 1.0 };

        // lr schedule: linear warmup then cosine to 10%
        adam.t += 1;
        let lr = if step < cfg.warmup {
            cfg.lr * (step + 1) as f64 / cfg.warmup as f64
        } else {
            let p = (step - cfg.warmup) as f64 / (cfg.steps - cfg.warmup).max(1) as f64;
            cfg.lr * (0.1 + 0.45 * (1.0 + (std::f64::consts::PI * p).cos()))
        };
        let bc1 = 1.0 - b1.powi(adam.t as i32);
        let bc2 = 1.0 - b2.powi(adam.t as i32);

        apply_adamw(model.params_mut(), &grads, &mut adam, lr, b1, b2, eps, bc1, bc2,
                    cfg.weight_decay, clip_scale);

        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
            let mean = window.iter().sum::<f64>() / window.len() as f64;
            curve.push(mean);
            log::info!(
                "step {:>5}/{} loss {:.4} lr {:.2e} ({:.1}s)",
                step + 1, cfg.steps, mean, lr, timer.elapsed().as_secs_f64()
            );
            window.clear();
        }
    }
    curve
}

#[allow(clippy::too_many_arguments)]
fn apply_adamw(
    params: &mut ParamStore,
    grads: &TensorStore,
    adam: &mut AdamState,
    lr: f64,
    b1: f64,
    b2: f64,
    eps: f64,
    bc1: f64,
    bc2: f64,
    wd: f64,
    clip_scale: f64,
) {
    for (name, g) in grads.tensors.iter() {
        // Densify on demand: training a packed checkpoint converts the
        // touched tensors back to dense (the paper's setting never does
        // this — post-training pruning — but the trainer must not crash).
        let p: &mut Mat = match params.tensors.get_mut(name) {
            Some(ws) => ws.dense_mut(),
            None => continue,
        };
        let m = adam.m.entry(name.clone()).or_insert_with(|| vec![0.0; g.data.len()]);
        let v = adam.v.entry(name.clone()).or_insert_with(|| vec![0.0; g.data.len()]);
        let decay = if name.contains("norm") || name == "embed" { 0.0 } else { wd };
        for i in 0..g.data.len() {
            let gi = g.data[i] as f64 * clip_scale;
            m[i] = (b1 * m[i] as f64 + (1.0 - b1) * gi) as f32;
            v[i] = (b2 * v[i] as f64 + (1.0 - b2) * gi * gi) as f32;
            let mhat = m[i] as f64 / bc1;
            let vhat = v[i] as f64 / bc2;
            let upd = lr * (mhat / (vhat.sqrt() + eps) + decay * p.data[i] as f64);
            p.data[i] -= upd as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{CorpusGen, Profile};
    use crate::model::{Mamba, MambaConfig, Transformer, TransformerConfig};

    #[test]
    fn training_reduces_loss_transformer() {
        let gen = CorpusGen::new(60, 2, 42);
        let data = gen.generate(Profile::C4Like, 20_000, 1);
        let vocab = gen.tokenizer.vocab_size();
        let mut model = Transformer::init(
            TransformerConfig { vocab, d_model: 32, n_layers: 2, n_heads: 2, d_ff: 48, max_seq: 64 },
            &mut Rng::new(3),
        );
        let cfg = TrainConfig { steps: 60, batch: 4, seq_len: 32, log_every: 10, ..Default::default() };
        let curve = train(&mut model, &data, &cfg);
        assert!(curve.len() >= 5);
        let first = curve[0];
        let last = *curve.last().unwrap();
        assert!(last < first - 0.5, "loss should drop: {first:.3} -> {last:.3}");
    }

    #[test]
    fn training_reduces_loss_mamba() {
        let gen = CorpusGen::new(60, 2, 43);
        let data = gen.generate(Profile::C4Like, 20_000, 2);
        let vocab = gen.tokenizer.vocab_size();
        let mut model = Mamba::init(
            MambaConfig { vocab, d_model: 32, d_inner: 48, n_layers: 2, max_seq: 64 },
            &mut Rng::new(4),
        );
        let cfg = TrainConfig { steps: 60, batch: 4, seq_len: 32, log_every: 10, ..Default::default() };
        let curve = train(&mut model, &data, &cfg);
        let first = curve[0];
        let last = *curve.last().unwrap();
        assert!(last < first - 0.3, "loss should drop: {first:.3} -> {last:.3}");
    }
}
