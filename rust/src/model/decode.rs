//! Incremental decode sessions: the serving path.
//!
//! `predict_last`-style callers used to re-run the **full context through
//! every block on every call** — O(T²·L) per token in a decode loop. A
//! [`DecodeSession`] carries per-block mutable state instead:
//!
//! - **transformer**: per-block K/V caches (RoPE applied at the absolute
//!   position offset); a step runs the 1-token query against the cached
//!   keys/values — O(T·L) per token;
//! - **mamba**: the selective-scan hidden state `h` plus a
//!   `CONV_K − 1`-deep ring buffer for the causal depthwise conv — O(1)
//!   per token in context length.
//!
//! Logits are computed **only for the last position** (`logits_row`,
//! skipping the full (B·T, V) matmul), and the incremental path is
//! pinned to match the full forward to <1e-5 across both families and
//! all three weight layouts (see `incremental_decode_matches_full_forward`
//! in the integration suite).
//!
//! The session API is `prefill(context) → step(token)`; `fork()` clones
//! the state so a prefilled context can be continued down several paths
//! from the same snapshot.
//!
//! Since the serving-engine redesign a session is a thin single-stream
//! wrapper over the same trait primitives the batched
//! [`crate::serve::Engine`] schedules: `prefill` takes the threaded
//! whole-prompt fast path (`prefill_append`), steps take the incremental
//! arm, and [`DecodeSession::with_window`] applies the same sliding-window
//! K/V bound the engine uses for long-running streams. Batched scoring
//! and sampled generation live in [`crate::serve`].

use super::mamba::MambaBlockState;
use super::transformer::TfBlockState;
use super::{log_softmax_at, LanguageModel};

/// Architecture-specific per-session mutable state, one entry per block.
#[derive(Clone, Debug)]
pub enum DecodeState {
    Transformer(Vec<TfBlockState>),
    Mamba(Vec<MambaBlockState>),
}

impl DecodeState {
    /// Bound every per-block K/V cache to the last `window` positions
    /// (sliding-window eviction for long-running serving): the caches
    /// are paged, so eviction advances the page cursor — O(1) per step,
    /// freeing whole pages onto a reuse list instead of shifting rows —
    /// while queries keep attending at absolute positions. Mamba's
    /// recurrent state is O(1) in context length and unaffected.
    pub fn enforce_window(&mut self, window: usize) {
        assert!(window >= 1, "window must hold at least one position");
        if let DecodeState::Transformer(blocks) = self {
            for st in blocks {
                st.k.evict_to(window);
                st.v.evict_to(window);
            }
        }
    }

    /// Roll back every per-block K/V cache to its OLDEST `len` rows —
    /// the speculative-decoding rollback: draft tokens the target
    /// rejected are discarded by moving the paged tail cursor back
    /// ([`crate::tensor::PagedKv::truncate_to`], O(1), freed pages
    /// recycled), never by recomputing. Panics for mamba: recurrent
    /// state folds every consumed token into `h` irreversibly, so
    /// rollback there is a pre-round [`Clone`] snapshot instead (the
    /// `fork` idiom) — see [`crate::serve::speculative`].
    pub fn truncate_to(&mut self, len: usize) {
        match self {
            DecodeState::Transformer(blocks) => {
                for st in blocks {
                    st.k.truncate_to(len);
                    st.v.truncate_to(len);
                }
            }
            DecodeState::Mamba(_) => {
                panic!("mamba state cannot be truncated; snapshot via clone() instead")
            }
        }
    }

    /// Positions currently held in the K/V caches (`None` for mamba,
    /// whose state does not grow with context).
    pub fn cached_len(&self) -> Option<usize> {
        match self {
            DecodeState::Transformer(blocks) => Some(blocks.first().map_or(0, |b| b.k.len())),
            DecodeState::Mamba(_) => None,
        }
    }

    /// K/V pages this state currently holds across every block (the
    /// engine's memory-budget unit). Mamba state is O(1) in context and
    /// holds no pages — it reports 0 and is exempt from the budget.
    pub fn kv_pages_live(&self) -> usize {
        match self {
            DecodeState::Transformer(blocks) => {
                blocks.iter().map(|b| b.k.pages_live() + b.v.pages_live()).sum()
            }
            DecodeState::Mamba(_) => 0,
        }
    }

    /// Pages a state shaped like this one would hold after caching
    /// `positions` rows with no eviction offset (the admission-time
    /// estimate: fresh prefills start page-aligned, so this is exact for
    /// them; an evicted stream can straddle one extra page per cache).
    pub fn kv_pages_for(&self, positions: usize) -> usize {
        match self {
            DecodeState::Transformer(blocks) => blocks
                .iter()
                .map(|b| 2 * positions.div_ceil(b.k.page_rows().max(1)))
                .sum(),
            DecodeState::Mamba(_) => 0,
        }
    }
}

/// Prefill `tokens` into `state` under a sliding-window bound: chunks of
/// `window` tokens with eviction between chunks, so peak cache memory
/// stays O(window) regardless of prompt length (a one-shot prefill would
/// materialize the whole prompt's K/V before trimming). Shared by
/// windowed [`DecodeSession`]s and the engine's admission path so the
/// two stay numerically identical. Returns the final hidden row.
pub(crate) fn prefill_windowed<M: LanguageModel + ?Sized>(
    model: &M,
    state: &mut DecodeState,
    pos0: usize,
    tokens: &[u32],
    window: usize,
) -> Vec<f32> {
    let mut pos = pos0;
    let mut h = None;
    for chunk in tokens.chunks(window.max(1)) {
        h = Some(model.prefill_append(state, pos, chunk));
        pos += chunk.len();
        state.enforce_window(window);
    }
    h.expect("prefill needs at least one token")
}

/// A mutable incremental-decode handle over any [`LanguageModel`].
///
/// ```text
/// let mut s = DecodeSession::new(&model);
/// s.prefill(&context);            // O(T·L) once
/// let tok = s.argmax_last();
/// s.step(tok);                    // O(T·L) per token (O(1)·L for mamba)
/// ```
pub struct DecodeSession<'m, M: LanguageModel + ?Sized> {
    model: &'m M,
    state: DecodeState,
    pos: usize,
    window: Option<usize>,
    last_logits: Option<Vec<f32>>,
}

impl<'m, M: LanguageModel + ?Sized> DecodeSession<'m, M> {
    pub fn new(model: &'m M) -> DecodeSession<'m, M> {
        DecodeSession { model, state: model.decode_state(), pos: 0, window: None, last_logits: None }
    }

    /// Session with a sliding-window K/V bound: appends run in chunks of
    /// at most `window` tokens with the caches trimmed to the last
    /// `window` positions between chunks, so peak memory stays
    /// O(window) even for prompts far longer than the window (mamba
    /// state is O(1) and unaffected). Logits match the unbounded session
    /// exactly while fewer than `window` positions have been consumed;
    /// beyond that, attention is truncated to the most recent cached
    /// tokens — the bounded-memory approximation long-running serving
    /// needs.
    pub fn with_window(model: &'m M, window: usize) -> DecodeSession<'m, M> {
        assert!(window >= 1, "window must hold at least one position");
        DecodeSession {
            model,
            state: model.decode_state(),
            pos: 0,
            window: Some(window),
            last_logits: None,
        }
    }

    /// Tokens consumed so far (prefill + steps).
    pub fn len(&self) -> usize {
        self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.pos == 0
    }

    /// Feed a chunk of tokens (a whole context, or a continuation of
    /// one); returns the logits at the last fed position. On an
    /// unbounded session chunks may be split arbitrarily — a prefill of
    /// `[a, b] + [c]` is equivalent to `[a, b, c]`. On a
    /// [`DecodeSession::with_window`] session eviction runs between
    /// window-sized chunks, so split and one-shot prefills agree only
    /// while the total stays within the window.
    pub fn prefill(&mut self, tokens: &[u32]) -> &[f32] {
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        let h = match self.window {
            Some(w) => prefill_windowed(self.model, &mut self.state, self.pos, tokens, w),
            None => self.model.prefill_append(&mut self.state, self.pos, tokens),
        };
        self.pos += tokens.len();
        self.last_logits = Some(self.model.logits_row(&h));
        self.last_logits.as_deref().unwrap()
    }

    /// Feed one token; returns the logits for the next position.
    pub fn step(&mut self, token: u32) -> &[f32] {
        self.prefill(&[token])
    }

    /// Logits at the last consumed position (panics before any prefill).
    pub fn last_logits(&self) -> &[f32] {
        self.last_logits.as_deref().expect("no tokens consumed yet")
    }

    /// Argmax of the last logits (first max wins on exact ties, same
    /// tie-break as the full-forward `predict_last`).
    pub fn argmax_last(&self) -> u32 {
        argmax(self.last_logits()) as u32
    }

    /// Greedy-generate `n` tokens from the current state (requires at
    /// least one consumed token). Each generated token is fed back, so
    /// the session ends `n` tokens longer.
    pub fn generate(&mut self, n: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let tok = self.argmax_last();
            out.push(tok);
            self.step(tok);
        }
        out
    }

    /// Sum log-prob of `continuation` scored from the current state
    /// (requires a prior prefill), stepping each token but the last.
    /// The single scoring loop behind both the trait's
    /// `continuation_logprob` and the zero-shot candidate scorer.
    pub fn continuation_logprob(&mut self, continuation: &[u32]) -> f64 {
        if continuation.is_empty() {
            return 0.0;
        }
        let mut lp = log_softmax_at(self.last_logits(), continuation[0] as usize);
        for w in continuation.windows(2) {
            self.step(w[0]);
            lp += log_softmax_at(self.last_logits(), w[1] as usize);
        }
        lp
    }

    /// Snapshot the session: an independent copy sharing the model, used
    /// to score multiple continuations of one prefilled context.
    pub fn fork(&self) -> DecodeSession<'m, M> {
        DecodeSession {
            model: self.model,
            state: self.state.clone(),
            pos: self.pos,
            window: self.window,
            last_logits: self.last_logits.clone(),
        }
    }
}

pub(crate) fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Mamba, MambaConfig, Transformer, TransformerConfig};
    use crate::util::Rng;

    fn tiny_transformer(seed: u64) -> Transformer {
        let cfg = TransformerConfig {
            vocab: 31,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 24,
            max_seq: 64,
        };
        Transformer::init(cfg, &mut Rng::new(seed))
    }

    fn tiny_mamba(seed: u64) -> Mamba {
        Mamba::init(
            MambaConfig { vocab: 31, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 64 },
            &mut Rng::new(seed),
        )
    }

    #[test]
    fn session_tracks_length_and_is_deterministic() {
        let m = tiny_transformer(1);
        let toks: Vec<u32> = (0..9).map(|i| (i * 7 % 31) as u32).collect();
        let mut s1 = DecodeSession::new(&m);
        s1.prefill(&toks);
        assert_eq!(s1.len(), 9);
        let mut s2 = DecodeSession::new(&m);
        s2.prefill(&toks);
        assert_eq!(s1.last_logits(), s2.last_logits());
        assert_eq!(s1.step(3), s2.step(3));
        assert_eq!(s1.len(), 10);
    }

    #[test]
    fn generate_extends_session_greedily() {
        for (name, model) in [
            ("microllama", Box::new(tiny_transformer(2)) as Box<dyn LanguageModel>),
            ("micromamba", Box::new(tiny_mamba(3)) as Box<dyn LanguageModel>),
        ] {
            let mut s = DecodeSession::new(model.as_ref());
            s.prefill(&[1, 2, 3]);
            let first = s.argmax_last();
            let gen = s.generate(5);
            assert_eq!(gen.len(), 5, "{name}");
            assert_eq!(gen[0], first, "{name}");
            assert_eq!(s.len(), 8, "{name}");
            assert!(gen.iter().all(|&t| (t as usize) < 31), "{name}");
            // replaying context + generated prefix reproduces the suffix
            let mut replay = DecodeSession::new(model.as_ref());
            let mut ctx = vec![1, 2, 3];
            ctx.extend_from_slice(&gen[..2]);
            replay.prefill(&ctx);
            assert_eq!(replay.argmax_last(), gen[2], "{name}");
        }
    }

    #[test]
    fn fork_is_independent() {
        let m = tiny_mamba(4);
        let mut base = DecodeSession::new(&m);
        base.prefill(&[5, 6, 7]);
        let snapshot = base.last_logits().to_vec();
        let mut a = base.fork();
        a.step(1);
        let mut b = base.fork();
        b.step(2);
        // diverged sessions don't share state, and the base is untouched
        assert_eq!(base.len(), 3);
        assert_eq!(base.last_logits(), &snapshot[..]);
        assert_ne!(a.last_logits(), b.last_logits());
    }

    #[test]
    fn truncate_rolls_back_overshoot_bit_exactly() {
        // The spec-decode rollback contract: append a rejected tail,
        // truncate it away, and the continuation is bit-identical to a
        // state that never saw the overshoot.
        let m = tiny_transformer(8);
        let ctx: Vec<u32> = (0..10).map(|i| (i * 3 % 31) as u32).collect();
        let mut clean = m.decode_state();
        m.prefill_append(&mut clean, 0, &ctx);
        let mut overshot = m.decode_state();
        m.prefill_append(&mut overshot, 0, &ctx);
        m.decode_append(&mut overshot, ctx.len(), &[4, 9, 2, 7]);
        assert_eq!(overshot.cached_len(), Some(ctx.len() + 4));
        overshot.truncate_to(ctx.len());
        assert_eq!(overshot.cached_len(), Some(ctx.len()));
        let h_clean = m.decode_append(&mut clean, ctx.len(), &[11, 13]);
        let h_rolled = m.decode_append(&mut overshot, ctx.len(), &[11, 13]);
        assert_eq!(h_clean, h_rolled);
    }

    #[test]
    #[should_panic(expected = "cannot be truncated")]
    fn mamba_truncate_panics() {
        let m = tiny_mamba(9);
        let mut st = m.decode_state();
        m.decode_append(&mut st, 0, &[1, 2, 3]);
        st.truncate_to(1);
    }

    #[test]
    #[should_panic(expected = "decode state/arch mismatch")]
    fn state_arch_mismatch_panics() {
        let t = tiny_transformer(5);
        let m = tiny_mamba(6);
        let mut state = m.decode_state();
        t.decode_append(&mut state, 0, &[1]);
    }

    #[test]
    #[should_panic(expected = "at least one token")]
    fn empty_prefill_panics() {
        let m = tiny_transformer(7);
        DecodeSession::new(&m).prefill(&[]);
    }
}
