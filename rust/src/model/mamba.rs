//! `micromamba`: a selective-SSM (Mamba-style) decoder with manual
//! forward/backward — the stand-in for the paper's Mamba-130M…2.8B rows.
//!
//! Mamba-lite block (DESIGN.md SS2 substitution table):
//!     n   = rmsnorm(x)
//!     u,z = split(n @ Win^T)                 (in_proj, prunable)
//!     u'  = silu(causal_depthwise_conv3(u))
//!     a   = sigmoid(u' @ Wdt^T)              (dt_proj, prunable; the
//!                                             input-*selective* gate)
//!     h_t = a_t . h_{t-1} + (1-a_t) . u'_t   (selective scan, state=1)
//!     y   = h . silu(z)
//!     out = x + y @ Wout^T                   (out_proj, prunable)
//!
//! The pruning surface (in/dt/out projections) mirrors real Mamba's
//! in_proj/x_proj/dt_proj/out_proj — the layers the paper prunes. The scan
//! itself is weight-free, exactly as in the paper's setting.

use std::borrow::Cow;

use anyhow::Result;

use crate::io::{ParamStore, TensorStore};
use crate::sparse::WeightStore;
use crate::tensor::Mat;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MambaConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub d_inner: usize,
    pub n_layers: usize,
    pub max_seq: usize,
}

impl MambaConfig {
    pub fn small(vocab: usize) -> Self {
        MambaConfig { vocab, d_model: 128, d_inner: 256, n_layers: 4, max_seq: 256 }
    }

    pub fn medium(vocab: usize) -> Self {
        MambaConfig { vocab, d_model: 256, d_inner: 512, n_layers: 6, max_seq: 256 }
    }
}

pub const MAMBA_LINEARS: [&str; 3] = ["in_proj", "dt_proj", "out_proj"];

/// Causal depthwise conv kernel depth; the decode-session ring buffer
/// carries the last `CONV_K - 1` conv inputs per block.
pub const CONV_K: usize = 3;

pub struct Mamba {
    pub cfg: MambaConfig,
    pub params: ParamStore,
}

fn key(b: usize, name: &str) -> String {
    format!("blocks.{b}.{name}")
}

impl Mamba {
    pub fn init(cfg: MambaConfig, rng: &mut Rng) -> Mamba {
        let mut p = ParamStore::new();
        let (d, e) = (cfg.d_model, cfg.d_inner);
        let sigma = 0.02f32;
        p.insert("embed", Mat::randn(cfg.vocab, d, sigma, rng));
        p.insert("final_norm", Mat::from_vec(1, d, vec![1.0; d]));
        for b in 0..cfg.n_layers {
            p.insert(&key(b, "norm"), Mat::from_vec(1, d, vec![1.0; d]));
            p.insert(&key(b, "in_proj"), Mat::randn(2 * e, d, sigma, rng));
            p.insert(&key(b, "dt_proj"), Mat::randn(e, e, sigma, rng));
            p.insert(
                &key(b, "out_proj"),
                Mat::randn(d, e, sigma / (2.0 * cfg.n_layers as f32).sqrt(), rng),
            );
            // depthwise conv: (CONV_K, e) weights + (1, e) bias
            p.insert(&key(b, "conv_w"), Mat::randn(CONV_K, e, 0.2, rng));
            p.insert(&key(b, "conv_b"), Mat::zeros(1, e));
        }
        Mamba { cfg, params: p }
    }

    pub fn n_params(&self) -> usize {
        self.params.total_params()
    }

    pub fn weight(&self, b: usize, name: &str) -> &WeightStore {
        self.params.get(&key(b, name)).expect("weight")
    }

    pub fn weight_mut(&mut self, b: usize, name: &str) -> &mut WeightStore {
        self.params.get_mut(&key(b, name)).expect("weight")
    }

    /// Dense view of a block linear for the backward path.
    fn wdense(&self, b: usize, name: &str) -> Cow<'_, Mat> {
        self.weight(b, name).dense_view()
    }

    pub fn embed(&self, tokens: &[u32]) -> Mat {
        let e = self.params.dense("embed").expect("embed is dense");
        let mut x = Mat::zeros(tokens.len(), self.cfg.d_model);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(e.row(t as usize));
        }
        x
    }

    pub fn block_forward(&self, b: usize, x: &Mat, bt: (usize, usize)) -> Mat {
        self.block_impl(b, x, MambaSeq::Full { bsz: bt.0, t: bt.1 }, None, &mut |_, _| {})
    }

    pub fn block_forward_collect(
        &self,
        b: usize,
        x: &Mat,
        bt: (usize, usize),
        sink: &mut dyn FnMut(&str, &Mat),
    ) -> Mat {
        self.block_impl(b, x, MambaSeq::Full { bsz: bt.0, t: bt.1 }, None, sink)
    }

    /// Incremental block forward: `x` holds newly appended tokens; the
    /// conv ring buffer and scan hidden state carry the context, so each
    /// step is O(1) in context length.
    pub(crate) fn block_decode(&self, b: usize, x: &Mat, st: &mut MambaBlockState) -> Mat {
        self.block_impl(b, x, MambaSeq::Decode { st }, None, &mut |_, _| {})
    }

    /// Batched decode step for one block: row `i` of `x` is stream `i`'s
    /// single new token continuing its own recurrent state `sts[i]`. The
    /// in/dt/out projections each run ONE (B, ·) matmul over the stacked
    /// streams instead of B separate single-row products.
    pub(crate) fn block_decode_batch(
        &self,
        b: usize,
        x: &Mat,
        sts: &mut [&mut MambaBlockState],
    ) -> Mat {
        self.block_impl(b, x, MambaSeq::BatchDecode { sts }, None, &mut |_, _| {})
    }

    /// Fresh per-block recurrent state for a decode session. Zero-filled
    /// history is exactly the causal zero-padding the full forward uses
    /// for positions before the sequence start.
    /// Sized per block from the actual `out_proj` store: structured
    /// pruning may have removed inner channels, so a block's scan/conv
    /// state is `out_proj.cols()` wide, not `d_inner`.
    pub(crate) fn new_block_states(&self) -> Vec<MambaBlockState> {
        (0..self.cfg.n_layers)
            .map(|b| MambaBlockState::new(self.weight(b, "out_proj").cols()))
            .collect()
    }

    fn block_impl(
        &self,
        b: usize,
        x: &Mat,
        mode: MambaSeq<'_, '_>,
        mut cache: Option<&mut MambaCache>,
        sink: &mut dyn FnMut(&str, &Mat),
    ) -> Mat {
        // Per-block inner width from the physical out_proj shape:
        // structured pruning removes whole channels, so a block may run
        // narrower than cfg.d_inner. in_proj (2e rows), dt_proj (e×e),
        // conv (e cols) and the scan state are all sliced by the same
        // kept-channel set, so every width below derives from this one.
        let e = self.weight(b, "out_proj").cols();
        let norm_g = self.params.dense(&key(b, "norm")).unwrap().row(0);
        let n = super::transformer_rmsnorm(x, norm_g);
        sink("in_proj", &n.y);
        let xz = self.weight(b, "in_proj").matmul_tb(&n.y); // (nrow, 2e)
        let (mut u, mut z) = (Mat::zeros(x.rows, e), Mat::zeros(x.rows, e));
        for r in 0..x.rows {
            u.row_mut(r).copy_from_slice(&xz.row(r)[..e]);
            z.row_mut(r).copy_from_slice(&xz.row(r)[e..]);
        }
        // causal depthwise conv + silu (never pruned; always dense)
        let cw = self.params.dense(&key(b, "conv_w")).unwrap();
        let cb = self.params.dense(&key(b, "conv_b")).unwrap();
        let mut pre = Mat::zeros(x.rows, e);
        let mut mode = mode;
        match &mut mode {
            MambaSeq::Full { bsz, t } => {
                for s in 0..*bsz {
                    for pos in 0..*t {
                        let dst = s * *t + pos;
                        for c in 0..e {
                            let mut acc = cb[(0, c)];
                            for kk in 0..CONV_K {
                                if pos >= kk {
                                    acc += cw[(kk, c)] * u[(s * *t + pos - kk, c)];
                                }
                            }
                            pre[(dst, c)] = acc;
                        }
                    }
                }
            }
            MambaSeq::Decode { st } => {
                // positions before the chunk come from the ring buffer
                // (conv[0] = u_{t-1}, conv[1] = u_{t-2}, …)
                let tn = x.rows;
                for pos in 0..tn {
                    for c in 0..e {
                        let mut acc = cb[(0, c)];
                        for kk in 0..CONV_K {
                            let uv = if pos >= kk {
                                u[(pos - kk, c)]
                            } else {
                                st.conv[kk - pos - 1][c]
                            };
                            acc += cw[(kk, c)] * uv;
                        }
                        pre[(pos, c)] = acc;
                    }
                }
                // in-place ring rotation, highest index first so shifted
                // survivors are read before they're overwritten — no
                // allocations on the per-token hot path
                for hi in (0..CONV_K - 1).rev() {
                    if tn > hi {
                        st.conv[hi].copy_from_slice(u.row(tn - 1 - hi));
                    } else {
                        let (head, tail) = st.conv.split_at_mut(hi);
                        tail[0].copy_from_slice(&head[hi - tn]);
                    }
                }
            }
            MambaSeq::BatchDecode { sts } => {
                // one token per stream: same accumulation order as the
                // single-stream arm at pos = 0, per-stream ring buffers
                assert_eq!(sts.len(), x.rows, "one recurrent state per stream");
                for (i, st) in sts.iter_mut().enumerate() {
                    for c in 0..e {
                        let mut acc = cb[(0, c)];
                        for kk in 0..CONV_K {
                            let uv = if kk == 0 { u[(i, c)] } else { st.conv[kk - 1][c] };
                            acc += cw[(kk, c)] * uv;
                        }
                        pre[(i, c)] = acc;
                    }
                    for hi in (1..CONV_K - 1).rev() {
                        let (head, tail) = st.conv.split_at_mut(hi);
                        tail[0].copy_from_slice(&head[hi - 1]);
                    }
                    st.conv[0].copy_from_slice(u.row(i));
                }
            }
        }
        let mut up = Mat::zeros(x.rows, e);
        for i in 0..pre.data.len() {
            up.data[i] = silu(pre.data[i]);
        }
        sink("dt_proj", &up);
        let dt = self.weight(b, "dt_proj").matmul_tb(&up);
        let mut alpha = Mat::zeros(x.rows, e);
        for i in 0..dt.data.len() {
            alpha.data[i] = sigmoid(dt.data[i]);
        }
        // selective scan
        let mut h = Mat::zeros(x.rows, e);
        match &mut mode {
            MambaSeq::Full { bsz, t } => {
                for s in 0..*bsz {
                    for pos in 0..*t {
                        let r = s * *t + pos;
                        for c in 0..e {
                            let prev = if pos == 0 { 0.0 } else { h[(r - 1, c)] };
                            let a = alpha[(r, c)];
                            h[(r, c)] = a * prev + (1.0 - a) * up[(r, c)];
                        }
                    }
                }
            }
            MambaSeq::Decode { st } => {
                let tn = x.rows;
                for pos in 0..tn {
                    for c in 0..e {
                        let prev = if pos == 0 { st.h[c] } else { h[(pos - 1, c)] };
                        let a = alpha[(pos, c)];
                        h[(pos, c)] = a * prev + (1.0 - a) * up[(pos, c)];
                    }
                }
                st.h.copy_from_slice(h.row(tn - 1));
            }
            MambaSeq::BatchDecode { sts } => {
                for (i, st) in sts.iter_mut().enumerate() {
                    for c in 0..e {
                        let a = alpha[(i, c)];
                        h[(i, c)] = a * st.h[c] + (1.0 - a) * up[(i, c)];
                    }
                    st.h.copy_from_slice(h.row(i));
                }
            }
        }
        // gate + out proj + residual
        let mut y = Mat::zeros(x.rows, e);
        for i in 0..y.data.len() {
            y.data[i] = h.data[i] * silu(z.data[i]);
        }
        sink("out_proj", &y);
        let proj = self.weight(b, "out_proj").matmul_tb(&y);
        let mut out = x.clone();
        out.add_assign(&proj);

        if let Some(c) = cache.as_deref_mut() {
            *c = MambaCache { x_in: x.clone(), n, u, z, pre, up, alpha, h, y };
        }
        out
    }

    pub fn logits(&self, x: &Mat) -> Mat {
        let n = super::transformer_rmsnorm(x, self.params.dense("final_norm").unwrap().row(0));
        n.y.matmul_tb(self.params.dense("embed").unwrap())
    }

    pub fn forward_loss(&self, tokens: &[u32], bt: (usize, usize)) -> f64 {
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            x = self.block_forward(b, &x, bt);
        }
        let logits = self.logits(&x);
        super::ce_loss(&logits, tokens, bt)
    }

    pub fn loss_and_grads(&self, tokens: &[u32], bt: (usize, usize)) -> (f64, TensorStore) {
        let cfg = &self.cfg;
        let mut caches = Vec::with_capacity(cfg.n_layers);
        let mut x = self.embed(tokens);
        for b in 0..cfg.n_layers {
            let mut c = MambaCache::empty();
            x = self.block_impl(
                b,
                &x,
                MambaSeq::Full { bsz: bt.0, t: bt.1 },
                Some(&mut c),
                &mut |_, _| {},
            );
            caches.push(c);
        }
        let fg = self.params.dense("final_norm").unwrap().row(0);
        let nfin = super::transformer_rmsnorm(&x, fg);
        let embed = self.params.dense("embed").unwrap();
        let logits = nfin.y.matmul_tb(embed);
        let (loss, dlogits) = super::ce_loss_and_grad(&logits, tokens, bt);

        let mut grads = TensorStore::new();
        let mut d_embed = dlogits.t().matmul(&nfin.y);
        let dnfin = dlogits.matmul(embed);
        let (mut dx, d_fn) = super::transformer_rmsnorm_backward(&x, fg, &nfin, &dnfin);
        grads.insert("final_norm", d_fn);

        for b in (0..cfg.n_layers).rev() {
            dx = self.block_backward(b, &caches[b], &dx, bt, &mut grads);
        }
        for (i, &tok) in tokens.iter().enumerate() {
            let dst = d_embed.row_mut(tok as usize);
            for (d, &v) in dst.iter_mut().zip(dx.row(i)) {
                *d += v;
            }
        }
        grads.insert("embed", d_embed);
        (loss, grads)
    }

    fn block_backward(
        &self,
        b: usize,
        c: &MambaCache,
        dout: &Mat,
        (bsz, t): (usize, usize),
        grads: &mut TensorStore,
    ) -> Mat {
        let e = self.cfg.d_inner;
        let nrow = dout.rows;

        // out = x + y @ Wout^T (dense views: the backward path densifies
        // packed layouts on demand)
        let dy = dout.matmul(&self.wdense(b, "out_proj")); // (n, e)
        let d_wout = dout.t().matmul(&c.y);
        grads.insert(&key(b, "out_proj"), d_wout);

        // y = h . silu(z)
        let mut dh = Mat::zeros(nrow, e);
        let mut dz = Mat::zeros(nrow, e);
        for i in 0..dy.data.len() {
            let zv = c.z.data[i];
            let s = sigmoid(zv);
            dh.data[i] = dy.data[i] * zv * s;
            dz.data[i] = dy.data[i] * c.h.data[i] * (s * (1.0 + zv * (1.0 - s)));
        }

        // scan backward: gh_t = dh_t + gh_{t+1} * a_{t+1}
        let mut dalpha = Mat::zeros(nrow, e);
        let mut dup = Mat::zeros(nrow, e);
        for s in 0..bsz {
            let mut gh = vec![0.0f32; e];
            for pos in (0..t).rev() {
                let r = s * t + pos;
                for cch in 0..e {
                    let g = dh[(r, cch)] + gh[cch];
                    let a = c.alpha[(r, cch)];
                    let prev = if pos == 0 { 0.0 } else { c.h[(r - 1, cch)] };
                    dalpha[(r, cch)] = g * (prev - c.up[(r, cch)]);
                    dup[(r, cch)] = g * (1.0 - a);
                    gh[cch] = g * a;
                }
            }
        }

        // alpha = sigmoid(dt); dt = up @ Wdt^T
        let mut ddt = Mat::zeros(nrow, e);
        for i in 0..ddt.data.len() {
            let a = c.alpha.data[i];
            ddt.data[i] = dalpha.data[i] * a * (1.0 - a);
        }
        let d_wdt = ddt.t().matmul(&c.up);
        grads.insert(&key(b, "dt_proj"), d_wdt);
        dup.add_assign(&ddt.matmul(&self.wdense(b, "dt_proj")));

        // up = silu(pre)
        let mut dpre = Mat::zeros(nrow, e);
        for i in 0..dpre.data.len() {
            let p = c.pre.data[i];
            let s = sigmoid(p);
            dpre.data[i] = dup.data[i] * (s * (1.0 + p * (1.0 - s)));
        }

        // conv backward
        let cw = self.params.dense(&key(b, "conv_w")).unwrap();
        let mut du = Mat::zeros(nrow, e);
        let mut d_cw = Mat::zeros(CONV_K, e);
        let mut d_cb = Mat::zeros(1, e);
        for s in 0..bsz {
            for pos in 0..t {
                let r = s * t + pos;
                for cch in 0..e {
                    let dp = dpre[(r, cch)];
                    d_cb[(0, cch)] += dp;
                    for kk in 0..CONV_K {
                        if pos >= kk {
                            du[(r - kk, cch)] += dp * cw[(kk, cch)];
                            d_cw[(kk, cch)] += dp * c.u[(r - kk, cch)];
                        }
                    }
                }
            }
        }
        grads.insert(&key(b, "conv_w"), d_cw);
        grads.insert(&key(b, "conv_b"), d_cb);

        // xz split backward -> in_proj
        let mut dxz = Mat::zeros(nrow, 2 * e);
        for r in 0..nrow {
            dxz.row_mut(r)[..e].copy_from_slice(du.row(r));
            dxz.row_mut(r)[e..].copy_from_slice(dz.row(r));
        }
        let d_win = dxz.t().matmul(&c.n.y);
        grads.insert(&key(b, "in_proj"), d_win);
        let dn = dxz.matmul(&self.wdense(b, "in_proj"));
        let norm_g = self.params.dense(&key(b, "norm")).unwrap().row(0);
        let (dx_from_norm, d_norm) =
            super::transformer_rmsnorm_backward(&c.x_in, norm_g, &c.n, &dn);
        grads.insert(&key(b, "norm"), d_norm);

        let mut dx = dout.clone();
        dx.add_assign(&dx_from_norm);
        dx
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        self.params.save(path)
    }

    pub fn load(cfg: MambaConfig, path: &std::path::Path) -> Result<Mamba> {
        Ok(Mamba { cfg, params: ParamStore::load(path)? })
    }
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// Sequence routing for `block_impl`: the whole-context batch path, or
/// the incremental step-state paths (single-stream and continuous-
/// batched) over sessions' recurrent state.
///
/// There is deliberately no cross-request packed-prefill arm here: the
/// `Decode` arm already runs its in/dt/out projections as whole-chunk
/// matmuls, and the scan/conv state is O(1) per stream, so the trait's
/// default per-request `prefill_batch` loop IS the fast path for this
/// family (padding would only add wasted scan work).
pub(crate) enum MambaSeq<'s, 'st> {
    /// B sequences of length T, scanned from h = 0 each.
    Full { bsz: usize, t: usize },
    /// Newly appended tokens continuing the session's carried state.
    Decode { st: &'s mut MambaBlockState },
    /// One new token per stream, each continuing its own carried state —
    /// the engine's continuous-batching step.
    BatchDecode { sts: &'s mut [&'st mut MambaBlockState] },
}

/// Per-block decode-session state: the selective-scan hidden state `h`
/// plus a `CONV_K - 1`-deep ring of past conv inputs (newest first), so
/// one decode step costs O(1) in context length.
#[derive(Clone, Debug)]
pub struct MambaBlockState {
    pub h: Vec<f32>,
    conv: Vec<Vec<f32>>,
}

impl MambaBlockState {
    fn new(d_inner: usize) -> MambaBlockState {
        MambaBlockState {
            h: vec![0.0; d_inner],
            conv: vec![vec![0.0; d_inner]; CONV_K - 1],
        }
    }
}

pub struct MambaCache {
    x_in: Mat,
    n: super::NormCachePub,
    u: Mat,
    z: Mat,
    pre: Mat,
    up: Mat,
    alpha: Mat,
    h: Mat,
    y: Mat,
}

impl MambaCache {
    fn empty() -> MambaCache {
        let z = || Mat::zeros(0, 0);
        MambaCache {
            x_in: z(),
            n: super::NormCachePub { y: Mat::zeros(0, 0), rinv: vec![] },
            u: z(),
            z: z(),
            pre: z(),
            up: z(),
            alpha: z(),
            h: z(),
            y: z(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> MambaConfig {
        MambaConfig { vocab: 29, d_model: 12, d_inner: 20, n_layers: 2, max_seq: 16 }
    }

    fn tiny(seed: u64) -> Mamba {
        Mamba::init(tiny_cfg(), &mut Rng::new(seed))
    }

    fn rand_tokens(n: usize, vocab: usize, seed: u64) -> Vec<u32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.below(vocab) as u32).collect()
    }

    #[test]
    fn forward_shapes_and_loss() {
        let m = tiny(1);
        let toks = rand_tokens(2 * 8, 29, 2);
        let loss = m.forward_loss(&toks, (2, 8));
        assert!(loss.is_finite());
        assert!((loss - (29f64).ln()).abs() < 0.6, "{loss}");
    }

    #[test]
    fn collect_hits_every_linear() {
        let m = tiny(3);
        let toks = rand_tokens(8, 29, 4);
        let x = m.embed(&toks);
        let mut seen = std::collections::HashSet::new();
        m.block_forward_collect(0, &x, (1, 8), &mut |name, _| {
            seen.insert(name.to_string());
        });
        for l in MAMBA_LINEARS {
            assert!(seen.contains(l), "{l}");
        }
    }

    #[test]
    fn causality_future_token_does_not_affect_past() {
        let m = tiny(5);
        let mut toks = rand_tokens(8, 29, 6);
        let run = |toks: &[u32]| {
            let mut x = m.embed(toks);
            for b in 0..2 {
                x = m.block_forward(b, &x, (1, 8));
            }
            m.logits(&x)
        };
        let l1 = run(&toks);
        toks[7] = (toks[7] + 1) % 29;
        let l2 = run(&toks);
        for i in 0..7 {
            for j in 0..29 {
                assert!((l1[(i, j)] - l2[(i, j)]).abs() < 1e-6, "pos {i}");
            }
        }
    }

    #[test]
    fn gradcheck_all_param_kinds() {
        let mut m = tiny(7);
        let toks = rand_tokens(2 * 6, 29, 8);
        let bt = (2, 6);
        let (_, grads) = m.loss_and_grads(&toks, bt);
        let eps = 2e-3f32;
        let names: Vec<String> = m.params.names().iter().map(|s| s.to_string()).collect();
        for name in names {
            let g = grads.get(&name).unwrap().clone();
            let len = g.data.len();
            for &fracidx in &[0usize, len / 2, len - 1] {
                let idx = fracidx.min(len - 1);
                let orig = m.params.dense(&name).unwrap().data[idx];
                m.params.dense_mut(&name).unwrap().data[idx] = orig + eps;
                let lp = m.forward_loss(&toks, bt);
                m.params.dense_mut(&name).unwrap().data[idx] = orig - eps;
                let lm = m.forward_loss(&toks, bt);
                m.params.dense_mut(&name).unwrap().data[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps as f64);
                let an = g.data[idx] as f64;
                let denom = fd.abs().max(an.abs()).max(1e-4);
                assert!(
                    ((fd - an) / denom).abs() < 0.08,
                    "{name}[{idx}]: fd={fd:.6} analytic={an:.6}"
                );
            }
        }
    }

    #[test]
    fn sparse_stores_match_dense_forward() {
        use crate::prune::{magnitude_prune, Sparsity};
        for sparsity in [Sparsity::Unstructured { rate: 0.6 }, Sparsity::two_four()] {
            let mut dense = tiny(9);
            for b in 0..dense.cfg.n_layers {
                for name in MAMBA_LINEARS {
                    magnitude_prune(dense.weight_mut(b, name).dense_mut(), sparsity);
                }
            }
            let mut packed = Mamba { cfg: dense.cfg, params: dense.params.clone() };
            for b in 0..dense.cfg.n_layers {
                for name in MAMBA_LINEARS {
                    let w = packed.weight(b, name).to_dense();
                    *packed.weight_mut(b, name) = crate::sparse::WeightStore::pack(&w, sparsity);
                    assert_eq!(packed.weight(b, name).to_dense(), w);
                    assert_ne!(packed.weight(b, name).format(), "dense");
                }
            }
            let toks = rand_tokens(2 * 8, 29, 10);
            let a = dense.forward_loss(&toks, (2, 8));
            let b = packed.forward_loss(&toks, (2, 8));
            assert!((a - b).abs() < 1e-5, "{sparsity:?}: {a} vs {b}");
        }
    }
}
