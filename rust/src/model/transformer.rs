//! `microllama`: a GPT-style decoder-only transformer (RMSNorm, RoPE,
//! multi-head causal attention, SwiGLU MLP, tied embeddings) with manual
//! forward/backward — the stand-in for the paper's LLaMA2/OPT/BLOOM
//! checkpoints (DESIGN.md SS2).
//!
//! The pruning surface is every linear projection: wq/wk/wv/wo and
//! w1/w2/w3 per block — exactly the set SparseGPT and the paper prune.
//! `block_forward_collect` exposes each projection's *input* activations,
//! which is what the layer-wise Hessian accumulation consumes.
//!
//! Parameters live in a [`ParamStore`] of [`WeightStore`]s: every linear
//! executes its forward `matmul_tb` through whichever layout it holds
//! (dense, CSR or packed 2:4 — the sparse serving path), while the
//! backward/training path takes dense views and densifies on demand.

use std::borrow::Cow;

use anyhow::Result;

use crate::io::{ParamStore, TensorStore};
use crate::sparse::WeightStore;
use crate::tensor::{dot, Mat, PagedKv};
use crate::util::{num_threads, Rng};

use super::{ce_loss, ce_loss_and_grad, transformer_rmsnorm as rmsnorm,
            transformer_rmsnorm_backward as rmsnorm_backward, NormCachePub as NormCache};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransformerConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl TransformerConfig {
    /// ~0.9M params; trains to sane perplexity in ~2 min on CPU.
    pub fn small(vocab: usize) -> Self {
        TransformerConfig { vocab, d_model: 128, n_layers: 4, n_heads: 4, d_ff: 256, max_seq: 256 }
    }

    /// ~4M params; the "larger family member" rows of the tables.
    pub fn medium(vocab: usize) -> Self {
        TransformerConfig { vocab, d_model: 256, n_layers: 6, n_heads: 8, d_ff: 512, max_seq: 256 }
    }

    /// ~14M params; used by the scaling rows + E2E example.
    pub fn large(vocab: usize) -> Self {
        TransformerConfig { vocab, d_model: 384, n_layers: 10, n_heads: 8, d_ff: 1024, max_seq: 256 }
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// Names of the prunable linear weights inside one transformer block.
pub const BLOCK_LINEARS: [&str; 7] = ["wq", "wk", "wv", "wo", "w1", "w2", "w3"];

/// The model: config + named parameters. Weights are stored (out, in) so
/// `y = x @ W^T` via `matmul_tb`, matching the paper's w x convention.
pub struct Transformer {
    pub cfg: TransformerConfig,
    pub params: ParamStore,
}

fn key(block: usize, name: &str) -> String {
    format!("blocks.{block}.{name}")
}

impl Transformer {
    pub fn init(cfg: TransformerConfig, rng: &mut Rng) -> Transformer {
        let mut p = ParamStore::new();
        let d = cfg.d_model;
        let sigma = 0.02f32;
        p.insert("embed", Mat::randn(cfg.vocab, d, sigma, rng));
        p.insert("final_norm", ones(1, d));
        for b in 0..cfg.n_layers {
            let proj_sigma = sigma / (2.0 * cfg.n_layers as f32).sqrt();
            p.insert(&key(b, "norm1"), ones(1, d));
            p.insert(&key(b, "norm2"), ones(1, d));
            p.insert(&key(b, "wq"), Mat::randn(d, d, sigma, rng));
            p.insert(&key(b, "wk"), Mat::randn(d, d, sigma, rng));
            p.insert(&key(b, "wv"), Mat::randn(d, d, sigma, rng));
            p.insert(&key(b, "wo"), Mat::randn(d, d, proj_sigma, rng));
            p.insert(&key(b, "w1"), Mat::randn(cfg.d_ff, d, sigma, rng));
            p.insert(&key(b, "w3"), Mat::randn(cfg.d_ff, d, sigma, rng));
            p.insert(&key(b, "w2"), Mat::randn(d, cfg.d_ff, proj_sigma, rng));
        }
        Transformer { cfg, params: p }
    }

    pub fn n_params(&self) -> usize {
        self.params.total_params()
    }

    pub fn weight(&self, block: usize, name: &str) -> &WeightStore {
        self.params.get(&key(block, name)).expect("weight")
    }

    pub fn weight_mut(&mut self, block: usize, name: &str) -> &mut WeightStore {
        self.params.get_mut(&key(block, name)).expect("weight")
    }

    /// Dense view of a block linear for the backward path (borrowed in
    /// the common dense case, materialized for packed layouts).
    fn wdense(&self, block: usize, name: &str) -> Cow<'_, Mat> {
        self.weight(block, name).dense_view()
    }

    // ------------------------------------------------------------- forward

    /// Token embedding lookup: (B*T, d).
    pub fn embed(&self, tokens: &[u32]) -> Mat {
        let e = self.params.dense("embed").expect("embed is dense");
        let d = self.cfg.d_model;
        let mut x = Mat::zeros(tokens.len(), d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(e.row(t as usize));
        }
        x
    }

    /// One block forward. `x`: (B*T, d) with B sequences of length T.
    pub fn block_forward(&self, b: usize, x: &Mat, bt: (usize, usize)) -> Mat {
        self.block_forward_impl(b, x, TfAttn::Full { bsz: bt.0, t: bt.1 }, None, &mut |_, _| {})
    }

    /// Block forward that also hands each linear's input matrix to `sink`
    /// (the Hessian accumulator). Keys: "wq","wk","wv" share one input.
    pub fn block_forward_collect(
        &self,
        b: usize,
        x: &Mat,
        bt: (usize, usize),
        sink: &mut dyn FnMut(&str, &Mat),
    ) -> Mat {
        self.block_forward_impl(b, x, TfAttn::Full { bsz: bt.0, t: bt.1 }, None, sink)
    }

    /// Incremental block forward: `x` holds the new tokens at absolute
    /// positions `pos0..pos0 + x.rows`, and attention runs against the
    /// session's cached keys/values instead of re-deriving the context.
    pub(crate) fn block_decode(
        &self,
        b: usize,
        x: &Mat,
        pos0: usize,
        st: &mut TfBlockState,
    ) -> Mat {
        self.block_forward_impl(b, x, TfAttn::Decode { pos0, st }, None, &mut |_, _| {})
    }

    /// Prefill fast path for one block: the threaded Full-arm attention
    /// (per-head matmuls) over a whole prompt, which also appends the
    /// rotated K/V to the (empty) session cache. Numerically identical to
    /// the incremental arm — same kernels, same op order.
    pub(crate) fn block_prefill(&self, b: usize, x: &Mat, st: &mut TfBlockState) -> Mat {
        self.block_forward_impl(b, x, TfAttn::Prefill { st }, None, &mut |_, _| {})
    }

    /// Packed cross-request prefill for one block: `x` holds B prompts
    /// right-padded to a common length (B·t rows); the first `lens[s]`
    /// K/V rows of stream `s` append to its (empty) cache. One threaded
    /// Full-arm pass instead of B separate prefills.
    pub(crate) fn block_prefill_batch(
        &self,
        b: usize,
        x: &Mat,
        lens: &[usize],
        sts: &mut [&mut TfBlockState],
    ) -> Mat {
        self.block_forward_impl(b, x, TfAttn::PrefillBatch { lens, sts }, None, &mut |_, _| {})
    }

    /// Batched decode step for one block: row `i` of `x` is stream `i`'s
    /// single new token at absolute position `poss[i]`, attending against
    /// its own K/V cache `sts[i]`. All linears run ONE (B, d) matmul over
    /// the stacked queries instead of B separate (1, d) products.
    pub(crate) fn block_decode_batch(
        &self,
        b: usize,
        x: &Mat,
        poss: &[usize],
        sts: &mut [&mut TfBlockState],
    ) -> Mat {
        self.block_forward_impl(b, x, TfAttn::BatchDecode { poss, sts }, None, &mut |_, _| {})
    }

    /// Fresh (empty) per-block K/V caches for a decode session. Sized
    /// per block from the actual `wq` store: structured pruning may
    /// have removed whole heads, so a block's K/V rows are `wq.rows()`
    /// (= surviving heads × head_dim) wide, not `d_model`.
    pub(crate) fn new_block_states(&self) -> Vec<TfBlockState> {
        (0..self.cfg.n_layers)
            .map(|b| TfBlockState::new(self.weight(b, "wq").rows()))
            .collect()
    }

    fn block_forward_impl(
        &self,
        b: usize,
        x: &Mat,
        mode: TfAttn<'_, '_>,
        mut cache: Option<&mut BlockCache>,
        sink: &mut dyn FnMut(&str, &Mat),
    ) -> Mat {
        let cfg = &self.cfg;
        // Per-block head count from the physical wq shape: structured
        // pruning removes whole heads, so a block may run fewer than
        // cfg.n_heads. head_dim is invariant (heads are dropped, never
        // narrowed), which keeps RoPE rotating every surviving head
        // exactly as the full-shape model would.
        let dh = cfg.head_dim();
        let h = self.weight(b, "wq").rows() / dh;
        let scale = 1.0 / (dh as f32).sqrt();

        // --- attention sublayer
        let n1 = rmsnorm(x, self.weight_norm(b, "norm1"));
        sink("wq", &n1.y);
        sink("wk", &n1.y);
        sink("wv", &n1.y);
        let q0 = self.weight(b, "wq").matmul_tb(&n1.y);
        let k0 = self.weight(b, "wk").matmul_tb(&n1.y);
        let v = self.weight(b, "wv").matmul_tb(&n1.y);
        let mut q = q0;
        let mut k = k0;

        let mut attn_out = Mat::zeros(x.rows, h * dh);
        let mut probs_cache: Vec<Mat> = Vec::new();
        match mode {
            TfAttn::Full { bsz, t } => {
                rope(&mut q, bsz, t, h, dh, false);
                rope(&mut k, bsz, t, h, dh, false);
                let probs = if cache.is_some() { Some(&mut probs_cache) } else { None };
                full_causal_attention(&q, &k, &v, bsz, t, h, dh, scale, &mut attn_out, probs);
            }
            TfAttn::Prefill { st } => {
                // whole-prompt fast path: the same threaded per-head
                // matmuls as Full, plus the K/V append the session needs
                assert_eq!(st.k.len(), 0, "prefill fast path needs an empty K/V cache");
                let t = x.rows;
                rope(&mut q, 1, t, h, dh, false);
                rope(&mut k, 1, t, h, dh, false);
                full_causal_attention(&q, &k, &v, 1, t, h, dh, scale, &mut attn_out, None);
                st.k.append_rows(&k);
                st.v.append_rows(&v);
            }
            TfAttn::PrefillBatch { lens, sts } => {
                // packed cross-request prefill: B prompts right-padded to
                // t rows run the SAME Full-arm threaded attention as one
                // batch; per-(seq, head) work is independent, so each
                // stream's rows are bit-identical to a solo prefill, and
                // the padding rows (causally downstream of every real
                // row) are simply never appended to a cache.
                let bsz = sts.len();
                assert_eq!(lens.len(), bsz, "one prompt length per stream");
                assert!(bsz >= 1 && x.rows % bsz == 0, "padded batch shape");
                let t = x.rows / bsz;
                rope(&mut q, bsz, t, h, dh, false);
                rope(&mut k, bsz, t, h, dh, false);
                full_causal_attention(&q, &k, &v, bsz, t, h, dh, scale, &mut attn_out, None);
                for (s, st) in sts.iter_mut().enumerate() {
                    assert!(lens[s] >= 1 && lens[s] <= t, "prompt length vs padded t");
                    assert_eq!(st.k.len(), 0, "packed prefill needs empty K/V caches");
                    for i in 0..lens[s] {
                        st.k.append_row(k.row(s * t + i));
                        st.v.append_row(v.row(s * t + i));
                    }
                }
            }
            TfAttn::Decode { pos0, st } => {
                // `cached` may trail pos0 when a sliding window evicted
                // the oldest rows; positions stay absolute for RoPE.
                let cached = st.k.len();
                assert!(cached <= pos0, "K/V cache out of sync with position");
                rope_rows(&mut q, pos0, h, dh, false);
                rope_rows(&mut k, pos0, h, dh, false);
                st.k.append_rows(&k);
                st.v.append_rows(&v);
                // each new query at absolute position pos0+i attends to
                // every cached position plus chunk rows 0..=i: O(T) per
                // token, not O(T²)
                let tn = x.rows;
                let mut scores: Vec<f32> = Vec::with_capacity(cached + tn);
                for i in 0..tn {
                    attend_cached(
                        q.row(i),
                        st,
                        cached + i + 1,
                        attn_out.row_mut(i),
                        (h, dh),
                        scale,
                        &mut scores,
                    );
                }
            }
            TfAttn::BatchDecode { poss, sts } => {
                // one token per stream, each against its own cache; the
                // q/k/v projections above already ran as ONE (B, d) matmul
                let bsz = x.rows;
                assert_eq!(poss.len(), bsz, "one position per stream");
                assert_eq!(sts.len(), bsz, "one K/V state per stream");
                for i in 0..bsz {
                    rope_row(q.row_mut(i), poss[i], h, dh, false);
                    rope_row(k.row_mut(i), poss[i], h, dh, false);
                }
                for (i, st) in sts.iter_mut().enumerate() {
                    let st: &mut TfBlockState = st;
                    assert!(st.k.len() <= poss[i], "K/V cache out of sync with position");
                    st.k.append_row(k.row(i));
                    st.v.append_row(v.row(i));
                }
                // per-stream attention: disjoint states, disjoint output
                // rows — threaded across the pool once B·T clears the
                // break-even, serial below it
                let views: Vec<&TfBlockState> = sts.iter().map(|s| &**s).collect();
                let work = views.iter().map(|st| st.k.len()).sum::<usize>() * cfg.d_model;
                let threaded = bsz > 1 && num_threads() > 1 && work >= batch_attn_threshold();
                batch_attend(&q, &views, &mut attn_out, (h, dh), scale, threaded);
            }
        }
        sink("wo", &attn_out);
        let proj = self.weight(b, "wo").matmul_tb(&attn_out);
        let mut x2 = x.clone();
        x2.add_assign(&proj);

        // --- mlp sublayer (SwiGLU)
        let n2 = rmsnorm(&x2, self.weight_norm(b, "norm2"));
        sink("w1", &n2.y);
        sink("w3", &n2.y);
        let u = self.weight(b, "w1").matmul_tb(&n2.y);
        let g = self.weight(b, "w3").matmul_tb(&n2.y);
        let mut a = Mat::zeros(u.rows, u.cols);
        for i in 0..u.data.len() {
            a.data[i] = silu(u.data[i]) * g.data[i];
        }
        sink("w2", &a);
        let mlp = self.weight(b, "w2").matmul_tb(&a);
        let mut out = x2.clone();
        out.add_assign(&mlp);

        if let Some(c) = cache.as_deref_mut() {
            *c = BlockCache {
                x_in: x.clone(),
                n1,
                q,
                k,
                v,
                probs: probs_cache,
                attn_out,
                x2,
                n2,
                u,
                g,
                a,
            };
        }
        out
    }

    fn weight_norm(&self, b: usize, name: &str) -> &[f32] {
        self.params.dense(&key(b, name)).unwrap().row(0)
    }

    /// Final norm + tied logits: (B*T, V).
    pub fn logits(&self, x: &Mat) -> Mat {
        let n = rmsnorm(x, self.params.dense("final_norm").unwrap().row(0));
        n.y.matmul_tb(self.params.dense("embed").unwrap())
    }

    /// Full forward (no caches): mean next-token cross-entropy on (B,T).
    pub fn forward_loss(&self, tokens: &[u32], bt: (usize, usize)) -> f64 {
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            x = self.block_forward(b, &x, bt);
        }
        let logits = self.logits(&x);
        ce_loss(&logits, tokens, bt)
    }

    // ------------------------------------------------------- training step

    /// Forward + backward; returns (loss, gradients keyed like params).
    pub fn loss_and_grads(&self, tokens: &[u32], bt: (usize, usize)) -> (f64, TensorStore) {
        let cfg = &self.cfg;
        let mut caches: Vec<BlockCache> = Vec::with_capacity(cfg.n_layers);
        let mut x = self.embed(tokens);
        for b in 0..cfg.n_layers {
            let mut c = BlockCache::empty();
            x = self.block_forward_impl(
                b,
                &x,
                TfAttn::Full { bsz: bt.0, t: bt.1 },
                Some(&mut c),
                &mut |_, _| {},
            );
            caches.push(c);
        }
        let final_g = self.params.dense("final_norm").unwrap().row(0);
        let nfin = rmsnorm(&x, final_g);
        let embed = self.params.dense("embed").unwrap();
        let logits = nfin.y.matmul_tb(embed);

        let (loss, dlogits) = ce_loss_and_grad(&logits, tokens, bt);

        let mut grads = TensorStore::new();
        // tied head: dE += dlogits^T @ nfin.y ; dnfin = dlogits @ E
        let mut d_embed = dlogits.t().matmul(&nfin.y);
        let dnfin = dlogits.matmul(embed);
        let (mut dx, d_final_norm) = rmsnorm_backward(&x, final_g, &nfin, &dnfin);
        grads.insert("final_norm", d_final_norm);

        for b in (0..cfg.n_layers).rev() {
            dx = self.block_backward(b, &caches[b], &dx, bt, &mut grads);
        }

        // embedding lookup backward: scatter-add rows of dx.
        for (i, &tok) in tokens.iter().enumerate() {
            let dst = d_embed.row_mut(tok as usize);
            for (d, &v) in dst.iter_mut().zip(dx.row(i)) {
                *d += v;
            }
        }
        grads.insert("embed", d_embed);
        (loss, grads)
    }

    fn block_backward(
        &self,
        b: usize,
        c: &BlockCache,
        dout: &Mat,
        (bsz, t): (usize, usize),
        grads: &mut TensorStore,
    ) -> Mat {
        let cfg = &self.cfg;
        let (h, dh) = (cfg.n_heads, cfg.head_dim());
        let scale = 1.0 / (dh as f32).sqrt();

        // ---- mlp backward: out = x2 + a @ W2^T (dense views: the
        // backward path densifies packed layouts on demand)
        let da = dout.matmul(&self.wdense(b, "w2")); // (n, d_ff)
        let d_w2 = dout.t().matmul(&c.a);
        let mut du = Mat::zeros(da.rows, da.cols);
        let mut dg = Mat::zeros(da.rows, da.cols);
        for i in 0..da.data.len() {
            let (uv, gv) = (c.u.data[i], c.g.data[i]);
            let s = sigmoid(uv);
            let sil = uv * s;
            dg.data[i] = da.data[i] * sil;
            du.data[i] = da.data[i] * gv * (s * (1.0 + uv * (1.0 - s)));
        }
        let d_w1 = du.t().matmul(&c.n2.y);
        let d_w3 = dg.t().matmul(&c.n2.y);
        let mut dn2 = du.matmul(&self.wdense(b, "w1"));
        dn2.add_assign(&dg.matmul(&self.wdense(b, "w3")));
        let (dx2_from_norm, d_norm2) =
            rmsnorm_backward(&c.x2, self.weight_norm(b, "norm2"), &c.n2, &dn2);
        grads.insert(&key(b, "w1"), d_w1);
        grads.insert(&key(b, "w2"), d_w2);
        grads.insert(&key(b, "w3"), d_w3);
        grads.insert(&key(b, "norm2"), d_norm2);

        let mut dx2 = dout.clone(); // residual
        dx2.add_assign(&dx2_from_norm);

        // ---- attention backward: x2 = x_in + attn_out @ Wo^T
        let d_attn_out = dx2.matmul(&self.wdense(b, "wo"));
        let d_wo = dx2.t().matmul(&c.attn_out);
        grads.insert(&key(b, "wo"), d_wo);

        let mut dq = Mat::zeros(c.q.rows, c.q.cols);
        let mut dk = Mat::zeros(c.k.rows, c.k.cols);
        let mut dv = Mat::zeros(c.v.rows, c.v.cols);
        for s in 0..bsz {
            for hd in 0..h {
                let probs = &c.probs[s * h + hd];
                let do_ = head_slice(&d_attn_out, s, t, hd, dh);
                let vs = head_slice(&c.v, s, t, hd, dh);
                let qs = head_slice(&c.q, s, t, hd, dh);
                let ks = head_slice(&c.k, s, t, hd, dh);
                let d_probs = do_.matmul_tb(&vs); // (t,t)
                let dvs = probs.t().matmul(&do_); // (t,dh)
                // softmax backward (row-wise, causal zeros preserved)
                let mut dscores = Mat::zeros(t, t);
                for i in 0..t {
                    let prow = probs.row(i);
                    let dprow = d_probs.row(i);
                    let dot: f32 = prow.iter().zip(dprow).map(|(p, d)| p * d).sum();
                    let drow = dscores.row_mut(i);
                    for j in 0..=i {
                        drow[j] = prow[j] * (dprow[j] - dot);
                    }
                }
                dscores.scale(scale);
                let dqs = dscores.matmul(&ks);
                let dks = dscores.t().matmul(&qs);
                write_head(&mut dq, &dqs, s, t, hd, dh);
                write_head(&mut dk, &dks, s, t, hd, dh);
                write_head(&mut dv, &dvs, s, t, hd, dh);
            }
        }
        // un-rotate gradients (RoPE is orthogonal: backward = inverse rot)
        rope(&mut dq, bsz, t, h, dh, true);
        rope(&mut dk, bsz, t, h, dh, true);

        let d_wq = dq.t().matmul(&c.n1.y);
        let d_wk = dk.t().matmul(&c.n1.y);
        let d_wv = dv.t().matmul(&c.n1.y);
        let mut dn1 = dq.matmul(&self.wdense(b, "wq"));
        dn1.add_assign(&dk.matmul(&self.wdense(b, "wk")));
        dn1.add_assign(&dv.matmul(&self.wdense(b, "wv")));
        let (dx_from_norm, d_norm1) =
            rmsnorm_backward(&c.x_in, self.weight_norm(b, "norm1"), &c.n1, &dn1);
        grads.insert(&key(b, "wq"), d_wq);
        grads.insert(&key(b, "wk"), d_wk);
        grads.insert(&key(b, "wv"), d_wv);
        grads.insert(&key(b, "norm1"), d_norm1);

        let mut dx = dx2; // residual into x_in
        dx.add_assign(&dx_from_norm);
        dx
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        self.params.save(path)
    }

    pub fn load(cfg: TransformerConfig, path: &std::path::Path) -> Result<Transformer> {
        let params = ParamStore::load(path)?;
        Ok(Transformer { cfg, params })
    }
}

// ---------------------------------------------------------------------------
// functional pieces
// ---------------------------------------------------------------------------

const NORM_EPS: f32 = 1e-5;

fn ones(r: usize, c: usize) -> Mat {
    Mat::from_vec(r, c, vec![1.0; r * c])
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

#[inline]
fn silu(x: f32) -> f32 {
    x * sigmoid(x)
}

/// In-place rotary embedding on interleaved head layout (B*T, h*dh).
/// `inverse` applies the transpose rotation (used in backward).
fn rope(x: &mut Mat, bsz: usize, t: usize, h: usize, dh: usize, inverse: bool) {
    for s in 0..bsz {
        for pos in 0..t {
            rope_row(x.row_mut(s * t + pos), pos, h, dh, inverse);
        }
    }
}

/// Rotary embedding for one sequence whose rows sit at absolute positions
/// `pos0..pos0 + x.rows` — the decode-session variant: the same rotation
/// `rope` applies, but with an explicit position offset so an incremental
/// chunk lands exactly where the full forward would have put it.
fn rope_rows(x: &mut Mat, pos0: usize, h: usize, dh: usize, inverse: bool) {
    for i in 0..x.rows {
        rope_row(x.row_mut(i), pos0 + i, h, dh, inverse);
    }
}

fn rope_row(row: &mut [f32], pos: usize, h: usize, dh: usize, inverse: bool) {
    let half = dh / 2;
    for hd in 0..h {
        let base = hd * dh;
        for i in 0..half {
            let theta = (pos as f32) * (10000f32).powf(-2.0 * i as f32 / dh as f32);
            let (sin, cos) = theta.sin_cos();
            let sin = if inverse { -sin } else { sin };
            let a = row[base + 2 * i];
            let b = row[base + 2 * i + 1];
            row[base + 2 * i] = a * cos - b * sin;
            row[base + 2 * i + 1] = a * sin + b * cos;
        }
    }
}

/// Extract head `hd` of sequence `s` as a (t, dh) matrix.
fn head_slice(x: &Mat, s: usize, t: usize, hd: usize, dh: usize) -> Mat {
    let mut out = Mat::zeros(t, dh);
    for i in 0..t {
        let src = &x.row(s * t + i)[hd * dh..(hd + 1) * dh];
        out.row_mut(i).copy_from_slice(src);
    }
    out
}

fn write_head(dst: &mut Mat, src: &Mat, s: usize, t: usize, hd: usize, dh: usize) {
    for i in 0..t {
        dst.row_mut(s * t + i)[hd * dh..(hd + 1) * dh].copy_from_slice(src.row(i));
    }
}

/// Row-wise causal softmax in place: row i attends to columns 0..=i.
/// Shares `softmax_1d` with the decode path, so incremental attention
/// probabilities reproduce the full forward's op-for-op.
fn causal_softmax(scores: &mut Mat) {
    let t = scores.rows;
    for i in 0..t {
        let row = scores.row_mut(i);
        softmax_1d(&mut row[..=i]);
        for j in i + 1..t {
            row[j] = 0.0;
        }
    }
}

/// Softmax over a fully-visible score slice: one decode query's causal
/// window, and the per-row kernel of `causal_softmax` — one body, so the
/// incremental and full paths can't drift apart.
fn softmax_1d(row: &mut [f32]) {
    let mut mx = f32::NEG_INFINITY;
    for &v in row.iter() {
        mx = mx.max(v);
    }
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - mx).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in row.iter_mut() {
        *v *= inv;
    }
}

/// Per-(sequence, head) causal attention over whole sequences — the body
/// shared by the Full (training/eval) and Prefill (serving) arms. Writes
/// the (B·T, h·dh) context into `attn_out`; optionally collects the
/// per-(seq, head) probability matrices for the backward pass.
#[allow(clippy::too_many_arguments)]
fn full_causal_attention(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    bsz: usize,
    t: usize,
    h: usize,
    dh: usize,
    scale: f32,
    attn_out: &mut Mat,
    mut probs_out: Option<&mut Vec<Mat>>,
) {
    for s in 0..bsz {
        for hd in 0..h {
            let qs = head_slice(q, s, t, hd, dh);
            let ks = head_slice(k, s, t, hd, dh);
            let vs = head_slice(v, s, t, hd, dh);
            let mut scores = qs.matmul_tb(&ks); // (t,t)
            scores.scale(scale);
            causal_softmax(&mut scores);
            let o = scores.matmul(&vs); // (t, dh)
            write_head(attn_out, &o, s, t, hd, dh);
            if let Some(p) = probs_out.as_deref_mut() {
                p.push(scores);
            }
        }
    }
}

/// One query row attending to the first `lim` rows of a session's K/V
/// cache, all heads — the per-token kernel shared by the single-stream
/// `Decode` and batched `BatchDecode` arms. The cache is paged, so the
/// loop walks it page by page via [`PagedKv::row_slices`]; rows arrive
/// in the same logical order a contiguous buffer would supply, and the
/// `dot`/`softmax_1d`/fused-accumulate op order is unchanged, so the
/// paths agree bit-for-bit with the full forward. `scores` is
/// caller-provided scratch to keep the decode hot path allocation-free.
fn attend_cached(
    qrow: &[f32],
    st: &TfBlockState,
    lim: usize,
    orow: &mut [f32],
    (h, dh): (usize, usize),
    scale: f32,
    scores: &mut Vec<f32>,
) {
    for hd in 0..h {
        let (c0, c1) = (hd * dh, (hd + 1) * dh);
        let qh = &qrow[c0..c1];
        scores.clear();
        scores.resize(lim, 0.0);
        let mut sc = scores.iter_mut();
        for krow in st.k.row_slices(lim) {
            *sc.next().expect("lim scores") = dot(qh, &krow[c0..c1]) * scale;
        }
        softmax_1d(scores);
        let oh = &mut orow[c0..c1];
        for (vrow, &p) in st.v.row_slices(lim).zip(scores.iter()) {
            let vh = &vrow[c0..c1];
            for (o, &vv) in oh.iter_mut().zip(vh) {
                *o = p.mul_add(vv, *o);
            }
        }
    }
}

/// Break-even for threading `BatchDecode` attention, in total
/// fused-multiply work units (Σ cached rows × d_model). Below it the
/// scoped-thread spawn costs more than the attention itself. Re-read
/// from `APT_BATCH_ATTN_THRESHOLD` on every call (not cached) so the
/// perf benches can force the serial baseline in-process.
fn batch_attn_threshold() -> usize {
    std::env::var("APT_BATCH_ATTN_THRESHOLD")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32_768)
}

/// Per-stream attention for the batched decode step: stream `i`'s query
/// row attends its own (just-appended) cache into output row `i`.
/// Streams are fully independent — disjoint states, disjoint output
/// rows — so the threaded path splits streams over the worker pool with
/// interleaved ownership (`i % nw`, balancing mixed cache lengths) and
/// is bit-identical to the serial path: the same [`attend_cached`]
/// kernel runs per stream either way.
fn batch_attend(
    q: &Mat,
    views: &[&TfBlockState],
    attn_out: &mut Mat,
    (h, dh): (usize, usize),
    scale: f32,
    threaded: bool,
) {
    let bsz = q.rows;
    debug_assert_eq!(views.len(), bsz);
    if !threaded {
        let mut scores: Vec<f32> = Vec::new();
        for (i, st) in views.iter().enumerate() {
            attend_cached(
                q.row(i),
                st,
                st.k.len(),
                attn_out.row_mut(i),
                (h, dh),
                scale,
                &mut scores,
            );
        }
        return;
    }
    let d = attn_out.cols;
    let nw = num_threads().min(bsz);
    let base = attn_out.data.as_mut_ptr() as usize;
    std::thread::scope(|s| {
        for w in 0..nw {
            s.spawn(move || {
                let mut scores: Vec<f32> = Vec::new();
                let mut i = w;
                while i < bsz {
                    // SAFETY: output rows are disjoint across workers
                    // (i % nw == w) and `attn_out` outlives the scope.
                    let orow: &mut [f32] = unsafe {
                        std::slice::from_raw_parts_mut((base as *mut f32).add(i * d), d)
                    };
                    let st = views[i];
                    attend_cached(q.row(i), st, st.k.len(), orow, (h, dh), scale, &mut scores);
                    i += nw;
                }
            });
        }
    });
}

/// Attention routing for `block_forward_impl`: the whole-context batch
/// path, the serving prefill fast path, or the incremental step-state
/// paths (single-stream and continuous-batched) against session caches.
pub(crate) enum TfAttn<'s, 'st> {
    /// B sequences of length T, causal within each sequence.
    Full { bsz: usize, t: usize },
    /// Whole prompt into an EMPTY cache: Full-arm threaded attention
    /// that also appends the rotated K/V — the serving prefill.
    Prefill { st: &'s mut TfBlockState },
    /// B whole prompts right-padded to a common length into B EMPTY
    /// caches, as ONE Full-arm pass — the engine's packed cross-request
    /// admission. `lens[s]` rows of stream `s` append to `sts[s]`.
    PrefillBatch { lens: &'s [usize], sts: &'s mut [&'st mut TfBlockState] },
    /// New tokens at absolute positions `pos0..`; K/V append to `st`.
    Decode { pos0: usize, st: &'s mut TfBlockState },
    /// One new token per stream at per-stream absolute positions, each
    /// against its own cache — the engine's continuous-batching step.
    BatchDecode { poss: &'s [usize], sts: &'s mut [&'st mut TfBlockState] },
}

/// Per-block decode-session state: the RoPE-rotated keys and values of
/// every live position, in paged (T, n_heads·head_dim) row storage.
/// Sliding-window eviction advances the page cursor — O(1) per step, no
/// row copying — instead of shifting a contiguous buffer.
#[derive(Clone, Debug)]
pub struct TfBlockState {
    pub k: PagedKv,
    pub v: PagedKv,
}

impl TfBlockState {
    fn new(d_model: usize) -> TfBlockState {
        TfBlockState { k: PagedKv::new(d_model), v: PagedKv::new(d_model) }
    }

    /// Custom page granularity — page-boundary tests only; sessions use
    /// the [`crate::tensor::KV_PAGE_ROWS`] default.
    #[cfg(test)]
    fn with_page_rows(d_model: usize, page_rows: usize) -> TfBlockState {
        TfBlockState {
            k: PagedKv::with_page_rows(d_model, page_rows),
            v: PagedKv::with_page_rows(d_model, page_rows),
        }
    }
}

pub struct BlockCache {
    x_in: Mat,
    n1: NormCache,
    q: Mat,
    k: Mat,
    v: Mat,
    probs: Vec<Mat>,
    attn_out: Mat,
    x2: Mat,
    n2: NormCache,
    u: Mat,
    g: Mat,
    a: Mat,
}

impl BlockCache {
    fn empty() -> BlockCache {
        let z = || Mat::zeros(0, 0);
        BlockCache {
            x_in: z(),
            n1: NormCache { y: z(), rinv: vec![] },
            q: z(),
            k: z(),
            v: z(),
            probs: vec![],
            attn_out: z(),
            x2: z(),
            n2: NormCache { y: z(), rinv: vec![] },
            u: z(),
            g: z(),
            a: z(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> TransformerConfig {
        TransformerConfig { vocab: 31, d_model: 16, n_layers: 2, n_heads: 2, d_ff: 24, max_seq: 16 }
    }

    fn tiny_model(seed: u64) -> Transformer {
        Transformer::init(tiny_cfg(), &mut Rng::new(seed))
    }

    fn rand_tokens(n: usize, vocab: usize, seed: u64) -> Vec<u32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.below(vocab) as u32).collect()
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(1);
        let toks = rand_tokens(2 * 8, 31, 2);
        let x = m.embed(&toks);
        assert_eq!(x.shape(), (16, 16));
        let y = m.block_forward(0, &x, (2, 8));
        assert_eq!(y.shape(), (16, 16));
        let logits = m.logits(&y);
        assert_eq!(logits.shape(), (16, 31));
    }

    #[test]
    fn loss_finite_and_near_uniform_at_init() {
        let m = tiny_model(3);
        let toks = rand_tokens(2 * 8, 31, 4);
        let loss = m.forward_loss(&toks, (2, 8));
        assert!(loss.is_finite());
        // ~ln(31)=3.43 for a near-uniform prediction at init
        assert!((loss - (31f64).ln()).abs() < 0.5, "{loss}");
    }

    #[test]
    fn collect_hits_every_linear() {
        let m = tiny_model(5);
        let toks = rand_tokens(8, 31, 6);
        let x = m.embed(&toks);
        let mut seen = std::collections::HashSet::new();
        m.block_forward_collect(0, &x, (1, 8), &mut |name, mat| {
            assert!(mat.rows == 8);
            seen.insert(name.to_string());
        });
        for l in BLOCK_LINEARS {
            assert!(seen.contains(l), "{l}");
        }
    }

    #[test]
    fn collect_forward_matches_plain_forward() {
        let m = tiny_model(7);
        let toks = rand_tokens(8, 31, 8);
        let x = m.embed(&toks);
        let a = m.block_forward(0, &x, (1, 8));
        let b = m.block_forward_collect(0, &x, (1, 8), &mut |_, _| {});
        assert_eq!(a, b);
    }

    #[test]
    fn causal_softmax_rows_sum_to_one() {
        let mut s = Mat::from_vec(3, 3, vec![1., 9., 9., 2., 3., 9., 0.5, 0.2, 0.1]);
        causal_softmax(&mut s);
        assert!((s[(0, 0)] - 1.0).abs() < 1e-6);
        assert_eq!(s[(0, 1)], 0.0);
        for i in 0..3 {
            let sum: f32 = s.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn rope_inverse_roundtrips() {
        let mut r = Rng::new(9);
        let orig = Mat::randn(8, 16, 1.0, &mut r);
        let mut x = orig.clone();
        rope(&mut x, 1, 8, 2, 8, false);
        rope(&mut x, 1, 8, 2, 8, true);
        assert!(x.max_abs_diff(&orig) < 1e-5);
    }

    #[test]
    fn causality_future_token_does_not_affect_past() {
        let m = tiny_model(11);
        let mut toks = rand_tokens(8, 31, 12);
        let lp1 = {
            let mut x = m.embed(&toks);
            for b in 0..2 {
                x = m.block_forward(b, &x, (1, 8));
            }
            m.logits(&x)
        };
        toks[7] = (toks[7] + 1) % 31; // change the LAST token
        let lp2 = {
            let mut x = m.embed(&toks);
            for b in 0..2 {
                x = m.block_forward(b, &x, (1, 8));
            }
            m.logits(&x)
        };
        // logits at positions 0..6 must be identical
        for i in 0..7 {
            for j in 0..31 {
                assert!((lp1[(i, j)] - lp2[(i, j)]).abs() < 1e-6, "pos {i}");
            }
        }
    }

    /// Finite-difference gradient check on a handful of parameters of every
    /// tensor — the strongest possible test of the manual backprop.
    #[test]
    fn gradcheck_all_param_kinds() {
        let mut m = tiny_model(13);
        let toks = rand_tokens(2 * 6, 31, 14);
        let bt = (2, 6);
        let (_, grads) = m.loss_and_grads(&toks, bt);
        let eps = 2e-3f32;
        let names: Vec<String> = m.params.names().iter().map(|s| s.to_string()).collect();
        for name in names {
            let g = grads.get(&name).unwrap().clone();
            // probe 3 entries spread through the tensor
            let len = g.data.len();
            for &frac in &[0usize, len / 2, len - 1] {
                let idx = frac.min(len - 1);
                let orig = m.params.dense(&name).unwrap().data[idx];
                m.params.dense_mut(&name).unwrap().data[idx] = orig + eps;
                let lp = m.forward_loss(&toks, bt);
                m.params.dense_mut(&name).unwrap().data[idx] = orig - eps;
                let lm = m.forward_loss(&toks, bt);
                m.params.dense_mut(&name).unwrap().data[idx] = orig;
                let fd = (lp - lm) / (2.0 * eps as f64);
                let an = g.data[idx] as f64;
                let denom = fd.abs().max(an.abs()).max(1e-4);
                assert!(
                    ((fd - an) / denom).abs() < 0.08,
                    "{name}[{idx}]: fd={fd:.6} analytic={an:.6}"
                );
            }
        }
    }

    /// Decode logits must be invariant to the K/V page granularity:
    /// paging is storage layout only, never math. Runs token-by-token
    /// decode with per-step window eviction across page sizes that
    /// divide, equal, and straddle the window.
    #[test]
    fn paged_decode_is_invariant_to_page_size() {
        let m = tiny_model(21);
        let toks = rand_tokens(40, 31, 22);
        let run = |page: usize, window: Option<usize>| -> Vec<f32> {
            let mut sts: Vec<TfBlockState> =
                (0..2).map(|_| TfBlockState::with_page_rows(16, page)).collect();
            let mut last = Vec::new();
            for (pos, &tok) in toks.iter().enumerate() {
                let mut x = m.embed(&[tok]);
                for b in 0..2 {
                    x = m.block_decode(b, &x, pos, &mut sts[b]);
                }
                if let Some(w) = window {
                    for st in sts.iter_mut() {
                        st.k.evict_to(w);
                        st.v.evict_to(w);
                    }
                }
                last = x.row(0).to_vec();
            }
            last
        };
        for window in [None, Some(8), Some(5), Some(40)] {
            let base = run(64, window);
            for page in [1usize, 5, 7, 8] {
                // bit-identical: same kernels, same row order
                assert_eq!(run(page, window), base, "page={page} window={window:?}");
            }
        }
    }

    /// The threaded BatchDecode attention path must be bit-identical to
    /// the serial one: streams are independent, so thread assignment can
    /// never change a result. Mixed cache lengths exercise the
    /// interleaved (i % nw) ownership.
    #[test]
    fn batch_attend_threaded_matches_serial_bitwise() {
        let (h, dh, d) = (2usize, 8usize, 16usize);
        let mut r = Rng::new(31);
        let rand_row = |r: &mut Rng| -> Vec<f32> {
            (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect()
        };
        let states: Vec<TfBlockState> = (0..8)
            .map(|i| {
                let mut st = TfBlockState::with_page_rows(d, 4);
                for _ in 0..(3 + i * 13) {
                    let kr = rand_row(&mut r);
                    let vr = rand_row(&mut r);
                    st.k.append_row(&kr);
                    st.v.append_row(&vr);
                }
                // exercise evicted heads too (page cursor mid-page)
                if i % 2 == 0 {
                    let keep = st.k.len().max(2) - 1;
                    st.k.evict_to(keep);
                    st.v.evict_to(keep);
                }
                st
            })
            .collect();
        let q = Mat::randn(8, d, 1.0, &mut r);
        let views: Vec<&TfBlockState> = states.iter().collect();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut serial = Mat::zeros(8, d);
        batch_attend(&q, &views, &mut serial, (h, dh), scale, false);
        let mut threaded = Mat::zeros(8, d);
        batch_attend(&q, &views, &mut threaded, (h, dh), scale, true);
        assert_eq!(serial, threaded);
    }

    /// The packed cross-request prefill arm (padded Full-arm batch) must
    /// reproduce per-stream solo prefills bit-for-bit: hidden rows AND
    /// the appended K/V caches.
    #[test]
    fn prefill_batch_matches_solo_prefills_bitwise() {
        use crate::model::{DecodeState, LanguageModel};
        let m = tiny_model(23);
        let prompts: Vec<Vec<u32>> =
            (0..4).map(|i| rand_tokens(1 + i * 5, 31, 24 + i as u64)).collect();
        let refs: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
        let mut batch_states: Vec<DecodeState> =
            (0..4).map(|_| LanguageModel::decode_state(&m)).collect();
        let h = LanguageModel::prefill_batch(&m, &mut batch_states, &refs);
        for (i, p) in prompts.iter().enumerate() {
            let mut solo = LanguageModel::decode_state(&m);
            let hr = m.prefill_append(&mut solo, 0, p);
            assert_eq!(h.row(i), &hr[..], "stream {i} hidden row");
            let (DecodeState::Transformer(a), DecodeState::Transformer(b)) =
                (&batch_states[i], &solo)
            else {
                unreachable!()
            };
            for (sa, sb) in a.iter().zip(b) {
                assert_eq!(sa.k.len(), sb.k.len(), "stream {i}");
                for j in 0..sa.k.len() {
                    assert_eq!(sa.k.row(j), sb.k.row(j), "stream {i} k row {j}");
                    assert_eq!(sa.v.row(j), sb.v.row(j), "stream {i} v row {j}");
                }
            }
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let m = tiny_model(15);
        let dir = std::env::temp_dir().join("apt_model_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ats");
        m.save(&p).unwrap();
        let l = Transformer::load(tiny_cfg(), &p).unwrap();
        let toks = rand_tokens(8, 31, 16);
        assert_eq!(m.forward_loss(&toks, (1, 8)), l.forward_loss(&toks, (1, 8)));
        std::fs::remove_file(p).ok();
    }

    #[test]
    fn sparse_stores_match_dense_forward() {
        use crate::model::LanguageModel;
        use crate::prune::{magnitude_prune, Sparsity};
        for sparsity in [Sparsity::Unstructured { rate: 0.6 }, Sparsity::two_four()] {
            let mut dense = tiny_model(17);
            for b in 0..dense.cfg.n_layers {
                for name in BLOCK_LINEARS {
                    magnitude_prune(dense.weight_mut(b, name).dense_mut(), sparsity);
                }
            }
            let mut packed = Transformer { cfg: dense.cfg, params: dense.params.clone() };
            for b in 0..dense.cfg.n_layers {
                for name in BLOCK_LINEARS {
                    let w = packed.weight(b, name).to_dense();
                    *packed.weight_mut(b, name) = crate::sparse::WeightStore::pack(&w, sparsity);
                    // mask bit-for-bit
                    assert_eq!(packed.weight(b, name).to_dense(), w);
                    assert_ne!(packed.weight(b, name).format(), "dense");
                }
            }
            let toks = rand_tokens(2 * 8, 31, 18);
            let a = dense.next_token_logprobs(&toks, (2, 8));
            let b = packed.next_token_logprobs(&toks, (2, 8));
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-5, "{sparsity:?}: {x} vs {y}");
            }
        }
    }
}
