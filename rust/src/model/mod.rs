//! Model substrate: microllama (transformer) + micromamba (SSM), a common
//! `LanguageModel` trait consumed by the coordinator/eval layers, shared
//! functional pieces (RMSNorm, cross-entropy), and the AdamW trainer.

pub mod decode;
pub mod mamba;
pub mod train;
pub mod transformer;

pub use decode::{DecodeSession, DecodeState};
pub use mamba::{Mamba, MambaConfig, CONV_K, MAMBA_LINEARS};
pub use train::{train, TrainConfig};
pub use transformer::{Transformer, TransformerConfig, BLOCK_LINEARS};

use crate::io::{ParamStore, TensorStore};
use crate::sparse::WeightStore;
use crate::tensor::{dot, Mat};

// ---------------------------------------------------------------------------
// shared functional pieces (used by both architectures)
// ---------------------------------------------------------------------------

pub struct NormCachePub {
    pub y: Mat,
    pub rinv: Vec<f32>,
}

const NORM_EPS: f32 = 1e-5;

pub fn transformer_rmsnorm(x: &Mat, gain: &[f32]) -> NormCachePub {
    let mut y = Mat::zeros(x.rows, x.cols);
    let mut rinv = vec![0.0f32; x.rows];
    for r in 0..x.rows {
        let row = x.row(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let ri = 1.0 / (ms + NORM_EPS).sqrt();
        rinv[r] = ri;
        let yrow = y.row_mut(r);
        for j in 0..x.cols {
            yrow[j] = row[j] * ri * gain[j];
        }
    }
    NormCachePub { y, rinv }
}

pub fn transformer_rmsnorm_backward(
    x: &Mat,
    gain: &[f32],
    cache: &NormCachePub,
    dy: &Mat,
) -> (Mat, Mat) {
    let d = x.cols;
    let mut dx = Mat::zeros(x.rows, d);
    let mut dgain = Mat::zeros(1, d);
    for r in 0..x.rows {
        let xrow = x.row(r);
        let dyrow = dy.row(r);
        let ri = cache.rinv[r];
        for j in 0..d {
            dgain.row_mut(0)[j] += dyrow[j] * xrow[j] * ri;
        }
        let mut dot = 0.0f32;
        for j in 0..d {
            dot += gain[j] * dyrow[j] * xrow[j];
        }
        let c = ri * ri * ri * dot / d as f32;
        let dxrow = dx.row_mut(r);
        for j in 0..d {
            dxrow[j] = gain[j] * dyrow[j] * ri - xrow[j] * c;
        }
    }
    (dx, dgain)
}

/// Mean next-token cross-entropy (no grad).
pub fn ce_loss(logits: &Mat, tokens: &[u32], bt: (usize, usize)) -> f64 {
    ce_impl(logits, tokens, bt, false).0
}

/// Mean next-token cross-entropy + logits gradient.
pub fn ce_loss_and_grad(logits: &Mat, tokens: &[u32], bt: (usize, usize)) -> (f64, Mat) {
    let (l, g) = ce_impl(logits, tokens, bt, true);
    (l, g.unwrap())
}

/// Log-prob of `target` under a log-softmax over `row` (f64 reduction,
/// same as the perplexity path).
pub fn log_softmax_at(row: &[f32], target: usize) -> f64 {
    let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
    let lse: f64 = row.iter().map(|&v| ((v as f64) - mx).exp()).sum::<f64>().ln() + mx;
    row[target] as f64 - lse
}

/// Final-norm + tied-embedding logits for ONE hidden row — the decode
/// fast path: a (1, V) product instead of the full (B·T, V) matmul. The
/// per-row math (rmsnorm loop order, `dot` kernel) is identical to
/// `logits`, so the result matches `logits(x).row(r)` bit-for-bit.
fn logits_row_impl(params: &ParamStore, h: &[f32]) -> Vec<f32> {
    let gain = params.dense("final_norm").expect("final_norm").row(0);
    let embed = params.dense("embed").expect("embed");
    let d = h.len();
    let ms: f32 = h.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let ri = 1.0 / (ms + NORM_EPS).sqrt();
    let mut y = vec![0.0f32; d];
    for j in 0..d {
        y[j] = h[j] * ri * gain[j];
    }
    (0..embed.rows).map(|v| dot(&y, embed.row(v))).collect()
}

fn ce_impl(
    logits: &Mat,
    tokens: &[u32],
    (bsz, t): (usize, usize),
    want_grad: bool,
) -> (f64, Option<Mat>) {
    let v = logits.cols;
    let n_pred = bsz * (t - 1);
    let mut loss = 0.0f64;
    let mut grad = if want_grad { Some(Mat::zeros(logits.rows, v)) } else { None };
    for s in 0..bsz {
        for i in 0..t - 1 {
            let r = s * t + i;
            let target = tokens[s * t + i + 1] as usize;
            let row = logits.row(r);
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b)) as f64;
            let sum: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum();
            let lse = sum.ln() + mx;
            loss += lse - row[target] as f64;
            if let Some(g) = grad.as_mut() {
                let grow = g.row_mut(r);
                let inv = (1.0 / n_pred as f64) as f32;
                for j in 0..v {
                    let p = ((row[j] as f64 - mx).exp() / sum) as f32;
                    grow[j] = p * inv;
                }
                grow[target] -= inv;
            }
        }
    }
    (loss / n_pred as f64, grad)
}

// ---------------------------------------------------------------------------
// the trait the coordinator/eval layers consume
// ---------------------------------------------------------------------------

/// Architecture-independent view of a decoder LM: block-streamable forward
/// (the coordinator prunes block-by-block) plus training/eval entry points.
/// Block weights are exposed as [`WeightStore`]s, so the coordinator can
/// swap a pruned linear's layout (dense → CSR / packed 2:4) in place and
/// every eval path executes the sparse kernels transparently.
pub trait LanguageModel: Send + Sync {
    fn arch(&self) -> &'static str;
    fn vocab(&self) -> usize;
    fn n_blocks(&self) -> usize;
    /// Names of prunable linear weights within each block.
    fn linear_names(&self) -> &'static [&'static str];
    fn n_params(&self) -> usize;

    fn params(&self) -> &ParamStore;
    fn params_mut(&mut self) -> &mut ParamStore;

    fn embed_tokens(&self, tokens: &[u32]) -> Mat;
    fn forward_block(&self, b: usize, x: &Mat, bt: (usize, usize)) -> Mat;
    fn forward_block_collect(
        &self,
        b: usize,
        x: &Mat,
        bt: (usize, usize),
        sink: &mut dyn FnMut(&str, &Mat),
    ) -> Mat;
    fn logits(&self, x: &Mat) -> Mat;

    fn block_weight(&self, b: usize, name: &str) -> &WeightStore;
    fn block_weight_mut(&mut self, b: usize, name: &str) -> &mut WeightStore;

    fn forward_loss(&self, tokens: &[u32], bt: (usize, usize)) -> f64;
    fn loss_and_grads(&self, tokens: &[u32], bt: (usize, usize)) -> (f64, TensorStore);

    // ---------------------------------------------- incremental decoding

    /// Fresh per-session decode state (K/V caches or recurrent state,
    /// one entry per block). Consumed through [`DecodeSession`].
    fn decode_state(&self) -> DecodeState;

    /// Append `tokens` at absolute positions `pos0..pos0 + tokens.len()`,
    /// mutating `state`; returns the final hidden row of the LAST
    /// appended position (feed it to [`LanguageModel::logits_row`]).
    /// Panics if `state` came from the other architecture.
    fn decode_append(&self, state: &mut DecodeState, pos0: usize, tokens: &[u32]) -> Vec<f32>;

    /// Like [`LanguageModel::decode_append`], but returns the final
    /// hidden rows of ALL `tokens.len()` appended positions as a
    /// (T, d) matrix — the speculative-verification primitive: the
    /// target model scores every draft position in one batched forward,
    /// and each row fed to [`LanguageModel::logits_row`] matches what a
    /// sequence of single-token `decode_append` calls would produce at
    /// the same absolute positions, bit-for-bit (the incremental arms
    /// append the whole chunk's K/V first, then attend row `i` against
    /// exactly `pos0 + i + 1` cached rows / scan positions in order).
    fn decode_append_full(&self, state: &mut DecodeState, pos0: usize, tokens: &[u32]) -> Mat;

    /// Prefill fast path: semantically identical to
    /// [`LanguageModel::decode_append`], but free to run a whole-chunk
    /// batch arm when starting from an empty cache. The transformer
    /// override runs the threaded Full attention arm (per-head matmuls)
    /// while appending the rotated K/V; mamba's incremental arm already
    /// batches its matmuls over the chunk, so the default suffices.
    fn prefill_append(&self, state: &mut DecodeState, pos0: usize, tokens: &[u32]) -> Vec<f32> {
        self.decode_append(state, pos0, tokens)
    }

    /// Batched decode step: `tokens[i]` is stream `i`'s single new token
    /// at absolute position `poss[i]`, continuing `states[i]`. Every
    /// linear runs ONE (B, d) matmul over the stacked queries — the
    /// weight-read amortization the serving engine is built on. Returns
    /// the (B, d) matrix of final hidden rows (feed it to
    /// [`LanguageModel::logits`]); row `i` matches what a lone
    /// [`LanguageModel::decode_append`] on `states[i]` would produce.
    fn decode_step_batch(&self, states: &mut [DecodeState], poss: &[usize], tokens: &[u32])
        -> Mat;

    /// Packed cross-request prefill: `prompts[i]` (non-empty) fills the
    /// FRESH state `states[i]` from position 0; returns the (B, d)
    /// matrix of final hidden rows, one per prompt (feed it to
    /// [`LanguageModel::logits`]). Row `i` matches what a lone
    /// [`LanguageModel::prefill_append`] on `states[i]` would produce.
    /// The transformer override right-pads the prompts to one batch and
    /// runs a single threaded Full-arm pass (per-(seq, head) work is
    /// independent, so results are bit-identical to solo prefills); the
    /// default loops per prompt — correct for mamba, whose incremental
    /// arm already batches its matmuls over each chunk.
    fn prefill_batch(&self, states: &mut [DecodeState], prompts: &[&[u32]]) -> Mat {
        assert_eq!(states.len(), prompts.len(), "one state per prompt");
        let rows: Vec<Vec<f32>> = states
            .iter_mut()
            .zip(prompts)
            .map(|(st, p)| self.prefill_append(st, 0, p))
            .collect();
        let d = rows.first().map_or(0, |r| r.len());
        let mut h = Mat::zeros(rows.len(), d);
        for (i, r) in rows.iter().enumerate() {
            h.row_mut(i).copy_from_slice(r);
        }
        h
    }

    /// Logits for a single final-hidden row: the (1, V) fast path that
    /// skips the full (B·T, V) matmul. Matches `logits(x).row(r)`
    /// bit-for-bit for the same hidden row.
    fn logits_row(&self, h: &[f32]) -> Vec<f32> {
        logits_row_impl(self.params(), h)
    }

    /// Last-position logits of a block-forward output — the single-
    /// position caller's fast path over [`LanguageModel::logits`].
    fn logits_last(&self, x: &Mat) -> Vec<f32> {
        self.logits_row(x.row(x.rows - 1))
    }

    /// Log-prob of each next token over a window (perplexity eval).
    fn next_token_logprobs(&self, tokens: &[u32], bt: (usize, usize)) -> Vec<f64> {
        let mut x = self.embed_tokens(tokens);
        for b in 0..self.n_blocks() {
            x = self.forward_block(b, &x, bt);
        }
        let logits = self.logits(&x);
        let (bsz, t) = bt;
        let mut out = Vec::new();
        for s in 0..bsz {
            for i in 0..t - 1 {
                let row = logits.row(s * t + i);
                out.push(log_softmax_at(row, tokens[s * t + i + 1] as usize));
            }
        }
        out
    }

    /// Greedy next-token prediction at every position of a window (one
    /// full forward, one argmax per row) — the eval-side primitive
    /// behind [`greedy_agreement`](crate::eval::greedy_agreement), which
    /// compares a pruned draft's argmaxes against the dense target's to
    /// predict speculative-decoding acceptance.
    fn next_token_argmaxes(&self, tokens: &[u32], bt: (usize, usize)) -> Vec<u32> {
        let mut x = self.embed_tokens(tokens);
        for b in 0..self.n_blocks() {
            x = self.forward_block(b, &x, bt);
        }
        let logits = self.logits(&x);
        let (bsz, t) = bt;
        let mut out = Vec::with_capacity(bsz * (t - 1));
        for s in 0..bsz {
            for i in 0..t - 1 {
                out.push(decode::argmax(logits.row(s * t + i)) as u32);
            }
        }
        out
    }

    /// Sum log-prob of a continuation given a context (zero-shot choice).
    /// Routed through a [`DecodeSession`]: the context is prefilled once
    /// and each continuation token is a single O(T·L) step.
    fn continuation_logprob(&self, context: &[u32], continuation: &[u32]) -> f64 {
        if continuation.is_empty() {
            return 0.0;
        }
        let mut s = DecodeSession::new(self);
        s.prefill(context);
        s.continuation_logprob(continuation)
    }

    /// Reference continuation scoring via one full quadratic forward —
    /// the equivalence oracle for the session path (and the honest
    /// no-cache baseline in the decode benches).
    fn continuation_logprob_full(&self, context: &[u32], continuation: &[u32]) -> f64 {
        let mut toks = context.to_vec();
        toks.extend_from_slice(continuation);
        let lp = self.next_token_logprobs(&toks, (1, toks.len()));
        // predictions for continuation tokens start at index |ctx|-1
        lp[context.len() - 1..].iter().sum()
    }

    /// Argmax next token after a context (LAMBADA eval). Routed through
    /// a [`DecodeSession`] — O(T·L) instead of O(T²·L).
    fn predict_last(&self, context: &[u32]) -> u32 {
        let mut s = DecodeSession::new(self);
        s.prefill(context);
        s.argmax_last()
    }

    /// Reference argmax via the full forward (every block re-runs the
    /// whole context) — the equivalence oracle and bench baseline. Uses
    /// the `logits_last` single-position fast path.
    fn predict_last_full(&self, context: &[u32]) -> u32 {
        let mut x = self.embed_tokens(context);
        for b in 0..self.n_blocks() {
            x = self.forward_block(b, &x, (1, context.len()));
        }
        decode::argmax(&self.logits_last(&x)) as u32
    }
}

impl LanguageModel for Transformer {
    fn arch(&self) -> &'static str {
        "microllama"
    }
    fn vocab(&self) -> usize {
        self.cfg.vocab
    }
    fn n_blocks(&self) -> usize {
        self.cfg.n_layers
    }
    fn linear_names(&self) -> &'static [&'static str] {
        &BLOCK_LINEARS
    }
    fn n_params(&self) -> usize {
        Transformer::n_params(self)
    }
    fn params(&self) -> &ParamStore {
        &self.params
    }
    fn params_mut(&mut self) -> &mut ParamStore {
        &mut self.params
    }
    fn embed_tokens(&self, tokens: &[u32]) -> Mat {
        self.embed(tokens)
    }
    fn forward_block(&self, b: usize, x: &Mat, bt: (usize, usize)) -> Mat {
        self.block_forward(b, x, bt)
    }
    fn forward_block_collect(
        &self,
        b: usize,
        x: &Mat,
        bt: (usize, usize),
        sink: &mut dyn FnMut(&str, &Mat),
    ) -> Mat {
        self.block_forward_collect(b, x, bt, sink)
    }
    fn logits(&self, x: &Mat) -> Mat {
        Transformer::logits(self, x)
    }
    fn block_weight(&self, b: usize, name: &str) -> &WeightStore {
        self.weight(b, name)
    }
    fn block_weight_mut(&mut self, b: usize, name: &str) -> &mut WeightStore {
        self.weight_mut(b, name)
    }
    fn forward_loss(&self, tokens: &[u32], bt: (usize, usize)) -> f64 {
        Transformer::forward_loss(self, tokens, bt)
    }
    fn loss_and_grads(&self, tokens: &[u32], bt: (usize, usize)) -> (f64, TensorStore) {
        Transformer::loss_and_grads(self, tokens, bt)
    }
    fn decode_state(&self) -> DecodeState {
        DecodeState::Transformer(self.new_block_states())
    }
    fn decode_append(&self, state: &mut DecodeState, pos0: usize, tokens: &[u32]) -> Vec<f32> {
        let DecodeState::Transformer(st) = state else {
            panic!("decode state/arch mismatch: microllama fed a mamba state")
        };
        assert_eq!(st.len(), self.cfg.n_layers, "decode state from another model");
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            x = self.block_decode(b, &x, pos0, &mut st[b]);
        }
        x.row(x.rows - 1).to_vec()
    }
    fn decode_append_full(&self, state: &mut DecodeState, pos0: usize, tokens: &[u32]) -> Mat {
        let DecodeState::Transformer(st) = state else {
            panic!("decode state/arch mismatch: microllama fed a mamba state")
        };
        assert_eq!(st.len(), self.cfg.n_layers, "decode state from another model");
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            x = self.block_decode(b, &x, pos0, &mut st[b]);
        }
        x
    }
    fn prefill_append(&self, state: &mut DecodeState, pos0: usize, tokens: &[u32]) -> Vec<f32> {
        // the threaded Full-arm fast path only applies from an empty
        // cache; continuation chunks take the incremental arm
        if pos0 != 0 || tokens.len() <= 1 {
            return self.decode_append(state, pos0, tokens);
        }
        let DecodeState::Transformer(st) = state else {
            panic!("decode state/arch mismatch: microllama fed a mamba state")
        };
        assert_eq!(st.len(), self.cfg.n_layers, "decode state from another model");
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            x = self.block_prefill(b, &x, &mut st[b]);
        }
        x.row(x.rows - 1).to_vec()
    }
    fn decode_step_batch(
        &self,
        states: &mut [DecodeState],
        poss: &[usize],
        tokens: &[u32],
    ) -> Mat {
        assert!(!tokens.is_empty(), "decode_step_batch needs at least one stream");
        assert_eq!(states.len(), tokens.len(), "one state per token");
        assert_eq!(poss.len(), tokens.len(), "one position per token");
        // validate arch + shape once; the per-block loop below only
        // projects out each stream's block state
        for s in states.iter() {
            let DecodeState::Transformer(v) = s else {
                panic!("decode state/arch mismatch: microllama fed a mamba state")
            };
            assert_eq!(v.len(), self.cfg.n_layers, "decode state from another model");
        }
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            let mut sts: Vec<&mut transformer::TfBlockState> = states
                .iter_mut()
                .map(|s| match s {
                    DecodeState::Transformer(v) => &mut v[b],
                    DecodeState::Mamba(_) => unreachable!("validated above"),
                })
                .collect();
            x = self.block_decode_batch(b, &x, poss, &mut sts);
        }
        x
    }
    fn prefill_batch(&self, states: &mut [DecodeState], prompts: &[&[u32]]) -> Mat {
        assert_eq!(states.len(), prompts.len(), "one state per prompt");
        assert!(!prompts.is_empty(), "prefill_batch needs at least one prompt");
        assert!(prompts.iter().all(|p| !p.is_empty()), "prompts must be non-empty");
        for s in states.iter() {
            let DecodeState::Transformer(v) = s else {
                panic!("decode state/arch mismatch: microllama fed a mamba state")
            };
            assert_eq!(v.len(), self.cfg.n_layers, "decode state from another model");
        }
        let bsz = prompts.len();
        let t = prompts.iter().map(|p| p.len()).max().unwrap();
        let lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
        // right-pad with each prompt's last token (any valid id works:
        // padding rows are causally downstream of every real row and
        // their K/V is never appended)
        let mut toks: Vec<u32> = Vec::with_capacity(bsz * t);
        for p in prompts {
            toks.extend_from_slice(p);
            toks.extend(std::iter::repeat(*p.last().unwrap()).take(t - p.len()));
        }
        let mut x = self.embed(&toks);
        for b in 0..self.cfg.n_layers {
            let mut sts: Vec<&mut transformer::TfBlockState> = states
                .iter_mut()
                .map(|s| match s {
                    DecodeState::Transformer(v) => &mut v[b],
                    DecodeState::Mamba(_) => unreachable!("validated above"),
                })
                .collect();
            x = self.block_prefill_batch(b, &x, &lens, &mut sts);
        }
        let mut h = Mat::zeros(bsz, self.cfg.d_model);
        for s in 0..bsz {
            h.row_mut(s).copy_from_slice(x.row(s * t + lens[s] - 1));
        }
        h
    }
}

impl LanguageModel for Mamba {
    fn arch(&self) -> &'static str {
        "micromamba"
    }
    fn vocab(&self) -> usize {
        self.cfg.vocab
    }
    fn n_blocks(&self) -> usize {
        self.cfg.n_layers
    }
    fn linear_names(&self) -> &'static [&'static str] {
        &MAMBA_LINEARS
    }
    fn n_params(&self) -> usize {
        Mamba::n_params(self)
    }
    fn params(&self) -> &ParamStore {
        &self.params
    }
    fn params_mut(&mut self) -> &mut ParamStore {
        &mut self.params
    }
    fn embed_tokens(&self, tokens: &[u32]) -> Mat {
        self.embed(tokens)
    }
    fn forward_block(&self, b: usize, x: &Mat, bt: (usize, usize)) -> Mat {
        self.block_forward(b, x, bt)
    }
    fn forward_block_collect(
        &self,
        b: usize,
        x: &Mat,
        bt: (usize, usize),
        sink: &mut dyn FnMut(&str, &Mat),
    ) -> Mat {
        self.block_forward_collect(b, x, bt, sink)
    }
    fn logits(&self, x: &Mat) -> Mat {
        Mamba::logits(self, x)
    }
    fn block_weight(&self, b: usize, name: &str) -> &WeightStore {
        self.weight(b, name)
    }
    fn block_weight_mut(&mut self, b: usize, name: &str) -> &mut WeightStore {
        self.weight_mut(b, name)
    }
    fn forward_loss(&self, tokens: &[u32], bt: (usize, usize)) -> f64 {
        Mamba::forward_loss(self, tokens, bt)
    }
    fn loss_and_grads(&self, tokens: &[u32], bt: (usize, usize)) -> (f64, TensorStore) {
        Mamba::loss_and_grads(self, tokens, bt)
    }
    fn decode_state(&self) -> DecodeState {
        DecodeState::Mamba(self.new_block_states())
    }
    fn decode_append(&self, state: &mut DecodeState, _pos0: usize, tokens: &[u32]) -> Vec<f32> {
        let DecodeState::Mamba(st) = state else {
            panic!("decode state/arch mismatch: micromamba fed a transformer state")
        };
        assert_eq!(st.len(), self.cfg.n_layers, "decode state from another model");
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            x = self.block_decode(b, &x, &mut st[b]);
        }
        x.row(x.rows - 1).to_vec()
    }
    fn decode_append_full(&self, state: &mut DecodeState, _pos0: usize, tokens: &[u32]) -> Mat {
        let DecodeState::Mamba(st) = state else {
            panic!("decode state/arch mismatch: micromamba fed a transformer state")
        };
        assert_eq!(st.len(), self.cfg.n_layers, "decode state from another model");
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            x = self.block_decode(b, &x, &mut st[b]);
        }
        x
    }
    fn decode_step_batch(
        &self,
        states: &mut [DecodeState],
        _poss: &[usize],
        tokens: &[u32],
    ) -> Mat {
        assert!(!tokens.is_empty(), "decode_step_batch needs at least one stream");
        assert_eq!(states.len(), tokens.len(), "one state per token");
        for s in states.iter() {
            let DecodeState::Mamba(v) = s else {
                panic!("decode state/arch mismatch: micromamba fed a transformer state")
            };
            assert_eq!(v.len(), self.cfg.n_layers, "decode state from another model");
        }
        let mut x = self.embed(tokens);
        for b in 0..self.cfg.n_layers {
            let mut sts: Vec<&mut mamba::MambaBlockState> = states
                .iter_mut()
                .map(|s| match s {
                    DecodeState::Mamba(v) => &mut v[b],
                    DecodeState::Transformer(_) => unreachable!("validated above"),
                })
                .collect();
            x = self.block_decode_batch(b, &x, &mut sts);
        }
        x
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn trait_objects_work_for_both_archs() {
        let mut rng = Rng::new(1);
        let t = Transformer::init(
            TransformerConfig { vocab: 17, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 12, max_seq: 8 },
            &mut rng,
        );
        let m = Mamba::init(
            MambaConfig { vocab: 17, d_model: 8, d_inner: 12, n_layers: 1, max_seq: 8 },
            &mut rng,
        );
        let models: Vec<Box<dyn LanguageModel>> = vec![Box::new(t), Box::new(m)];
        let toks: Vec<u32> = (0..8).map(|i| (i * 3 % 17) as u32).collect();
        for model in &models {
            let loss = model.forward_loss(&toks, (1, 8));
            assert!(loss.is_finite(), "{}", model.arch());
            let lp = model.next_token_logprobs(&toks, (1, 8));
            assert_eq!(lp.len(), 7);
            assert!(lp.iter().all(|v| *v <= 0.0));
            let pred = model.predict_last(&toks);
            assert!((pred as usize) < 17);
        }
    }

    #[test]
    fn continuation_logprob_finite() {
        let mut rng = Rng::new(2);
        let t = Transformer::init(
            TransformerConfig { vocab: 17, d_model: 8, n_layers: 1, n_heads: 2, d_ff: 12, max_seq: 16 },
            &mut rng,
        );
        let lp = t.continuation_logprob(&[1, 2, 3, 4], &[5, 6]);
        assert!(lp < 0.0 && lp.is_finite());
    }

    fn both_archs(seed: u64) -> Vec<Box<dyn LanguageModel>> {
        let mut rng = Rng::new(seed);
        let t = Transformer::init(
            TransformerConfig { vocab: 17, d_model: 8, n_layers: 2, n_heads: 2, d_ff: 12, max_seq: 32 },
            &mut rng,
        );
        let m = Mamba::init(
            MambaConfig { vocab: 17, d_model: 8, d_inner: 12, n_layers: 2, max_seq: 32 },
            &mut rng,
        );
        vec![Box::new(t), Box::new(m)]
    }

    #[test]
    fn logits_last_matches_full_logits_row_exactly() {
        for model in both_archs(3) {
            let toks: Vec<u32> = (0..10).map(|i| (i * 5 % 17) as u32).collect();
            let mut x = model.embed_tokens(&toks);
            for b in 0..model.n_blocks() {
                x = model.forward_block(b, &x, (1, toks.len()));
            }
            let full = model.logits(&x);
            let fast = model.logits_last(&x);
            // same rmsnorm loop + same `dot` kernel: bit-for-bit
            assert_eq!(fast.as_slice(), full.row(full.rows - 1), "{}", model.arch());
        }
    }

    #[test]
    fn decode_append_full_rows_match_sequential_steps() {
        // The speculative-verification contract: one batched chunk
        // append yields, per position, the SAME final hidden row (and
        // hence the same logits_row) as single-token steps — bit-exact.
        for model in both_archs(5) {
            let toks: Vec<u32> = (0..9).map(|i| (i * 7 % 17) as u32).collect();
            let mut st_seq = model.decode_state();
            let mut seq_rows = Vec::new();
            for (i, &t) in toks.iter().enumerate() {
                seq_rows.push(model.decode_append(&mut st_seq, i, &[t]));
            }
            let mut st = model.decode_state();
            model.decode_append(&mut st, 0, &toks[..4]);
            let full = model.decode_append_full(&mut st, 4, &toks[4..]);
            assert_eq!(full.rows, 5, "{}", model.arch());
            for i in 0..full.rows {
                assert_eq!(full.row(i), &seq_rows[4 + i][..], "{} row {i}", model.arch());
                assert_eq!(
                    model.logits_row(full.row(i)),
                    model.logits_row(&seq_rows[4 + i]),
                    "{} logits {i}",
                    model.arch()
                );
            }
        }
    }

    #[test]
    fn session_continuation_and_predict_match_full_forward() {
        for model in both_archs(4) {
            let ctx: Vec<u32> = (0..12).map(|i| (i * 3 % 17) as u32).collect();
            let cont = [2u32, 9, 4];
            let a = model.continuation_logprob(&ctx, &cont);
            let b = model.continuation_logprob_full(&ctx, &cont);
            assert!((a - b).abs() < 1e-5, "{}: {a} vs {b}", model.arch());
            assert_eq!(
                model.predict_last(&ctx),
                model.predict_last_full(&ctx),
                "{}",
                model.arch()
            );
            assert_eq!(model.continuation_logprob(&ctx, &[]), 0.0);
        }
    }
}
