//! `apt` — launcher CLI for the APT-Repro pruning system.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!   train      train a dense stand-in model and cache the checkpoint
//!   prune      prune a cached model with one method and save it
//!   eval       perplexity + zero-shot of a checkpoint
//!   pipeline   end-to-end: train -> prune (all methods) -> eval table
//!   table      regenerate a paper table/figure (table1|table2|table3|a1|a2|fig_a1|all)
//!   artifacts  verify every AOT artifact loads + executes via PJRT
//!
//! Config overrides: any `--key=value` from config::ExperimentConfig,
//! plus `--config=<file.json>`.

use std::path::Path;

use anyhow::Result;

use apt::config::ExperimentConfig;
use apt::coordinator::{prune_model, PipelineConfig};
use apt::data::Profile;
use apt::harness::{self, Zoo};
use apt::prune::Method;
use apt::runtime::{Backend, Runtime};
use apt::util::profile_report;

struct SimpleLogger;

impl log::Log for SimpleLogger {
    fn enabled(&self, _: &log::Metadata) -> bool {
        true
    }
    fn log(&self, record: &log::Record) {
        eprintln!("[{}] {}", record.level(), record.args());
    }
    fn flush(&self) {}
}

static LOGGER: SimpleLogger = SimpleLogger;

fn main() -> Result<()> {
    log::set_logger(&LOGGER).ok();
    log::set_max_level(log::LevelFilter::Info);

    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = ExperimentConfig::default();
    for a in &args {
        if let Some(path) = a.strip_prefix("--config=") {
            cfg.apply_file(Path::new(path))?;
        }
    }
    let rest: Vec<String> = args
        .iter()
        .filter(|a| !a.starts_with("--config="))
        .cloned()
        .collect();
    let positional: Vec<String> = {
        let refs = cfg.apply_args(&rest)?;
        refs.into_iter().map(|s| s.to_string()).collect()
    };

    let cmd = positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&cfg),
        "prune" => cmd_prune(&cfg),
        "eval" => cmd_eval(&cfg),
        "pipeline" => cmd_pipeline(&cfg),
        "table" => cmd_table(&cfg, positional.get(1).map(|s| s.as_str())),
        "artifacts" => cmd_artifacts(),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "apt — 'Pruning Foundation Models for High Accuracy without Retraining' repro

USAGE: apt <command> [--key=value ...]

COMMANDS:
  train                train + cache a dense model (--arch --size --steps)
  prune                prune the cached model (--method --sparsity --block)
  eval                 perplexity + zero-shot of the cached dense model
  pipeline             end-to-end: train -> all methods -> comparison table
  table <id>           regenerate a paper table: table1 table2 table3 a1 a2 fig_a1 all
  artifacts            check all AOT HLO artifacts load + run via PJRT

CONFIG KEYS (as --key=value):
  arch=microllama|micromamba  size=small|medium  method=magnitude|wanda|ss|sm|ms|mm
  sparsity=0.5|70%|2:4        block=0(all)|128   gamma=0.01   n_calib=32
  engine=native|hlo           steps=400          seed=42      out=results"
    );
}

fn family_of(cfg: &ExperimentConfig) -> &'static str {
    if cfg.arch.contains("mamba") {
        "mamba"
    } else if cfg.arch.contains("opt") {
        "opt"
    } else if cfg.arch.contains("bloom") {
        "bloom"
    } else {
        "llama"
    }
}

fn load_runtime(cfg: &ExperimentConfig) -> Option<Runtime> {
    if cfg.engine != Backend::Hlo {
        return None;
    }
    match Runtime::load(Path::new("artifacts")) {
        Ok(rt) => {
            log::info!("PJRT runtime up: {} ({} artifacts)", rt.platform(), rt.entries().len());
            Some(rt)
        }
        Err(e) => {
            log::warn!("HLO engine requested but runtime failed ({e}); falling back to native");
            None
        }
    }
}

fn cmd_train(cfg: &ExperimentConfig) -> Result<()> {
    let zoo = Zoo::new(cfg.seed);
    let model = zoo.model(family_of(cfg), &cfg.size, cfg.train_steps)?;
    println!(
        "trained {} {} ({} params) — cached in results/model_cache/",
        cfg.arch,
        cfg.size,
        model.as_dyn().n_params()
    );
    Ok(())
}

fn cmd_prune(cfg: &ExperimentConfig) -> Result<()> {
    let zoo = Zoo::new(cfg.seed);
    let runtime = load_runtime(cfg);
    let mut model = zoo.model(family_of(cfg), &cfg.size, cfg.train_steps)?;
    let calib_profile = Profile::from_name(&cfg.calib_profile).unwrap_or(Profile::C4Like);
    let calib = zoo.calibration(calib_profile, cfg.n_calib, cfg.calib_seq_len);
    let pipe = PipelineConfig::new(cfg.prune_config()).with_engine(cfg.engine);
    let report = prune_model(model.as_dyn_mut(), &calib, &pipe, runtime.as_ref())?;
    println!(
        "pruned {} linears to {:.1}% sparsity in {:.1}s (calib {:.1}s, prune {:.1}s, propagate {:.1}s; hlo {:.0}%)",
        report.linears.len(),
        report.overall_sparsity() * 100.0,
        report.total_ms / 1e3,
        report.calib_ms / 1e3,
        report.prune_ms / 1e3,
        report.propagate_ms / 1e3,
        report.hlo_fraction() * 100.0
    );
    std::fs::create_dir_all(&cfg.out_dir).ok();
    let out = Path::new(&cfg.out_dir).join(format!(
        "{}_{}_{}_{}.ats",
        family_of(cfg),
        cfg.size,
        cfg.method.name().replace(['(', ')'], "_"),
        cfg.sparsity.label().replace([':', '%'], "_")
    ));
    match &model {
        harness::AnyModel::Llama(m) => m.save(&out)?,
        harness::AnyModel::Mamba(m) => m.save(&out)?,
    }
    println!("saved pruned checkpoint to {}", out.display());
    println!("\n{}", profile_report());
    Ok(())
}

fn cmd_eval(cfg: &ExperimentConfig) -> Result<()> {
    let zoo = Zoo::new(cfg.seed);
    let model = zoo.model(family_of(cfg), &cfg.size, cfg.train_steps)?;
    let ppl = harness::eval_ppl(model.as_dyn(), &zoo);
    println!("perplexity: {ppl:?}");
    let zs = harness::suite::eval_zeroshot(model.as_dyn(), &zoo, 100);
    println!(
        "zero-shot: lambada {:.1}% hellaswag {:.1}% piqa {:.1}% arc {:.1}% wino {:.1}% avg {:.2}%",
        zs.lambada * 100.0,
        zs.hellaswag * 100.0,
        zs.piqa * 100.0,
        zs.arc * 100.0,
        zs.winogrande * 100.0,
        zs.average() * 100.0
    );
    Ok(())
}

fn cmd_pipeline(cfg: &ExperimentConfig) -> Result<()> {
    use apt::harness::{format_table, origin_row, prune_and_eval, RunOpts};
    let zoo = Zoo::new(cfg.seed);
    let runtime = load_runtime(cfg);
    let base = zoo.model(family_of(cfg), &cfg.size, cfg.train_steps)?;
    println!("dense {} {}: {} params", cfg.arch, cfg.size, base.as_dyn().n_params());
    let mut rows = vec![origin_row(&base, &zoo)];
    let methods: &[Method] = if matches!(cfg.sparsity, apt::prune::Sparsity::SemiStructured { .. })
    {
        &[Method::Magnitude, Method::Wanda, Method::SS, Method::SM, Method::MS, Method::MM]
    } else {
        &[Method::Magnitude, Method::Wanda, Method::SS, Method::SM]
    };
    for &m in methods {
        let mut o = RunOpts::new(m, cfg.sparsity);
        o.block_size = if cfg.block_size == 0 { None } else { Some(cfg.block_size) };
        o.gamma = cfg.gamma;
        o.n_calib = cfg.n_calib;
        o.engine = cfg.engine;
        rows.push(prune_and_eval(&base, &zoo, &o, runtime.as_ref())?);
    }
    let table = format_table(
        &format!("pipeline — {} {} @ {}", cfg.arch, cfg.size, cfg.sparsity.label()),
        &rows,
    );
    println!("{table}");
    harness::save_rows("pipeline", &rows)?;
    println!("{}", profile_report());
    Ok(())
}

fn cmd_table(cfg: &ExperimentConfig, id: Option<&str>) -> Result<()> {
    let zoo = Zoo::new(cfg.seed);
    let runtime = load_runtime(cfg);
    match id {
        Some("all") | None => {
            for id in harness::ALL_TABLES {
                let out = harness::run_table(id, &zoo, runtime.as_ref())?;
                println!("{out}");
            }
        }
        Some(id) => {
            let out = harness::run_table(id, &zoo, runtime.as_ref())?;
            println!("{out}");
        }
    }
    Ok(())
}

fn cmd_artifacts() -> Result<()> {
    let rt = Runtime::load(Path::new("artifacts"))?;
    println!("platform: {}, {} artifacts", rt.platform(), rt.entries().len());
    let mut ok = 0usize;
    let mut failed = 0usize;
    for e in rt.entries().to_vec() {
        let run = || -> Result<()> {
            use apt::tensor::Mat;
            let mut rng = apt::util::Rng::new(7);
            match e.name.as_str() {
                "hessian_update" => {
                    let x = Mat::randn(e.t, e.m, 1.0, &mut rng);
                    let h = Mat::zeros(e.m, e.m);
                    rt.exec(&e, &[&x, &h], &[], &[e.m])?;
                }
                "hessian_finalize" => {
                    let x = Mat::randn(4 * e.m, e.m, 1.0, &mut rng);
                    let mut acc = apt::prune::HessianAccumulator::new(e.m);
                    acc.add_chunk(&x);
                    let h = acc.h.to_f32();
                    rt.exec(&e, &[&h], &[0.01], &[e.m])?;
                }
                "prune_seq" => {
                    let w = Mat::randn(e.n, e.m, 1.0, &mut rng);
                    let mask = Mat::zeros(e.n, e.m);
                    let hinv = spd(e.m, &mut rng);
                    rt.exec(&e, &[&w, &mask, &hinv], &[], &[e.n])?;
                }
                _ => {
                    let w = Mat::randn(e.n, e.m, 1.0, &mut rng);
                    let hinv = spd(e.m, &mut rng);
                    rt.exec_prune(&e, &w, &hinv)?;
                }
            }
            Ok(())
        };
        match run() {
            Ok(()) => {
                ok += 1;
                println!("  ok   {}", e.file);
            }
            Err(err) => {
                failed += 1;
                println!("  FAIL {}: {err}", e.file);
            }
        }
    }
    println!("{ok} ok, {failed} failed");
    if failed > 0 {
        anyhow::bail!("{failed} artifacts failed");
    }
    Ok(())
}

fn spd(m: usize, rng: &mut apt::util::Rng) -> apt::tensor::Mat {
    let x = apt::tensor::Mat::randn(2 * m, m, 1.0, rng);
    let mut acc = apt::prune::HessianAccumulator::new(m);
    acc.add_chunk(&x);
    let (_hd, hinv) = acc.finalize(0.01);
    hinv.to_f32()
}
