//! Experiment configuration: typed config resolved from defaults -> JSON
//! config file -> `--key=value` CLI overrides (highest priority). This is
//! the launcher-facing config system the table harness and CLI share.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use crate::json::{self, Json};
use crate::prune::{Method, PruneConfig, Sparsity};
use crate::runtime::Backend;

/// Everything one experiment run needs.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// "microllama" or "micromamba".
    pub arch: String,
    /// "small" | "medium" | "large".
    pub size: String,
    pub method: Method,
    pub sparsity: Sparsity,
    /// Column block size S; 0 = all.
    pub block_size: usize,
    pub gamma: f64,
    pub n_calib: usize,
    pub calib_seq_len: usize,
    pub eval_seq_len: usize,
    pub train_steps: usize,
    pub seed: u64,
    pub engine: Backend,
    /// Calibration profile name ("c4" | "lambada" | ...).
    pub calib_profile: String,
    pub out_dir: String,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            arch: "microllama".into(),
            size: "small".into(),
            method: Method::SM,
            sparsity: Sparsity::Unstructured { rate: 0.5 },
            block_size: 0,
            gamma: 0.01,
            n_calib: 32,
            calib_seq_len: 64,
            eval_seq_len: 128,
            train_steps: 300,
            seed: 42,
            engine: Backend::Native,
            calib_profile: "c4".into(),
            out_dir: "results".into(),
        }
    }
}

impl ExperimentConfig {
    pub fn prune_config(&self) -> PruneConfig {
        PruneConfig::new(self.method, self.sparsity)
            .with_block(if self.block_size == 0 { None } else { Some(self.block_size) })
            .with_gamma(self.gamma)
    }

    /// Apply a single `key=value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "arch" => self.arch = value.into(),
            "size" => self.size = value.into(),
            "method" => {
                self.method = Method::from_name(value)
                    .ok_or_else(|| anyhow!("unknown method '{value}'"))?
            }
            "sparsity" => self.sparsity = parse_sparsity(value)?,
            "block_size" | "block" => self.block_size = value.parse()?,
            "gamma" | "damp" => self.gamma = value.parse()?,
            "n_calib" | "calib" => self.n_calib = value.parse()?,
            "calib_seq_len" => self.calib_seq_len = value.parse()?,
            "eval_seq_len" => self.eval_seq_len = value.parse()?,
            "train_steps" | "steps" => self.train_steps = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "engine" => {
                self.engine = Backend::from_name(value)
                    .ok_or_else(|| anyhow!("unknown engine '{value}'"))?
            }
            "calib_profile" => self.calib_profile = value.into(),
            "out_dir" | "out" => self.out_dir = value.into(),
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    /// Load overrides from a JSON object file.
    pub fn apply_file(&mut self, path: &Path) -> Result<()> {
        let text = std::fs::read_to_string(path)?;
        let root = json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let Json::Obj(map) = root else { bail!("config root must be an object") };
        for (k, v) in &map {
            let s = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) => {
                    if n.fract() == 0.0 {
                        format!("{}", *n as i64)
                    } else {
                        format!("{n}")
                    }
                }
                Json::Bool(b) => b.to_string(),
                _ => bail!("config value for '{k}' must be scalar"),
            };
            self.set(k, &s)?;
        }
        Ok(())
    }

    /// Apply `--key=value` style CLI args; returns non-config args.
    pub fn apply_args<'a>(&mut self, args: &'a [String]) -> Result<Vec<&'a str>> {
        let mut rest = Vec::new();
        for a in args {
            if let Some(kv) = a.strip_prefix("--") {
                if let Some((k, v)) = kv.split_once('=') {
                    self.set(k, v)?;
                    continue;
                }
            }
            rest.push(a.as_str());
        }
        Ok(rest)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("arch", Json::Str(self.arch.clone()))
            .set("size", Json::Str(self.size.clone()))
            .set("method", Json::Str(self.method.name().into()))
            .set("sparsity", Json::Str(self.sparsity.label()))
            .set("block_size", Json::Num(self.block_size as f64))
            .set("gamma", Json::Num(self.gamma))
            .set("n_calib", Json::Num(self.n_calib as f64))
            .set("train_steps", Json::Num(self.train_steps as f64))
            .set("seed", Json::Num(self.seed as f64))
            .set("calib_profile", Json::Str(self.calib_profile.clone()));
        o
    }
}

/// "50%" | "0.5" | "2:4".
pub fn parse_sparsity(s: &str) -> Result<Sparsity> {
    if let Some((n, m)) = s.split_once(':') {
        let (n, m): (usize, usize) = (n.parse()?, m.parse()?);
        if n >= m {
            bail!("N:M needs n < m");
        }
        return Ok(Sparsity::SemiStructured { n, m });
    }
    let rate: f64 = if let Some(pct) = s.strip_suffix('%') {
        pct.parse::<f64>()? / 100.0
    } else {
        s.parse()?
    };
    if !(0.0..1.0).contains(&rate) {
        bail!("rate must be in [0,1)");
    }
    Ok(Sparsity::Unstructured { rate })
}

/// Key=value map of overrides collected from the environment (APT_CFG_*).
pub fn env_overrides() -> BTreeMap<String, String> {
    std::env::vars()
        .filter_map(|(k, v)| {
            k.strip_prefix("APT_CFG_").map(|s| (s.to_ascii_lowercase(), v))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = ExperimentConfig::default();
        assert_eq!(c.method, Method::SM);
        assert!(c.prune_config().block_size.is_none());
    }

    #[test]
    fn parse_sparsity_forms() {
        assert_eq!(parse_sparsity("0.5").unwrap(), Sparsity::Unstructured { rate: 0.5 });
        assert_eq!(parse_sparsity("70%").unwrap(), Sparsity::Unstructured { rate: 0.7 });
        assert_eq!(parse_sparsity("2:4").unwrap(), Sparsity::SemiStructured { n: 2, m: 4 });
        assert!(parse_sparsity("4:2").is_err());
        assert!(parse_sparsity("1.5").is_err());
    }

    #[test]
    fn cli_overrides() {
        let mut c = ExperimentConfig::default();
        let args: Vec<String> = ["--method=mm", "--sparsity=2:4", "--block=128", "positional"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let rest = c.apply_args(&args).unwrap();
        assert_eq!(c.method, Method::MM);
        assert_eq!(c.sparsity, Sparsity::two_four());
        assert_eq!(c.block_size, 128);
        assert_eq!(rest, vec!["positional"]);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = ExperimentConfig::default();
        assert!(c.set("bogus", "1").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let mut c = ExperimentConfig::default();
        let dir = std::env::temp_dir().join("apt_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"method": "wanda", "gamma": 0.05, "n_calib": 64}"#).unwrap();
        c.apply_file(&p).unwrap();
        assert_eq!(c.method, Method::Wanda);
        assert!((c.gamma - 0.05).abs() < 1e-12);
        assert_eq!(c.n_calib, 64);
        std::fs::remove_file(p).ok();
    }
}
