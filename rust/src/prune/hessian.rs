//! Streaming layer-Hessian accumulation: H = 2 Σ X_c^T X_c (+ dampening).
//!
//! The calibration pipeline feeds activation chunks X:(T, m) one at a
//! time (the paper's "load one block at a time" memory bound); we never
//! materialize the full (n_calib*T, m) activation matrix. All accumulation
//! is f64 (DESIGN.md SS7). Mirrors the L1 `hessian.py` kernel, which the
//! runtime path uses instead when an artifact for the shape exists.

use crate::linalg::{cholesky, inv_spd};
use crate::tensor::{Mat, MatF64};

#[derive(Clone, Debug)]
pub struct HessianAccumulator {
    pub h: MatF64,
    pub n_rows: usize,
}

impl HessianAccumulator {
    pub fn new(m: usize) -> Self {
        HessianAccumulator { h: MatF64::zeros(m, m), n_rows: 0 }
    }

    pub fn dim(&self) -> usize {
        self.h.rows
    }

    /// Accumulate one activation chunk X:(T, m): H += 2 X^T X.
    ///
    /// SSPerf iteration 2 (EXPERIMENTS.md): rows are converted to f64 once
    /// up front so the inner axpy has no cvtss2sd on the critical path —
    /// 2.9x over the in-loop-convert variant (kept below for the ablation).
    pub fn add_chunk(&mut self, x: &Mat) {
        assert_eq!(x.cols, self.dim(), "activation width mismatch");
        let rows64: Vec<Vec<f64>> = (0..x.rows)
            .map(|r| x.row(r).iter().map(|&v| v as f64).collect())
            .collect();
        self.h.syrk_add_2xtx_f64(&rows64);
        self.n_rows += x.rows;
    }

    /// Pre-iteration-2 variant (converts f32->f64 inside the inner loop);
    /// kept for the SSPerf ablation bench.
    pub fn add_chunk_convert_in_loop(&mut self, x: &Mat) {
        assert_eq!(x.cols, self.dim(), "activation width mismatch");
        let rows: Vec<&[f32]> = (0..x.rows).map(|r| x.row(r)).collect();
        self.h.syrk_add_2xtx(&rows);
        self.n_rows += x.rows;
    }

    /// Merge another accumulator (parallel calibration workers).
    pub fn merge(&mut self, other: &HessianAccumulator) {
        assert_eq!(self.dim(), other.dim());
        for (a, &b) in self.h.data.iter_mut().zip(&other.h.data) {
            *a += b;
        }
        self.n_rows += other.n_rows;
    }

    /// Remark 4.1 dampening: H + gamma * mean(diag(H)) * I.
    pub fn damped(&self, gamma: f64) -> MatF64 {
        let m = self.dim();
        let mean_diag = self.h.diag().iter().sum::<f64>() / m as f64;
        // Dead-input guard: if a column never activates, mean-diag damping
        // still regularizes it.
        let damp = gamma * mean_diag.max(1e-8);
        let mut hd = self.h.clone();
        for i in 0..m {
            hd[(i, i)] += damp;
        }
        hd
    }

    /// Damped H and its inverse (one Cholesky per layer — the paper's
    /// Limitations-section cost center). Escalates dampening if the
    /// calibration sample left H near-singular.
    pub fn finalize(&self, gamma: f64) -> (MatF64, MatF64) {
        let mut g = gamma;
        for _ in 0..8 {
            let hd = self.damped(g);
            if cholesky(&hd).is_some() {
                let hinv = inv_spd(&hd).expect("cholesky ok implies invertible");
                return (hd, hinv);
            }
            g = if g == 0.0 { 1e-4 } else { g * 10.0 };
        }
        panic!("hessian not invertible even with heavy dampening");
    }
}

/// Column l2 norms of the calibration activations, ||X_.j||_2 = sqrt(H_jj/2)
/// — the Wanda statistic, recovered from the same accumulator.
pub fn column_norms(acc: &HessianAccumulator) -> Vec<f64> {
    acc.h.diag().iter().map(|&d| (d / 2.0).max(0.0).sqrt()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn chunk(t: usize, m: usize, seed: u64) -> Mat {
        Mat::randn(t, m, 1.0, &mut Rng::new(seed))
    }

    #[test]
    fn accumulation_matches_explicit() {
        let m = 8;
        let (a, b) = (chunk(10, m, 1), chunk(6, m, 2));
        let mut acc = HessianAccumulator::new(m);
        acc.add_chunk(&a);
        acc.add_chunk(&b);
        assert_eq!(acc.n_rows, 16);
        for i in 0..m {
            for j in 0..m {
                let mut s = 0.0f64;
                for r in 0..10 {
                    s += a[(r, i)] as f64 * a[(r, j)] as f64;
                }
                for r in 0..6 {
                    s += b[(r, i)] as f64 * b[(r, j)] as f64;
                }
                assert!((acc.h[(i, j)] - 2.0 * s).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn merge_equals_sequential() {
        let m = 6;
        let (a, b) = (chunk(7, m, 3), chunk(9, m, 4));
        let mut seq = HessianAccumulator::new(m);
        seq.add_chunk(&a);
        seq.add_chunk(&b);
        let mut p1 = HessianAccumulator::new(m);
        p1.add_chunk(&a);
        let mut p2 = HessianAccumulator::new(m);
        p2.add_chunk(&b);
        p1.merge(&p2);
        assert!(seq.h.max_abs_diff(&p1.h) < 1e-9);
        assert_eq!(seq.n_rows, p1.n_rows);
    }

    #[test]
    fn damped_adds_scaled_identity() {
        let mut acc = HessianAccumulator::new(4);
        acc.add_chunk(&chunk(12, 4, 5));
        let hd = acc.damped(0.01);
        let mean_diag = acc.h.diag().iter().sum::<f64>() / 4.0;
        for i in 0..4 {
            assert!((hd[(i, i)] - acc.h[(i, i)] - 0.01 * mean_diag).abs() < 1e-9);
        }
    }

    #[test]
    fn finalize_produces_inverse() {
        let mut acc = HessianAccumulator::new(8);
        acc.add_chunk(&chunk(32, 8, 6));
        let (hd, hinv) = acc.finalize(0.01);
        for i in 0..8 {
            for j in 0..8 {
                let mut s = 0.0;
                for k in 0..8 {
                    s += hd[(i, k)] * hinv[(k, j)];
                }
                let e = if i == j { 1.0 } else { 0.0 };
                assert!((s - e).abs() < 1e-7, "({i},{j})");
            }
        }
    }

    #[test]
    fn finalize_escalates_damp_on_rank_deficiency() {
        // Fewer calibration rows than columns -> rank-deficient H.
        let mut acc = HessianAccumulator::new(16);
        acc.add_chunk(&chunk(3, 16, 7));
        let (_, hinv) = acc.finalize(0.0); // must not panic
        assert!(hinv.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn column_norms_match_direct() {
        let x = chunk(20, 5, 8);
        let mut acc = HessianAccumulator::new(5);
        acc.add_chunk(&x);
        let norms = column_norms(&acc);
        for j in 0..5 {
            let direct: f64 =
                (0..20).map(|r| (x[(r, j)] as f64).powi(2)).sum::<f64>().sqrt();
            assert!((norms[j] - direct).abs() < 1e-6);
        }
    }
}
