//! Structured post-training pruning: score whole attention heads / FFN
//! channels from the same calibration Hessians the unstructured path
//! accumulates, select a keep-set under a budget, and compensate the
//! surviving weights with the paper's Eq. 13 least-squares
//! reconstruction before the consumer/producer pair is physically
//! sliced down to a [`crate::sparse::ReducedDense`] store.
//!
//! The granularity trick (Kwon et al.'s fast post-training framework,
//! Compresso's channel variant): a structural unit — one head, one FFN
//! channel, one mamba inner channel — is visible to exactly one or two
//! *consumer* linears as a contiguous set of input columns. Removing
//! the unit therefore scores as an Eq. 12 GROUP loss on the consumer's
//! Hessian, and zeroing it compensates with the same column-uniform
//! Eq. 13 solve used per-row by the unstructured path. Once the
//! consumer columns are exact zeros, the producer rows feeding them are
//! dead code and can be dropped with NO further approximation — that
//! step is lossless, which is why the reduced model is gated against
//! the masked full-shape oracle at <1e-5 (f32 re-association only)
//! rather than a looser tolerance.

use crate::linalg::{cholesky_unblocked, solve_lower, solve_lower_t};
use crate::prune::{compensate_m, Mask};
use crate::tensor::{Mat, MatF64};

/// Structured pruning budget + calibration knobs, shared by the
/// coordinator's transformer and mamba entry points. All `keep_*`
/// fractions are of the structural unit count (heads / channels), not
/// of parameters; at least one unit always survives.
#[derive(Clone, Copy, Debug)]
pub struct StructuredConfig {
    /// Fraction of attention heads kept per transformer block.
    pub keep_heads: f64,
    /// Fraction of FFN channels kept per transformer block.
    pub keep_ffn: f64,
    /// Fraction of inner channels kept per mamba block.
    pub keep_channels: f64,
    /// Hessian dampening ratio (Remark 4.1; paper default 0.01).
    pub gamma: f64,
    /// Calibration sequences per forward batch.
    pub batch: usize,
    /// Bounded-queue depth for the propagate stage.
    pub queue_cap: usize,
    /// Oracle mode: stop after Eq. 13 compensation, leaving every
    /// linear at its full logical shape with exact zeros in the dropped
    /// columns. Decisions and compensation are byte-identical to the
    /// reducing run on the same calibration set, so a `masked: true`
    /// run is the reference the physically reduced model is gated
    /// against.
    pub masked: bool,
}

impl StructuredConfig {
    /// Uniform keep-fraction across heads, FFN channels and mamba
    /// channels, with the pipeline defaults for everything else.
    pub fn new(keep: f64) -> StructuredConfig {
        StructuredConfig {
            keep_heads: keep,
            keep_ffn: keep,
            keep_channels: keep,
            gamma: 0.01,
            batch: 8,
            queue_cap: 4,
            masked: false,
        }
    }
}

/// The structural units of a `cols`-wide consumer input, as contiguous
/// column groups of width `group_size` (head_dim for attention heads,
/// 1 for FFN / mamba channels). `cols` must divide evenly.
pub fn column_groups(cols: usize, group_size: usize) -> Vec<Vec<usize>> {
    assert!(group_size > 0 && cols % group_size == 0, "{cols} cols / group {group_size}");
    (0..cols / group_size)
        .map(|g| (g * group_size..(g + 1) * group_size).collect())
        .collect()
}

/// Eq. 12 group removal loss per unit: for group G of consumer columns,
/// Σ_rows ½ · w[r,G]ᵀ (Hinv[G,G])⁻¹ w[r,G]. The G×G sub-matrix is
/// factored once and back-solved per row (the mask is column-uniform,
/// so unlike the per-row unstructured path one factorization serves
/// every row).
pub fn group_scores(w: &Mat, hinv: &MatF64, groups: &[Vec<usize>]) -> Vec<f64> {
    assert_eq!(hinv.rows, w.cols, "hessian dim {} != consumer in-dim {}", hinv.rows, w.cols);
    groups
        .iter()
        .map(|g| {
            let l = cholesky_unblocked(&hinv.sub(g, g))
                .expect("Hinv principal submatrix must be SPD");
            let mut total = 0.0f64;
            for r in 0..w.rows {
                let row = w.row(r);
                let rhs: Vec<f64> = g.iter().map(|&c| row[c] as f64).collect();
                let lam = solve_lower_t(&l, &solve_lower(&l, &rhs));
                total += 0.5 * lam.iter().zip(&rhs).map(|(a, b)| a * b).sum::<f64>();
            }
            total
        })
        .collect()
}

/// Keep the `⌈keep·n⌉` highest-scoring units (always ≥ 1, ties broken
/// toward the lower index for determinism). Returns the kept unit
/// indices in ascending order.
pub fn select_kept_groups(scores: &[f64], keep: f64) -> Vec<usize> {
    let n = scores.len();
    let n_keep = ((keep * n as f64).ceil() as usize).clamp(1, n.max(1));
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).expect("group score must not be NaN").then(a.cmp(&b))
    });
    let mut kept: Vec<usize> = order[..n_keep].to_vec();
    kept.sort_unstable();
    kept
}

/// Expand kept unit indices into kept logical column indices
/// (ascending), each unit covering a contiguous `group_size`-wide range.
pub fn kept_columns(kept_groups: &[usize], group_size: usize) -> Vec<u32> {
    kept_groups
        .iter()
        .flat_map(|&g| (g * group_size..(g + 1) * group_size).map(|c| c as u32))
        .collect()
}

/// The complement of a sorted kept-index list over `0..n`.
pub fn dropped_columns(kept: &[u32], n: usize) -> Vec<usize> {
    let keep: std::collections::BTreeSet<u32> = kept.iter().copied().collect();
    (0..n).filter(|&c| !keep.contains(&(c as u32))).collect()
}

/// Eq. 13 compensation for a column-uniform removal: every row of the
/// consumer prunes exactly `dropped`, the survivors absorb the update,
/// and the dropped columns end as exact zeros. Returns the Eq. 12
/// predicted loss (= Σ of the joint group loss over rows).
pub fn compensate_columns(w: &mut Mat, hinv: &MatF64, dropped: &[usize]) -> f64 {
    if dropped.is_empty() {
        return 0.0;
    }
    let mut mask = Mask::new(w.rows, w.cols);
    for r in 0..w.rows {
        for &c in dropped {
            mask.set(r, c, true);
        }
    }
    compensate_m(w, &mask, hinv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::{quadratic_loss, HessianAccumulator};
    use crate::util::Rng;

    fn eye(n: usize) -> MatF64 {
        let mut m = MatF64::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[test]
    fn column_groups_partition_the_input() {
        let g = column_groups(12, 4);
        assert_eq!(g.len(), 3);
        assert_eq!(g[0], vec![0, 1, 2, 3]);
        assert_eq!(g[2], vec![8, 9, 10, 11]);
        let singles = column_groups(3, 1);
        assert_eq!(singles, vec![vec![0], vec![1], vec![2]]);
    }

    #[test]
    fn group_scores_identity_hessian_is_half_sq_norm() {
        // With Hinv = I the Eq. 12 group loss degenerates to ½‖w[:,G]‖²
        // — the magnitude baseline — which pins the solve path exactly.
        let mut rng = Rng::new(7);
        let w = Mat::randn(5, 8, 1.0, &mut rng);
        let groups = column_groups(8, 2);
        let scores = group_scores(&w, &eye(8), &groups);
        for (gi, g) in groups.iter().enumerate() {
            let expect: f64 = (0..5)
                .map(|r| {
                    g.iter().map(|&c| (w[(r, c)] as f64).powi(2)).sum::<f64>() * 0.5
                })
                .sum();
            assert!((scores[gi] - expect).abs() < 1e-9, "group {gi}");
        }
    }

    #[test]
    fn select_kept_groups_budget_and_ordering() {
        let scores = [3.0, 0.5, 9.0, 1.0];
        assert_eq!(select_kept_groups(&scores, 0.5), vec![0, 2]);
        assert_eq!(select_kept_groups(&scores, 1.0), vec![0, 1, 2, 3]);
        // floor of one unit even under an absurd budget
        assert_eq!(select_kept_groups(&scores, 0.0), vec![2]);
        // ⌈0.6·4⌉ = 3: drops only the weakest
        assert_eq!(select_kept_groups(&scores, 0.6), vec![0, 2, 3]);
        // ties resolve toward the lower index
        assert_eq!(select_kept_groups(&[1.0, 1.0, 1.0], 0.34), vec![0]);
    }

    #[test]
    fn kept_and_dropped_columns_are_complementary() {
        let kept = kept_columns(&[0, 2], 3);
        assert_eq!(kept, vec![0, 1, 2, 6, 7, 8]);
        assert_eq!(dropped_columns(&kept, 9), vec![3, 4, 5]);
        assert_eq!(dropped_columns(&[], 2), vec![0, 1]);
    }

    #[test]
    fn compensate_columns_identity_hessian_zeros_only_dropped() {
        // Hinv = I ⇒ the Eq. 13 update touches exactly the pruned
        // columns; survivors must be bit-identical.
        let mut rng = Rng::new(8);
        let w0 = Mat::randn(4, 6, 1.0, &mut rng);
        let mut w = w0.clone();
        let loss = compensate_columns(&mut w, &eye(6), &[1, 4]);
        let expect: f64 = (0..4)
            .map(|r| {
                0.5 * ((w0[(r, 1)] as f64).powi(2) + (w0[(r, 4)] as f64).powi(2))
            })
            .sum();
        assert!((loss - expect).abs() < 1e-9);
        for r in 0..4 {
            assert_eq!(w[(r, 1)], 0.0);
            assert_eq!(w[(r, 4)], 0.0);
            for c in [0usize, 2, 3, 5] {
                assert_eq!(w[(r, c)], w0[(r, c)], "row {r} col {c}");
            }
        }
        // empty drop-set is a no-op
        let mut w2 = w0.clone();
        assert_eq!(compensate_columns(&mut w2, &eye(6), &[]), 0.0);
        assert_eq!(w2, w0);
    }

    #[test]
    fn compensation_beats_naive_column_zeroing() {
        // On a real calibration Hessian, Eq. 13 reconstruction of the
        // survivors must not lose to just zeroing the dropped columns
        // (the paper's core claim, at structured granularity).
        let mut rng = Rng::new(9);
        let w0 = Mat::randn(6, 16, 1.0, &mut rng);
        let x = Mat::randn(64, 16, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(16);
        acc.add_chunk(&x);
        let (hd, hinv) = acc.finalize(0.01);

        let groups = column_groups(16, 4);
        let scores = group_scores(&w0, &hinv, &groups);
        let kept = select_kept_groups(&scores, 0.5);
        let dropped = dropped_columns(&kept_columns(&kept, 4), 16);

        let mut comp = w0.clone();
        compensate_columns(&mut comp, &hinv, &dropped);
        let mut naive = w0.clone();
        for r in 0..6 {
            for &c in &dropped {
                naive[(r, c)] = 0.0;
            }
        }
        let l_comp = quadratic_loss(&w0, &comp, &hd);
        let l_naive = quadratic_loss(&w0, &naive, &hd);
        assert!(l_comp <= l_naive * (1.0 + 1e-9), "{l_comp} vs {l_naive}");
        // and pruning the LOWEST-scoring units beats pruning the highest
        let worst: Vec<usize> = {
            let best = select_kept_groups(&scores, 0.5);
            (0..4).filter(|g| !best.contains(g)).collect()
        };
        let mut flipped = w0.clone();
        compensate_columns(
            &mut flipped,
            &hinv,
            &dropped_columns(&kept_columns(&worst, 4), 16),
        );
        let l_flipped = quadratic_loss(&w0, &flipped, &hd);
        assert!(l_comp <= l_flipped * (1.0 + 1e-9), "{l_comp} vs {l_flipped}");
    }

    #[test]
    fn group_scores_match_compensate_loss_single_group() {
        // Dropping exactly one unit: the selection score must equal the
        // Eq. 12 loss the compensation path reports — same math, two
        // code paths.
        let mut rng = Rng::new(10);
        let w0 = Mat::randn(5, 12, 1.0, &mut rng);
        let x = Mat::randn(48, 12, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(12);
        acc.add_chunk(&x);
        let (_hd, hinv) = acc.finalize(0.01);
        let groups = column_groups(12, 3);
        let scores = group_scores(&w0, &hinv, &groups);
        for (gi, g) in groups.iter().enumerate() {
            let mut w = w0.clone();
            let loss = compensate_columns(&mut w, &hinv, g);
            assert!(
                (loss - scores[gi]).abs() < 1e-9 * scores[gi].abs().max(1.0),
                "group {gi}: {loss} vs {}",
                scores[gi]
            );
        }
    }
}
