//! The paper's contribution: the Multiple Removal Problem solver.
//!
//! Mask rules (Sec. 4.2.1):
//!   S — Eq. (14) diagonal scores  w_ij^2 / (2*Hinv_jj)
//!   M — Eq. (12) full-interaction group loss, enumerated per N:M group
//!       (implemented for 2:4; unstructured M-mask is combinatorial and not
//!        implemented, exactly as the paper states).
//!
//! Compensation rule M (Sec. 4.2.2, Eq. 13):
//!   dw[r, :] = -w[r,P] . inv(Hinv[P,P]) . Hinv[P, :]
//! computed per row with a cumulative pruned set P. Blockwise processing
//! (Algorithm 1) re-solves with the union mask; rows already zeroed stay
//! zero because their rhs entries are zero, so earlier constraints remain
//! satisfied exactly.
//!
//! Two solver paths implement the blockwise loop (see PERF.md §MRP):
//! - [`compensate_m`] — the reference: re-materializes `Hinv[P, P]` and
//!   re-factors the *cumulative* pruned set from scratch at every block,
//!   O(blocks · rows · |P|³). Kept for equivalence tests and benches.
//! - [`IncrementalMrp`] — the hot path: carries one [`GrowingCholesky`]
//!   factor per row across blocks, rank-extending it by the block's newly
//!   pruned columns (O(|ΔP|·|P|²)) and exploiting that the rhs `w[r, P]`
//!   is exactly zero outside ΔP, so the forward solve skips the
//!   established prefix. One O(rows · |P|³) total across all blocks.

use crate::linalg::{solve_spd, GrowingCholesky};
use crate::tensor::{axpy_f64, Mat, MatF64};
use crate::util::num_threads;

use super::mask::Mask;

/// Which implementation of the blockwise Eq. 13 loop to use.
/// `Incremental` and `Reference` agree bit-for-bit on masks and to well
/// under 1e-6 on weights (see the equivalence tests in `prune::tests`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MrpSolver {
    /// Per-row growing Cholesky factors carried across blocks (fast path).
    Incremental,
    /// Re-factor the cumulative pruned set at every block (seed behavior).
    Reference,
}

/// Eq. (14) score of one weight.
#[inline]
pub fn score_s(w: f32, hinv_diag: f64) -> f64 {
    (w as f64) * (w as f64) / (2.0 * hinv_diag)
}

/// Eq. (12) loss for pruning {a, b} (global col indices) of row weights,
/// using the closed-form 2x2 inverse of the Hinv sub-block.
#[inline]
pub fn group_loss_2(wa: f64, wb: f64, saa: f64, sab: f64, sbb: f64) -> f64 {
    let det = saa * sbb - sab * sab;
    0.5 * (wa * wa * sbb - 2.0 * wa * wb * sab + wb * wb * saa) / det
}

/// Solution-S unstructured mask for columns [c0, c1): the `rate` fraction
/// of smallest Eq. (14) scores across the whole block (paper Sec. 4.3.1 —
/// all blocks share the same pruning rate).
///
/// Selects on a flat f64 score buffer: one select-nth on a scratch copy
/// finds the k-th smallest score, then a single threshold pass over the
/// (row-major) buffer sets the mask bits — taking everything strictly
/// below the threshold plus the first ties in row-major order until
/// exactly k bits are set. This replaces the seed's rows×cols
/// `Vec<(f64, u32, u32)>` of tagged entries (3× the memory traffic and a
/// comparator on tuples); see `select_unstructured_s_reference`.
pub fn select_unstructured_s(
    w: &Mat,
    hinv_diag: &[f64],
    c0: usize,
    c1: usize,
    rate: f64,
) -> Mask {
    let bw = c1 - c0;
    let total = w.rows * bw;
    let mut mask = Mask::new(w.rows, w.cols);
    let k = ((total as f64) * rate).round() as usize;
    if k == 0 || total == 0 {
        return mask;
    }
    let k = k.min(total);
    let mut scores = vec![0.0f64; total];
    for r in 0..w.rows {
        let row = w.row(r);
        let dst = &mut scores[r * bw..(r + 1) * bw];
        for (d, c) in dst.iter_mut().zip(c0..c1) {
            *d = score_s(row[c], hinv_diag[c]);
        }
    }
    let mut scratch = scores.clone();
    let (_, &mut thresh, _) =
        scratch.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
    let n_below = scores.iter().filter(|&&s| s < thresh).count();
    let mut ties_left = k - n_below;
    for (i, &s) in scores.iter().enumerate() {
        let take = if s < thresh {
            true
        } else if s == thresh && ties_left > 0 {
            ties_left -= 1;
            true
        } else {
            false
        };
        if take {
            mask.set(i / bw, c0 + i % bw, true);
        }
    }
    mask
}

/// Seed implementation of [`select_unstructured_s`] (tagged-tuple
/// select-nth). Kept as the equivalence oracle: on tie-free scores both
/// implementations must produce the identical mask.
pub fn select_unstructured_s_reference(
    w: &Mat,
    hinv_diag: &[f64],
    c0: usize,
    c1: usize,
    rate: f64,
) -> Mask {
    let mut entries: Vec<(f64, u32, u32)> = Vec::with_capacity(w.rows * (c1 - c0));
    for r in 0..w.rows {
        let row = w.row(r);
        for c in c0..c1 {
            entries.push((score_s(row[c], hinv_diag[c]), r as u32, c as u32));
        }
    }
    let k = ((entries.len() as f64) * rate).round() as usize;
    let mut mask = Mask::new(w.rows, w.cols);
    if k == 0 {
        return mask;
    }
    let k = k.min(entries.len());
    entries.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
    for &(_, r, c) in &entries[..k] {
        mask.set(r as usize, c as usize, true);
    }
    mask
}

/// Solution-S 2:4 mask for columns [c0, c1): 2 smallest Eq. (14) scores in
/// every 4-group of every row.
pub fn select_24_s(w: &Mat, hinv_diag: &[f64], c0: usize, c1: usize) -> Mask {
    assert_eq!((c1 - c0) % 4, 0, "2:4 block must align to groups of 4");
    let mut mask = Mask::new(w.rows, w.cols);
    for r in 0..w.rows {
        let row = w.row(r);
        for g0 in (c0..c1).step_by(4) {
            let mut idx = [0usize, 1, 2, 3];
            let sc: Vec<f64> =
                (0..4).map(|i| score_s(row[g0 + i], hinv_diag[g0 + i])).collect();
            idx.sort_by(|&a, &b| sc[a].partial_cmp(&sc[b]).unwrap());
            mask.set(r, g0 + idx[0], true);
            mask.set(r, g0 + idx[1], true);
        }
    }
    mask
}

/// Solution-M 2:4 mask (Eq. 12, 6-combo enumeration per group). Returns
/// (mask, total group-metric loss).
pub fn select_24_m(w: &Mat, hinv: &MatF64, c0: usize, c1: usize) -> (Mask, f64) {
    assert_eq!((c1 - c0) % 4, 0);
    const COMBOS: [(usize, usize); 6] = [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)];
    let mut mask = Mask::new(w.rows, w.cols);
    let mut total = 0.0;
    for r in 0..w.rows {
        let row = w.row(r);
        for g0 in (c0..c1).step_by(4) {
            let mut best = f64::INFINITY;
            let mut best_c = (0usize, 1usize);
            for &(a, b) in &COMBOS {
                let (ca, cb) = (g0 + a, g0 + b);
                let l = group_loss_2(
                    row[ca] as f64,
                    row[cb] as f64,
                    hinv[(ca, ca)],
                    hinv[(ca, cb)],
                    hinv[(cb, cb)],
                );
                if l < best {
                    best = l;
                    best_c = (ca, cb);
                }
            }
            mask.set(r, best_c.0, true);
            mask.set(r, best_c.1, true);
            total += best;
        }
    }
    (mask, total)
}

/// Eq. (13) Solution-M compensation, parallel over rows: for each row,
/// solve the |P|x|P| SPD system on the Hinv sub-matrix and update the
/// whole row. Pruned entries end exactly zero. Returns the Eq. (12)
/// predicted loss total.
///
/// This is the *reference* solver: it re-factors the full pruned set on
/// every call. The blockwise loop in `prune_layer` uses [`IncrementalMrp`]
/// instead, which carries the factorization across blocks.
pub fn compensate_m(w: &mut Mat, mask: &Mask, hinv: &MatF64) -> f64 {
    let (n, m) = (w.rows, w.cols);
    assert_eq!((mask.rows, mask.cols), (n, m));
    assert_eq!((hinv.rows, hinv.cols), (m, m));
    let nt = num_threads().min(n.max(1));
    let chunk = n.div_ceil(nt);
    let losses = std::sync::Mutex::new(0.0f64);

    std::thread::scope(|s| {
        for (ci, wrows) in w.data.chunks_mut(chunk * m).enumerate() {
            let r0 = ci * chunk;
            let losses = &losses;
            s.spawn(move || {
                let mut local = 0.0f64;
                let mut frow = vec![0.0f64; m];
                let mut p: Vec<usize> = Vec::with_capacity(m);
                for (ri, wrow) in wrows.chunks_mut(m).enumerate() {
                    let r = r0 + ri;
                    mask.row_indices_into(r, &mut p);
                    if p.is_empty() {
                        continue;
                    }
                    let sub = hinv.sub(&p, &p);
                    let rhs: Vec<f64> = p.iter().map(|&c| wrow[c] as f64).collect();
                    let lam = solve_spd(&sub, &rhs)
                        .expect("Hinv principal submatrix must be SPD");
                    local += 0.5 * lam.iter().zip(&rhs).map(|(l, r)| l * r).sum::<f64>();
                    // row update in f64: w_r -= lam @ Hinv[P, :]
                    for (fi, wv) in frow.iter_mut().zip(wrow.iter()) {
                        *fi = *wv as f64;
                    }
                    for (&li, &pi) in lam.iter().zip(&p) {
                        axpy_f64(-li, hinv.row(pi), &mut frow);
                    }
                    for (wv, &f) in wrow.iter_mut().zip(frow.iter()) {
                        *wv = f as f32;
                    }
                    for &c in &p {
                        wrow[c] = 0.0; // exact zeros
                    }
                }
                *losses.lock().unwrap() += local;
            });
        }
    });
    losses.into_inner().unwrap()
}

/// Blockwise Eq. (13) solver that carries per-row Cholesky factors of
/// `Hinv[P_r, P_r]` across column blocks (Algorithm 1 without the
/// re-factorization): each call appends the block's newly pruned columns
/// to every row's [`GrowingCholesky`] and applies the compensation update
/// for the *cumulative* pruned set.
///
/// Why appending constraints keeps earlier rows' pruned entries exactly
/// zero: the solve enforces w[r, P] = 0 for the whole cumulative P, and
/// because the established entries of the rhs are exactly 0.0 (we store
/// hard zeros), the forward substitution provably yields zero multipliers
/// on the established prefix — only the new columns drive the update.
/// See PERF.md §MRP for the full derivation and cost model.
pub struct IncrementalMrp<'a> {
    hinv: &'a MatF64,
    factors: Vec<GrowingCholesky>,
    /// Per row: pruned column indices in insertion order (ascending, since
    /// blocks sweep left to right) — the factor's index ordering.
    pruned: Vec<Vec<usize>>,
}

impl<'a> IncrementalMrp<'a> {
    pub fn new(hinv: &'a MatF64, rows: usize) -> Self {
        assert_eq!(hinv.rows, hinv.cols);
        IncrementalMrp {
            hinv,
            factors: (0..rows).map(|_| GrowingCholesky::new()).collect(),
            pruned: vec![Vec::new(); rows],
        }
    }

    /// Total pruned entries tracked so far (across all rows).
    pub fn tracked(&self) -> usize {
        self.pruned.iter().map(Vec::len).sum()
    }

    /// Apply Eq. (13) for `new_mask`'s entries (the block's newly pruned
    /// positions; entries already tracked are skipped), updating `w` in
    /// place against the cumulative pruned set. Returns this step's
    /// Eq. (12) predicted loss — the same quantity `compensate_m` returns
    /// when called with the cumulative mask at this point.
    pub fn compensate_block(&mut self, w: &mut Mat, new_mask: &Mask) -> f64 {
        let (n, m) = (w.rows, w.cols);
        assert_eq!((new_mask.rows, new_mask.cols), (n, m));
        assert_eq!(self.factors.len(), n, "solver built for a different row count");
        assert_eq!(self.hinv.rows, m);
        let hinv = self.hinv;
        let nt = num_threads().min(n.max(1));
        let chunk = n.div_ceil(nt);
        let losses = std::sync::Mutex::new(0.0f64);

        std::thread::scope(|s| {
            let mut r0 = 0;
            let iter = w
                .data
                .chunks_mut(chunk * m)
                .zip(self.factors.chunks_mut(chunk).zip(self.pruned.chunks_mut(chunk)));
            for (wrows, (factors, pruned)) in iter {
                let start = r0;
                r0 += wrows.len() / m;
                let losses = &losses;
                s.spawn(move || {
                    let mut local = 0.0f64;
                    let mut frow = vec![0.0f64; m];
                    let mut rhs: Vec<f64> = Vec::new();
                    let mut lam: Vec<f64> = Vec::new();
                    let mut arow: Vec<f64> = Vec::new();
                    for (ri, wrow) in wrows.chunks_mut(m).enumerate() {
                        let fac = &mut factors[ri];
                        let p = &mut pruned[ri];
                        let established = p.len();
                        // 1) rank-extend the factor by the newly pruned
                        //    columns: O(|ΔP|·|P|²) total. Membership is a
                        //    linear scan on purpose: `p` is only sorted
                        //    when blocks arrive left-to-right, and the
                        //    factor is valid for any insertion order.
                        for (c, &bit) in new_mask.row(start + ri).iter().enumerate() {
                            if !bit || p.contains(&c) {
                                continue;
                            }
                            arow.clear();
                            arow.extend(p.iter().map(|&pi| hinv[(c, pi)]));
                            fac.push(&arow, hinv[(c, c)])
                                .expect("Hinv principal submatrix must be SPD");
                            p.push(c);
                        }
                        if p.len() == established {
                            continue; // nothing new: multipliers are exactly 0
                        }
                        // 2) rhs = w[r, P]; the established prefix is hard
                        //    zeros, so the forward solve skips it.
                        rhs.clear();
                        rhs.extend(p.iter().map(|&c| wrow[c] as f64));
                        fac.solve_prefix_sparse(&rhs, established, &mut lam);
                        local += 0.5 * lam.iter().zip(&rhs).map(|(l, b)| l * b).sum::<f64>();
                        // 3) row update in f64: w_r -= lam @ Hinv[P, :]
                        for (fi, wv) in frow.iter_mut().zip(wrow.iter()) {
                            *fi = *wv as f64;
                        }
                        for (&li, &pi) in lam.iter().zip(p.iter()) {
                            if li != 0.0 {
                                axpy_f64(-li, hinv.row(pi), &mut frow);
                            }
                        }
                        for (wv, &f) in wrow.iter_mut().zip(frow.iter()) {
                            *wv = f as f32;
                        }
                        for &c in p.iter() {
                            wrow[c] = 0.0; // exact zeros (prerequisite above)
                        }
                    }
                    *losses.lock().unwrap() += local;
                });
            }
        });
        losses.into_inner().unwrap()
    }
}

/// Achieved quadratic loss 1/2 sum_rows dw H dw^T (for tests/benches).
pub fn quadratic_loss(before: &Mat, after: &Mat, h: &MatF64) -> f64 {
    assert_eq!(before.shape(), after.shape());
    let m = before.cols;
    let mut total = 0.0;
    let mut dw = vec![0.0f64; m];
    for r in 0..before.rows {
        let (b, a) = (before.row(r), after.row(r));
        for j in 0..m {
            dw[j] = a[j] as f64 - b[j] as f64;
        }
        for i in 0..m {
            if dw[i] == 0.0 {
                continue;
            }
            let hrow = h.row(i);
            let mut s = 0.0;
            for j in 0..m {
                s += hrow[j] * dw[j];
            }
            total += dw[i] * s;
        }
    }
    0.5 * total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::inv_spd;
    use crate::prune::hessian::HessianAccumulator;
    use crate::util::prop::prop_check_msg;
    use crate::util::Rng;

    pub(crate) fn setup(n: usize, m: usize, seed: u64) -> (Mat, MatF64, MatF64) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(n, m, 1.0, &mut rng);
        let x = Mat::randn(4 * m, m, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(m);
        acc.add_chunk(&x);
        let hd = acc.damped(0.01);
        let hinv = inv_spd(&hd).unwrap();
        (w, hd, hinv)
    }

    #[test]
    fn compensation_constraint_exact() {
        let (mut w, _hd, hinv) = setup(6, 16, 1);
        let mask = select_unstructured_s(&w, &hinv.diag(), 0, 16, 0.5);
        compensate_m(&mut w, &mask, &hinv);
        for r in 0..6 {
            for &c in &mask.row_indices(r) {
                assert_eq!(w[(r, c)], 0.0);
            }
        }
    }

    #[test]
    fn predicted_loss_equals_achieved() {
        let (w0, hd, hinv) = setup(5, 12, 2);
        let mut w = w0.clone();
        let mask = select_unstructured_s(&w, &hinv.diag(), 0, 12, 0.5);
        let pred = compensate_m(&mut w, &mask, &hinv);
        let achieved = quadratic_loss(&w0, &w, &hd);
        assert!(
            ((pred - achieved) / achieved.max(1e-9)).abs() < 1e-6,
            "pred {pred} achieved {achieved}"
        );
    }

    #[test]
    fn compensation_beats_plain_zeroing() {
        let (w0, hd, hinv) = setup(8, 20, 3);
        let mask = select_unstructured_s(&w0, &hinv.diag(), 0, 20, 0.5);
        let mut w = w0.clone();
        let pred = compensate_m(&mut w, &mask, &hinv);
        // plain zeroing with the SAME mask
        let mut wz = w0.clone();
        for r in 0..8 {
            for &c in &mask.row_indices(r) {
                wz[(r, c)] = 0.0;
            }
        }
        let zero_loss = quadratic_loss(&w0, &wz, &hd);
        assert!(pred <= zero_loss * (1.0 + 1e-9), "{pred} vs {zero_loss}");
    }

    #[test]
    fn flat_select_matches_reference_implementation() {
        // The flat-buffer + threshold-pass rework must reproduce the seed
        // implementation's mask exactly (scores are continuous, so ties —
        // where the two could legitimately differ — have measure zero).
        for seed in 0..6 {
            let (w, _, hinv) = setup(12, 40, 400 + seed);
            let d = hinv.diag();
            for rate in [0.0, 0.25, 0.5, 0.7, 1.0] {
                for (c0, c1) in [(0, 40), (8, 24), (32, 40)] {
                    let new = select_unstructured_s(&w, &d, c0, c1, rate);
                    let old = select_unstructured_s_reference(&w, &d, c0, c1, rate);
                    assert_eq!(
                        new, old,
                        "seed {seed} rate {rate} block ({c0},{c1})"
                    );
                }
            }
        }
    }

    #[test]
    fn flat_select_breaks_ties_in_row_major_order() {
        // Equal scores: the threshold pass takes the earliest (row-major)
        // tied entries, deterministically.
        let w = Mat::from_vec(2, 4, vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let diag = vec![1.0; 4];
        let mask = select_unstructured_s(&w, &diag, 0, 4, 0.5);
        assert_eq!(mask.count(), 4);
        assert_eq!(mask.row_indices(0), vec![0, 1, 2, 3]);
        assert!(mask.row_indices(1).is_empty());
    }

    #[test]
    fn unstructured_rate_respected() {
        let (w, _, hinv) = setup(16, 32, 4);
        for rate in [0.25, 0.5, 0.7] {
            let mask = select_unstructured_s(&w, &hinv.diag(), 0, 32, rate);
            let expect = (16.0 * 32.0 * rate).round() as usize;
            assert_eq!(mask.count(), expect, "rate {rate}");
        }
    }

    #[test]
    fn blockwise_selection_local() {
        let (w, _, hinv) = setup(4, 16, 5);
        let mask = select_unstructured_s(&w, &hinv.diag(), 8, 16, 0.5);
        // nothing pruned outside the block
        for r in 0..4 {
            for c in 0..8 {
                assert!(!mask.get(r, c));
            }
        }
        assert_eq!(mask.count(), 16);
    }

    #[test]
    fn mask_24_rules_valid() {
        let (w, _, hinv) = setup(8, 24, 6);
        let s_mask = select_24_s(&w, &hinv.diag(), 0, 24);
        assert!(s_mask.check_nm(2, 4));
        let (m_mask, _) = select_24_m(&w, &hinv, 0, 24);
        assert!(m_mask.check_nm(2, 4));
    }

    #[test]
    fn m_mask_optimal_in_group_metric() {
        // For every row/group, the Eq. 12 loss of the M-mask choice is <=
        // the loss of the S-mask choice (both measured by Eq. 12).
        let (w, _, hinv) = setup(6, 16, 7);
        let s_mask = select_24_s(&w, &hinv.diag(), 0, 16);
        let (m_mask, _) = select_24_m(&w, &hinv, 0, 16);
        let loss_of = |mask: &Mask, r: usize, g0: usize| {
            let cols: Vec<usize> =
                (g0..g0 + 4).filter(|&c| mask.get(r, c)).collect();
            group_loss_2(
                w[(r, cols[0])] as f64,
                w[(r, cols[1])] as f64,
                hinv[(cols[0], cols[0])],
                hinv[(cols[0], cols[1])],
                hinv[(cols[1], cols[1])],
            )
        };
        for r in 0..6 {
            for g in 0..4 {
                let (lm, ls) = (loss_of(&m_mask, r, g * 4), loss_of(&s_mask, r, g * 4));
                assert!(lm <= ls * (1.0 + 1e-12), "row {r} group {g}: {lm} vs {ls}");
            }
        }
    }

    #[test]
    fn cumulative_blockwise_keeps_earlier_zeros() {
        let (mut w, _, hinv) = setup(4, 16, 8);
        let d = hinv.diag();
        let mut cum = Mask::new(4, 16);
        for (c0, c1) in [(0, 8), (8, 16)] {
            let mask = select_unstructured_s(&w, &d, c0, c1, 0.5);
            cum.or_with(&mask);
            compensate_m(&mut w, &cum, &hinv);
        }
        // all pruned positions from BOTH blocks are zero
        for r in 0..4 {
            for &c in &cum.row_indices(r) {
                assert_eq!(w[(r, c)], 0.0, "row {r} col {c}");
            }
        }
        assert_eq!(cum.count(), 32);
    }

    #[test]
    fn incremental_blockwise_matches_reference_loop() {
        // Direct solver-level equivalence (prune::tests covers the full
        // prune_layer path): same per-block masks, reference re-solves the
        // cumulative set, incremental extends factors — same weights,
        // same per-block losses.
        let (w0, _, hinv) = setup(6, 24, 21);
        let d = hinv.diag();
        let mut wr = w0.clone();
        let mut wi = w0.clone();
        let mut inc = IncrementalMrp::new(&hinv, 6);
        let mut cum = Mask::new(6, 24);
        for (c0, c1) in [(0, 8), (8, 16), (16, 24)] {
            // select on the reference path's weights; both paths stay in
            // lockstep well inside the selection's decision margins
            let block = select_unstructured_s(&wr, &d, c0, c1, 0.5);
            cum.or_with(&block);
            let lr = compensate_m(&mut wr, &cum, &hinv);
            let li = inc.compensate_block(&mut wi, &block);
            assert!(
                (lr - li).abs() <= 1e-6 * lr.abs().max(1.0),
                "block ({c0},{c1}): loss {lr} vs {li}"
            );
        }
        assert_eq!(inc.tracked(), cum.count());
        assert!(wr.max_abs_diff(&wi) < 1e-6, "{}", wr.max_abs_diff(&wi));
        for r in 0..6 {
            for &c in &cum.row_indices(r) {
                assert_eq!(wi[(r, c)], 0.0, "row {r} col {c}");
            }
        }
    }

    #[test]
    fn incremental_skips_duplicate_mask_entries() {
        // Passing the cumulative mask again must be a no-op (duplicates
        // are filtered, multipliers come out exactly zero).
        let (mut w, _, hinv) = setup(4, 16, 22);
        let mask = select_unstructured_s(&w, &hinv.diag(), 0, 16, 0.5);
        let mut inc = IncrementalMrp::new(&hinv, 4);
        inc.compensate_block(&mut w, &mask);
        let before = w.clone();
        let loss = inc.compensate_block(&mut w, &mask);
        assert_eq!(loss, 0.0);
        assert_eq!(w.max_abs_diff(&before), 0.0);
        assert_eq!(inc.tracked(), mask.count());
    }

    #[test]
    fn prop_compensation_optimality_vs_random_feasible() {
        // MRP solution is optimal among feasible dw: any random feasible
        // perturbation on top of it cannot reduce the quadratic loss.
        prop_check_msg(
            "mrp-kkt-optimality",
            12,
            |r| {
                let n = 2 + r.below(3);
                let m = 8 + 4 * r.below(3);
                (setup(n, m, r.next_u64()), r.next_u64())
            },
            |((w0, hd, hinv), seed)| {
                let mut w = w0.clone();
                let mask = select_unstructured_s(&w, &hinv.diag(), 0, w.cols, 0.5);
                let pred = compensate_m(&mut w, &mask, &hinv);
                let mut rng = Rng::new(*seed);
                for _ in 0..5 {
                    // random feasible perturbation (zero at pruned entries)
                    let mut w2 = w.clone();
                    for r in 0..w2.rows {
                        for c in 0..w2.cols {
                            if !mask.get(r, c) {
                                w2[(r, c)] += rng.normal_f32(0.0, 0.05);
                            }
                        }
                    }
                    let loss2 = quadratic_loss(w0, &w2, hd);
                    if loss2 < pred * (1.0 - 1e-9) {
                        return Err(format!("found better feasible point: {loss2} < {pred}"));
                    }
                }
                Ok(())
            },
        );
    }
}
