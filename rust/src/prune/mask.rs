//! Pruning-mask representation, sparsity patterns and block partitions.

/// Target sparsity pattern.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sparsity {
    /// Prune `rate` fraction of each column-block (paper Sec. 4.3.1).
    Unstructured { rate: f64 },
    /// N:M — prune `n` weights in every group of `m` consecutive columns.
    SemiStructured { n: usize, m: usize },
}

impl Sparsity {
    pub fn two_four() -> Sparsity {
        Sparsity::SemiStructured { n: 2, m: 4 }
    }

    pub fn rate(&self) -> f64 {
        match self {
            Sparsity::Unstructured { rate } => *rate,
            Sparsity::SemiStructured { n, m } => *n as f64 / *m as f64,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Sparsity::Unstructured { rate } => format!("{:.0}%", rate * 100.0),
            Sparsity::SemiStructured { n, m } => format!("{n}:{m}"),
        }
    }
}

/// Row-major boolean mask; `true` = pruned (paper's M with 1 = prune).
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    pub bits: Vec<bool>,
}

impl Mask {
    pub fn new(rows: usize, cols: usize) -> Mask {
        Mask { rows, cols, bits: vec![false; rows * cols] }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> bool {
        self.bits[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: bool) {
        self.bits[r * self.cols + c] = v;
    }

    pub fn count(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    pub fn sparsity(&self) -> f64 {
        self.count() as f64 / self.bits.len() as f64
    }

    /// Row r as a slice (hot loops index this instead of calling `get`
    /// per element).
    #[inline]
    pub fn row(&self, r: usize) -> &[bool] {
        &self.bits[r * self.cols..(r + 1) * self.cols]
    }

    /// Pruned column indices of row r (ascending).
    pub fn row_indices(&self, r: usize) -> Vec<usize> {
        let mut v = Vec::new();
        self.row_indices_into(r, &mut v);
        v
    }

    /// Fill `out` with row r's pruned column indices (ascending) without
    /// allocating — the hot-loop variant of [`Mask::row_indices`], which
    /// would otherwise allocate a fresh Vec per row per block.
    pub fn row_indices_into(&self, r: usize, out: &mut Vec<usize>) {
        out.clear();
        for (c, &b) in self.row(r).iter().enumerate() {
            if b {
                out.push(c);
            }
        }
    }

    /// Merge another mask in (logical or).
    pub fn or_with(&mut self, other: &Mask) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, &b) in self.bits.iter_mut().zip(&other.bits) {
            *a |= b;
        }
    }

    /// Check every m-group has exactly n pruned entries.
    pub fn check_nm(&self, n: usize, m: usize) -> bool {
        if self.cols % m != 0 {
            return false;
        }
        for r in 0..self.rows {
            for g in 0..self.cols / m {
                let cnt = (0..m).filter(|&i| self.get(r, g * m + i)).count();
                if cnt != n {
                    return false;
                }
            }
        }
        true
    }
}

/// Column-block partition [c0, c1) for block pruning; `size=None` = S=all.
pub fn column_blocks(cols: usize, size: Option<usize>) -> Vec<(usize, usize)> {
    match size {
        None => vec![(0, cols)],
        Some(s) => {
            let s = s.max(1);
            (0..cols.div_ceil(s))
                .map(|i| (i * s, ((i + 1) * s).min(cols)))
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sparsity_labels() {
        assert_eq!(Sparsity::Unstructured { rate: 0.5 }.label(), "50%");
        assert_eq!(Sparsity::two_four().label(), "2:4");
        assert!((Sparsity::two_four().rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mask_basics() {
        let mut m = Mask::new(2, 4);
        m.set(0, 1, true);
        m.set(1, 3, true);
        assert_eq!(m.count(), 2);
        assert_eq!(m.row_indices(0), vec![1]);
        assert!((m.sparsity() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn row_accessors_agree() {
        let mut m = Mask::new(3, 5);
        for (r, c) in [(0, 0), (0, 4), (1, 2), (2, 1), (2, 2), (2, 3)] {
            m.set(r, c, true);
        }
        let mut buf = vec![99usize; 8]; // stale contents must be cleared
        for r in 0..3 {
            m.row_indices_into(r, &mut buf);
            assert_eq!(buf, m.row_indices(r), "row {r}");
            let from_slice: Vec<usize> = m
                .row(r)
                .iter()
                .enumerate()
                .filter_map(|(c, &b)| b.then_some(c))
                .collect();
            assert_eq!(buf, from_slice, "row {r}");
        }
    }

    #[test]
    fn or_accumulates() {
        let mut a = Mask::new(1, 4);
        a.set(0, 0, true);
        let mut b = Mask::new(1, 4);
        b.set(0, 2, true);
        a.or_with(&b);
        assert_eq!(a.row_indices(0), vec![0, 2]);
    }

    #[test]
    fn nm_check() {
        let mut m = Mask::new(1, 8);
        for c in [0, 1, 4, 6] {
            m.set(0, c, true);
        }
        assert!(m.check_nm(2, 4));
        m.set(0, 2, true);
        assert!(!m.check_nm(2, 4));
    }

    #[test]
    fn blocks_partition_exactly() {
        assert_eq!(column_blocks(10, None), vec![(0, 10)]);
        assert_eq!(column_blocks(10, Some(4)), vec![(0, 4), (4, 8), (8, 10)]);
        let blocks = column_blocks(512, Some(128));
        assert_eq!(blocks.len(), 4);
        assert_eq!(blocks.iter().map(|(a, b)| b - a).sum::<usize>(), 512);
    }
}
