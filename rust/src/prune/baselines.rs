//! Heuristic baselines: Magnitude (Zhu & Gupta 2017) and Wanda (Sun et al.
//! 2023), implemented as the paper's comparison points. Neither updates
//! the surviving weights.

use crate::tensor::Mat;

use super::mask::{Mask, Sparsity};

/// Magnitude pruning: global-per-layer smallest |w| (unstructured) or
/// per-group smallest |w| (N:M). Returns the mask; `w` is zeroed in place.
pub fn magnitude_prune(w: &mut Mat, sparsity: Sparsity) -> Mask {
    let mask = match sparsity {
        Sparsity::Unstructured { rate } => {
            let mut entries: Vec<(f32, u32, u32)> = Vec::with_capacity(w.rows * w.cols);
            for r in 0..w.rows {
                for (c, &v) in w.row(r).iter().enumerate() {
                    entries.push((v.abs(), r as u32, c as u32));
                }
            }
            let k = ((entries.len() as f64) * rate).round() as usize;
            let mut mask = Mask::new(w.rows, w.cols);
            if k > 0 {
                let k = k.min(entries.len());
                entries.select_nth_unstable_by(k - 1, |a, b| a.0.partial_cmp(&b.0).unwrap());
                for &(_, r, c) in &entries[..k] {
                    mask.set(r as usize, c as usize, true);
                }
            }
            mask
        }
        Sparsity::SemiStructured { n, m } => nm_mask_by(w, n, m, |w, r, c| w[(r, c)].abs() as f64),
    };
    apply(w, &mask);
    mask
}

/// Wanda: score = |w_ij| * ||X_:,j||_2 with per-output-row comparison
/// groups (the Wanda paper's prescription), no weight update.
/// `col_norms` come from the shared Hessian accumulator diag (hessian.rs).
pub fn wanda_prune(w: &mut Mat, col_norms: &[f64], sparsity: Sparsity) -> Mask {
    assert_eq!(col_norms.len(), w.cols);
    let score = |w: &Mat, r: usize, c: usize| (w[(r, c)].abs() as f64) * col_norms[c];
    let mask = match sparsity {
        Sparsity::Unstructured { rate } => {
            // per-row selection: prune `rate` fraction of each row
            let k = ((w.cols as f64) * rate).round() as usize;
            let mut mask = Mask::new(w.rows, w.cols);
            for r in 0..w.rows {
                let mut idx: Vec<usize> = (0..w.cols).collect();
                idx.sort_by(|&a, &b| {
                    score(w, r, a).partial_cmp(&score(w, r, b)).unwrap()
                });
                for &c in &idx[..k.min(w.cols)] {
                    mask.set(r, c, true);
                }
            }
            mask
        }
        Sparsity::SemiStructured { n, m } => nm_mask_by(w, n, m, score),
    };
    apply(w, &mask);
    mask
}

/// Build an N:M mask by pruning the n smallest-scoring entries per group.
fn nm_mask_by(w: &Mat, n: usize, m: usize, score: impl Fn(&Mat, usize, usize) -> f64) -> Mask {
    assert_eq!(w.cols % m, 0, "cols must divide into {m}-groups");
    let mut mask = Mask::new(w.rows, w.cols);
    for r in 0..w.rows {
        for g0 in (0..w.cols).step_by(m) {
            let mut idx: Vec<usize> = (0..m).collect();
            idx.sort_by(|&a, &b| {
                score(w, r, g0 + a).partial_cmp(&score(w, r, g0 + b)).unwrap()
            });
            for &i in &idx[..n] {
                mask.set(r, g0 + i, true);
            }
        }
    }
    mask
}

fn apply(w: &mut Mat, mask: &Mask) {
    for r in 0..w.rows {
        let row = w.row_mut(r);
        for c in 0..row.len() {
            if mask.get(r, c) {
                row[c] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn magnitude_prunes_smallest() {
        let mut w = Mat::from_vec(1, 4, vec![0.1, -5.0, 0.2, 3.0]);
        let mask = magnitude_prune(&mut w, Sparsity::Unstructured { rate: 0.5 });
        assert!(mask.get(0, 0) && mask.get(0, 2));
        assert_eq!(w.row(0), &[0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn magnitude_24_structure() {
        let mut w = Mat::randn(8, 32, 1.0, &mut Rng::new(1));
        let mask = magnitude_prune(&mut w, Sparsity::two_four());
        assert!(mask.check_nm(2, 4));
    }

    #[test]
    fn wanda_uses_activation_norms() {
        // big activation norm on column 0 protects a small weight there
        let mut w = Mat::from_vec(1, 4, vec![0.1, 0.2, 0.3, 0.4]);
        let norms = vec![100.0, 1.0, 1.0, 1.0];
        let mask = wanda_prune(&mut w, &norms, Sparsity::Unstructured { rate: 0.5 });
        assert!(!mask.get(0, 0), "column 0 must survive (Wanda signal)");
        assert!(mask.get(0, 1) && mask.get(0, 2));
    }

    #[test]
    fn wanda_per_row_rate() {
        let mut w = Mat::randn(6, 16, 1.0, &mut Rng::new(2));
        let norms = vec![1.0; 16];
        let mask = wanda_prune(&mut w, &norms, Sparsity::Unstructured { rate: 0.5 });
        for r in 0..6 {
            assert_eq!(mask.row_indices(r).len(), 8, "row {r}");
        }
    }

    #[test]
    fn wanda_24_structure() {
        let mut w = Mat::randn(4, 16, 1.0, &mut Rng::new(3));
        let norms: Vec<f64> = (0..16).map(|i| 1.0 + i as f64).collect();
        let mask = wanda_prune(&mut w, &norms, Sparsity::two_four());
        assert!(mask.check_nm(2, 4));
    }
}
