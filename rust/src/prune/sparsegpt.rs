//! SparseGPT (Frantar & Alistarh 2023) — the paper's primary baseline and
//! its Solution-S compensation rule.
//!
//! Faithful sequential sweep: columns are processed left to right with the
//! upper Cholesky factor U of Hinv (Hinv = U^T U); everything left of the
//! cursor is frozen (the paper's Sec. 2.3.2 critique). The per-column OBS
//! update with freezing is
//!     w[:, j:] -= (w[:,j] . mask_j / U_jj)  (x)  U[j, j:]
//! which zeroes column j's pruned entries exactly and compensates only
//! columns to the right.

use crate::linalg::cholesky_upper;
use crate::tensor::{axpy_f64, Mat, MatF64};
use crate::util::num_threads;

use super::mask::{column_blocks, Mask, Sparsity};
use super::mrp::{select_24_m, select_24_s, select_unstructured_s};

/// Sequential Solution-S compensation for a *given* mask (used by the SS
/// and MS method variants). Sweeps all columns once, entirely in f64 (a
/// single full-range sweep has no f32 round-trips between columns).
pub fn compensate_sequential(w: &mut Mat, mask: &Mask, u: &MatF64) {
    let m = w.cols;
    compensate_sequential_range(w, mask, u, 0, m);
}

/// Full SparseGPT-style pruning of one layer: blockwise mask selection
/// (Solution S scores on the *current* weights) + sequential compensation.
/// `m_mask_24` switches the 2:4 mask rule to Eq. 12 (the paper's MS).
pub fn sparsegpt_prune(
    w: &mut Mat,
    hinv: &MatF64,
    sparsity: Sparsity,
    block_size: Option<usize>,
    m_mask_24: bool,
) -> Mask {
    let u = cholesky_upper(hinv).expect("Hinv must be SPD");
    let diag = hinv.diag();
    let mut cum = Mask::new(w.rows, w.cols);
    for (c0, c1) in column_blocks(w.cols, block_size) {
        let mask = match sparsity {
            Sparsity::Unstructured { rate } => {
                select_unstructured_s(w, &diag, c0, c1, rate)
            }
            Sparsity::SemiStructured { n: 2, m: 4 } => {
                if m_mask_24 {
                    select_24_m(w, hinv, c0, c1).0
                } else {
                    select_24_s(w, &diag, c0, c1)
                }
            }
            Sparsity::SemiStructured { .. } => {
                unimplemented!("only 2:4 semi-structured wired up")
            }
        };
        // Sweep only this block's columns (they are the newly pruned set);
        // the update itself reaches all columns to the right.
        compensate_sequential_range(w, &mask, &u, c0, c1);
        cum.or_with(&mask);
    }
    cum
}

/// Sequential Solution-S sweep over columns [c0, c1) only (the update
/// itself still reaches every column to the right). `compensate_sequential`
/// is the [0, m) special case.
pub fn compensate_sequential_range(w: &mut Mat, mask: &Mask, u: &MatF64, c0: usize, c1: usize) {
    let (n, m) = (w.rows, w.cols);
    assert_eq!((u.rows, u.cols), (m, m));
    assert!(c0 <= c1 && c1 <= m);
    // Parallel over row-chunks: each row's sweep is independent.
    let nt = num_threads().min(n.max(1));
    let chunk = n.div_ceil(nt);
    std::thread::scope(|s| {
        for (ci, wrows) in w.data.chunks_mut(chunk * m).enumerate() {
            let r0 = ci * chunk;
            s.spawn(move || {
                let mut frow = vec![0.0f64; m];
                for (ri, wrow) in wrows.chunks_mut(m).enumerate() {
                    let mrow = mask.row(r0 + ri);
                    for (f, &v) in frow.iter_mut().zip(wrow.iter()) {
                        *f = v as f64;
                    }
                    for j in c0..c1 {
                        if !mrow[j] {
                            continue;
                        }
                        let urow = u.row(j);
                        let err = frow[j] / urow[j];
                        // axpy over the frozen-prefix-free suffix: the
                        // chunks_exact + mul_add kernel autovectorizes.
                        axpy_f64(-err, &urow[j..], &mut frow[j..]);
                        frow[j] = 0.0; // exact zero
                    }
                    for (v, &f) in wrow.iter_mut().zip(frow.iter()) {
                        *v = f as f32;
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::inv_spd;
    use crate::prune::hessian::HessianAccumulator;
    use crate::prune::mrp::{compensate_m, quadratic_loss, select_unstructured_s};
    use crate::tensor::Mat;
    use crate::util::Rng;

    fn setup(n: usize, m: usize, seed: u64) -> (Mat, MatF64, MatF64) {
        let mut rng = Rng::new(seed);
        let w = Mat::randn(n, m, 1.0, &mut rng);
        let x = Mat::randn(4 * m, m, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(m);
        acc.add_chunk(&x);
        let hd = acc.damped(0.01);
        let hinv = inv_spd(&hd).unwrap();
        (w, hd, hinv)
    }

    #[test]
    fn pruned_entries_exactly_zero() {
        let (mut w, _, hinv) = setup(6, 16, 1);
        let mask = sparsegpt_prune(&mut w, &hinv, Sparsity::Unstructured { rate: 0.5 }, Some(8), false);
        for r in 0..6 {
            for &c in &mask.row_indices(r) {
                assert_eq!(w[(r, c)], 0.0);
            }
        }
        assert!((mask.sparsity() - 0.5).abs() < 0.05);
    }

    #[test]
    fn sequential_beats_plain_zeroing() {
        let (w0, hd, hinv) = setup(8, 20, 2);
        let u = cholesky_upper(&hinv).unwrap();
        let mask = select_unstructured_s(&w0, &hinv.diag(), 0, 20, 0.5);
        let mut w = w0.clone();
        compensate_sequential(&mut w, &mask, &u);
        let seq_loss = quadratic_loss(&w0, &w, &hd);
        let mut wz = w0.clone();
        for r in 0..8 {
            for &c in &mask.row_indices(r) {
                wz[(r, c)] = 0.0;
            }
        }
        let zero_loss = quadratic_loss(&w0, &wz, &hd);
        assert!(seq_loss <= zero_loss * (1.0 + 1e-9), "{seq_loss} vs {zero_loss}");
    }

    #[test]
    fn mrp_beats_sequential_same_mask() {
        // The paper's Sec. 4.4 claim, on the native implementations.
        for seed in 0..5 {
            let (w0, hd, hinv) = setup(8, 24, 100 + seed);
            let u = cholesky_upper(&hinv).unwrap();
            let mask = select_unstructured_s(&w0, &hinv.diag(), 0, 24, 0.5);
            let mut ws = w0.clone();
            compensate_sequential(&mut ws, &mask, &u);
            let mut wm = w0.clone();
            compensate_m(&mut wm, &mask, &hinv);
            let ls = quadratic_loss(&w0, &ws, &hd);
            let lm = quadratic_loss(&w0, &wm, &hd);
            assert!(lm <= ls * (1.0 + 1e-9), "seed {seed}: MRP {lm} vs seq {ls}");
        }
    }

    #[test]
    fn two_four_structure_preserved() {
        let (mut w, _, hinv) = setup(8, 32, 3);
        let mask = sparsegpt_prune(&mut w, &hinv, Sparsity::two_four(), None, false);
        assert!(mask.check_nm(2, 4));
        // matrix itself is 2:4: count zeros per group
        for r in 0..8 {
            for g in 0..8 {
                let zeros = (0..4).filter(|i| w[(r, g * 4 + i)] == 0.0).count();
                assert!(zeros >= 2, "row {r} group {g}");
            }
        }
    }

    #[test]
    fn ms_variant_uses_m_mask() {
        let (mut w_s, _, hinv) = setup(8, 32, 4);
        let mut w_m = w_s.clone();
        let mask_s = sparsegpt_prune(&mut w_s, &hinv, Sparsity::two_four(), None, false);
        let mask_m = sparsegpt_prune(&mut w_m, &hinv, Sparsity::two_four(), None, true);
        assert!(mask_m.check_nm(2, 4));
        assert_ne!(mask_s, mask_m, "M-mask should differ from S-mask");
    }

    #[test]
    fn range_sweeps_compose_to_full_sweep() {
        // Sweeping consecutive ranges must equal one full sweep; the only
        // divergence is the f64->f32 round-trip at range boundaries, so
        // the tolerance is a few f32 ulps — not exact equality.
        for seed in [9, 10, 11] {
            let (w0, _, hinv) = setup(8, 24, seed);
            let u = cholesky_upper(&hinv).unwrap();
            let mask = select_unstructured_s(&w0, &hinv.diag(), 0, 24, 0.5);
            let mut wa = w0.clone();
            compensate_sequential(&mut wa, &mask, &u);
            let mut wb = w0.clone();
            for (c0, c1) in [(0, 8), (8, 16), (16, 24)] {
                compensate_sequential_range(&mut wb, &mask, &u, c0, c1);
            }
            let d = wa.max_abs_diff(&wb);
            assert!(d < 1e-4, "seed {seed}: composed ranges diverged by {d}");
            // pruned entries are exact zeros on both paths
            for r in 0..8 {
                for &c in &mask.row_indices(r) {
                    assert_eq!(wa[(r, c)], 0.0);
                    assert_eq!(wb[(r, c)], 0.0);
                }
            }
        }
    }

    #[test]
    fn blockwise_equals_global_when_single_block() {
        let (w0, _, hinv) = setup(4, 16, 5);
        let mut wa = w0.clone();
        let mut wb = w0.clone();
        let ma = sparsegpt_prune(&mut wa, &hinv, Sparsity::Unstructured { rate: 0.5 }, None, false);
        let mb = sparsegpt_prune(&mut wb, &hinv, Sparsity::Unstructured { rate: 0.5 }, Some(16), false);
        assert_eq!(ma, mb);
        assert!(wa.max_abs_diff(&wb) < 1e-6);
    }
}
